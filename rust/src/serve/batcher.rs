//! Admission control for the serve engine: a FIFO queue with deadline and
//! max-wait awareness.
//!
//! Both engine modes admit through `expire_overdue` + `pop_ready` (the
//! engine's `admit`): continuous mode per freed lane, drain mode whenever
//! all lanes are free. `next_batch`/`next_batch_timed` pop whole batches
//! for one-shot callers, and `batch_ready`/`max_wait` are the admission
//! gate for an asynchronous front-end that has to choose between waiting
//! for a full batch and cutting a partial one — the synchronous engine's
//! pre-queued workloads never wait, so nothing in-process consults them.
//!
//! The coordinator invariants tested here (capacity, no starvation, FIFO)
//! are the property-test surface for the serving layer.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::GenRequest;

#[derive(Debug, Clone)]
struct Queued {
    id: u64,
    req: GenRequest,
    submitted: Instant,
    deadline: Option<Duration>,
}

/// FIFO admission queue with deadline expiry and a max-wait batch cut.
#[derive(Debug)]
pub struct Batcher {
    /// widest batch the engine can take (== its lane count)
    pub capacity: usize,
    /// drain-mode cut: launch a partial batch once the oldest request has
    /// waited this long
    pub max_wait: Duration,
    queue: VecDeque<Queued>,
    next_id: u64,
}

impl Batcher {
    /// A queue for an engine of `capacity` lanes (default 50 ms max-wait).
    pub fn new(capacity: usize) -> Batcher {
        assert!(capacity > 0);
        Batcher {
            capacity,
            max_wait: Duration::from_millis(50),
            queue: VecDeque::new(),
            next_id: 0,
        }
    }

    /// Builder-style override of the max-wait cut interval.
    pub fn with_max_wait(mut self, max_wait: Duration) -> Batcher {
        self.max_wait = max_wait;
        self
    }

    /// Enqueue a request (no deadline); returns its id.
    pub fn submit(&mut self, req: GenRequest) -> u64 {
        self.submit_with_deadline(req, None)
    }

    /// Submit with a queue-time deadline: if the request is still waiting
    /// for a lane after `deadline`, admission drops it (`expire_overdue`).
    pub fn submit_with_deadline(
        &mut self,
        req: GenRequest,
        deadline: Option<Duration>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Queued {
            id,
            req,
            submitted: Instant::now(),
            deadline,
        });
        id
    }

    /// Requests currently waiting for a lane.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next batch (up to capacity, FIFO). Empty queue -> None.
    pub fn next_batch(&mut self) -> Option<Vec<(u64, GenRequest)>> {
        self.next_batch_timed().map(|batch| {
            batch.into_iter().map(|(id, req, _)| (id, req)).collect()
        })
    }

    /// Like `next_batch` but also returns each request's submit time so
    /// the engine can account queue latency.
    pub fn next_batch_timed(&mut self) -> Option<Vec<(u64, GenRequest, Instant)>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.capacity.min(self.queue.len());
        Some(
            self.queue
                .drain(..n)
                .map(|q| (q.id, q.req, q.submitted))
                .collect(),
        )
    }

    /// Drain-mode admission gate: a batch is worth launching when it is
    /// full, or when the oldest waiter has exceeded `max_wait`.
    pub fn batch_ready(&self, now: Instant) -> bool {
        self.queue.len() >= self.capacity
            || self
                .queue
                .front()
                .map(|q| now.duration_since(q.submitted) >= self.max_wait)
                .unwrap_or(false)
    }

    /// Continuous admission: pop the oldest queued request for a freed
    /// lane. FIFO; deadline filtering is done by `expire_overdue` first.
    pub fn pop_ready(&mut self, _now: Instant) -> Option<(u64, GenRequest, Instant)> {
        self.queue.pop_front().map(|q| (q.id, q.req, q.submitted))
    }

    /// Look at the request `pop_ready` would return without dequeuing it
    /// — the engine peeks first so admission that fails page-budget
    /// reservation (pool backpressure) leaves the request queued, FIFO
    /// position and deadline intact. Borrowed, not cloned: a
    /// backpressured engine peeks the same head every step.
    pub fn peek_ready(&self, _now: Instant) -> Option<(u64, &GenRequest, Instant)> {
        self.queue.front().map(|q| (q.id, &q.req, q.submitted))
    }

    /// Remove and return every queued request whose deadline elapsed
    /// before it was admitted.
    pub fn expire_overdue(&mut self, now: Instant) -> Vec<(u64, GenRequest)> {
        let mut kept = VecDeque::with_capacity(self.queue.len());
        let mut expired = Vec::new();
        for q in self.queue.drain(..) {
            let overdue = q
                .deadline
                .map(|d| now.duration_since(q.submitted) >= d)
                .unwrap_or(false);
            if overdue {
                expired.push((q.id, q.req));
            } else {
                kept.push_back(q);
            }
        }
        self.queue = kept;
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn req(n: usize) -> GenRequest {
        GenRequest { prompt: "x".repeat(n % 40 + 1), max_new_tokens: 4 }
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let mut b = Batcher::new(3);
        let ids: Vec<u64> = (0..7).map(|i| b.submit(req(i))).collect();
        let mut drained = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 3);
            drained.extend(batch.into_iter().map(|(id, _)| id));
        }
        assert_eq!(drained, ids);
    }

    #[test]
    fn batcher_invariants_property() {
        // invariant: across any submit/drain interleaving, every request is
        // delivered exactly once, in order, and no batch exceeds capacity
        check(
            "batcher-exactly-once-fifo",
            40,
            |r: &mut Rng| {
                let ops = r.below(60) + 5;
                (0..ops).map(|_| r.below(3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut b = Batcher::new(4);
                let mut submitted = Vec::new();
                let mut delivered = Vec::new();
                for &op in ops {
                    if op < 2 {
                        submitted.push(b.submit(req(op)));
                    } else if let Some(batch) = b.next_batch() {
                        if batch.len() > 4 {
                            return Err("over capacity".into());
                        }
                        delivered.extend(batch.into_iter().map(|(i, _)| i));
                    }
                }
                while let Some(batch) = b.next_batch() {
                    delivered.extend(batch.into_iter().map(|(i, _)| i));
                }
                if delivered != submitted {
                    return Err(format!(
                        "delivered {delivered:?} != submitted {submitted:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b = Batcher::new(2);
        assert!(b.next_batch().is_none());
        b.submit(req(1));
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_wait_cut() {
        let mut b = Batcher::new(4).with_max_wait(Duration::from_millis(20));
        let now = Instant::now();
        // empty queue is never ready
        assert!(!b.batch_ready(now + Duration::from_secs(1)));
        b.submit(req(1));
        // fresh and underfull: wait for more work
        assert!(!b.batch_ready(Instant::now()));
        // the oldest waiter ages past max_wait: cut a partial batch
        assert!(b.batch_ready(Instant::now() + Duration::from_millis(25)));
        // a full batch is ready regardless of age
        for i in 0..3 {
            b.submit(req(i));
        }
        assert!(b.batch_ready(Instant::now()));
    }

    #[test]
    fn deadline_expiry_drops_only_overdue() {
        let mut b = Batcher::new(2);
        let slow = b.submit_with_deadline(req(1), Some(Duration::from_millis(5)));
        let patient = b.submit(req(2));
        let lenient =
            b.submit_with_deadline(req(3), Some(Duration::from_secs(3600)));
        let expired = b.expire_overdue(Instant::now() + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, slow);
        assert_eq!(b.pending(), 2);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch[0].0, patient);
        assert_eq!(batch[1].0, lenient);
    }

    #[test]
    fn pop_ready_is_fifo() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let c = b.submit(req(2));
        let now = Instant::now();
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert_eq!(b.pop_ready(now).unwrap().0, c);
        assert!(b.pop_ready(now).is_none());
    }

    #[test]
    fn peek_ready_does_not_dequeue() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let now = Instant::now();
        // peeking twice sees the same head; the queue is untouched
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.pending(), 1);
        // pop returns exactly what peek advertised
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert!(b.peek_ready(now).is_none());
    }
}
