//! Calibration capture: runs the FP model over the calibration set with the
//! `block_capture` artifact and accumulates per-(layer, linear) activation
//! statistics — the inputs to the structured mask (Eq. 4), AWQ/SmoothQuant
//! scaling, and the GPTQ/BiLLM Hessians.
//!
//! Also provides the block-input streams (FP and quantized-prefix) the
//! block-wise optimizer consumes.

use std::collections::HashMap;

use anyhow::Result;

use super::Pipeline;
use crate::data::calib::CalibSet;
use crate::model::{Params, LINEARS};
use crate::quant::LinearCalib;
use crate::tensor::Tensor;

/// Which capture tensor feeds which linear.
pub fn capture_index(linear: &str) -> usize {
    match linear {
        "wq" | "wk" | "wv" => 0,  // x_attn
        "wo" => 1,                // x_o
        "w_gate" | "w_up" => 2,   // x_mlp
        "w_down" => 3,            // x_down
        other => panic!("unknown linear {other}"),
    }
}

/// Per-layer, per-linear calibration statistics.
pub struct ModelCalib {
    /// calib["l{l}.{lin}"]
    pub linears: HashMap<String, LinearCalib>,
    /// FP inputs of each block per calibration batch: h_fp[layer][batch]
    pub block_inputs: Vec<Vec<Tensor>>,
}

/// Run capture over the whole calibration set.
pub fn capture(
    pipe: &Pipeline,
    params: &Params,
    calib: &CalibSet,
    with_hessian: bool,
) -> Result<ModelCalib> {
    let cfg = &pipe.cfg;
    let mut linears: HashMap<String, LinearCalib> = HashMap::new();
    for l in 0..cfg.n_layers {
        for lin in LINEARS {
            let in_dim = crate::model::linear_shape(cfg, lin).1;
            linears.insert(
                format!("l{l}.{lin}"),
                LinearCalib::empty(in_dim),
            );
        }
    }
    let mut block_inputs: Vec<Vec<Tensor>> =
        vec![Vec::new(); cfg.n_layers];
    for batch in &calib.batches {
        let mut h = pipe.embed(params, batch)?;
        for l in 0..cfg.n_layers {
            block_inputs[l].push(h.clone());
            let caps = pipe.block_capture(&h, &params.block(l))?;
            // caps = [x_attn, x_o, x_mlp, x_down, h_out]
            for lin in LINEARS {
                let cap = &caps[capture_index(lin)];
                let rows = cap.shape[0] * cap.shape[1];
                let flat = Tensor::from_vec(
                    &[rows, cap.shape[2]],
                    cap.data.clone(),
                );
                linears
                    .get_mut(&format!("l{l}.{lin}"))
                    .unwrap()
                    .accumulate(&flat, with_hessian);
            }
            h = caps.into_iter().last().unwrap();
        }
    }
    Ok(ModelCalib { linears, block_inputs })
}

impl ModelCalib {
    pub fn get(&self, l: usize, lin: &str) -> &LinearCalib {
        &self.linears[&format!("l{l}.{lin}")]
    }
}
