//! Serve-engine bench: scheduling (drain vs continuous) and decode-path
//! (full-window vs KV-cached) comparisons, with a correctness gate.
//!
//! Part 1 replays one skewed request-length workload through three
//! configurations — static drain batching, continuous batching over the
//! full-window forward, and continuous batching with the KV cache — and
//! asserts all three produce token-identical responses (greedy decode is
//! per-lane deterministic, so scheduling and caching must not change a
//! single token).
//!
//! Part 2 decodes long sequences and reports per-step wall time early vs
//! late in the sequence: the full-window path grows with position (each
//! step re-runs the whole window), the KV-cached path stays roughly flat
//! (each step runs one token against cached K/V).
//!
//! Runs on FP-initialized weights (scheduling/caching cost is independent
//! of training) and needs no artifacts directory.

use std::time::Instant;

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, GenResponse, MetricsRegistry};

fn run_mode(
    pipe: &Pipeline,
    model: &ModelEval,
    reqs: &[GenRequest],
    label: &str,
    drain: bool,
    kv: bool,
) -> (MetricsRegistry, Vec<GenResponse>, f64) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new(label);
    let mut engine = Engine::new(pipe, model);
    engine.cfg.use_kv_cache = kv;
    let t0 = Instant::now();
    let mut resps = if drain {
        engine.run_drain(&mut batcher, &mut metrics).unwrap()
    } else {
        engine.run(&mut batcher, &mut metrics).unwrap()
    };
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(resps.len(), reqs.len(), "{label}: lost requests");
    assert_eq!(engine.kv_cache().in_use_count(), 0, "{label}: leaked slots");
    resps.sort_by_key(|r| r.id);
    (metrics, resps, wall)
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn main() {
    let rt = Runtime::open(&ptq161::artifacts_dir()).unwrap();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(7);
    let model = ModelEval::Dense(&params);

    // ---- part 1: scheduling + decode-path throughput --------------------
    // 16 requests, 1-in-4 long: the regime where batch drain stalls lanes
    let reqs: Vec<GenRequest> = (0..16)
        .map(|i| GenRequest {
            prompt: format!("the quiet river of alda {} ", i % 3),
            max_new_tokens: if i % 4 == 0 { 40 } else { 4 },
        })
        .collect();
    let total_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    println!(
        "# bench_serve: {} requests, {} tokens, lane capacity {}",
        reqs.len(),
        total_tokens,
        pipe.cfg.b_eval
    );
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    let mut texts: Vec<Vec<String>> = Vec::new();
    for (label, drain, kv) in [
        ("drain", true, true),
        ("full-window", false, false),
        ("continuous+kv", false, true),
    ] {
        let (metrics, resps, wall) = run_mode(&pipe, &model, &reqs, label, drain, kv);
        println!(
            "{label:<14} {:>3} steps  occupancy {:.2}  {:>7.1} tok/s  \
             wall {:.2}s  p50 {:>6.0} ms  p95 {:>6.0} ms",
            metrics.steps,
            metrics.lane_occupancy(),
            metrics.throughput_tok_s(),
            wall,
            metrics.p50_ms(),
            metrics.p95_ms()
        );
        results.push((label.to_string(), metrics.throughput_tok_s(), wall));
        texts.push(resps.into_iter().map(|r| r.text).collect());
    }
    // correctness gate: every configuration must emit identical tokens
    for (mode, t) in texts.iter().enumerate().skip(1) {
        assert_eq!(
            t, &texts[0],
            "{}: output differs from {}",
            results[mode].0, results[0].0
        );
    }
    println!("token-identical across all modes: ok");
    let sched = results[2].1 / results[0].1.max(1e-9);
    let cache = results[2].1 / results[1].1.max(1e-9);
    println!("continuous+kv / drain throughput:       {sched:.2}x");
    println!("continuous+kv / full-window throughput: {cache:.2}x");

    // ---- part 2: per-step decode time vs sequence position --------------
    // every lane decodes a long sequence; per-step time early vs late in
    // the run shows full-window growing and cached staying flat
    let long = pipe.cfg.seq - 16;
    let long_reqs: Vec<GenRequest> = (0..pipe.cfg.b_eval)
        .map(|i| GenRequest {
            prompt: format!("position scan {i} "),
            max_new_tokens: long,
        })
        .collect();
    println!("\n# per-step decode time over {long} positions");
    let mut step_series: Vec<Vec<f64>> = Vec::new();
    for (label, kv) in [("full-window", false), ("kv-cached", true)] {
        let (metrics, _, _) = run_mode(&pipe, &model, &long_reqs, label, false, kv);
        let steps = &metrics.step_ms;
        let q = (steps.len() / 4).max(1);
        let early = mean(&steps[..q]);
        let late = mean(&steps[steps.len() - q..]);
        println!(
            "{label:<12} first-quartile step {early:>7.2} ms   \
             last-quartile step {late:>7.2} ms   late/early {:.2}x",
            late / early.max(1e-9)
        );
        step_series.push(steps.clone());
    }
    let growth = |s: &[f64]| {
        let q = (s.len() / 4).max(1);
        mean(&s[s.len() - q..]) / mean(&s[..q]).max(1e-9)
    };
    println!(
        "growth in step time, full-window {:.2}x vs kv-cached {:.2}x \
         (cached decode is ~flat in sequence position)",
        growth(&step_series[0]),
        growth(&step_series[1])
    );
}
