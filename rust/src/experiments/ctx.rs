//! Shared experiment context: corpora, pretrained/preprocessed checkpoints
//! (disk-cached under runs/), calibration captures, and a memoized
//! quantized-model cache so tables that share a method don't requantize.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::coordinator::blockopt::{ptq161_optimize, BlockOptCfg};
use crate::coordinator::capture::{capture, ModelCalib};
use crate::coordinator::preprocess::{preprocess, PreprocessCfg};
use crate::coordinator::pretrain::{pretrain_cached, PretrainConfig};
use crate::coordinator::quantize::{quantize_model, QuantModel};
use crate::coordinator::Pipeline;
use crate::data::{calib, Corpus, Style};
use crate::eval::ppl::perplexity;
use crate::eval::ModelEval;
use crate::model::Params;
use crate::runtime::Runtime;

pub struct ExperimentCtx {
    pub rt: Runtime,
    pub wiki: Corpus,
    pub c4: Corpus,
    /// experiment scale knobs
    pub pretrain_steps: usize,
    pub preprocess_steps: usize,
    pub blockopt_epochs: usize,
    pub calib_segments: usize,
    pub ppl_batches: usize,
    pub tasks_per_suite: usize,
    /// model configs exercised by tables (tiny always; small with --full)
    pub models: Vec<String>,
    pretrained: HashMap<String, Params>,
    preprocessed: HashMap<String, Params>,
    calibs: HashMap<(String, bool), ModelCalib>, // (model, preprocessed)
    qcache: HashMap<(String, String, bool), QuantModel>,
}

impl ExperimentCtx {
    pub fn new(full: bool) -> Result<ExperimentCtx> {
        let rt = Runtime::open(&crate::artifacts_dir())?;
        let wiki = Corpus::build(Style::Wiki, 600_000, 41);
        let c4 = Corpus::build(Style::C4, 120_000, 42);
        let models = if full {
            vec!["tiny".to_string(), "small".to_string()]
        } else {
            vec!["tiny".to_string()]
        };
        Ok(ExperimentCtx {
            rt,
            wiki,
            c4,
            pretrain_steps: 400,
            preprocess_steps: 120,
            blockopt_epochs: 12,
            calib_segments: 16,
            ppl_batches: 8,
            tasks_per_suite: 40,
            models,
            pretrained: HashMap::new(),
            preprocessed: HashMap::new(),
            calibs: HashMap::new(),
            qcache: HashMap::new(),
        })
    }

    /// Quick-scale context for smoke tests and benches.
    pub fn quick() -> Result<ExperimentCtx> {
        let mut ctx = Self::new(false)?;
        ctx.pretrain_steps = 60;
        ctx.preprocess_steps = 20;
        ctx.blockopt_epochs = 3;
        ctx.calib_segments = 8;
        ctx.ppl_batches = 3;
        ctx.tasks_per_suite = 10;
        Ok(ctx)
    }

    pub fn pipeline(&self, model: &str) -> Result<Pipeline<'_>> {
        Pipeline::new(&self.rt, model)
    }

    pub fn pretrained(&mut self, model: &str) -> Result<Params> {
        if !self.pretrained.contains_key(model) {
            let pipe = Pipeline::new(&self.rt, model)?;
            let res = pretrain_cached(
                &pipe,
                &self.wiki,
                &PretrainConfig {
                    steps: self.pretrain_steps,
                    ..Default::default()
                },
            )?;
            self.pretrained.insert(model.to_string(), res.params);
        }
        Ok(self.pretrained[model].clone())
    }

    pub fn calib(&mut self, model: &str, preprocessed: bool) -> Result<ModelCalib> {
        let key = (model.to_string(), preprocessed);
        if !self.calibs.contains_key(&key) {
            let params = if preprocessed {
                self.preprocessed(model)?
            } else {
                self.pretrained(model)?
            };
            let pipe = Pipeline::new(&self.rt, model)?;
            let cal = calib::sample(
                &self.wiki,
                self.calib_segments,
                pipe.cfg.b_eval,
                pipe.cfg.seq,
                99,
            );
            let mc = capture(&pipe, &params, &cal, true)?;
            self.calibs.insert(key.clone(), mc);
        }
        self.calibs
            .remove(&key)
            .map(|mc| {
                // reinsert a cheap clone-by-rebuild? ModelCalib is big; we
                // instead return it and re-cache via insert-back pattern.
                mc
            })
            .ok_or_else(|| anyhow!("calib vanished"))
    }

    pub fn cache_calib(&mut self, model: &str, preprocessed: bool, mc: ModelCalib) {
        self.calibs.insert((model.to_string(), preprocessed), mc);
    }

    pub fn preprocessed(&mut self, model: &str) -> Result<Params> {
        if !self.preprocessed.contains_key(model) {
            let path = crate::runs_dir().join(format!(
                "preprocessed_{model}_{}steps.bin",
                self.preprocess_steps
            ));
            let params = if path.exists() {
                Params::load(&path)?
            } else {
                let base = self.pretrained(model)?;
                let mc = self.calib(model, false)?;
                let pipe = Pipeline::new(&self.rt, model)?;
                let res = preprocess(
                    &pipe,
                    &base,
                    &mc,
                    &self.wiki,
                    &PreprocessCfg {
                        steps: self.preprocess_steps,
                        verbose: true,
                        ..Default::default()
                    },
                )?;
                self.cache_calib(model, false, mc);
                res.params.save(&path)?;
                res.params
            };
            self.preprocessed.insert(model.to_string(), params);
        }
        Ok(self.preprocessed[model].clone())
    }

    /// Quantize `model` with `method`; PTQ1.61 runs the block-wise
    /// optimizer; `preprocessed` selects the section-3.4 starting point.
    pub fn quantized(
        &mut self,
        model: &str,
        method: &str,
        preprocessed: bool,
    ) -> Result<QuantModel> {
        let key = (model.to_string(), method.to_string(), preprocessed);
        if let Some(q) = self.qcache.get(&key) {
            return Ok(clone_qm(q));
        }
        let params = if preprocessed {
            self.preprocessed(model)?
        } else {
            self.pretrained(model)?
        };
        let mc = self.calib(model, preprocessed)?;
        let pipe = Pipeline::new(&self.rt, model)?;
        let qm = if method == "ptq161" {
            let (qm, _) = ptq161_optimize(
                &pipe,
                &params,
                &mc,
                &BlockOptCfg {
                    epochs: self.blockopt_epochs,
                    ..Default::default()
                },
            )?;
            qm
        } else {
            let q = crate::quant::by_name(method)
                .ok_or_else(|| anyhow!("unknown method {method}"))?;
            quantize_model(&pipe, &params, &mc, q.as_ref())?
        };
        self.cache_calib(model, preprocessed, mc);
        self.qcache.insert(key, clone_qm(&qm));
        Ok(qm)
    }

    /// PPL of a dense params model on a corpus.
    pub fn ppl(&self, model: &str, params: &Params, corpus: &Corpus) -> Result<f64> {
        let pipe = Pipeline::new(&self.rt, model)?;
        perplexity(&pipe, &ModelEval::Dense(params), corpus, self.ppl_batches)
    }
}

fn clone_qm(q: &QuantModel) -> QuantModel {
    QuantModel {
        method: q.method.clone(),
        bits_label: q.bits_label.clone(),
        params: q.params.clone(),
        parts: q.parts.clone(),
        containers: q.containers.clone(),
        avg_bits: q.avg_bits,
    }
}
