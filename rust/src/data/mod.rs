//! Data substrate: synthetic corpus ("wiki" / "c4" styles), calibration
//! sampling, and zero-shot task generators.
//!
//! The corpus is a deterministic synthetic language with learnable
//! regularities that mirror what the paper's benchmarks probe:
//!   1. name -> preferred-verb agreement        (ARC-style 4-choice)
//!   2. noun -> fixed-adjective collocation     (PIQA-style 2-choice)
//!   3. paragraph topic repetition              (LAMBADA-style cloze)
//!   4. "key K is V" facts                      (LongBench-style retrieval)
//!   5. digit arithmetic lines                  (GSM8K-analog, near-chance)
//! "wiki" (in-domain held-out) and "c4" (shifted function words) splits play
//! WikiText2 / C4 in every perplexity table.

pub mod calib;
pub mod tasks;

use crate::model::tokenizer::ByteTokenizer;
use crate::util::rng::{zipf_cdf, Rng};

pub const NOUNS: [&str; 24] = [
    "river", "stone", "garden", "engine", "book", "tower", "forest", "lamp",
    "bridge", "cloud", "market", "violin", "harbor", "signal", "meadow",
    "mirror", "anchor", "castle", "barrel", "comet", "valley", "copper",
    "falcon", "orchid",
];

pub const VERBS: [&str; 16] = [
    "holds", "turns", "guards", "lifts", "draws", "keeps", "moves", "finds",
    "shapes", "brings", "carries", "watches", "builds", "counts", "marks",
    "sees",
];

pub const ADJS: [&str; 24] = [
    "quiet", "bright", "heavy", "ancient", "narrow", "golden", "distant",
    "hollow", "gentle", "frozen", "crimson", "silent", "steep", "velvet",
    "amber", "pale", "sturdy", "misty", "lively", "somber", "vivid", "stark",
    "mellow", "brisk",
];

pub const NAMES: [&str; 16] = [
    "alda", "boris", "celia", "darin", "elena", "felix", "greta", "henry",
    "iris", "jonas", "karla", "leo", "mira", "nils", "opal", "petra",
];

pub const VALUES: [&str; 12] = [
    "red", "blue", "green", "black", "white", "gray", "gold", "pink",
    "teal", "rust", "jade", "plum",
];

/// High-entropy filler vocabulary (Zipf-sampled). This is what separates
/// methods: a heavily damaged model keeps the deterministic grammar but
/// loses the memorized filler distribution, exactly like real LLMs losing
/// long-tail knowledge under extreme quantization.
pub const FILLERS: [&str; 48] = [
    "able", "band", "cost", "dawn", "edge", "fact", "gain", "hint", "idea",
    "joke", "kind", "loan", "mood", "note", "oath", "pace", "quest", "rank",
    "seed", "tide", "unit", "vote", "wave", "yarn", "zone", "arch", "bloom",
    "craft", "drift", "ember", "flock", "grain", "haze", "inlet", "jolt",
    "knack", "ledge", "motif", "nook", "orbit", "plume", "quirk", "ridge",
    "slope", "trail", "urge", "vault", "wisp",
];

/// name i prefers verb (i mod VERBS); noun j takes adjective (j mod ADJS).
pub fn preferred_verb(name_idx: usize) -> &'static str {
    VERBS[name_idx % VERBS.len()]
}

pub fn collocated_adj(noun_idx: usize) -> &'static str {
    ADJS[noun_idx % ADJS.len()]
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Style {
    Wiki,
    C4,
}

/// One paragraph of the synthetic language.
fn paragraph(style: Style, rng: &mut Rng, noun_cdf: &[f64], fill_cdf: &[f64]) -> String {
    let topic = rng.zipf(NOUNS.len(), 1.05, noun_cdf);
    let mut out = String::new();
    let n_sent = 3 + rng.below(4);
    let filler = |rng: &mut Rng| -> &'static str {
        FILLERS[rng.zipf(FILLERS.len(), 1.15, fill_cdf)]
    };
    for s in 0..n_sent {
        let name_i = rng.below(NAMES.len());
        let noun_i = if s == 0 { topic } else { rng.zipf(NOUNS.len(), 1.05, noun_cdf) };
        let verb = preferred_verb(name_i);
        let adj = collocated_adj(noun_i);
        let sent = match (style, rng.below(4)) {
            // 25%: key-value fact line (regularity 4)
            (_, 0) => {
                let k = rng.below(NAMES.len());
                let v = rng.below(VALUES.len());
                match style {
                    Style::Wiki => format!(
                        "key {} is {} near the {} .",
                        NAMES[k], VALUES[v], filler(rng)
                    ),
                    Style::C4 => format!(
                        "note : key {} is {} by the {} !",
                        NAMES[k], VALUES[v], filler(rng)
                    ),
                }
            }
            // 25%: arithmetic line (regularity 5)
            (_, 1) => {
                let a = rng.below(9) + 1;
                let b = rng.below(9) + 1;
                match style {
                    Style::Wiki => format!("{} plus {} equals {} .", a, b, a + b),
                    Style::C4 => format!("so {} plus {} equals {} ok .", a, b, a + b),
                }
            }
            // 50%: agreement sentence (regularities 1+2) carrying two
            // Zipf-sampled filler slots and a number (entropy the model
            // must spend capacity on)
            (Style::Wiki, _) => format!(
                "the {} {} of {} {} the {} {} with a {} {} over {} .",
                adj, NOUNS[noun_i], NAMES[name_i], verb,
                collocated_adj(topic), NOUNS[topic],
                filler(rng), filler(rng), rng.below(90) + 10,
            ),
            (Style::C4, _) => format!(
                "you know {} {} a {} {} like some {} {} around {} !",
                NAMES[name_i], verb, adj, NOUNS[noun_i],
                filler(rng), filler(rng), rng.below(90) + 10,
            ),
        };
        out.push_str(&sent);
        out.push(' ');
    }
    // topic repetition close (regularity 3, the cloze signal)
    match style {
        Style::Wiki => out.push_str(&format!(
            "in the end it was the {} .\n", NOUNS[topic]
        )),
        Style::C4 => out.push_str(&format!(
            "and yes folks it was the {} !\n", NOUNS[topic]
        )),
    }
    out
}

/// Generate at least `n_chars` of corpus text, deterministic in `seed`.
pub fn gen_text(style: Style, n_chars: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed ^ match style {
        Style::Wiki => 0x5757,
        Style::C4 => 0xC4C4,
    });
    let noun_cdf = zipf_cdf(NOUNS.len(), 1.05);
    let fill_cdf = zipf_cdf(FILLERS.len(), 1.15);
    let mut out = String::with_capacity(n_chars + 256);
    while out.len() < n_chars {
        out.push_str(&paragraph(style, &mut rng, &noun_cdf, &fill_cdf));
    }
    out
}

/// Tokenized corpus with train/test split (test plays the held-out PPL set).
#[derive(Debug, Clone)]
pub struct Corpus {
    pub style: Style,
    pub train: Vec<i32>,
    pub test: Vec<i32>,
}

impl Corpus {
    pub fn build(style: Style, n_chars: usize, seed: u64) -> Corpus {
        let tk = ByteTokenizer;
        let tokens = tk.encode(&gen_text(style, n_chars, seed));
        let split = tokens.len() * 9 / 10;
        Corpus {
            style,
            train: tokens[..split].to_vec(),
            test: tokens[split..].to_vec(),
        }
    }

    /// Random training batch (b, t) of contiguous windows.
    pub fn batch(&self, b: usize, t: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(b * t);
        for _ in 0..b {
            let start = rng.below(self.train.len() - t);
            out.extend_from_slice(&self.train[start..start + t]);
        }
        out
    }

    /// Deterministic eval windows covering the test split: k batches of
    /// (b, t) tokens, non-overlapping stride.
    pub fn eval_batches(&self, b: usize, t: usize, max_batches: usize) -> Vec<Vec<i32>> {
        let mut out = Vec::new();
        let mut pos = 0;
        while out.len() < max_batches && pos + b * t <= self.test.len() {
            out.push(self.test[pos..pos + b * t].to_vec());
            pos += b * t;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        assert_eq!(gen_text(Style::Wiki, 2000, 7), gen_text(Style::Wiki, 2000, 7));
        assert_ne!(gen_text(Style::Wiki, 2000, 7), gen_text(Style::Wiki, 2000, 8));
    }

    #[test]
    fn styles_differ() {
        let w = gen_text(Style::Wiki, 4000, 1);
        let c = gen_text(Style::C4, 4000, 1);
        assert!(w.contains("in the end it was the"));
        assert!(c.contains("and yes folks it was the"));
        assert!(!w.contains("folks"));
    }

    #[test]
    fn corpus_split_and_batches() {
        let c = Corpus::build(Style::Wiki, 50_000, 3);
        assert!(c.train.len() > 8 * c.test.len() - 4096);
        let mut rng = Rng::new(1);
        let b = c.batch(4, 128, &mut rng);
        assert_eq!(b.len(), 4 * 128);
        assert!(b.iter().all(|&t| (0..256).contains(&t)));
        let evs = c.eval_batches(4, 128, 8);
        assert!(!evs.is_empty());
        assert_eq!(evs[0].len(), 4 * 128);
        // non-overlapping
        assert_ne!(evs[0], evs[1]);
    }

    #[test]
    fn agreement_regularity_present() {
        // every "of NAME VERB" in wiki style uses the preferred verb
        let text = gen_text(Style::Wiki, 30_000, 11);
        for (i, name) in NAMES.iter().enumerate() {
            let pat = format!("of {} ", name);
            if let Some(pos) = text.find(&pat) {
                let after = &text[pos + pat.len()..];
                let verb = after.split_whitespace().next().unwrap();
                assert_eq!(verb, preferred_verb(i), "name {name}");
            }
        }
    }
}
