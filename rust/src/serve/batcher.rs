//! Dynamic batcher: groups incoming generation requests into fixed-width
//! device batches (b_eval lanes), FIFO with a max-wait cut. The coordinator
//! invariants tested here (capacity, no starvation, FIFO within batch) are
//! the property-test surface for the serving layer.

use std::collections::VecDeque;

use super::GenRequest;

#[derive(Debug)]
pub struct Batcher {
    pub capacity: usize,
    queue: VecDeque<(u64, GenRequest)>,
    next_id: u64,
}

impl Batcher {
    pub fn new(capacity: usize) -> Batcher {
        assert!(capacity > 0);
        Batcher { capacity, queue: VecDeque::new(), next_id: 0 }
    }

    pub fn submit(&mut self, req: GenRequest) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, req));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next batch (up to capacity, FIFO). Empty queue -> None.
    pub fn next_batch(&mut self) -> Option<Vec<(u64, GenRequest)>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.capacity.min(self.queue.len());
        Some(self.queue.drain(..n).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn req(n: usize) -> GenRequest {
        GenRequest { prompt: "x".repeat(n % 40 + 1), max_new_tokens: 4 }
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let mut b = Batcher::new(3);
        let ids: Vec<u64> = (0..7).map(|i| b.submit(req(i))).collect();
        let mut drained = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 3);
            drained.extend(batch.into_iter().map(|(id, _)| id));
        }
        assert_eq!(drained, ids);
    }

    #[test]
    fn batcher_invariants_property() {
        // invariant: across any submit/drain interleaving, every request is
        // delivered exactly once, in order, and no batch exceeds capacity
        check(
            "batcher-exactly-once-fifo",
            40,
            |r: &mut Rng| {
                let ops = r.below(60) + 5;
                (0..ops).map(|_| r.below(3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut b = Batcher::new(4);
                let mut submitted = Vec::new();
                let mut delivered = Vec::new();
                for &op in ops {
                    if op < 2 {
                        submitted.push(b.submit(req(op)));
                    } else if let Some(batch) = b.next_batch() {
                        if batch.len() > 4 {
                            return Err("over capacity".into());
                        }
                        delivered.extend(batch.into_iter().map(|(i, _)| i));
                    }
                }
                while let Some(batch) = b.next_batch() {
                    delivered.extend(batch.into_iter().map(|(i, _)| i));
                }
                if delivered != submitted {
                    return Err(format!(
                        "delivered {delivered:?} != submitted {submitted:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b = Batcher::new(2);
        assert!(b.next_batch().is_none());
        b.submit(req(1));
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }
}
