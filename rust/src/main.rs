//! PTQ1.61 CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain    --model tiny --steps 400
//!   preprocess  --model tiny --steps 120
//!   quantize    --model tiny --method ptq161 [--preprocessed]
//!   eval        --model tiny --method ptq161 [--preprocessed] [--fused]
//!   serve       --model tiny --method ptq161 --requests 16 [--drain]
//!               [--no-kv] [--backend dense|fused|packed] [--workers N]
//!               [--intra-threads N] [--page-size 16] [--kv-pages N]
//!               [--prefill-chunk N] [--preempt] [--overload]
//!               [--verify-identity]
//!               (quick-scale by default; --full for the full pipeline;
//!               paged KV-cached incremental decode unless --no-kv;
//!               ptq161 defaults to the prepared packed-container
//!               backend; --kv-pages undersizes the page pool to see
//!               admission backpressure; --workers N shards lanes and
//!               the page pool across N OS threads over a work-stealing
//!               queue (clamped to b_eval; incompatible with --drain);
//!               --intra-threads caps the global intra-op kernel thread
//!               budget the pool splits across workers (defaults to the
//!               host's cores; PTQ161_INTRA_THREADS env equivalent);
//!               --prefill-chunk caps prefill tokens per step so decode
//!               lanes keep emitting between a long prompt's chunks;
//!               --preempt evicts low-progress lanes under page pressure
//!               instead of backpressuring (parked requests restore by
//!               recompute, token-identically); --overload switches the
//!               workload to a mixed long/short prompt soup that makes
//!               an undersized pool preempt; --verify-identity re-runs
//!               the workload on the full-window dense baseline and
//!               asserts token-identical output — gating the paged KV
//!               cache, the packed decode backend, chunking, and
//!               preemption in one pass; writes runs/serve_metrics.json
//!               plus a run-id-suffixed copy so concurrent runs never
//!               clobber each other's artifact)
//!               [--http ADDR] swaps the synthetic workload for the
//!               streaming HTTP front door: POST /generate submits into
//!               the live engine and streams tokens per decode step as
//!               SSE, GET /stats exposes live gauges, and a full queue
//!               answers 429 + Retry-After
//!               ([--http-queue-cap N] [--http-max-requests N])
//!   load        --requests 32 --rate 20 --seed 7 [--model tiny]
//!               [--method ptq161] [--workers N] [--addr HOST:PORT [--seq N]]
//!               (open-loop load harness: seeded-Poisson arrivals over a
//!               chat/summarize/classify prompt mix against the HTTP
//!               edge — self-hosts a front door on an ephemeral loopback
//!               port unless --addr points at a running one; records
//!               client-observed wall-clock TTFT/ITL percentiles to
//!               runs/load_metrics.json)
//!   experiment  <t1..t13|f1|f3..f7|appA|all> [--full]
//!   all         run every experiment (EXPERIMENTS.md regeneration)

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::experiments::{self, ExperimentCtx};
use ptq161::quant::PackedModel;
use ptq161::runtime::kv::PrefixRouter;
use ptq161::serve::batcher::{Batcher, ShardedQueue};
use ptq161::serve::{
    effective_workers, place_request, run_open_loop, run_sharded, schedule,
    serve_http, Engine, EngineCfg, GenRequest, HttpServerCfg, LoadCfg,
    LoadReport, MetricsRegistry, ShardRun, ShardSpec,
};
use ptq161::util::cli::Args;
use ptq161::util::runid::{run_id, suffixed};

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "pretrain" => {
            let mut ctx = ctx_from(&args)?;
            ctx.pretrain_steps = args.usize_opt("steps", ctx.pretrain_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.pretrained(&model)?;
            println!("pretrained {model}: {} params", p.total_params());
        }
        "preprocess" => {
            let mut ctx = ctx_from(&args)?;
            ctx.preprocess_steps = args.usize_opt("steps", ctx.preprocess_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.preprocessed(&model)?;
            println!("preprocessed {model}: {} params", p.total_params());
        }
        "quantize" | "eval" => {
            let mut ctx = ctx_from(&args)?;
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let pre = args.flag("preprocessed") || method == "ptq161";
            let qm = ctx.quantized(&model, &method, pre)?;
            println!(
                "quantized {model} with {} ({}): {:.3} bits/weight at 4096^2",
                qm.method, qm.bits_label, qm.avg_bits
            );
            if sub == "eval" {
                let wiki = ctx.ppl(&model, &qm.params, &ctx.wiki.clone())?;
                let c4 = ctx.ppl(&model, &qm.params, &ctx.c4.clone())?;
                println!("ppl wiki {wiki:.2}  c4 {c4:.2}");
                if args.flag("fused") {
                    let parts = qm.parts.as_ref().expect("fused path needs ptq161");
                    let pipe = Pipeline::new(&ctx.rt, &model)?;
                    let p = ptq161::eval::ppl::perplexity(
                        &pipe,
                        &ModelEval::Fused { params: &qm.params, parts },
                        &ctx.wiki,
                        ctx.ppl_batches,
                    )?;
                    println!("ppl wiki via fused Pallas-kernel path: {p:.2}");
                }
            }
        }
        "serve" => {
            // serving wants a ready model, not a long experiment: default
            // to quick-scale quantization unless --full is passed
            let mut ctx = if args.flag("full") {
                ExperimentCtx::new(true)?
            } else {
                ExperimentCtx::quick()?
            };
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let n = args.usize_opt("requests", 8);
            let qm = ctx.quantized(&model, &method, method == "ptq161")?;
            let pipe = Pipeline::new(&ctx.rt, &model)?;
            // backend choice: ptq161 serves from the prepared packed
            // containers by default (pack once here, decode forever);
            // --backend dense|fused selects the reconstruction baselines
            let backend = args.str_opt(
                "backend",
                if method == "ptq161" { "packed" } else { "dense" },
            );
            let packed = if backend == "packed" {
                // any method whose quantizer emitted serve-ready containers
                // can be packed; ptq161 packs from the block-optimized
                // parts instead (containers built at quantize time would
                // predate the learned scaling factors)
                let pm = if let Some(parts) = qm.parts.as_ref() {
                    PackedModel::pack(parts)
                } else if let Some(layers) = qm.containers.as_ref() {
                    PackedModel::from_containers(&method, layers)
                } else {
                    anyhow::bail!(
                        "--backend packed: method '{method}' has no \
                         PackedContainer impl (supported: ptq161, billm, \
                         pbllm, rtn2/4/8, gptq2/4/8); use --backend dense"
                    )
                };
                println!(
                    "packed {} layers ({}): {} KiB resident, {:.3} bits/weight",
                    pm.n_layers(),
                    pm.method(),
                    pm.resident_bytes() / 1024,
                    pm.effective_bits()
                );
                Some(pm)
            } else {
                None
            };
            let me = match backend.as_str() {
                "dense" => ModelEval::Dense(&qm.params),
                "fused" => ModelEval::Fused {
                    params: &qm.params,
                    parts: qm.parts.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("--backend fused needs a ptq161 model")
                    })?,
                },
                "packed" => ModelEval::Packed {
                    params: &qm.params,
                    packed: packed.as_ref().unwrap(),
                },
                other => {
                    anyhow::bail!("unknown backend '{other}' (dense|fused|packed)")
                }
            };
            // default workload: skewed request lengths sharing a prompt
            // prefix — what continuous batching + the paged prefix index
            // are built for. --overload swaps in a mixed long/short soup:
            // every third prompt nearly fills the window (truncated to
            // the model's seq), so an undersized --kv-pages pool has to
            // preempt and --prefill-chunk has chunks to split.
            let overload = args.flag("overload");
            let requests: Vec<GenRequest> = (0..n)
                .map(|i| {
                    if overload {
                        if i % 3 == 2 {
                            GenRequest {
                                prompt: format!(
                                    "req {i} tells the long history of the \
                                     valley and the river people in full"
                                ),
                                max_new_tokens: 4,
                            }
                        } else {
                            GenRequest {
                                prompt: format!("q{i}"),
                                max_new_tokens: 12,
                            }
                        }
                    } else {
                        GenRequest {
                            prompt: format!("the quiet river of alda {}", i % 3),
                            max_new_tokens: if i % 4 == 3 { 48 } else { 6 },
                        }
                    }
                })
                .collect();
            let label = if args.flag("drain") { "drain" } else { "continuous" };
            let mut metrics = MetricsRegistry::new(label);
            // paged-cache geometry: --page-size positions per page and an
            // optional --kv-pages pool size (undersizing the pool trades
            // concurrency for memory and shows up as backpressure)
            let page_size = args.usize_opt(
                "page-size",
                ptq161::serve::engine::DEFAULT_PAGE_SIZE,
            );
            let kv_pages = match args.usize_opt("kv-pages", 0) {
                0 => None,
                p => Some(p),
            };
            // scheduler levers: --prefill-chunk caps prefill tokens per
            // step (0/absent = whole prompts at once), --preempt turns
            // page-pressure backpressure into lane eviction + parked
            // restore-by-recompute
            let prefill_chunk = match args.usize_opt("prefill-chunk", 0) {
                0 => None,
                c => Some(c),
            };
            let preempt = args.flag("preempt");
            // --intra-threads N pins the global intra-op kernel thread
            // budget (sharded workers split it; 0/absent keeps the
            // PTQ161_INTRA_THREADS / host-core default)
            let intra = args.usize_opt("intra-threads", 0);
            if intra > 0 {
                ptq161::runtime::pool::set_thread_budget(intra);
            }
            // --workers N shards lanes + page pool across N OS threads
            // (clamped so every worker owns at least one lane); the drain
            // baseline is a single static-batching loop by definition
            let workers =
                effective_workers(args.usize_opt("workers", 1), pipe.cfg.b_eval);
            anyhow::ensure!(
                workers == 1 || !args.flag("drain"),
                "--drain is the single-loop static baseline; it cannot be \
                 combined with --workers > 1"
            );
            // --http ADDR: instead of the synthetic request list, run the
            // streaming front door over the live sharded deployment —
            // requests arrive over HTTP mid-flight and tokens stream back
            // per decode step as SSE. Blocks until shutdown (or until
            // --http-max-requests terminal requests, how CI bounds it).
            let http_addr = args.str_opt("http", "");
            if !http_addr.is_empty() {
                anyhow::ensure!(
                    !args.flag("drain"),
                    "--http serves the live continuous engine; --drain has \
                     no incremental-submission path"
                );
                let ecfg = EngineCfg {
                    use_kv_cache: !args.flag("no-kv"),
                    workers,
                    prefill_chunk,
                    preempt,
                    ..EngineCfg::default()
                };
                let spec = ShardSpec { label: "http", page_size, kv_pages };
                let hcfg = HttpServerCfg {
                    queue_cap: args.usize_opt("http-queue-cap", 64),
                    retry_after_s: 1,
                    max_requests: match args.usize_opt("http-max-requests", 0)
                    {
                        0 => None,
                        k => Some(k),
                    },
                };
                let listener = std::net::TcpListener::bind(http_addr.as_str())?;
                println!(
                    "http front door on {} ({workers} worker{})",
                    listener.local_addr()?,
                    if workers == 1 { "" } else { "s" }
                );
                let run = serve_http(&pipe, &me, &ecfg, &spec, &hcfg, listener)?;
                anyhow::ensure!(
                    run.worker_panics == 0,
                    "{} worker(s) panicked; failed requests {:?}",
                    run.worker_panics,
                    run.failed_requests
                );
                run.metrics.print_summary();
                write_serve_metrics(&run.metrics)?;
                return Ok(());
            }
            let resps = if workers > 1 {
                let queue = ShardedQueue::new(workers);
                let router = PrefixRouter::new(page_size.clamp(1, pipe.cfg.seq));
                for r in &requests {
                    // placement hook: route prompts whose prefix pages a
                    // worker already holds to that worker, else spread by
                    // load (the router fills as workers publish prompts)
                    queue.submit_placed(r.clone(), None, place_request(&router, r));
                }
                let ecfg = EngineCfg {
                    use_kv_cache: !args.flag("no-kv"),
                    workers,
                    prefill_chunk,
                    preempt,
                    ..EngineCfg::default()
                };
                let spec = ShardSpec { label, page_size, kv_pages };
                let run = run_sharded(&pipe, &me, &ecfg, &queue, &router, &spec)?;
                anyhow::ensure!(
                    run.worker_panics == 0,
                    "{} worker(s) panicked; failed requests {:?}",
                    run.worker_panics,
                    run.failed_requests
                );
                metrics = run.metrics;
                run.responses
            } else {
                let mut batcher = Batcher::new(pipe.cfg.b_eval);
                for r in &requests {
                    batcher.submit(r.clone());
                }
                let mut engine =
                    Engine::with_cache_geometry(&pipe, &me, page_size, kv_pages);
                // KV-cached incremental decode is the default; --no-kv
                // selects the full-window baseline (token-identical, but
                // per-step cost grows with sequence position)
                engine.cfg.use_kv_cache = !args.flag("no-kv");
                engine.cfg.prefill_chunk = prefill_chunk;
                engine.cfg.preempt = preempt;
                let resps = if args.flag("drain") {
                    engine.run_drain(&mut batcher, &mut metrics)?
                } else {
                    engine.run(&mut batcher, &mut metrics)?
                };
                // single-loop runs still export the per-worker schema so
                // the metrics JSON shape is worker-count independent
                metrics.set_single_worker();
                resps
            };
            for r in &resps {
                let preview: String = r.text.chars().take(56).collect();
                println!(
                    "-> [{:>2}] +{:<3} tok  queue {:>5.0} ms  decode {:>6.0} ms  {preview:?}",
                    r.id, r.new_tokens, r.queue_ms, r.decode_ms
                );
            }
            metrics.print_summary();
            for w in &metrics.worker_stats {
                println!(
                    "worker {}: {} req, {} steps, occ {:.2}, p95 {:.1} ms{}",
                    w.worker,
                    w.requests,
                    w.steps,
                    w.occupancy,
                    w.p95_ms,
                    if w.panicked { "  PANICKED" } else { "" }
                );
            }
            println!(
                "kv: {} B reserved, {} B live peak, prefix hit rate {:.2}, \
                 {} CoW splits, {} backpressure",
                metrics.kv_reserved_bytes.unwrap_or(0),
                metrics.kv_live_bytes.unwrap_or(0),
                metrics.prefix_hit_rate(),
                metrics.kv_cow_splits.unwrap_or(0),
                metrics.kv_backpressure_events,
            );
            println!(
                "scheduler: {} preemptions, {} prefill chunks, \
                 {} restored positions, p99 itl {:.2} ms",
                metrics.preemptions,
                metrics.prefill_chunks,
                metrics.restored_positions,
                metrics.p99_itl_ms(),
            );
            write_serve_metrics(&metrics)?;
            if args.flag("verify-identity") {
                // token-identity gate: the same workload on the legacy
                // full-window *dense* path must decode byte-identical
                // responses, so one pass gates both the paged KV cache and
                // any packed/fused decode backend against the reference
                // reconstruction. When the primary run already was the
                // dense full-window baseline the comparison is vacuous, so
                // reject that combination outright.
                anyhow::ensure!(
                    backend != "dense" || !args.flag("no-kv"),
                    "--verify-identity checks the serve path against the \
                     full-window dense baseline; with --backend dense it \
                     cannot be combined with --no-kv (that would compare \
                     the baseline to itself)"
                );
                let mut b2 = Batcher::new(pipe.cfg.b_eval);
                for r in &requests {
                    b2.submit(r.clone());
                }
                let mut m2 = MetricsRegistry::new("identity-baseline");
                let base_me = ModelEval::Dense(&qm.params);
                let mut e2 = Engine::new(&pipe, &base_me);
                e2.cfg.use_kv_cache = false;
                let mut base = if args.flag("drain") {
                    e2.run_drain(&mut b2, &mut m2)?
                } else {
                    e2.run(&mut b2, &mut m2)?
                };
                base.sort_by_key(|r| r.id);
                let mut got = resps.clone();
                got.sort_by_key(|r| r.id);
                anyhow::ensure!(
                    got.len() == base.len(),
                    "identity check lost requests: {} vs {}",
                    got.len(),
                    base.len()
                );
                for (a, b) in got.iter().zip(&base) {
                    anyhow::ensure!(
                        a.text == b.text,
                        "token identity violated for request {} \
                         (backend {backend} vs full-window dense)",
                        a.id
                    );
                }
                println!(
                    "token-identity vs full-window dense baseline: ok \
                     ({} requests, backend {backend})",
                    base.len()
                );
            }
        }
        "load" => {
            // open-loop load harness: seeded-Poisson arrivals over a
            // chat/summarize/classify mix against the HTTP edge, with
            // wall-clock TTFT/ITL percentiles measured at the client.
            // Open-loop means arrivals never wait on completions, so
            // saturation shows up as a TTFT knee instead of silently
            // throttling the offered rate.
            let n = args.usize_opt("requests", 32);
            let rate = args.f32_opt("rate", 20.0) as f64;
            let seed = args.u64_opt("seed", 7);
            let addr = args.str_opt("addr", "");
            if !addr.is_empty() {
                // drive an already-running front door; --seq must match
                // the served model's window (it sizes long-context
                // prompts and the identity reconstruction)
                let seq = args.usize_opt("seq", 64);
                let lcfg = LoadCfg { rate_hz: rate, requests: n, seed, seq };
                let report = run_open_loop(&addr, &schedule(&lcfg), rate, seq);
                finish_load(&report)?;
                return Ok(());
            }
            // self-host: quantize at quick scale, spawn the front door on
            // an ephemeral loopback port, drive it, retire after n
            // terminal requests (every offered request ends terminal:
            // streamed, failed, or shed with 429)
            let mut ctx = if args.flag("full") {
                ExperimentCtx::new(true)?
            } else {
                ExperimentCtx::quick()?
            };
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let qm = ctx.quantized(&model, &method, method == "ptq161")?;
            let pipe = Pipeline::new(&ctx.rt, &model)?;
            // production backend when the quantizer emitted serve-ready
            // containers, dense reconstruction otherwise
            let packed = if let Some(parts) = qm.parts.as_ref() {
                Some(PackedModel::pack(parts))
            } else if let Some(layers) = qm.containers.as_ref() {
                Some(PackedModel::from_containers(&method, layers))
            } else {
                None
            };
            let me = match packed.as_ref() {
                Some(pm) => {
                    ModelEval::Packed { params: &qm.params, packed: pm }
                }
                None => ModelEval::Dense(&qm.params),
            };
            let workers =
                effective_workers(args.usize_opt("workers", 1), pipe.cfg.b_eval);
            let ecfg = EngineCfg { workers, ..EngineCfg::default() };
            let spec = ShardSpec {
                label: "load",
                page_size: ptq161::serve::engine::DEFAULT_PAGE_SIZE,
                kv_pages: None,
            };
            let hcfg = HttpServerCfg {
                queue_cap: args.usize_opt("http-queue-cap", 64),
                retry_after_s: 1,
                max_requests: Some(n),
            };
            let lcfg = LoadCfg {
                rate_hz: rate,
                requests: n,
                seed,
                seq: pipe.cfg.seq,
            };
            let arrivals = schedule(&lcfg);
            let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
            let bound = listener.local_addr()?.to_string();
            println!(
                "self-hosted front door on {bound} ({workers} worker{}), \
                 offering {n} requests at {rate:.1} req/s (seed {seed})",
                if workers == 1 { "" } else { "s" }
            );
            let (report, run) = std::thread::scope(
                |scope| -> Result<(LoadReport, ShardRun)> {
                    let (p, m, e, sp, h) =
                        (&pipe, &me, &ecfg, &spec, &hcfg);
                    let server = scope
                        .spawn(move || serve_http(p, m, e, sp, h, listener));
                    let report =
                        run_open_loop(&bound, &arrivals, rate, pipe.cfg.seq);
                    let run = server.join().expect("server thread panicked")?;
                    Ok((report, run))
                },
            )?;
            anyhow::ensure!(
                run.worker_panics == 0,
                "{} worker(s) panicked; failed requests {:?}",
                run.worker_panics,
                run.failed_requests
            );
            finish_load(&report)?;
            write_serve_metrics(&run.metrics)?;
        }
        "experiment" | "all" => {
            let mut ctx = ctx_from(&args)?;
            let ids: Vec<String> = if sub == "all"
                || args.positional.first().map(String::as_str) == Some("all")
            {
                let mut v: Vec<String> = experiments::ALL_IDS
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                v.extend(experiments::EXTRA_IDS.iter().map(|s| s.to_string()));
                v.push("appA".into());
                v
            } else {
                args.positional.clone()
            };
            for id in ids {
                eprintln!("\n##### experiment {id} #####");
                experiments::run(&mut ctx, &id)?;
            }
        }
        _ => {
            println!(
                "usage: ptq161 <pretrain|preprocess|quantize|eval|serve|load|experiment|all> \
                 [--model tiny|small] [--method NAME] [--quick] [--full] ..."
            );
        }
    }
    Ok(())
}

fn ctx_from(args: &Args) -> Result<ExperimentCtx> {
    if args.flag("quick") {
        ExperimentCtx::quick()
    } else {
        ExperimentCtx::new(args.flag("full"))
    }
}

/// Export serve metrics twice: a run-id-suffixed file (concurrent or
/// repeated runs never clobber each other's artifact) plus the stable
/// `serve_metrics.json` name tooling hardcodes (CI smoke lanes, docs).
fn write_serve_metrics(metrics: &MetricsRegistry) -> Result<()> {
    let dir = ptq161::runs_dir();
    let unique = dir.join(suffixed("serve_metrics.json", &run_id()));
    metrics.write_json(&unique)?;
    let stable = dir.join("serve_metrics.json");
    metrics.write_json(&stable)?;
    println!(
        "metrics written to {} (stable copy {})",
        unique.display(),
        stable.display()
    );
    Ok(())
}

/// Print the open-loop report and export it (run-id-suffixed + stable
/// `load_metrics.json`, same convention as the serve metrics).
fn finish_load(report: &LoadReport) -> Result<()> {
    println!(
        "open-loop: offered {} -> ok {}, 429 {}, errors {} \
         (completion {:.2}, identity {:.2})",
        report.offered,
        report.ok,
        report.rejected,
        report.errors,
        report.completion(),
        report.identity(),
    );
    for (class, count) in &report.class_counts {
        println!("  mix {class}: {count}");
    }
    let json = report.to_json();
    let ttft = |k: &str| json.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
    println!(
        "ttft p50/p95/p99 {:.1}/{:.1}/{:.1} ms, itl p50/p99 {:.1}/{:.1} ms, \
         {:.1} tok/s over {:.0} ms",
        ttft("ttft_p50_ms"),
        ttft("ttft_p95_ms"),
        ttft("ttft_p99_ms"),
        ttft("itl_p50_ms"),
        ttft("itl_p99_ms"),
        report.achieved_tok_s(),
        report.wall_ms,
    );
    let dir = ptq161::runs_dir();
    std::fs::create_dir_all(&dir)?;
    let payload = json.dump();
    let unique = dir.join(suffixed("load_metrics.json", &run_id()));
    std::fs::write(&unique, &payload)?;
    let stable = dir.join("load_metrics.json");
    std::fs::write(&stable, &payload)?;
    println!(
        "load metrics written to {} (stable copy {})",
        unique.display(),
        stable.display()
    );
    Ok(())
}
