//! SmoothQuant (Xiao et al., 2023) W4A4 — the weight-activation baseline of
//! Table 13. The smoothing vectors s_j = max|x_j|^α / max|w_j|^(1-α) are
//! computed here from calibration stats; the actual W4A4 fake-quant forward
//! runs in the AOT `qblock_w4a4_fwd` artifact (L2 quant_ops.w4a4_linear).

use super::LinearCalib;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct SmoothQuant {
    pub alpha: f32,
}

impl Default for SmoothQuant {
    fn default() -> Self {
        SmoothQuant { alpha: 0.5 }
    }
}

impl SmoothQuant {
    /// Per-input-channel smoothing vector for one linear.
    pub fn smooth_vector(&self, w: &Tensor, calib: &LinearCalib) -> Vec<f32> {
        let m = w.cols();
        // channel-wise weight max |w|
        let mut wmax = vec![0.0f32; m];
        for i in 0..w.rows() {
            for (j, &x) in w.row(i).iter().enumerate() {
                wmax[j] = wmax[j].max(x.abs());
            }
        }
        (0..m)
            .map(|j| {
                let a = calib.act_abs_mean[j].max(1e-5);
                let ww = wmax[j].max(1e-5);
                (a.powf(self.alpha) / ww.powf(1.0 - self.alpha)).max(1e-4)
            })
            .collect()
    }

    /// Shared vector for a group of linears consuming the same input
    /// (q/k/v share x_attn; gate/up share x_mlp) — elementwise max of the
    /// per-linear vectors, as the deployment would need one scale per input.
    pub fn shared_vector(&self, ws: &[&Tensor], calib: &LinearCalib) -> Vec<f32> {
        let mut out = vec![0.0f32; ws[0].cols()];
        for w in ws {
            let v = self.smooth_vector(w, calib);
            for (o, x) in out.iter_mut().zip(v) {
                *o = o.max(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::demo;

    #[test]
    fn hot_channels_get_big_scales() {
        let (w, calib) = demo(16, 32, 17);
        let s = SmoothQuant::default().smooth_vector(&w, &calib);
        // channels 0,8,16,24 were boosted 8x in demo()
        let hot = (s[0] + s[8] + s[16] + s[24]) / 4.0;
        let cold: f32 =
            (0..32).filter(|j| j % 8 != 0).map(|j| s[j]).sum::<f32>() / 28.0;
        assert!(hot > cold * 1.5, "hot {hot} vs cold {cold}");
    }

    #[test]
    fn shared_vector_dominates_each() {
        let (w1, calib) = demo(16, 32, 18);
        let (w2, _) = demo(16, 32, 19);
        let sq = SmoothQuant::default();
        let shared = sq.shared_vector(&[&w1, &w2], &calib);
        for (j, &s) in sq.smooth_vector(&w1, &calib).iter().enumerate() {
            assert!(shared[j] >= s - 1e-6);
        }
    }

    #[test]
    fn all_positive() {
        let (w, calib) = demo(8, 16, 20);
        assert!(SmoothQuant::default()
            .smooth_vector(&w, &calib)
            .iter()
            .all(|&x| x > 0.0));
    }
}
