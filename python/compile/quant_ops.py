"""L2 quantization ops shared by the model graphs (build-time only).

Contains the straight-through estimator used by the restorative-LoRA
preprocessing path and the W4A4 SmoothQuant fake-quant ops for the paper's
Table 13 comparison. The PTQ1.61 reconstruction itself lives in
kernels/ref.py (oracle) and kernels/binary_matmul.py (fused Pallas kernel).
"""

import jax
import jax.numpy as jnp

from .kernels import ref


def ste(fq, w):
    """Straight-through estimator: forward = fq, gradient = identity on w."""
    return w + jax.lax.stop_gradient(fq - w)


def fake_quant_ptq161_ste(w, mask):
    """PTQ1.61 fake quantization (analytic alphas) wrapped in an STE so the
    restorative LoRA can backprop through it (paper section 3.4 / D.5)."""
    return ste(ref.fake_quant_ptq161_ref(w, mask), w)


def quant_sym(x, bits, axis=None):
    """Symmetric fake quantization to ``bits`` with per-axis or per-tensor
    max-abs scaling. axis=None -> per-tensor."""
    qmax = 2.0 ** (bits - 1) - 1.0
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax / qmax, 1e-8)
    return jnp.clip(jnp.round(x / scale), -qmax, qmax) * scale


def w4a4_linear(x, w, smooth):
    """SmoothQuant-style W4A4 fake-quant linear for Table 13.

    x (b, t, in), w (out, in), smooth (in,): activation outliers are migrated
    into the weights (x/s)(w*s), then weights are quantized per-output-channel
    to 4-bit and activations per-tensor (dynamic) to 4-bit.
    """
    xs = x / smooth[None, None, :]
    ws = w * smooth[None, :]
    xq = quant_sym(xs, 4, axis=None)
    wq = quant_sym(ws, 4, axis=1)
    return xq @ wq.T
