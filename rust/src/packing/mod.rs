//! Bit-exact packing + storage accounting.
//!
//! The paper evaluates by fake quantization (its §Limitation) but its
//! *claims* are about storage: 1.61 effective bits/weight vs PB-LLM's 2.7
//! and BiLLM's 2.1 (Appendix A), and the Table 12 inference-memory model.
//! This module makes those claims bit-exact: real packed containers for
//! sign bits / 4-bit nibbles / channel bitmaps, plus the Appendix-A
//! calculator and the Table-12 memory model over real LLaMA shapes.

pub mod bitpack;
pub mod bitwidth;
pub mod codes;
pub mod memory;
pub mod nibble;

pub use bitpack::BitVec;
pub use bitwidth::{average_bits, BitScheme};
pub use codes::CodeVec;
pub use nibble::NibbleVec;
