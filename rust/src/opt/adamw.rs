//! AdamW (Loshchilov & Hutter) over host tensors. The gradient itself comes
//! from an AOT-lowered XLA executable; the optimizer state and update rule
//! live here in the coordinator, one state slot per parameter tensor.

use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct AdamW {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl AdamW {
    /// Paper setting: zero weight decay; lr supplied per use (5e-4 / 1e-3).
    pub fn new(lr: f32, n_params: usize) -> AdamW {
        AdamW {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            step: 0,
            m: vec![Vec::new(); n_params],
            v: vec![Vec::new(); n_params],
        }
    }

    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// One update over parallel slices of params and grads.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len(), "optimizer sized differently");
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            assert_eq!(p.shape, g.shape, "param/grad {i} shape mismatch");
            if self.m[i].is_empty() {
                self.m[i] = vec![0.0; p.data.len()];
                self.v[i] = vec![0.0; p.data.len()];
            }
            let (m, v) = (&mut self.m[i], &mut self.v[i]);
            for j in 0..p.data.len() {
                let gj = g.data[j];
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p.data[j] -= self.lr
                    * (mhat / (vhat.sqrt() + self.eps)
                        + self.weight_decay * p.data[j]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_matches_hand_computation() {
        // With m=v=0 and bias correction, the first step is lr * sign(g)
        // (up to eps): mhat = g, vhat = g^2, update = lr * g/|g|.
        let mut opt = AdamW::new(0.1, 1);
        let mut p = vec![Tensor::from_vec(&[2], vec![1.0, -2.0])];
        let g = vec![Tensor::from_vec(&[2], vec![0.5, -0.25])];
        opt.step(&mut p, &g);
        assert!((p[0].data[0] - (1.0 - 0.1)).abs() < 1e-4);
        assert!((p[0].data[1] - (-2.0 + 0.1)).abs() < 1e-4);
    }

    #[test]
    fn minimizes_quadratic() {
        // f(x) = (x - 3)^2, grad = 2(x-3)
        let mut opt = AdamW::new(0.05, 1);
        let mut p = vec![Tensor::from_vec(&[1], vec![0.0])];
        for _ in 0..500 {
            let g = vec![Tensor::from_vec(&[1], vec![2.0 * (p[0].data[0] - 3.0)])];
            opt.step(&mut p, &g);
        }
        assert!((p[0].data[0] - 3.0).abs() < 0.05, "x={}", p[0].data[0]);
    }

    #[test]
    fn weight_decay_shrinks() {
        let mut opt = AdamW::new(0.01, 1);
        opt.weight_decay = 0.1;
        let mut p = vec![Tensor::from_vec(&[1], vec![5.0])];
        let g = vec![Tensor::from_vec(&[1], vec![0.0])];
        for _ in 0..10 {
            opt.step(&mut p, &g);
        }
        assert!(p[0].data[0] < 5.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_checked() {
        let mut opt = AdamW::new(0.01, 1);
        let mut p = vec![Tensor::zeros(&[2])];
        let g = vec![Tensor::zeros(&[3])];
        opt.step(&mut p, &g);
    }
}
