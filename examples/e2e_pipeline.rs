//! End-to-end driver (the repo's validation workload): pretrains the tiny
//! transformer on the synthetic corpus while logging the loss curve, runs
//! quantization preprocessing, quantizes with every method in Table 1, and
//! prints the paper-shaped comparison. Results are recorded in
//! EXPERIMENTS.md.
//!
//!   cargo run --release --example e2e_pipeline [-- --full]

use anyhow::Result;
use ptq161::coordinator::pretrain::{pretrain, PretrainConfig};
use ptq161::coordinator::Pipeline;
use ptq161::experiments::{self, ExperimentCtx};
use ptq161::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut ctx = if args.flag("quick") {
        ExperimentCtx::quick()?
    } else {
        ExperimentCtx::new(args.flag("full"))?
    };

    // Phase 1: pretraining with an explicit loss curve (fresh run so the
    // curve is visible even when a cached checkpoint exists).
    let pipe = Pipeline::new(&ctx.rt, "tiny")?;
    let steps = if args.flag("quick") { 60 } else { 200 };
    let res = pretrain(
        &pipe,
        &ctx.wiki,
        &PretrainConfig { steps, ..Default::default() },
    )?;
    println!("\n== pretraining loss curve (tiny, {steps} steps) ==");
    for (s, l) in &res.curve {
        println!("step {s:>4}  loss {l:.4}");
    }
    let first = res.curve.first().unwrap().1;
    let last = res.curve.last().unwrap().1;
    assert!(last < first * 0.6, "training must make clear progress");

    // Phase 2+3: the full Table-1 regeneration (quantize all methods,
    // PPL on both corpora) plus the bit-accounting check.
    experiments::run(&mut ctx, "t1")?;
    experiments::run(&mut ctx, "appA")?;
    Ok(())
}
