//! GPTQ (Frantar et al., 2022) and OWQ (Lee et al., 2024).
//!
//! GPTQ quantizes weight columns one at a time against the layer Hessian
//! H = 2 X^T X + λI and spreads each column's quantization error over the
//! not-yet-quantized columns using the Cholesky factor of H^-1 — the exact
//! algorithm of the paper's strongest classical 2-bit baseline.
//!
//! OWQ (Appendix B.2 comparison) reuses the machinery: columns with the
//! highest quantization sensitivity (diag(H) · ||w_col||^2) are kept in
//! fp16 and the rest are GPTQ-quantized at 2-bit.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::quant::container::IntPacked;
use crate::tensor::{cholesky, spd_inverse, Tensor};

/// Per-row b-bit asymmetric quantize of a single value, also returning
/// the integer code so the packed container can decode
/// `code * scale + min` bit-exactly.
fn quantize_scalar_coded(x: f32, mn: f32, mx: f32, qmax: f32) -> (f32, u16) {
    let scale = ((mx - mn) / qmax).max(1e-8);
    let q = ((x - mn) / scale).round().clamp(0.0, qmax);
    (q * scale + mn, q as u16)
}

/// Integer planes emitted alongside a GPTQ run when every column is
/// active: row-major codes over (out, in) plus per-row `(scale, min)`.
struct IntCodes {
    codes: Vec<u16>,
    row_scale: Vec<f32>,
    row_min: Vec<f32>,
}

#[derive(Debug, Clone, Copy)]
pub struct Gptq {
    pub bits: u32,
    /// λ = percdamp * mean(diag(H)) added to the Hessian diagonal
    pub percdamp: f32,
    /// process columns in descending diag(H) order (act-order / desc_act)
    pub act_order: bool,
}

impl Gptq {
    pub fn new(bits: u32) -> Gptq {
        Gptq { bits, percdamp: 0.01, act_order: true }
    }

    /// Core GPTQ over an explicit set of active columns. Frozen columns
    /// (not in `order`) are left untouched and excluded from error
    /// propagation — OWQ freezes its fp16 outlier columns this way.
    fn run(&self, w: &Tensor, hess: &Tensor, order: &[usize]) -> Tensor {
        self.run_coded(w, hess, order).0
    }

    /// [`Gptq::run`] that also emits the integer code planes when the
    /// active set covers every column (plain GPTQ; `None` under OWQ's
    /// frozen fp16 columns, which have no codes).
    fn run_coded(
        &self,
        w: &Tensor,
        hess: &Tensor,
        order: &[usize],
    ) -> (Tensor, Option<IntCodes>) {
        let (n, m) = (w.rows(), w.cols());
        let full = order.len() == m;
        let k = order.len();
        // sub-Hessian over active columns, damped
        let mut h = Tensor::zeros(&[k, k]);
        for (a, &ca) in order.iter().enumerate() {
            for (b, &cb) in order.iter().enumerate() {
                *h.at2_mut(a, b) = hess.at2(ca, cb);
            }
        }
        let mean_diag =
            (0..k).map(|i| h.at2(i, i)).sum::<f32>() / k.max(1) as f32;
        let damp = (self.percdamp * mean_diag).max(1e-6);
        for i in 0..k {
            *h.at2_mut(i, i) += damp;
        }
        // Hinv via SPD inverse, then its Cholesky (upper through transpose):
        // the GPTQ recursion uses U = chol(H^-1)^T row by row.
        let hinv = match spd_inverse(&h) {
            Ok(x) => x,
            Err(_) => {
                // degenerate calibration: fall back to plain RTN (over all
                // columns, so the code planes are always complete here)
                let mut out = w.clone();
                let qmax = ((1u32 << self.bits) - 1) as f32;
                let mut ic = IntCodes {
                    codes: vec![0u16; n * m],
                    row_scale: Vec::with_capacity(n),
                    row_min: Vec::with_capacity(n),
                };
                for r in 0..n {
                    let row = out.row_mut(r);
                    let mn = row.iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx =
                        row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    ic.row_scale.push(((mx - mn) / qmax).max(1e-8));
                    ic.row_min.push(mn);
                    for (j, x) in row.iter_mut().enumerate() {
                        let (d, c) = quantize_scalar_coded(*x, mn, mx, qmax);
                        *x = d;
                        ic.codes[r * m + j] = c;
                    }
                }
                return (out, Some(ic));
            }
        };
        let l = match cholesky(&hinv) {
            Ok(x) => x,
            Err(_) => Tensor::zeros(&[k, k]),
        };
        // per-row quantization grid from the *active* columns
        let qmax = ((1u32 << self.bits) - 1) as f32;
        let mut grid: Vec<(f32, f32)> = Vec::with_capacity(n);
        for r in 0..n {
            let vals: Vec<f32> = order.iter().map(|&c| w.at2(r, c)).collect();
            let mn = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            grid.push((mn, mx));
        }
        let mut work = w.clone();
        let mut out = w.clone();
        let mut codes = vec![0u16; if full { n * m } else { 0 }];
        // iterate active columns; d = L[j][j] (diag of chol(H^-1)),
        // propagation coefficients L[j..][j] / d.
        for (j, &cj) in order.iter().enumerate() {
            let d = l.at2(j, j).max(1e-8);
            for r in 0..n {
                let (mn, mx) = grid[r];
                let wv = work.at2(r, cj);
                let (q, code) = quantize_scalar_coded(wv, mn, mx, qmax);
                *out.at2_mut(r, cj) = q;
                if full {
                    codes[r * m + cj] = code;
                }
                let err = (wv - q) / d;
                // compensate the remaining active columns
                for (j2, &cj2) in order.iter().enumerate().skip(j + 1) {
                    *work.at2_mut(r, cj2) -= err * l.at2(j2, j);
                }
            }
        }
        let ic = full.then(|| IntCodes {
            codes,
            row_scale: grid
                .iter()
                .map(|&(mn, mx)| ((mx - mn) / qmax).max(1e-8))
                .collect(),
            row_min: grid.iter().map(|&(mn, _)| mn).collect(),
        });
        (out, ic)
    }
}

impl Quantizer for Gptq {
    fn name(&self) -> &'static str {
        "GPTQ"
    }

    fn bits_label(&self) -> String {
        format!("{}", self.bits)
    }

    fn needs_hessian(&self) -> bool {
        true
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let m = w.cols();
        let hess = calib
            .hessian
            .clone()
            .unwrap_or_else(|| diag_tensor(&calib.act_sq_mean));
        let mut order: Vec<usize> = (0..m).collect();
        if self.act_order {
            order.sort_by(|&a, &b| {
                hess.at2(b, b).partial_cmp(&hess.at2(a, a)).unwrap()
            });
        }
        let (deq, ic) = self.run_coded(w, &hess, &order);
        let container = ic.map(|ic| {
            std::sync::Arc::new(IntPacked::new(
                &format!("gptq{}", self.bits),
                self.bits,
                ic.codes,
                ic.row_scale,
                ic.row_min,
                &deq,
            )) as crate::quant::ArcContainer
        });
        QuantizedLinear {
            deq,
            scheme: BitScheme::Uniform { bits: self.bits as f64 },
            parts: None,
            container,
        }
    }
}

fn diag_tensor(d: &[f32]) -> Tensor {
    let m = d.len();
    let mut t = Tensor::zeros(&[m, m]);
    for i in 0..m {
        *t.at2_mut(i, i) = d[i].max(1e-6);
    }
    t
}

/// OWQ: fp16 outlier columns by sensitivity, GPTQ-2bit on the rest.
#[derive(Debug, Clone, Copy)]
pub struct Owq {
    pub fp16_ratio: f64,
}

impl Owq {
    pub fn new(fp16_ratio: f64) -> Owq {
        Owq { fp16_ratio }
    }

    /// Column sensitivity: diag(H)_j * ||w_:,j||^2 (OWQ's λ‖ΔW‖² proxy).
    pub fn sensitivity(w: &Tensor, hdiag: &[f32]) -> Vec<f32> {
        let (n, m) = (w.rows(), w.cols());
        let mut s = vec![0.0f32; m];
        for i in 0..n {
            for (j, &v) in w.row(i).iter().enumerate() {
                s[j] += v * v;
            }
        }
        for j in 0..m {
            s[j] *= hdiag[j];
        }
        s
    }
}

impl Quantizer for Owq {
    fn name(&self) -> &'static str {
        "OWQ"
    }

    fn bits_label(&self) -> String {
        "2".into()
    }

    fn needs_hessian(&self) -> bool {
        true
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let m = w.cols();
        let hess = calib
            .hessian
            .clone()
            .unwrap_or_else(|| diag_tensor(&calib.act_sq_mean));
        let hdiag: Vec<f32> = (0..m).map(|j| hess.at2(j, j)).collect();
        let sens = Owq::sensitivity(w, &hdiag);
        let mut idx: Vec<usize> = (0..m).collect();
        idx.sort_by(|&a, &b| sens[b].partial_cmp(&sens[a]).unwrap());
        let n_fp = ((m as f64) * self.fp16_ratio).round() as usize;
        let mut active: Vec<usize> = idx[n_fp..].to_vec();
        // keep GPTQ's act-order inside the active set
        active.sort_by(|&a, &b| {
            hess.at2(b, b).partial_cmp(&hess.at2(a, a)).unwrap()
        });
        let gptq = Gptq { bits: 2, percdamp: 0.01, act_order: false };
        QuantizedLinear {
            deq: gptq.run(w, &hess, &active), // frozen columns stay fp
            scheme: BitScheme::Owq { fp16_ratio: self.fp16_ratio },
            parts: None,
            // no container: the frozen fp16 columns have no code plane
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::{demo, output_mse};
    use crate::quant::rtn::rtn_dense;

    #[test]
    fn gptq_beats_rtn_on_output_mse() {
        let (w, calib) = demo(48, 64, 3);
        let g = Gptq::new(2).quantize_linear(&w, &calib);
        let r = rtn_dense(&w, 2, 1.0);
        let e_g = output_mse(&w, &g.deq, 1);
        let e_r = output_mse(&w, &r, 1);
        assert!(e_g < e_r, "gptq {e_g} vs rtn {e_r}");
    }

    #[test]
    fn gptq_4bit_much_better_than_2bit() {
        let (w, calib) = demo(32, 48, 4);
        let g4 = Gptq::new(4).quantize_linear(&w, &calib);
        let g2 = Gptq::new(2).quantize_linear(&w, &calib);
        let e4 = output_mse(&w, &g4.deq, 2);
        let e2 = output_mse(&w, &g2.deq, 2);
        assert!(e4 < e2 / 10.0, "4-bit {e4} vs 2-bit {e2}");
    }

    #[test]
    fn owq_keeps_outlier_columns_fp() {
        let (w, calib) = demo(32, 40, 5);
        let q = Owq::new(0.2).quantize_linear(&w, &calib);
        // the frozen fp16 columns must match w exactly
        let hess = calib.hessian.as_ref().unwrap();
        let hdiag: Vec<f32> = (0..40).map(|j| hess.at2(j, j)).collect();
        let sens = Owq::sensitivity(&w, &hdiag);
        let mut idx: Vec<usize> = (0..40).collect();
        idx.sort_by(|&a, &b| sens[b].partial_cmp(&sens[a]).unwrap());
        let mut exact = 0;
        for &j in &idx[..8] {
            let same = (0..32).all(|i| q.deq.at2(i, j) == w.at2(i, j));
            if same {
                exact += 1;
            }
        }
        assert_eq!(exact, 8);
    }

    #[test]
    fn owq_better_than_gptq2() {
        let (w, calib) = demo(48, 64, 6);
        let o = Owq::new(0.2).quantize_linear(&w, &calib);
        let g = Gptq::new(2).quantize_linear(&w, &calib);
        assert!(output_mse(&w, &o.deq, 3) < output_mse(&w, &g.deq, 3));
    }
}
