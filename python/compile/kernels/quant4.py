"""L1 Pallas kernel: per-input-channel asymmetric 4-bit fake quantization.

Used (a) standalone in the W4A4 SmoothQuant comparison path (paper Table 13)
and (b) as the reference implementation the Rust packer is validated against.
Tiled along the output dimension; each tile computes its own column min/max
over the full row extent, so the per-column quantization parameters are
identical to the unfused oracle in ref.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, pref: int = 128) -> int:
    b = min(n, pref)
    while n % b != 0:
        b -= 1
    return b


def _kernel(w_ref, mask_ref, o_ref):
    w = w_ref[...]
    w_min = jnp.min(w, axis=0, keepdims=True)
    w_max = jnp.max(w, axis=0, keepdims=True)
    scale = jnp.maximum((w_max - w_min) / 15.0, 1e-8)
    q = jnp.clip(jnp.round((w - w_min) / scale), 0.0, 15.0)
    dq = q * scale + w_min
    o_ref[...] = jnp.where(mask_ref[...][None, :] > 0.5, dq, w)


def quant4(w, mask):
    """Fake-quantize salient columns of w (out, in) to 4-bit; mask (in,)."""
    out, k = w.shape
    kb = _pick_block(k)
    grid = (k // kb,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((out, kb), lambda j: (0, j)),
            pl.BlockSpec((kb,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((out, kb), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((out, k), jnp.float32),
        interpret=True,
    )(w, mask)
