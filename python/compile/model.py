"""L2: TinyLlama-family model graphs (build-time Python, AOT-lowered).

Defines every computation the Rust coordinator executes at run time:

  embed_fwd        tokens -> hidden states
  block_fwd        one FP transformer block (also used for every fake-quant
                   baseline: the coordinator feeds dequantized weights)
  block_capture    block fwd that also returns the inputs of each linear
                   (activation stats for the structured mask, Hessians for
                   GPTQ/BiLLM, AWQ grids, block-opt targets)
  qblock_fwd       PTQ1.61 quantized block: every linear goes through the
                   fused Pallas kernel reconstructing Eq. 9 in-tile
  qblock_w4a4_fwd  SmoothQuant W4A4 block (paper Table 13)
  head_fwd         final norm + lm head; returns (nll_sum, logits)
  lm_grad          LM loss + grads wrt all params (pretraining)
  lora_grad        restorative-LoRA loss + grads wrt (A, B) with the model
                   fake-quantized via STE (paper section 3.4)
  block_opt_grad   two-branch block loss (Eq. 5-7) + grads wrt the learnable
                   scaling factors alpha_s/alpha_r1/alpha_r2 (and the
                   optional learnable row mean mu for the Table 9 ablation)

The parameter flattening order defined by ``param_spec`` is the binary
contract with the Rust side; aot.py records it in the manifest.
"""

import jax
import jax.numpy as jnp

from .kernels.binary_matmul import binary_matmul_3d
from . import quant_ops

# Linear layers quantized inside each block, in canonical order. Embeddings
# and the LM head stay FP16-equivalent (f32 here), as in PB-LLM/BiLLM.
LINEARS = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]

CONFIGS = {
    # "tiny" plays LLaMA-7B's column in the paper's tables, "small" 13B.
    "tiny": dict(name="tiny", vocab=256, d=128, n_heads=4, n_layers=4,
                 ffn=352, seq=128, b_train=8, b_eval=4, rope_theta=10000.0,
                 lora_rank=8),
    "small": dict(name="small", vocab=256, d=192, n_heads=6, n_layers=6,
                  ffn=512, seq=128, b_train=8, b_eval=4, rope_theta=10000.0,
                  lora_rank=8),
}

EPS = 1e-5


def linear_shape(cfg, name):
    d, ffn = cfg["d"], cfg["ffn"]
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_gate": (ffn, d), "w_up": (ffn, d), "w_down": (d, ffn),
    }[name]


def block_param_spec(cfg, l=0):
    """Canonical (name, shape) list for one block's parameters."""
    d = cfg["d"]
    spec = [(f"l{l}.attn_norm", (d,))]
    for n in ["wq", "wk", "wv", "wo"]:
        spec.append((f"l{l}.{n}", linear_shape(cfg, n)))
    spec.append((f"l{l}.mlp_norm", (d,)))
    for n in ["w_gate", "w_up", "w_down"]:
        spec.append((f"l{l}.{n}", linear_shape(cfg, n)))
    return spec


def param_spec(cfg):
    """Canonical (name, shape) list for the full model (the Rust contract)."""
    spec = [("embed", (cfg["vocab"], cfg["d"]))]
    for l in range(cfg["n_layers"]):
        spec.extend(block_param_spec(cfg, l))
    spec.append(("norm_f", (cfg["d"],)))
    spec.append(("w_out", (cfg["vocab"], cfg["d"])))
    return spec


def unflatten(spec, flat):
    assert len(spec) == len(flat), f"{len(spec)} vs {len(flat)}"
    return {name: x for (name, _), x in zip(spec, flat)}


# ---------------------------------------------------------------------------
# FP forward pieces
# ---------------------------------------------------------------------------

def rmsnorm(x, g):
    return x * g / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def rope(q, theta):
    """Rotary embedding over (b, t, h, hd)."""
    b, t, h, hd = q.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    q1, q2 = q[..., :half], q[..., half:]
    return jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], -1)


def attention(q, k, v, cfg):
    """Causal multi-head attention over projected (b, t, d) tensors.
    Returns the pre-wo context (b, t, d) — the capture point for x_o."""
    b, t, d = q.shape
    h = cfg["n_heads"]
    hd = d // h
    q = rope(q.reshape(b, t, h, hd), cfg["rope_theta"])
    k = rope(k.reshape(b, t, h, hd), cfg["rope_theta"])
    v = v.reshape(b, t, h, hd)
    scores = jnp.einsum("bthc,bshc->bhts", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    scores = jnp.where(causal[None, None] > 0.5, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhts,bshc->bthc", probs, v)
    return ctx.reshape(b, t, d)


def _block_pieces(h, p, cfg, lin):
    """Shared block body; ``lin(name, x)`` performs the named linear on x.
    Returns (x_attn, x_o, x_mlp, x_down, h_out) — the 4 linear-input capture
    tensors plus the block output."""
    x_attn = rmsnorm(h, p["attn_norm"])
    q = lin("wq", x_attn)
    k = lin("wk", x_attn)
    v = lin("wv", x_attn)
    x_o = attention(q, k, v, cfg)
    h = h + lin("wo", x_o)
    x_mlp = rmsnorm(h, p["mlp_norm"])
    x_down = jax.nn.silu(lin("w_gate", x_mlp)) * lin("w_up", x_mlp)
    h_out = h + lin("w_down", x_down)
    return x_attn, x_o, x_mlp, x_down, h_out


def block_fwd(h, p, cfg):
    def lin(name, x):
        return x @ p[name].T
    return _block_pieces(h, p, cfg, lin)[-1]


def block_capture(h, p, cfg):
    def lin(name, x):
        return x @ p[name].T
    return _block_pieces(h, p, cfg, lin)


def qblock_fwd(h, norms, qparts, cfg):
    """PTQ1.61 quantized block. qparts[name] = (w_sal, sign_ns, a_s, a_r1,
    a_r2, mu); every linear runs through the fused Pallas kernel, with the
    optional learnable row-mean mu (Table 9 ablation) added afterwards."""
    p = {"attn_norm": norms[0], "mlp_norm": norms[1]}

    def lin(name, x):
        w_sal, sign_ns, a_s, a_r1, a_r2, mu = qparts[name]
        y = binary_matmul_3d(x, w_sal, sign_ns, a_s, a_r1, a_r2)
        # mu is a learnable per-row mean added to every *binarized* weight
        # element (QA-LoRA group-size=1 analog, Table 9 ablation); it is
        # identically zero in the standard PTQ1.61 configuration. Adding mu
        # to each non-salient weight of row o contributes
        # mu[o] * sum_{i in ns} x[., i], so it folds into one extra GEMV.
        ns_col = jnp.abs(sign_ns)[0]  # (in,) 1.0 exactly on binarized cols
        xs = x @ ns_col               # (b, t)
        return y + xs[..., None] * mu[None, None, :]

    return _block_pieces(h, p, cfg, lin)[-1]


def qblock_w4a4_fwd(h, p, smooth, cfg):
    """SmoothQuant W4A4 block (Table 13). smooth[name] is the per-input
    smoothing vector; q/k/v share one, gate/up share one."""
    def lin(name, x):
        return quant_ops.w4a4_linear(x, p[name], smooth[name])
    return _block_pieces(h, p, cfg, lin)[-1]


def embed_fwd(tokens, embed):
    return embed[tokens]


def head_fwd(h, norm_f, w_out, tokens):
    """Returns (nll_sum, logits). nll_sum = sum of next-token NLL over all
    (b, t-1) positions; the coordinator divides by token count for PPL."""
    logits = rmsnorm(h, norm_f) @ w_out.T
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll), logits


def lm_loss(params, tokens, cfg):
    spec = param_spec(cfg)
    p = unflatten(spec, params)
    h = embed_fwd(tokens, p["embed"])
    for l in range(cfg["n_layers"]):
        bp = {k.split(".", 1)[1]: p[k] for k, _ in block_param_spec(cfg, l)}
        h = block_fwd(h, bp, cfg)
    nll_sum, _ = head_fwd(h, p["norm_f"], p["w_out"], tokens)
    b, t = tokens.shape
    return nll_sum / (b * (t - 1))


# ---------------------------------------------------------------------------
# Restorative-LoRA preprocessing (section 3.4)
# ---------------------------------------------------------------------------

def lora_loss(ab_flat, params, masks, tokens, cfg):
    """LM loss of the STE-fake-quantized model with LoRA deltas merged.

    ab_flat: [A, B] per (layer, linear) in canonical order; A (r, in),
    B (out, r). masks: per (layer, linear) salient-channel vectors (in,).
    Only block linears get LoRA + fake quant; embeddings/norms/head stay FP.
    """
    spec = param_spec(cfg)
    p = unflatten(spec, params)
    r = cfg["lora_rank"]
    i = 0
    h = embed_fwd(tokens, p["embed"])
    for l in range(cfg["n_layers"]):
        bp = {k.split(".", 1)[1]: p[k] for k, _ in block_param_spec(cfg, l)}
        for n in LINEARS:
            a, b_ = ab_flat[2 * i], ab_flat[2 * i + 1]
            mask = masks[i]
            w_eff = bp[n] + (b_ @ a) / float(r)
            bp[n] = quant_ops.fake_quant_ptq161_ste(w_eff, mask)
            i += 1
        h = block_fwd(h, bp, cfg)
    nll_sum, _ = head_fwd(h, p["norm_f"], p["w_out"], tokens)
    b, t = tokens.shape
    return nll_sum / (b * (t - 1))


# ---------------------------------------------------------------------------
# Block-wise scaling-factor optimization (section 3.3, Eq. 5-7)
# ---------------------------------------------------------------------------

def _distance(f1, f2, nlc_w):
    """Eq. 5: E(f1, f2) = MSE + nlc_w * (-log cosine-similarity)."""
    mse = jnp.mean((f1 - f2) ** 2)
    a = f1.reshape(-1)
    b = f2.reshape(-1)
    cos = jnp.sum(a * b) / jnp.maximum(
        jnp.linalg.norm(a) * jnp.linalg.norm(b), 1e-8
    )
    nlc = -jnp.log(jnp.clip(cos, 1e-3, 1.0))
    return mse + nlc_w * nlc


def block_opt_loss(learn_flat, x_q, f1, f3, norms, consts_flat, nlc_w, cfg):
    """Two-branch objective (Eq. 7) for one block.

    learn_flat : per linear [a_s, a_r1, a_r2, mu] (4 x 7 arrays, learnable)
    x_q        : input activations of the quantized block
    f1         : F(X, W)   — FP block on FP inputs (precomputed by Rust)
    f3         : F(X_q, W) — FP block on quantized inputs (precomputed)
    consts_flat: per linear [w_sal, sign_ns] (2 x 7 arrays, fixed)
    nlc_w      : scalar weight on the angular term (0.0 for Table 7 w/o row)
    """
    qparts = {}
    for i, n in enumerate(LINEARS):
        a_s, a_r1, a_r2, mu = learn_flat[4 * i:4 * i + 4]
        w_sal, sign_ns = consts_flat[2 * i:2 * i + 2]
        qparts[n] = (w_sal, sign_ns, a_s, a_r1, a_r2, mu)
    f2 = qblock_fwd(x_q, norms, qparts, cfg)
    return _distance(f1, f2, nlc_w) + _distance(f3, f2, nlc_w)


# ---------------------------------------------------------------------------
# Grad wrappers (what aot.py actually lowers)
# ---------------------------------------------------------------------------

def lm_grad_fn(cfg):
    spec = param_spec(cfg)
    n = len(spec)

    def fn(*args):
        params = list(args[:n])
        tokens = args[n]
        loss, grads = jax.value_and_grad(
            lambda ps: lm_loss(ps, tokens, cfg)
        )(params)
        return tuple([loss] + list(grads))

    return fn


def lora_grad_fn(cfg):
    spec = param_spec(cfg)
    n = len(spec)
    nlin = cfg["n_layers"] * len(LINEARS)

    def fn(*args):
        params = list(args[:n])
        ab = list(args[n:n + 2 * nlin])
        masks = list(args[n + 2 * nlin:n + 3 * nlin])
        tokens = args[n + 3 * nlin]
        loss, grads = jax.value_and_grad(
            lambda abf: lora_loss(abf, params, masks, tokens, cfg)
        )(ab)
        return tuple([loss] + list(grads))

    return fn


def block_opt_grad_fn(cfg):
    nl = len(LINEARS)

    def fn(*args):
        learn = list(args[:4 * nl])
        x_q, f1, f3, attn_norm, mlp_norm = args[4 * nl:4 * nl + 5]
        consts = list(args[4 * nl + 5:4 * nl + 5 + 2 * nl])
        nlc_w = args[4 * nl + 5 + 2 * nl]
        loss, grads = jax.value_and_grad(
            lambda lf: block_opt_loss(
                lf, x_q, f1, f3, (attn_norm, mlp_norm), consts, nlc_w, cfg
            )
        )(learn)
        return tuple([loss] + list(grads))

    return fn
