//! End-to-end bench (Table 1 regeneration at smoke scale): quantize + PPL
//! of the headline methods on a briefly-trained tiny model. One iteration
//! per method — this is a minutes-scale end-to-end measurement, reported
//! once, not a statistical microbench.

use std::time::Instant;

use ptq161::coordinator::capture::capture;
use ptq161::coordinator::pretrain::lm_grad;
use ptq161::coordinator::quantize::quantize_model;
use ptq161::coordinator::Pipeline;
use ptq161::data::{calib, Corpus, Style};
use ptq161::eval::ppl::perplexity;
use ptq161::eval::ModelEval;
use ptq161::runtime::Runtime;
use ptq161::util::rng::Rng;

fn main() {
    let dir = ptq161::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_e2e: artifacts not built, skipping");
        return;
    }
    let rt = Runtime::open(&dir).unwrap();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let corpus = Corpus::build(Style::Wiki, 200_000, 60);
    let mut params = pipe.init_params(61);
    let mut opt = ptq161::opt::AdamW::new(3e-3, params.tensors.len());
    let mut rng = Rng::new(62);
    for _ in 0..40 {
        let batch = corpus.batch(pipe.cfg.b_train, pipe.cfg.seq, &mut rng);
        let (_, grads) = lm_grad(&pipe, &params, &batch).unwrap();
        opt.step(&mut params.tensors, &grads);
    }
    let cal = calib::sample(&corpus, 8, pipe.cfg.b_eval, pipe.cfg.seq, 63);
    let mc = capture(&pipe, &params, &cal, true).unwrap();
    println!("# e2e: quantize + 2-batch PPL per method (one-shot timings)");
    for method in ["rtn1", "gptq2", "pbllm", "billm", "ptq161"] {
        let t0 = Instant::now();
        let q = ptq161::quant::by_name(method).unwrap();
        let qm = quantize_model(&pipe, &params, &mc, q.as_ref()).unwrap();
        let quant_s = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let ppl =
            perplexity(&pipe, &ModelEval::Dense(&qm.params), &corpus, 2)
                .unwrap();
        println!(
            "{method:<10} quantize {quant_s:>6.2}s  eval {:>5.2}s  ppl {ppl:>9.2}",
            t1.elapsed().as_secs_f64()
        );
    }
}
