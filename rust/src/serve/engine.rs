//! Continuous-batching serve engine with paged, KV-cached incremental
//! decode and batched prefill.
//!
//! A slot-based scheduler over the pipeline's `b_eval` lanes. Each lane
//! binds to a lane of the paged [`KvCache`] for the life of a request:
//! admission reserves the request's worst-case *page* budget (prompt +
//! generation budget, in `--page-size` position pages) and backpressures
//! on **pool exhaustion** rather than lane count — with a pool smaller
//! than `lanes × window`, short requests still admit because pages, not
//! whole windows, are the unit of accounting. The first decode step after
//! admission prefills the prompt; subsequent steps run the model over
//! exactly *one new token per lane* against the cached K/V, so per-token
//! cost is flat in sequence position. Lanes are compacted out of the
//! batch when they finish, their pages are released (shared pages when
//! the last reader finishes), and freed lanes refill from the queue on
//! the next step — a request never waits for the rest of its batch.
//!
//! **Batched prefill**: newly admitted lanes are prefilled together, not
//! one `b=1` forward at a time — prompts are bucketed by the length still
//! to compute and each bucket runs as one chunked `*_decode` forward (the
//! decode kernels take per-lane past lengths, so lanes with different
//! amounts of adopted prefix batch together as long as their new chunks
//! are the same length).
//!
//! **Shared-prefix reuse**: before prefilling, each lane adopts the
//! longest registered whole-page token prefix of its prompt from the
//! cache's content-keyed index ([`KvCache::adopt_prefix`]) — positions
//! covered by adopted pages skip the forward entirely, and after prefill
//! the lane registers its own full prompt pages for later requests.
//! Identical system prompts are therefore cached once, not once per lane,
//! and the metrics' `prefix_hit_rate` reports the fraction of prompt
//! positions served from shared pages.
//!
//! `EngineCfg::use_kv_cache = false` selects the legacy full-window step
//! (re-running the entire padded window every token); both paths produce
//! token-identical output for the dense, PTQ1.61-fused and packed models,
//! which `benches/bench_serve.rs` and `tests/paged_kv.rs` gate on.
//!
//! The weight representation is the [`ModelEval`] handed to
//! [`Engine::new`] — for PTQ1.61 the production choice is
//! `ModelEval::Packed` over a `PackedModel` built **once** from the
//! quantizer's parts, so every decode step contracts the 1.61-bit
//! containers directly instead of reconstructing dense weights
//! (`tests/packed_serve.rs` gates the token identity and the
//! zero-reconstruction invariant). `EngineCfg::backend` records the
//! choice and the run's metrics carry the resident-memory split (KV
//! reserved/live bytes, packed-model bytes, effective bits/weight).
//!
//! [`Engine::run_drain`] is the classic static-batching baseline for
//! comparison: it admits whole batches and only takes the next batch when
//! every lane has finished — exactly what a deployment without in-flight
//! refill pays. (With the KV cache enabled, drain mode still decodes
//! compacted active lanes; the fixed-width padding cost model only exists
//! on the full-window path.)

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::Batcher;
use super::metrics::{MetricsRegistry, RequestMetric};
use super::{GenRequest, GenResponse};
use crate::coordinator::Pipeline;
use crate::eval::ModelEval;
use crate::model::tokenizer::ByteTokenizer;
use crate::runtime::kv::KvCache;

pub use crate::runtime::kv::DEFAULT_PAGE_SIZE;

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineCfg {
    /// hard cap on decode steps per run (runaway guard)
    pub max_steps: usize,
    /// decode incrementally against per-lane cached K/V (the production
    /// path); `false` re-runs the full padded window every step (the
    /// baseline `bench_serve` compares against)
    pub use_kv_cache: bool,
    /// which weight representation this engine decodes from — derived
    /// from the [`ModelEval`] at construction (`dense` / `fused` /
    /// `packed` / `w4a4`; the CLI's `--backend` flag selects which
    /// `ModelEval` gets built) and exported into the metrics JSON
    pub backend: &'static str,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg { max_steps: 100_000, use_kv_cache: true, backend: "dense" }
    }
}

/// One in-flight request bound to a lane (and, when the KV cache is on,
/// to a cache lane from admission until finish).
#[derive(Debug, Clone)]
struct Lane {
    id: u64,
    seq: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    submitted: Instant,
    admitted: Instant,
    /// paged-cache lane, reserved at admission (KV path only)
    slot: Option<usize>,
    /// prompt has been prefilled (first token emitted)
    prefilled: bool,
}

/// Continuous-batching decode loop over the lane pool (see module docs).
pub struct Engine<'a> {
    pipe: &'a Pipeline<'a>,
    model: &'a ModelEval<'a>,
    /// engine tunables (step cap, KV cache on/off)
    pub cfg: EngineCfg,
    lanes: Vec<Option<Lane>>,
    cache: KvCache,
}

impl<'a> Engine<'a> {
    /// An engine over `pipe.cfg.b_eval` lanes with a fully provisioned
    /// page pool (one window per lane, [`DEFAULT_PAGE_SIZE`] positions
    /// per page), decoding `model`.
    pub fn new(pipe: &'a Pipeline<'a>, model: &'a ModelEval<'a>) -> Engine<'a> {
        Self::with_cache_geometry(pipe, model, DEFAULT_PAGE_SIZE, None)
    }

    /// An engine with explicit cache geometry: `page_size` positions per
    /// page and `kv_pages` pool pages (`None` = one full window per
    /// lane). The pool is floored at one full window so a maximal
    /// request stays admissible; an undersized pool trades concurrency
    /// for memory and surfaces as admission backpressure in the metrics.
    pub fn with_cache_geometry(
        pipe: &'a Pipeline<'a>,
        model: &'a ModelEval<'a>,
        page_size: usize,
        kv_pages: Option<usize>,
    ) -> Engine<'a> {
        let cfg = &pipe.cfg;
        let ps = page_size.clamp(1, cfg.seq);
        let per_lane = cfg.seq.div_ceil(ps);
        let pages = kv_pages.unwrap_or(cfg.b_eval * per_lane).max(per_lane);
        let lanes = (0..cfg.b_eval).map(|_| None).collect();
        let cache = KvCache::with_geometry(
            cfg.b_eval,
            cfg.n_layers,
            cfg.seq,
            cfg.n_heads,
            cfg.d / cfg.n_heads,
            ps,
            pages,
        );
        let cfg = EngineCfg { backend: model.label(), ..EngineCfg::default() };
        Engine { pipe, model, cfg, lanes, cache }
    }

    /// Record the run's resident-memory accounting (KV reserved/live
    /// bytes and paging stats, packed-model bytes + effective
    /// bits/weight, backend label) into the metrics registry. Called at
    /// the top of every run loop and again after it drains, so the JSON
    /// carries the final live high-water mark and CoW count.
    fn export_memory(&self, metrics: &mut MetricsRegistry) {
        metrics.set_backend(self.cfg.backend);
        if self.cfg.use_kv_cache {
            metrics.set_kv_paging(
                self.cache.bytes(),
                self.cache.peak_live_bytes(),
                self.cache.page_size(),
                self.cache.total_pages(),
                self.cache.cow_splits(),
                self.cache.page_alloc_count(),
            );
        }
        if let Some(pm) = self.model.packed() {
            metrics.set_packed_model(pm.resident_bytes(), pm.effective_bits());
        }
    }

    /// Number of lanes (== max concurrent requests).
    pub fn capacity(&self) -> usize {
        self.lanes.len()
    }

    /// The engine's paged KV cache (occupancy / sharing accounting).
    pub fn kv_cache(&self) -> &KvCache {
        &self.cache
    }

    fn active_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Greedy next token from one vocab row — shared by the cached and
    /// full-window paths so tie-breaking is identical in both.
    fn argmax(row: &[f32]) -> i32 {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap()
    }

    /// The tokenized shape of a request: `(prompt_len, max_new)` after
    /// window truncation and empty-prompt seeding. Shared by admission's
    /// page-budget reservation and [`Self::make_lane`] so the reserved
    /// budget always matches the lane that decodes against it.
    fn lane_shape(&self, req: &GenRequest) -> (usize, usize) {
        let t = self.pipe.cfg.seq;
        // the byte tokenizer is one token per byte; empty prompts are
        // seeded with a single space, long ones truncate to the window
        let prompt_len = req.prompt.len().clamp(1, t - 1);
        let max_new = req.max_new_tokens.min(t - prompt_len);
        (prompt_len, max_new)
    }

    fn make_lane(
        &self,
        id: u64,
        req: &GenRequest,
        submitted: Instant,
        admitted: Instant,
    ) -> Lane {
        let t = self.pipe.cfg.seq;
        let tk = ByteTokenizer;
        let mut seq = tk.encode(&req.prompt);
        seq.truncate(t - 1);
        if seq.is_empty() {
            seq.push(b' ' as i32);
        }
        let (prompt_len, max_new) = self.lane_shape(req);
        assert_eq!(
            prompt_len,
            seq.len(),
            "lane_shape must match the tokenized prompt"
        );
        Lane {
            id,
            seq,
            prompt_len,
            max_new,
            submitted,
            admitted,
            slot: None,
            prefilled: false,
        }
    }

    fn finish(
        lane: Lane,
        cached_positions: usize,
        now: Instant,
        metrics: &mut MetricsRegistry,
    ) -> GenResponse {
        let tk = ByteTokenizer;
        let queue_ms =
            lane.admitted.duration_since(lane.submitted).as_secs_f64() * 1000.0;
        let decode_ms = now.duration_since(lane.admitted).as_secs_f64() * 1000.0;
        let new_tokens = lane.seq.len() - lane.prompt_len;
        metrics.record_request(RequestMetric {
            id: lane.id,
            queue_ms,
            decode_ms,
            total_ms: queue_ms + decode_ms,
            new_tokens,
            cached_positions,
        });
        GenResponse {
            id: lane.id,
            text: tk.decode(&lane.seq),
            new_tokens,
            queue_ms,
            decode_ms,
            latency_ms: queue_ms + decode_ms,
        }
    }

    /// Take lane `li` out of the pool, release its cache pages, and emit
    /// the response (recording the lane's cached-position high-water mark
    /// before the free resets it).
    fn finish_lane(
        &mut self,
        li: usize,
        now: Instant,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let lane = self.lanes[li].take().unwrap();
        let cached_positions =
            lane.slot.map(|slot| self.cache.len(slot)).unwrap_or(0);
        if let Some(slot) = lane.slot {
            self.cache.free(slot);
        }
        out.push(Self::finish(lane, cached_positions, now, metrics));
    }

    /// Admit queued requests into free lanes (continuous mode). Requests
    /// whose deadline lapsed in the queue are dropped; zero-token requests
    /// complete immediately without occupying a lane. On the KV path each
    /// admission reserves the request's worst-case page budget — when the
    /// pool cannot cover it, admission stops (backpressure) and the
    /// request stays queued until finishing lanes release pages.
    fn admit(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let now = Instant::now();
        metrics.record_expired(batcher.expire_overdue(now).len());
        for i in 0..self.lanes.len() {
            while self.lanes[i].is_none() {
                // peek first (borrowed, no clone): the page budget comes
                // from `lane_shape` without tokenizing, so a rejected
                // admission leaves the request queued at zero cost
                let Some((_, peeked, _)) = batcher.peek_ready(now) else {
                    return;
                };
                let (prompt_len, max_new) = self.lane_shape(peeked);
                let mut slot = None;
                if max_new > 0 && self.cfg.use_kv_cache {
                    match self.cache.alloc_with_budget(prompt_len + max_new) {
                        Some(s) => slot = Some(s),
                        None => {
                            // pool exhausted: leave the request queued
                            metrics.record_backpressure();
                            return;
                        }
                    }
                }
                let (id, req, submitted) =
                    batcher.pop_ready(now).expect("peeked head vanished");
                let mut lane = self.make_lane(id, &req, submitted, now);
                if lane.max_new == 0 {
                    out.push(Self::finish(lane, 0, now, metrics));
                    continue;
                }
                lane.slot = slot;
                self.lanes[i] = Some(lane);
            }
        }
    }

    /// `true` once the lane produced its budget of new tokens or filled
    /// the window — same rule on both decode paths.
    fn lane_done(&self, li: usize) -> bool {
        let lane = self.lanes[li].as_ref().unwrap();
        lane.seq.len() - lane.prompt_len >= lane.max_new
            || lane.seq.len() >= self.pipe.cfg.seq
    }

    /// One full-window decode step (`use_kv_cache = false`). In compact
    /// mode only active lanes enter the forward (cost scales with load);
    /// in fixed-width mode every lane slot is computed, finished-lane rows
    /// as padding — the static batching cost model.
    fn decode_step_full(
        &mut self,
        fixed_width: bool,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let (t, vocab) = (self.pipe.cfg.seq, self.pipe.cfg.vocab);
        let layout: Vec<Option<usize>> = if fixed_width {
            (0..self.lanes.len())
                .map(|i| self.lanes[i].is_some().then_some(i))
                .collect()
        } else {
            (0..self.lanes.len())
                .filter(|&i| self.lanes[i].is_some())
                .map(Some)
                .collect()
        };
        let n_active = layout.iter().filter(|r| r.is_some()).count();
        if n_active == 0 {
            return Ok(());
        }
        let b = layout.len();
        let mut tokens = vec![0i32; b * t];
        for (row, slot) in layout.iter().enumerate() {
            if let Some(li) = slot {
                let lane = self.lanes[*li].as_ref().unwrap();
                tokens[row * t..row * t + lane.seq.len()].copy_from_slice(&lane.seq);
            }
        }
        let step_started = Instant::now();
        let h = self.model.forward_h(self.pipe, &tokens)?;
        let (_, logits) = self.pipe.head(self.model.params(), &h, &tokens)?;
        metrics.record_step_from(step_started, n_active, self.lanes.len());
        let now = Instant::now();
        for (row, slot) in layout.iter().enumerate() {
            let Some(li) = slot else { continue };
            {
                let lane = self.lanes[*li].as_mut().unwrap();
                let pos = lane.seq.len() - 1;
                let base = (row * t + pos) * vocab;
                let next = Self::argmax(&logits.data[base..base + vocab]);
                lane.seq.push(next);
            }
            metrics.record_tokens(1);
            if self.lane_done(*li) {
                self.finish_lane(*li, now, metrics, out);
            }
        }
        Ok(())
    }

    /// One KV-cached decode step. Newly admitted lanes adopt any shared
    /// whole-page prompt prefix from the cache's index, then prefill in
    /// *batched* buckets — lanes whose remaining (post-adoption) chunks
    /// are the same length run as one chunked forward instead of one
    /// `b=1` forward each. Lanes already prefilled decode their single
    /// newest token as one compacted batch. Either way every active lane
    /// yields exactly one token per step, matching the full-window step's
    /// accounting.
    fn decode_step_cached(
        &mut self,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let vocab = self.pipe.cfg.vocab;
        let active: Vec<usize> =
            (0..self.lanes.len()).filter(|&i| self.lanes[i].is_some()).collect();
        if active.is_empty() {
            return Ok(());
        }
        let n_active = active.len();
        let (pipe, model) = (self.pipe, self.model);
        let step_started = Instant::now();
        let decoding: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&li| self.lanes[li].as_ref().unwrap().prefilled)
            .collect();
        // batched prefill: adopt shared prefixes, then bucket the lanes
        // by remaining chunk length (BTreeMap for deterministic order)
        let mut buckets: BTreeMap<usize, Vec<(usize, Vec<i32>)>> = BTreeMap::new();
        for &li in &active {
            if self.lanes[li].as_ref().unwrap().prefilled {
                continue;
            }
            let (slot, prompt) = {
                let lane = self.lanes[li].as_ref().unwrap();
                (lane.slot.expect("cached lane without a slot"), lane.seq.clone())
            };
            let reused = self.cache.adopt_prefix(slot, &prompt);
            metrics.record_prefill(prompt.len(), reused);
            let suffix = prompt[reused..].to_vec();
            buckets.entry(suffix.len()).or_default().push((li, suffix));
        }
        for (&t_new, group) in &buckets {
            let slots: Vec<usize> = group
                .iter()
                .map(|(li, _)| self.lanes[*li].as_ref().unwrap().slot.unwrap())
                .collect();
            let tokens: Vec<i32> =
                group.iter().flat_map(|(_, s)| s.iter().copied()).collect();
            let h = model.forward_h_incremental(pipe, &mut self.cache, &slots, &tokens)?;
            let logits = pipe.head_decode(model.params(), &h)?;
            for (row, (li, _)) in group.iter().enumerate() {
                let base = (row * t_new + (t_new - 1)) * vocab;
                let next = Self::argmax(&logits.data[base..base + vocab]);
                let lane = self.lanes[*li].as_mut().unwrap();
                lane.seq.push(next);
                lane.prefilled = true;
            }
            // register after the forward so the pages hold the prompt K/V
            for (li, _) in group {
                let lane = self.lanes[*li].as_ref().unwrap();
                let (slot, plen) = (lane.slot.unwrap(), lane.prompt_len);
                let prompt = lane.seq[..plen].to_vec();
                self.cache.register_prefix(slot, &prompt);
            }
        }
        if !decoding.is_empty() {
            let slots: Vec<usize> = decoding
                .iter()
                .map(|&li| self.lanes[li].as_ref().unwrap().slot.unwrap())
                .collect();
            let toks: Vec<i32> = decoding
                .iter()
                .map(|&li| *self.lanes[li].as_ref().unwrap().seq.last().unwrap())
                .collect();
            let h = model.forward_h_incremental(pipe, &mut self.cache, &slots, &toks)?;
            let logits = pipe.head_decode(model.params(), &h)?;
            for (row, &li) in decoding.iter().enumerate() {
                let next = Self::argmax(&logits.data[row * vocab..(row + 1) * vocab]);
                self.lanes[li].as_mut().unwrap().seq.push(next);
            }
        }
        metrics.record_step_from(step_started, n_active, self.lanes.len());
        let now = Instant::now();
        for &li in &active {
            metrics.record_tokens(1);
            if self.lane_done(li) {
                self.finish_lane(li, now, metrics, out);
            }
        }
        Ok(())
    }

    /// One decode step on whichever path `cfg.use_kv_cache` selects.
    fn decode_step(
        &mut self,
        fixed_width: bool,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        if self.cfg.use_kv_cache {
            self.decode_step_cached(metrics, out)
        } else {
            self.decode_step_full(fixed_width, metrics, out)
        }
    }

    /// How long to sleep when requests are queued but none is admissible
    /// (page-pool backpressure with idle lanes, or a deadline/max-wait
    /// gated batcher): bounded by the batcher's own cut interval so a
    /// ready batch is picked up promptly, floored so an aggressive
    /// `max_wait` cannot turn the wait back into a hot spin.
    fn idle_backoff(batcher: &Batcher) -> Duration {
        batcher
            .max_wait
            .min(Duration::from_millis(1))
            .max(Duration::from_micros(50))
    }

    /// Continuous batching: a finished sequence's lane is refilled from
    /// the queue on the next decode step.
    pub fn run(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        self.export_memory(metrics);
        for _ in 0..self.cfg.max_steps {
            self.admit(batcher, metrics, &mut out);
            if self.active_lanes() == 0 {
                if batcher.pending() == 0 {
                    break;
                }
                // reachable only if admission is gated with every lane
                // idle — an empty pool always covers one full window, so
                // back off briefly rather than burning the step budget
                std::thread::sleep(Self::idle_backoff(batcher));
                continue;
            }
            self.decode_step(false, metrics, &mut out)?;
        }
        self.export_memory(metrics);
        Ok(out)
    }

    /// Drain (static) batching baseline: admit a full batch, decode until
    /// every lane finishes, only then take the next batch. Admission goes
    /// through the same deadline-aware `admit` as continuous mode (called
    /// only when every lane is free, which is exactly batch admission), so
    /// oversized queues and lapsed deadlines are handled per batch, not
    /// just once up front. Cache pages release at each lane's finish and
    /// are reused by the next batch.
    pub fn run_drain(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        self.export_memory(metrics);
        let mut total_steps = 0;
        while total_steps < self.cfg.max_steps {
            self.admit(batcher, metrics, &mut out);
            if self.active_lanes() == 0 {
                break;
            }
            while self.active_lanes() > 0 && total_steps < self.cfg.max_steps {
                self.decode_step(true, metrics, &mut out)?;
                total_steps += 1;
            }
        }
        self.export_memory(metrics);
        Ok(out)
    }

    /// One-shot drain over an explicit request list (the legacy
    /// `generate_batch` contract): responses in request order.
    pub fn run_drain_batch(
        &mut self,
        requests: &[GenRequest],
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        assert!(requests.len() <= self.capacity(), "batch too wide");
        let mut batcher = Batcher::new(self.capacity());
        for r in requests {
            batcher.submit(r.clone());
        }
        let mut out = self.run_drain(&mut batcher, metrics)?;
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}
