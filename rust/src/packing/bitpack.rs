//! 1-bit packing: sign matrices and channel bitmaps as u64 words.
//! This is the container a real sub-2-bit deployment ships; the fake-quant
//! eval path round-trips through it in tests to prove the dense and packed
//! representations agree bit-for-bit.

#[derive(Debug, Clone, PartialEq)]
pub struct BitVec {
    pub len: usize,
    words: Vec<u64>,
}

impl BitVec {
    pub fn zeros(len: usize) -> BitVec {
        BitVec { len, words: vec![0; len.div_ceil(64)] }
    }

    pub fn from_bools(bits: &[bool]) -> BitVec {
        let mut v = BitVec::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                v.set(i, true);
            }
        }
        v
    }

    /// Pack the sign pattern of a float slice (>= 0 -> 1).
    pub fn from_signs(xs: &[f32]) -> BitVec {
        let mut v = BitVec::zeros(xs.len());
        for (i, &x) in xs.iter().enumerate() {
            if x >= 0.0 {
                v.set(i, true);
            }
        }
        v
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, b: bool) {
        debug_assert!(i < self.len);
        let (w, o) = (i / 64, i % 64);
        if b {
            self.words[w] |= 1 << o;
        } else {
            self.words[w] &= !(1 << o);
        }
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Unpack to +-1.0 floats (sign reconstruction).
    pub fn to_signs(&self) -> Vec<f32> {
        (0..self.len)
            .map(|i| if self.get(i) { 1.0 } else { -1.0 })
            .collect()
    }

    pub fn to_bools(&self) -> Vec<bool> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// The raw 64-bit words, low bit first. Bits at or beyond `len` are
    /// always zero, so word-wise consumers (the packed matvec kernel's
    /// ±1 accumulation) never see phantom set bits in the tail.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Storage in bits (what the accounting layer charges).
    pub fn storage_bits(&self) -> usize {
        self.len
    }

    pub fn storage_bytes_padded(&self) -> usize {
        self.words.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn set_get_round_trip() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(64, true);
        v.set(129, true);
        assert!(v.get(0) && v.get(64) && v.get(129));
        assert!(!v.get(1) && !v.get(63) && !v.get(128));
        assert_eq!(v.count_ones(), 3);
    }

    #[test]
    fn signs_round_trip_property() {
        check(
            "bitpack-sign-roundtrip",
            60,
            |r: &mut Rng| {
                let n = r.below(300) + 1;
                (0..n).map(|_| r.normal()).collect::<Vec<f32>>()
            },
            |xs| {
                let v = BitVec::from_signs(xs);
                let back = v.to_signs();
                for (x, s) in xs.iter().zip(&back) {
                    let want = if *x >= 0.0 { 1.0 } else { -1.0 };
                    if *s != want {
                        return Err(format!("{x} -> {s}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bools_round_trip() {
        let bits: Vec<bool> =
            (0..97).map(|i| i % 3 == 0 || i % 7 == 0).collect();
        assert_eq!(BitVec::from_bools(&bits).to_bools(), bits);
    }

    #[test]
    fn storage_accounting() {
        let v = BitVec::zeros(4096);
        assert_eq!(v.storage_bits(), 4096);
        assert_eq!(v.storage_bytes_padded(), 4096 / 8);
    }
}
