//! Native pure-Rust executor for the AOT artifact contract.
//!
//! Implements every artifact base the coordinator drives — the forward
//! passes (`embed_fwd`, `block_fwd`, `block_capture`, `qblock_fwd`,
//! `qblock_w4a4_fwd`, `head_fwd`), the KV-cached incremental-decode
//! variants (`embed_fwd_decode`, `block_fwd_decode`, `qblock_fwd_decode`,
//! `qblock_w4a4_fwd_decode`, `head_fwd_decode`), and the three gradient
//! executables (`lm_grad`, `lora_grad`, `block_opt_grad`) — with
//! semantics matching python/compile/model.py one for one. Full-window
//! graphs are built on the autodiff tape (runtime::autodiff); the decode
//! variants are forward-only and run the tape ops' factored-out forward
//! kernels directly, which keeps cached decode bit-identical to the
//! full-window path (dense and PTQ1.61-fused; see `block_decode` below).
//! This is what lets the repo build, test, and *serve* without an XLA
//! toolchain; a PJRT path can slot back in behind the same
//! `Runtime::run` contract.

use anyhow::{bail, Result};

use super::autodiff::{
    attn_decode, linear_fwd, qlinear_fwd, rmsnorm_fwd, rope_at, silu_mul_fwd,
    NodeId, Tape, ROPE_THETA,
};
use super::manifest::{ArtifactSpec, ModelConfig};
use super::Value;
use crate::model::LINEARS;
use crate::quant::{ArcContainer, PackedContainer};
use crate::tensor::Tensor;

/// Offsets of the 7 block linears inside the 9-tensor block parameter list
/// (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down).
const LINEAR_OFFSETS: [usize; 7] = [1, 2, 3, 4, 6, 7, 8];

fn tensor_of(v: &Value) -> Result<&Tensor> {
    match v {
        Value::F32(t) => Ok(t),
        Value::I32(..) => bail!("expected f32 tensor input"),
    }
}

fn tokens_of(v: &Value) -> Result<(&[usize], &[i32])> {
    match v {
        Value::I32(s, d) => Ok((s, d)),
        Value::F32(_) => bail!("expected i32 token input"),
    }
}

/// How one block linear is evaluated inside the shared block graph.
enum Lin<'a> {
    /// FP or dense-dequantized weight (a tape node, so grads can flow).
    Dense(NodeId),
    /// PTQ1.61 fused reconstruction (Eq. 9) with learnable scaling factors.
    Quant {
        a_s: NodeId,
        r1: NodeId,
        r2: NodeId,
        mu: NodeId,
        w_sal: &'a Tensor,
        sign: &'a Tensor,
    },
    /// SmoothQuant W4A4 fake-quant linear (forward-only, Table 13).
    W4A4 { w: &'a Tensor, smooth: &'a Tensor },
}

fn apply_lin(tp: &mut Tape, x: NodeId, lin: &Lin) -> NodeId {
    match lin {
        Lin::Dense(w) => tp.linear(x, *w),
        Lin::Quant { a_s, r1, r2, mu, w_sal, sign } => {
            tp.qlinear(x, *a_s, *r1, *r2, *mu, w_sal, sign)
        }
        Lin::W4A4 { w, smooth } => {
            let y = w4a4_linear(tp.val(x), w, smooth);
            tp.input(y)
        }
    }
}

/// SmoothQuant W4A4 fake-quant linear: migrate outliers via `smooth`, then
/// 4-bit symmetric quantization — activations per-tensor, weights per
/// output row (quant_ops.w4a4_linear).
fn w4a4_linear(x: &Tensor, w: &Tensor, smooth: &Tensor) -> Tensor {
    let inn = *x.shape.last().unwrap();
    let rows = x.numel() / inn;
    let out = w.shape[0];
    let qmax = 7.0f32;
    let mut xs = x.clone();
    for r in 0..rows {
        let xr = &mut xs.data[r * inn..(r + 1) * inn];
        for (v, s) in xr.iter_mut().zip(&smooth.data) {
            *v /= s;
        }
    }
    let amax = xs.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let xscale = (amax / qmax).max(1e-8);
    for v in xs.data.iter_mut() {
        *v = (*v / xscale).round().clamp(-qmax, qmax) * xscale;
    }
    let mut wq = w.clone();
    for o in 0..out {
        let row = wq.row_mut(o);
        for (v, s) in row.iter_mut().zip(&smooth.data) {
            *v *= s;
        }
        let wmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let wscale = (wmax / qmax).max(1e-8);
        for v in row.iter_mut() {
            *v = (*v / wscale).round().clamp(-qmax, qmax) * wscale;
        }
    }
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    for r in 0..rows {
        let xr = &xs.data[r * inn..(r + 1) * inn];
        let yr = &mut y.data[r * out..(r + 1) * out];
        for (o, yo) in yr.iter_mut().enumerate() {
            let wr = &wq.data[o * inn..(o + 1) * inn];
            *yo = xr.iter().zip(wr).map(|(a, b)| a * b).sum();
        }
    }
    y
}

/// Forward-only view of one block linear for the decode kernels — the
/// tape-free counterpart of [`Lin`].
enum LinFwd<'a> {
    /// FP or dense-dequantized weight.
    Dense(&'a Tensor),
    /// PTQ1.61 fused reconstruction (Eq. 9).
    Quant {
        a_s: &'a Tensor,
        r1: &'a Tensor,
        r2: &'a Tensor,
        mu: &'a Tensor,
        w_sal: &'a Tensor,
        sign: &'a Tensor,
    },
    /// SmoothQuant W4A4 fake-quant linear.
    W4A4 { w: &'a Tensor, smooth: &'a Tensor },
    /// Prepared packed container, any method (no per-step reconstruction;
    /// the container's own decode kernel runs).
    Packed(&'a dyn PackedContainer),
}

fn apply_lin_fwd(x: &Tensor, lin: &LinFwd) -> Tensor {
    match lin {
        LinFwd::Dense(w) => linear_fwd(x, w),
        LinFwd::Quant { a_s, r1, r2, mu, w_sal, sign } => {
            qlinear_fwd(x, a_s, r1, r2, mu, w_sal, sign)
        }
        LinFwd::W4A4 { w, smooth } => w4a4_linear(x, w, smooth),
        LinFwd::Packed(c) => c.decode_fwd(x),
    }
}

/// One transformer block over `t_new` *new* positions against cached K/V
/// (the `*_decode` bases). `h_new` is `(b, t_new, d)`, `k_cache`/`v_cache`
/// are `(b, capacity, n_heads, head_dim)` with `lens[bi]` valid cached
/// positions per lane; lane `bi`'s new row `j` sits at absolute position
/// `lens[bi] + j`. Returns `[h_out, k_new, v_new]` where `k_new` is the
/// *roped* keys of the new positions — the cache stores post-rope keys so
/// a cached position is never re-rotated.
///
/// The position-local pieces (rmsnorm, linears, SwiGLU, residuals) and the
/// attention accumulation run the same kernels in the same order as the
/// full-window tape graph, so dense and PTQ1.61-fused decode are
/// bit-identical to re-running the whole window. The W4A4 path is the one
/// documented exception: its activation scale is per-forward-call, so a
/// decode step quantizes over the new chunk only (numerically close, not
/// bit-equal, to the full-window fake-quant).
fn block_decode(
    cfg: &ModelConfig,
    h_new: &Tensor,
    k_cache: &Tensor,
    v_cache: &Tensor,
    lens: &[usize],
    attn_norm: &Tensor,
    mlp_norm: &Tensor,
    lins: &[LinFwd],
) -> Result<Vec<Tensor>> {
    assert_eq!(lins.len(), LINEARS.len());
    let (b, tn, d) = (h_new.shape[0], h_new.shape[1], h_new.shape[2]);
    let nh = cfg.n_heads;
    let hd = d / nh;
    if lens.len() != b {
        bail!("block_decode: {} lens for batch {b}", lens.len());
    }
    let cap = k_cache.shape[1];
    for &l in lens {
        if l + tn > cap {
            bail!("block_decode: {l} cached + {tn} new > window {cap}");
        }
    }
    let x_attn = rmsnorm_fwd(h_new, attn_norm);
    let q = apply_lin_fwd(&x_attn, &lins[0]).reshape(&[b, tn, nh, hd]);
    let k = apply_lin_fwd(&x_attn, &lins[1]).reshape(&[b, tn, nh, hd]);
    let v = apply_lin_fwd(&x_attn, &lins[2]).reshape(&[b, tn, nh, hd]);
    let qr = rope_at(&q, lens, ROPE_THETA);
    let kr = rope_at(&k, lens, ROPE_THETA);
    let ctx = attn_decode(&qr, &kr, &v, k_cache, v_cache, lens);
    let x_o = ctx.reshape(&[b, tn, d]);
    let attn_out = apply_lin_fwd(&x_o, &lins[3]);
    let h2 = h_new.add(&attn_out);
    let x_mlp = rmsnorm_fwd(&h2, mlp_norm);
    let gate = apply_lin_fwd(&x_mlp, &lins[4]);
    let up = apply_lin_fwd(&x_mlp, &lins[5]);
    let x_down = silu_mul_fwd(&gate, &up);
    let down = apply_lin_fwd(&x_down, &lins[6]);
    let h_out = h2.add(&down);
    Ok(vec![h_out, kr, v])
}

/// One transformer block over new positions with every linear served from
/// its prepared [`PackedContainer`] — the packed-backend entry the
/// pipeline calls directly, for any method with a container impl (packed
/// containers are host structures, not manifest `Value`s, so this path
/// bypasses the artifact marshalling; the attention/norm/residual kernels
/// and their ordering are exactly `block_decode`'s). `layer` holds one
/// container per block linear in `LINEARS` order.
pub fn packed_block_decode(
    cfg: &ModelConfig,
    h_new: &Tensor,
    k_cache: &Tensor,
    v_cache: &Tensor,
    lens: &[usize],
    attn_norm: &Tensor,
    mlp_norm: &Tensor,
    layer: &[ArcContainer],
) -> Result<Vec<Tensor>> {
    if layer.len() != LINEARS.len() {
        bail!(
            "packed_block_decode: {} linears, want {}",
            layer.len(),
            LINEARS.len()
        );
    }
    let lins: Vec<LinFwd> =
        layer.iter().map(|c| LinFwd::Packed(c.as_ref())).collect();
    block_decode(cfg, h_new, k_cache, v_cache, lens, attn_norm, mlp_norm, &lins)
}

/// Decode the `pos` input (per-lane valid cache lengths) of a `*_decode`
/// artifact.
fn lens_of(v: &Value) -> Result<Vec<usize>> {
    let (_, pos) = tokens_of(v)?;
    Ok(pos.iter().map(|&p| p.max(0) as usize).collect())
}

struct BlockIo {
    x_attn: NodeId,
    x_o: NodeId,
    x_mlp: NodeId,
    x_down: NodeId,
    h_out: NodeId,
}

/// The shared transformer-block body (model.py `_block_pieces`): returns
/// the four linear-input capture points plus the block output.
fn block_graph(
    tp: &mut Tape,
    cfg: &ModelConfig,
    h: NodeId,
    attn_norm: NodeId,
    mlp_norm: NodeId,
    lins: &[Lin],
) -> BlockIo {
    assert_eq!(lins.len(), LINEARS.len());
    let shape = tp.val(h).shape.clone();
    let (b, t, d) = (shape[0], shape[1], shape[2]);
    let nh = cfg.n_heads;
    let hd = d / nh;
    let x_attn = tp.rmsnorm(h, attn_norm);
    let q = apply_lin(tp, x_attn, &lins[0]);
    let k = apply_lin(tp, x_attn, &lins[1]);
    let v = apply_lin(tp, x_attn, &lins[2]);
    let q4 = tp.reshape(q, &[b, t, nh, hd]);
    let k4 = tp.reshape(k, &[b, t, nh, hd]);
    let v4 = tp.reshape(v, &[b, t, nh, hd]);
    let qr = tp.rope(q4, ROPE_THETA);
    let kr = tp.rope(k4, ROPE_THETA);
    let s = tp.attn_scores(qr, kr);
    let p = tp.causal_softmax(s);
    let ctx = tp.attn_ctx(p, v4);
    let x_o = tp.reshape(ctx, &[b, t, d]);
    let attn_out = apply_lin(tp, x_o, &lins[3]);
    let h2 = tp.add(h, attn_out);
    let x_mlp = tp.rmsnorm(h2, mlp_norm);
    let gate = apply_lin(tp, x_mlp, &lins[4]);
    let up = apply_lin(tp, x_mlp, &lins[5]);
    let sg = tp.silu(gate);
    let x_down = tp.mul(sg, up);
    let down = apply_lin(tp, x_down, &lins[6]);
    let h_out = tp.add(h2, down);
    BlockIo { x_attn, x_o, x_mlp, x_down, h_out }
}

/// Final norm + head: (nll node, logits node).
fn head_graph(
    tp: &mut Tape,
    h: NodeId,
    norm_f: NodeId,
    w_out: NodeId,
    tokens: &[i32],
    b: usize,
    t: usize,
) -> (NodeId, NodeId) {
    let xn = tp.rmsnorm(h, norm_f);
    let logits = tp.linear(xn, w_out);
    let nll = tp.nll_sum(logits, tokens, b, t);
    (nll, logits)
}

/// Number of full-model parameter tensors (embed + 9/block + norm_f +
/// w_out) — the lm_grad/lora_grad input prefix.
fn n_params(cfg: &ModelConfig) -> usize {
    9 * cfg.n_layers + 3
}

/// Execute one artifact natively. Shapes were validated against the
/// manifest by `Runtime::run` (with a flexible leading batch dimension);
/// batch sizes are re-derived here from the actual inputs.
pub fn execute(spec: &ArtifactSpec, cfg: &ModelConfig, inputs: &[Value]) -> Result<Vec<Tensor>> {
    match spec.base.as_str() {
        // embed_fwd_decode is the same gather, just over a (b, t_new)
        // chunk instead of the full (b_eval, seq) window
        "embed_fwd" | "embed_fwd_decode" => {
            let (tshape, toks) = tokens_of(&inputs[0])?;
            let embed = tensor_of(&inputs[1])?;
            let (b, t) = (tshape[0], tshape[1]);
            let mut tp = Tape::new();
            let e = tp.input(embed.clone());
            let h = tp.gather(e, toks, b, t);
            Ok(vec![tp.val(h).clone()])
        }
        "block_fwd" | "block_capture" => {
            let mut tp = Tape::new();
            let hid = tp.input(tensor_of(&inputs[0])?.clone());
            let mut ids = Vec::with_capacity(9);
            for v in &inputs[1..10] {
                let t = tensor_of(v)?.clone();
                ids.push(tp.input(t));
            }
            let lins: Vec<Lin> =
                LINEAR_OFFSETS.iter().map(|&o| Lin::Dense(ids[o])).collect();
            let io = block_graph(&mut tp, cfg, hid, ids[0], ids[5], &lins);
            if spec.base == "block_fwd" {
                Ok(vec![tp.val(io.h_out).clone()])
            } else {
                Ok(vec![
                    tp.val(io.x_attn).clone(),
                    tp.val(io.x_o).clone(),
                    tp.val(io.x_mlp).clone(),
                    tp.val(io.x_down).clone(),
                    tp.val(io.h_out).clone(),
                ])
            }
        }
        "qblock_fwd" => {
            if inputs.len() != 3 + 6 * LINEARS.len() {
                bail!("qblock_fwd wants {} inputs", 3 + 6 * LINEARS.len());
            }
            let mut tp = Tape::new();
            let hid = tp.input(tensor_of(&inputs[0])?.clone());
            let an = tp.input(tensor_of(&inputs[1])?.clone());
            let mn = tp.input(tensor_of(&inputs[2])?.clone());
            let mut lins: Vec<Lin> = Vec::with_capacity(LINEARS.len());
            for j in 0..LINEARS.len() {
                let base = 3 + 6 * j;
                let w_sal = tensor_of(&inputs[base])?;
                let sign = tensor_of(&inputs[base + 1])?;
                let a_s = tp.input(tensor_of(&inputs[base + 2])?.clone());
                let r1 = tp.input(tensor_of(&inputs[base + 3])?.clone());
                let r2 = tp.input(tensor_of(&inputs[base + 4])?.clone());
                let mu = tp.input(tensor_of(&inputs[base + 5])?.clone());
                lins.push(Lin::Quant { a_s, r1, r2, mu, w_sal, sign });
            }
            let io = block_graph(&mut tp, cfg, hid, an, mn, &lins);
            Ok(vec![tp.val(io.h_out).clone()])
        }
        "qblock_w4a4_fwd" => {
            if inputs.len() != 14 {
                bail!("qblock_w4a4_fwd wants 14 inputs");
            }
            let mut tp = Tape::new();
            let hid = tp.input(tensor_of(&inputs[0])?.clone());
            let an = tp.input(tensor_of(&inputs[1])?.clone());
            let mn = tp.input(tensor_of(&inputs[6])?.clone());
            // q/k/v share s_attn, gate/up share s_mlp (aot.py w4a4_fn)
            let smooth_idx = [10, 10, 10, 11, 12, 12, 13];
            let mut lins: Vec<Lin> = Vec::with_capacity(LINEARS.len());
            for j in 0..LINEARS.len() {
                lins.push(Lin::W4A4 {
                    // block params occupy inputs[1..10]; offsets are 0-based
                    w: tensor_of(&inputs[1 + LINEAR_OFFSETS[j]])?,
                    smooth: tensor_of(&inputs[smooth_idx[j]])?,
                });
            }
            let io = block_graph(&mut tp, cfg, hid, an, mn, &lins);
            Ok(vec![tp.val(io.h_out).clone()])
        }
        "block_fwd_decode" => {
            if inputs.len() != 13 {
                bail!("block_fwd_decode wants 13 inputs");
            }
            let h = tensor_of(&inputs[0])?;
            let kc = tensor_of(&inputs[1])?;
            let vc = tensor_of(&inputs[2])?;
            let lens = lens_of(&inputs[3])?;
            let blk: Vec<&Tensor> =
                inputs[4..13].iter().map(tensor_of).collect::<Result<_>>()?;
            let lins: Vec<LinFwd> =
                LINEAR_OFFSETS.iter().map(|&o| LinFwd::Dense(blk[o])).collect();
            block_decode(cfg, h, kc, vc, &lens, blk[0], blk[5], &lins)
        }
        "qblock_fwd_decode" => {
            if inputs.len() != 6 + 6 * LINEARS.len() {
                bail!("qblock_fwd_decode wants {} inputs", 6 + 6 * LINEARS.len());
            }
            let h = tensor_of(&inputs[0])?;
            let kc = tensor_of(&inputs[1])?;
            let vc = tensor_of(&inputs[2])?;
            let lens = lens_of(&inputs[3])?;
            let an = tensor_of(&inputs[4])?;
            let mn = tensor_of(&inputs[5])?;
            let mut lins: Vec<LinFwd> = Vec::with_capacity(LINEARS.len());
            for j in 0..LINEARS.len() {
                let base = 6 + 6 * j;
                lins.push(LinFwd::Quant {
                    w_sal: tensor_of(&inputs[base])?,
                    sign: tensor_of(&inputs[base + 1])?,
                    a_s: tensor_of(&inputs[base + 2])?,
                    r1: tensor_of(&inputs[base + 3])?,
                    r2: tensor_of(&inputs[base + 4])?,
                    mu: tensor_of(&inputs[base + 5])?,
                });
            }
            block_decode(cfg, h, kc, vc, &lens, an, mn, &lins)
        }
        "qblock_w4a4_fwd_decode" => {
            if inputs.len() != 17 {
                bail!("qblock_w4a4_fwd_decode wants 17 inputs");
            }
            let h = tensor_of(&inputs[0])?;
            let kc = tensor_of(&inputs[1])?;
            let vc = tensor_of(&inputs[2])?;
            let lens = lens_of(&inputs[3])?;
            let an = tensor_of(&inputs[4])?;
            let mn = tensor_of(&inputs[9])?;
            // q/k/v share s_attn, gate/up share s_mlp (aot.py w4a4_fn);
            // block params occupy inputs[4..13], smooth vectors 13..17
            let smooth_idx = [13, 13, 13, 14, 15, 15, 16];
            let mut lins: Vec<LinFwd> = Vec::with_capacity(LINEARS.len());
            for j in 0..LINEARS.len() {
                lins.push(LinFwd::W4A4 {
                    w: tensor_of(&inputs[4 + LINEAR_OFFSETS[j]])?,
                    smooth: tensor_of(&inputs[smooth_idx[j]])?,
                });
            }
            block_decode(cfg, h, kc, vc, &lens, an, mn, &lins)
        }
        "head_fwd_decode" => {
            // final norm + output projection only: decode wants logits for
            // the new positions, never the window NLL
            let h = tensor_of(&inputs[0])?;
            let nf = tensor_of(&inputs[1])?;
            let wo = tensor_of(&inputs[2])?;
            Ok(vec![linear_fwd(&rmsnorm_fwd(h, nf), wo)])
        }
        "head_fwd" => {
            let h = tensor_of(&inputs[0])?;
            let (b, t) = (h.shape[0], h.shape[1]);
            let (tshape, toks) = tokens_of(&inputs[3])?;
            if tshape[0] != b || tshape[1] != t {
                bail!("head_fwd: h batch {b}x{t} vs tokens {tshape:?}");
            }
            let mut tp = Tape::new();
            let hid = tp.input(h.clone());
            let nf = tp.input(tensor_of(&inputs[1])?.clone());
            let wo = tp.input(tensor_of(&inputs[2])?.clone());
            let (nll, logits) = head_graph(&mut tp, hid, nf, wo, toks, b, t);
            Ok(vec![tp.val(nll).clone(), tp.val(logits).clone()])
        }
        "lm_grad" => {
            let n = n_params(cfg);
            if inputs.len() != n + 1 {
                bail!("lm_grad wants {} inputs, got {}", n + 1, inputs.len());
            }
            let (tshape, toks) = tokens_of(&inputs[n])?;
            let (b, t) = (tshape[0], tshape[1]);
            let mut tp = Tape::new();
            let mut ids = Vec::with_capacity(n);
            for v in &inputs[..n] {
                let tv = tensor_of(v)?.clone();
                ids.push(tp.input(tv));
            }
            let mut h = tp.gather(ids[0], toks, b, t);
            for l in 0..cfg.n_layers {
                let base = 1 + 9 * l;
                let lins: Vec<Lin> = LINEAR_OFFSETS
                    .iter()
                    .map(|&o| Lin::Dense(ids[base + o]))
                    .collect();
                let io = block_graph(&mut tp, cfg, h, ids[base], ids[base + 5], &lins);
                h = io.h_out;
            }
            let (nll, _) = head_graph(&mut tp, h, ids[n - 2], ids[n - 1], toks, b, t);
            let loss = tp.scale(nll, 1.0 / (b * (t - 1)) as f32);
            let grads = tp.backward(loss);
            let mut out = Vec::with_capacity(n + 1);
            out.push(tp.val(loss).clone());
            for (i, &id) in ids.iter().enumerate() {
                let shape = tensor_of(&inputs[i])?.shape.clone();
                out.push(Tape::grad(&grads, id, &shape));
            }
            Ok(out)
        }
        "lora_grad" => {
            let n = n_params(cfg);
            let nlin = cfg.n_layers * LINEARS.len();
            if inputs.len() != n + 3 * nlin + 1 {
                bail!("lora_grad wants {} inputs, got {}", n + 3 * nlin + 1, inputs.len());
            }
            let (tshape, toks) = tokens_of(&inputs[n + 3 * nlin])?;
            let (b, t) = (tshape[0], tshape[1]);
            let mut tp = Tape::new();
            let mut pids = Vec::with_capacity(n);
            for v in &inputs[..n] {
                let tv = tensor_of(v)?.clone();
                pids.push(tp.input(tv));
            }
            let mut ab_ids = Vec::with_capacity(2 * nlin);
            for v in &inputs[n..n + 2 * nlin] {
                let tv = tensor_of(v)?.clone();
                ab_ids.push(tp.input(tv));
            }
            let inv_r = 1.0 / cfg.lora_rank as f32;
            let mut h = tp.gather(pids[0], toks, b, t);
            for l in 0..cfg.n_layers {
                let base = 1 + 9 * l;
                let mut lins: Vec<Lin> = Vec::with_capacity(LINEARS.len());
                for (j, &off) in LINEAR_OFFSETS.iter().enumerate() {
                    let idx = l * LINEARS.len() + j;
                    let ba = tp.matmul2d(ab_ids[2 * idx + 1], ab_ids[2 * idx]);
                    let delta = tp.scale(ba, inv_r);
                    let w_eff = tp.add(pids[base + off], delta);
                    let mask_t = tensor_of(&inputs[n + 2 * nlin + idx])?;
                    let mask: Vec<bool> = mask_t.data.iter().map(|&x| x > 0.5).collect();
                    let wq = tp.ste_quant(w_eff, mask);
                    lins.push(Lin::Dense(wq));
                }
                let io = block_graph(&mut tp, cfg, h, pids[base], pids[base + 5], &lins);
                h = io.h_out;
            }
            let (nll, _) = head_graph(&mut tp, h, pids[n - 2], pids[n - 1], toks, b, t);
            let loss = tp.scale(nll, 1.0 / (b * (t - 1)) as f32);
            let grads = tp.backward(loss);
            let mut out = Vec::with_capacity(1 + 2 * nlin);
            out.push(tp.val(loss).clone());
            for (i, &id) in ab_ids.iter().enumerate() {
                let shape = tensor_of(&inputs[n + i])?.shape.clone();
                out.push(Tape::grad(&grads, id, &shape));
            }
            Ok(out)
        }
        "block_opt_grad" => {
            let nl = LINEARS.len();
            let want = 4 * nl + 5 + 2 * nl + 1;
            if inputs.len() != want {
                bail!("block_opt_grad wants {want} inputs, got {}", inputs.len());
            }
            let mut tp = Tape::new();
            let mut learn_ids = Vec::with_capacity(4 * nl);
            for v in &inputs[..4 * nl] {
                let tv = tensor_of(v)?.clone();
                learn_ids.push(tp.input(tv));
            }
            let xq = tp.input(tensor_of(&inputs[4 * nl])?.clone());
            let f1 = tensor_of(&inputs[4 * nl + 1])?;
            let f3 = tensor_of(&inputs[4 * nl + 2])?;
            let an = tp.input(tensor_of(&inputs[4 * nl + 3])?.clone());
            let mn = tp.input(tensor_of(&inputs[4 * nl + 4])?.clone());
            let consts_base = 4 * nl + 5;
            let nlc_w = tensor_of(&inputs[consts_base + 2 * nl])?.data[0];
            let mut lins: Vec<Lin> = Vec::with_capacity(nl);
            for j in 0..nl {
                lins.push(Lin::Quant {
                    a_s: learn_ids[4 * j],
                    r1: learn_ids[4 * j + 1],
                    r2: learn_ids[4 * j + 2],
                    mu: learn_ids[4 * j + 3],
                    w_sal: tensor_of(&inputs[consts_base + 2 * j])?,
                    sign: tensor_of(&inputs[consts_base + 2 * j + 1])?,
                });
            }
            let io = block_graph(&mut tp, cfg, xq, an, mn, &lins);
            let d1 = tp.distance(io.h_out, f1, nlc_w);
            let d2 = tp.distance(io.h_out, f3, nlc_w);
            let loss = tp.add(d1, d2);
            let grads = tp.backward(loss);
            let mut out = Vec::with_capacity(1 + 4 * nl);
            out.push(tp.val(loss).clone());
            for (i, &id) in learn_ids.iter().enumerate() {
                let shape = tensor_of(&inputs[i])?.shape.clone();
                out.push(Tape::grad(&grads, id, &shape));
            }
            Ok(out)
        }
        other => bail!("native backend: unknown artifact base '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn w4a4_quantizes_both_sides() {
        let x = Tensor::from_vec(&[1, 2, 3], vec![1.0, -2.0, 0.5, 8.0, 0.1, -0.3]);
        let w = Tensor::from_vec(&[2, 3], vec![0.5, 0.2, -0.1, 1.0, -1.0, 0.25]);
        let smooth = Tensor::from_vec(&[3], vec![1.0, 1.0, 1.0]);
        let y = w4a4_linear(&x, &w, &smooth);
        assert_eq!(y.shape, vec![1, 2, 2]);
        // quantization is lossy but bounded: compare against FP product
        let fp = x.clone().reshape(&[2, 3]).matmul(&w.t());
        for (a, b) in y.data.iter().zip(&fp.data) {
            assert!((a - b).abs() < 2.0, "{a} vs {b}");
        }
    }

    #[test]
    fn linear_offsets_match_block_layout() {
        // block params: attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up,
        // w_down — offsets must select the 7 linears in LINEARS order
        let names = crate::model::block_param_names(0);
        for (j, &off) in LINEAR_OFFSETS.iter().enumerate() {
            assert_eq!(names[off], format!("l0.{}", LINEARS[j]));
        }
    }

    #[test]
    fn packed_block_decode_matches_fused_block_decode() {
        // one block, empty cache: the packed containers must reproduce the
        // fused (reconstruct-Wq') block to float-roundoff accuracy
        use crate::quant::ptq161::{initial_parts, PackedLinear};
        use crate::util::rng::Rng;
        let cfg = crate::runtime::Manifest::builtin().configs["micro"].clone();
        let (b, t, d, ffn) = (1, 4, cfg.d, cfg.ffn);
        let mut rng = Rng::new(91);
        let h = Tensor::randn(&[b, t, d], 1.0, &mut rng);
        let kc = Tensor::zeros(&[b, cfg.seq, cfg.n_heads, d / cfg.n_heads]);
        let vc = kc.clone();
        let an = Tensor::ones(&[d]);
        let mn = Tensor::ones(&[d]);
        let shapes = [(d, d), (d, d), (d, d), (d, d), (ffn, d), (ffn, d), (d, ffn)];
        let parts: Vec<_> = shapes
            .iter()
            .map(|&(o, i)| {
                let w = Tensor::randn(&[o, i], 0.2, &mut rng);
                let mask: Vec<bool> = (0..i).map(|j| j % 4 == 0).collect();
                initial_parts(&w, &mask)
            })
            .collect();
        let packed: Vec<ArcContainer> = parts
            .iter()
            .map(|p| std::sync::Arc::new(PackedLinear::pack(p)) as ArcContainer)
            .collect();
        let lens = vec![0usize; b];
        let vecs: Vec<(Tensor, Tensor, Tensor, Tensor)> = parts
            .iter()
            .map(|p| {
                let out = p.alpha_s.len();
                let inn = p.alpha_r2.len();
                (
                    Tensor::from_vec(&[out], p.alpha_s.clone()),
                    Tensor::from_vec(&[out], p.alpha_r1.clone()),
                    Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                    Tensor::from_vec(&[out], p.mu.clone()),
                )
            })
            .collect();
        let lins: Vec<LinFwd> = parts
            .iter()
            .zip(&vecs)
            .map(|(p, v)| LinFwd::Quant {
                a_s: &v.0,
                r1: &v.1,
                r2: &v.2,
                mu: &v.3,
                w_sal: &p.w_sal,
                sign: &p.sign_ns,
            })
            .collect();
        let fused =
            block_decode(&cfg, &h, &kc, &vc, &lens, &an, &mn, &lins).unwrap();
        let via_packed =
            packed_block_decode(&cfg, &h, &kc, &vc, &lens, &an, &mn, &packed)
                .unwrap();
        for (a, e) in via_packed.iter().zip(&fused) {
            assert_eq!(a.shape, e.shape);
            let m = a.mse(e);
            assert!(m < 1e-9, "packed deviates from fused: mse {m}");
        }
    }
}
