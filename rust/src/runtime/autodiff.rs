//! Reverse-mode autodiff tape over host tensors, plus the forward-only
//! incremental-decode kernels.
//!
//! The native backend (runtime::native) builds every model graph — forward
//! *and* the three gradient artifacts (`lm_grad`, `lora_grad`,
//! `block_opt_grad`) — out of the ops defined here, so the whole system
//! runs without an XLA toolchain. Each op computes eagerly on push and
//! registers a backward closure capturing exactly the values it needs;
//! `Tape::backward` walks the (already topologically ordered) tape in
//! reverse accumulating gradients per node.
//!
//! The free functions at the bottom ([`linear_fwd`], [`rmsnorm_fwd`],
//! [`qlinear_fwd`], [`rope_at`], [`attn_decode`], [`silu_mul_fwd`]) are
//! the KV-cached decode kernels: tape-free forwards whose math is shared
//! with (or bit-identical to) the corresponding tape ops, which is what
//! makes cached decode token-identical to the full-window path.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::quant::ptq161::PackedLinear;
use crate::runtime::{pool, simd};
use crate::tensor::Tensor;

/// RMSNorm variance epsilon (matches python/compile/model.py).
pub const EPS: f32 = 1e-5;
/// Rotary-embedding base frequency (matches python/compile/model.py).
pub const ROPE_THETA: f32 = 10000.0;

/// Lifetime count of dense `Wq'` reconstructions (every [`qlinear_fwd`] /
/// [`Tape::qlinear`] call pays one). The packed decode path must leave
/// this flat across a whole serve run — `tests/packed_serve.rs` and
/// `bench_serve` gate on the delta being zero.
static QLINEAR_RECONSTRUCTIONS: AtomicU64 = AtomicU64::new(0);

/// Read the reconstruction counter (monotone; diff two reads to count an
/// interval).
pub fn qlinear_weight_reconstructions() -> u64 {
    QLINEAR_RECONSTRUCTIONS.load(Ordering::Relaxed)
}

thread_local! {
    /// Nanoseconds this thread has spent inside the decode-path matvec
    /// kernels (dense, fused and packed), measured around the whole
    /// dispatch — pool chunk time is covered because the submitting
    /// thread blocks until every chunk finishes. Thread-local so each
    /// sharded engine worker attributes only its own kernel time; the
    /// engine diffs two reads around a run and exports the per-step
    /// kernel share in the metrics JSON.
    static KERNEL_NANOS: Cell<u64> = const { Cell::new(0) };
}

/// This thread's cumulative decode-kernel time (monotone; diff two
/// reads to measure an interval).
pub fn kernel_nanos() -> u64 {
    KERNEL_NANOS.with(|c| c.get())
}

/// Run `f`, charging its wall time to this thread's kernel counter.
pub(crate) fn time_kernel<T>(f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let y = f();
    KERNEL_NANOS.with(|c| c.set(c.get() + t0.elapsed().as_nanos() as u64));
    y
}

/// Read `PTQ161_FORCE_SCALAR` dynamically — per dispatch call, not
/// cached — so in-process tests can toggle the fallback path.
pub(crate) fn force_scalar() -> bool {
    std::env::var("PTQ161_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false)
}

/// The kernel tier [`packed_decode_fwd`] will run right now: `"scalar"`,
/// `"blocked"`, `"avx2"` or `"neon"`. Resolution order:
/// `PTQ161_FORCE_SCALAR=1` forces the scalar oracle, then
/// `PTQ161_KERNEL=scalar|blocked|simd` overrides, then runtime ISA
/// detection picks the SIMD tier with the blocked kernel as fallback.
pub fn kernel_tier() -> &'static str {
    if force_scalar() {
        return "scalar";
    }
    match std::env::var("PTQ161_KERNEL").ok().as_deref() {
        Some("scalar") => return "scalar",
        Some("blocked") => return "blocked",
        _ => {}
    }
    match simd::detected() {
        "none" => "blocked",
        tier => tier,
    }
}

pub type NodeId = usize;

type BackFn = Box<dyn Fn(&Tensor) -> Vec<(NodeId, Tensor)>>;

#[derive(Default)]
pub struct Tape {
    vals: Vec<Tensor>,
    backs: Vec<Option<BackFn>>,
}

fn add_into(acc: &mut Tensor, x: &Tensor) {
    debug_assert_eq!(acc.shape, x.shape);
    for (a, b) in acc.data.iter_mut().zip(&x.data) {
        *a += b;
    }
}

/// A raw `*mut f32` the parallel drivers move across threads: each pool
/// chunk receives a *disjoint* sub-slice of one output buffer, so the
/// aliasing the pointer smuggles past the borrow checker never occurs.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Run `f(row_index, row_slice)` over the rows of a flat buffer, chunking
/// row ranges across the persistent intra-op pool when the work is big
/// enough to pay for it ([`pool::plan_chunks`] owns the heuristics — the
/// old per-call scoped threads, `min(8)` cap and `rows / 128` threshold
/// are gone).
pub(crate) fn par_rows(
    out: &mut [f32],
    row_len: usize,
    f: &(dyn Fn(usize, &mut [f32]) + Sync),
) {
    if row_len == 0 || out.is_empty() {
        return;
    }
    let rows = out.len() / row_len;
    let chunks = pool::plan_chunks(rows, row_len * 4, pool::local_intra());
    if chunks <= 1 {
        for (r, chunk) in out.chunks_mut(row_len).enumerate() {
            f(r, chunk);
        }
        return;
    }
    let per = rows.div_ceil(chunks);
    let base = SendPtr(out.as_mut_ptr());
    pool::run_chunked(rows.div_ceil(per), &|ci| {
        let r0 = ci * per;
        let r1 = ((ci + 1) * per).min(rows);
        for r in r0..r1 {
            // SAFETY: row ranges [r0, r1) are disjoint across chunks, so
            // each row slice is exclusively owned by exactly one chunk,
            // and `out` outlives run_chunked (the caller blocks in it)
            let chunk = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r * row_len), row_len)
            };
            f(r, chunk);
        }
    });
}

/// Parallel driver for the decode-path matvecs: `y` is `(rows, out)`
/// row-major, `prep(r)` builds batch row `r`'s shared operands once, and
/// `fill(ctx, r, o0, ys)` computes outputs `[o0, o0 + ys.len())` of that
/// row. Two split regimes, chosen by shape: with at least as many batch
/// rows as intra-op threads the *batch* rows are chunked (prefill /
/// training shape); otherwise each matvec's *output* rows are chunked —
/// decode's actual shape (a handful of lanes against a wide layer), which
/// the old batch-only split left serial on any host. `bytes_per_out`
/// approximates the weight bytes one output row touches and feeds the
/// bytes-of-work split threshold.
///
/// Every `y[r][o]` is computed whole inside exactly one chunk, so any
/// chunk count is bit-identical to the serial loop.
pub(crate) fn par_matvec<T, P, F>(
    y: &mut [f32],
    out: usize,
    bytes_per_out: usize,
    prep: P,
    fill: F,
) where
    T: Sync,
    P: Fn(usize) -> T + Sync,
    F: Fn(&T, usize, usize, &mut [f32]) + Sync,
{
    if out == 0 || y.is_empty() {
        return;
    }
    let rows = y.len() / out;
    let threads = pool::local_intra();
    if rows >= threads {
        let row_bytes = out.saturating_mul(bytes_per_out);
        let chunks = pool::plan_chunks(rows, row_bytes, threads);
        if chunks > 1 {
            let per = rows.div_ceil(chunks);
            let base = SendPtr(y.as_mut_ptr());
            pool::run_chunked(rows.div_ceil(per), &|ci| {
                let r0 = ci * per;
                let r1 = ((ci + 1) * per).min(rows);
                for r in r0..r1 {
                    let ctx = prep(r);
                    // SAFETY: batch-row ranges are disjoint across chunks
                    let ys = unsafe {
                        std::slice::from_raw_parts_mut(base.0.add(r * out), out)
                    };
                    fill(&ctx, r, 0, ys);
                }
            });
            return;
        }
    } else {
        let chunks = pool::plan_chunks(out, bytes_per_out, threads);
        if chunks > 1 {
            let per = out.div_ceil(chunks);
            for r in 0..rows {
                let ctx = prep(r);
                let base = SendPtr(y[r * out..(r + 1) * out].as_mut_ptr());
                pool::run_chunked(out.div_ceil(per), &|ci| {
                    let o0 = ci * per;
                    let o1 = ((ci + 1) * per).min(out);
                    // SAFETY: output ranges [o0, o1) are disjoint across
                    // chunks within this batch row
                    let ys = unsafe {
                        std::slice::from_raw_parts_mut(base.0.add(o0), o1 - o0)
                    };
                    fill(&ctx, r, o0, ys);
                });
            }
            return;
        }
    }
    for r in 0..rows {
        let ctx = prep(r);
        fill(&ctx, r, 0, &mut y[r * out..(r + 1) * out]);
    }
}

impl Tape {
    pub fn new() -> Tape {
        Tape::default()
    }

    fn push(&mut self, val: Tensor, back: Option<BackFn>) -> NodeId {
        self.vals.push(val);
        self.backs.push(back);
        self.vals.len() - 1
    }

    /// Graph input: a leaf (parameter) or a constant. Gradients accumulate
    /// into its slot either way; the caller decides which slots it reads.
    pub fn input(&mut self, t: Tensor) -> NodeId {
        self.push(t, None)
    }

    pub fn val(&self, id: NodeId) -> &Tensor {
        &self.vals[id]
    }

    /// Reverse pass from a scalar root. Returns one gradient slot per node
    /// (None where no gradient flowed); interior slots are consumed, input
    /// slots are left filled for the caller.
    pub fn backward(&self, root: NodeId) -> Vec<Option<Tensor>> {
        let mut grads: Vec<Option<Tensor>> = (0..self.vals.len()).map(|_| None).collect();
        let root_shape = self.vals[root].shape.clone();
        grads[root] = Some(Tensor::ones(&root_shape));
        for id in (0..=root).rev() {
            if self.backs[id].is_none() {
                continue;
            }
            let Some(g) = grads[id].take() else { continue };
            let back = self.backs[id].as_ref().unwrap();
            for (pid, contrib) in back(&g) {
                debug_assert!(pid < id, "tape must be topologically ordered");
                if grads[pid].is_none() {
                    grads[pid] = Some(contrib);
                } else {
                    add_into(grads[pid].as_mut().unwrap(), &contrib);
                }
            }
        }
        grads
    }

    /// Gradient of an input node after `backward`, zeros if disconnected.
    pub fn grad(grads: &[Option<Tensor>], id: NodeId, shape: &[usize]) -> Tensor {
        grads[id].clone().unwrap_or_else(|| Tensor::zeros(shape))
    }

    // ------------------------------------------------------------------
    // elementwise ops
    // ------------------------------------------------------------------

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let y = self.vals[a].add(&self.vals[b]);
        self.push(
            y,
            Some(Box::new(move |g| {
                vec![(a, g.clone()), (b, g.clone())]
            })),
        )
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let av = self.vals[a].clone();
        let bv = self.vals[b].clone();
        let y = av.zip(&bv, |x, z| x * z);
        self.push(
            y,
            Some(Box::new(move |g| {
                vec![(a, g.zip(&bv, |gi, z| gi * z)), (b, g.zip(&av, |gi, x| gi * x))]
            })),
        )
    }

    pub fn scale(&mut self, a: NodeId, c: f32) -> NodeId {
        let y = self.vals[a].scale(c);
        self.push(y, Some(Box::new(move |g| vec![(a, g.scale(c))])))
    }

    pub fn silu(&mut self, a: NodeId) -> NodeId {
        let av = self.vals[a].clone();
        let y = av.map(|x| x / (1.0 + (-x).exp()));
        self.push(
            y,
            Some(Box::new(move |g| {
                let dx = g.zip(&av, |gi, x| {
                    let s = 1.0 / (1.0 + (-x).exp());
                    gi * s * (1.0 + x * (1.0 - s))
                });
                vec![(a, dx)]
            })),
        )
    }

    pub fn reshape(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        let old = self.vals[a].shape.clone();
        let y = self.vals[a].clone().reshape(shape);
        self.push(
            y,
            Some(Box::new(move |g| {
                vec![(a, g.clone().reshape(&old))]
            })),
        )
    }

    // ------------------------------------------------------------------
    // embedding / norm / linear
    // ------------------------------------------------------------------

    /// h[r] = embed[tokens[r]] over r in 0..b*t; output (b, t, d).
    pub fn gather(&mut self, embed: NodeId, tokens: &[i32], b: usize, t: usize) -> NodeId {
        let ev = &self.vals[embed];
        let (vocab, d) = (ev.shape[0], ev.shape[1]);
        assert_eq!(tokens.len(), b * t, "gather token count");
        let mut y = Tensor::zeros(&[b, t, d]);
        for (r, &tok) in tokens.iter().enumerate() {
            let tok = (tok.max(0) as usize).min(vocab - 1);
            y.data[r * d..(r + 1) * d].copy_from_slice(&ev.data[tok * d..(tok + 1) * d]);
        }
        let toks: Vec<i32> = tokens.to_vec();
        self.push(
            y,
            Some(Box::new(move |g| {
                let mut de = Tensor::zeros(&[vocab, d]);
                for (r, &tok) in toks.iter().enumerate() {
                    let tok = (tok.max(0) as usize).min(vocab - 1);
                    let dst = &mut de.data[tok * d..(tok + 1) * d];
                    let src = &g.data[r * d..(r + 1) * d];
                    for (a, s) in dst.iter_mut().zip(src) {
                        *a += s;
                    }
                }
                vec![(embed, de)]
            })),
        )
    }

    /// y = x * g / sqrt(mean(x^2, last) + EPS); x (..., d), g (d).
    pub fn rmsnorm(&mut self, x: NodeId, gain: NodeId) -> NodeId {
        let xv = self.vals[x].clone();
        let gv = self.vals[gain].clone();
        let d = *xv.shape.last().unwrap();
        let rows = xv.numel() / d;
        let (y, inv) = rmsnorm_fwd_with_inv(&xv, &gv);
        self.push(
            y,
            Some(Box::new(move |g| {
                let mut dx = Tensor::zeros(&xv.shape);
                let mut dg = Tensor::zeros(&[d]);
                for r in 0..rows {
                    let xr = &xv.data[r * d..(r + 1) * d];
                    let gr = &g.data[r * d..(r + 1) * d];
                    let rinv = inv[r];
                    let mut dot = 0.0f32;
                    for i in 0..d {
                        dot += gr[i] * gv.data[i] * xr[i];
                    }
                    let c = rinv * rinv * rinv * dot / d as f32;
                    let dxr = &mut dx.data[r * d..(r + 1) * d];
                    for i in 0..d {
                        dxr[i] = rinv * gr[i] * gv.data[i] - c * xr[i];
                        dg.data[i] += gr[i] * xr[i] * rinv;
                    }
                }
                vec![(x, dx), (gain, dg)]
            })),
        )
    }

    /// y = x @ w^T over the last axis; x (..., in), w (out, in).
    pub fn linear(&mut self, x: NodeId, w: NodeId) -> NodeId {
        let xv = self.vals[x].clone();
        let wv = self.vals[w].clone();
        let inn = *xv.shape.last().unwrap();
        let out = wv.shape[0];
        let rows = xv.numel() / inn;
        let y = linear_fwd(&xv, &wv);
        let xshape = xv.shape.clone();
        self.push(
            y,
            Some(Box::new(move |g| {
                let mut dx = Tensor::zeros(&xshape);
                {
                    let gd = &g.data;
                    let wd = &wv.data;
                    par_rows(&mut dx.data, inn, &|r, dxr| {
                        let gr = &gd[r * out..(r + 1) * out];
                        for (o, &go) in gr.iter().enumerate() {
                            if go == 0.0 {
                                continue;
                            }
                            let wr = &wd[o * inn..(o + 1) * inn];
                            for (a, b) in dxr.iter_mut().zip(wr) {
                                *a += go * b;
                            }
                        }
                    });
                }
                let mut dw = Tensor::zeros(&[out, inn]);
                {
                    let gd = &g.data;
                    let xd = &xv.data;
                    par_rows(&mut dw.data, inn, &|o, dwr| {
                        for r in 0..rows {
                            let go = gd[r * out + o];
                            if go == 0.0 {
                                continue;
                            }
                            let xr = &xd[r * inn..(r + 1) * inn];
                            for (a, b) in dwr.iter_mut().zip(xr) {
                                *a += go * b;
                            }
                        }
                    });
                }
                vec![(x, dx), (w, dw)]
            })),
        )
    }

    /// Plain 2-D matmul: a (n, k) @ b (k, m) -> (n, m). Used for LoRA B@A.
    pub fn matmul2d(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let av = self.vals[a].clone();
        let bv = self.vals[b].clone();
        let y = av.matmul(&bv);
        self.push(
            y,
            Some(Box::new(move |g| {
                let da = g.matmul(&bv.t());
                let db = av.t().matmul(g);
                vec![(a, da), (b, db)]
            })),
        )
    }

    // ------------------------------------------------------------------
    // attention
    // ------------------------------------------------------------------

    /// Rotary embedding over (b, t, h, hd); rotation by position-dependent
    /// angles — the backward pass is the transposed rotation.
    pub fn rope(&mut self, x: NodeId, theta: f32) -> NodeId {
        let xv = self.vals[x].clone();
        let (b, t, nh, hd) = (xv.shape[0], xv.shape[1], xv.shape[2], xv.shape[3]);
        let half = hd / 2;
        // powf once per lane index (rope_freqs), trig once per (pos, i)
        let freqs = rope_freqs(half, theta);
        let mut cos = vec![0.0f32; t * half];
        let mut sin = vec![0.0f32; t * half];
        for ti in 0..t {
            for i in 0..half {
                let ang = ti as f32 * freqs[i];
                cos[ti * half + i] = ang.cos();
                sin[ti * half + i] = ang.sin();
            }
        }
        let mut y = Tensor::zeros(&xv.shape);
        for bi in 0..b {
            for ti in 0..t {
                for hi in 0..nh {
                    let base = ((bi * t + ti) * nh + hi) * hd;
                    for i in 0..half {
                        let (c, s) = (cos[ti * half + i], sin[ti * half + i]);
                        let x1 = xv.data[base + i];
                        let x2 = xv.data[base + half + i];
                        y.data[base + i] = x1 * c - x2 * s;
                        y.data[base + half + i] = x1 * s + x2 * c;
                    }
                }
            }
        }
        let shape = xv.shape.clone();
        self.push(
            y,
            Some(Box::new(move |g| {
                let mut dx = Tensor::zeros(&shape);
                for bi in 0..b {
                    for ti in 0..t {
                        for hi in 0..nh {
                            let base = ((bi * t + ti) * nh + hi) * hd;
                            for i in 0..half {
                                let (c, s) = (cos[ti * half + i], sin[ti * half + i]);
                                let g1 = g.data[base + i];
                                let g2 = g.data[base + half + i];
                                dx.data[base + i] = g1 * c + g2 * s;
                                dx.data[base + half + i] = -g1 * s + g2 * c;
                            }
                        }
                    }
                }
                vec![(x, dx)]
            })),
        )
    }

    /// Causal attention scores: q, k (b, t, h, hd) -> (b, h, t, t), scaled
    /// by 1/sqrt(hd). Entries above the diagonal are left at zero (the
    /// causal softmax never reads them).
    pub fn attn_scores(&mut self, q: NodeId, k: NodeId) -> NodeId {
        let qv = self.vals[q].clone();
        let kv = self.vals[k].clone();
        let (b, t, nh, hd) = (qv.shape[0], qv.shape[1], qv.shape[2], qv.shape[3]);
        let inv = 1.0 / (hd as f32).sqrt();
        let idx4 = move |bi: usize, ti: usize, hi: usize| ((bi * t + ti) * nh + hi) * hd;
        let mut s = Tensor::zeros(&[b, nh, t, t]);
        for bi in 0..b {
            for hi in 0..nh {
                for ti in 0..t {
                    let qr = &qv.data[idx4(bi, ti, hi)..idx4(bi, ti, hi) + hd];
                    let srow = ((bi * nh + hi) * t + ti) * t;
                    for si in 0..=ti {
                        let kr = &kv.data[idx4(bi, si, hi)..idx4(bi, si, hi) + hd];
                        s.data[srow + si] =
                            qr.iter().zip(kr).map(|(a, c)| a * c).sum::<f32>() * inv;
                    }
                }
            }
        }
        let qshape = qv.shape.clone();
        self.push(
            s,
            Some(Box::new(move |g| {
                let mut dq = Tensor::zeros(&qshape);
                let mut dk = Tensor::zeros(&qshape);
                for bi in 0..b {
                    for hi in 0..nh {
                        for ti in 0..t {
                            let grow = ((bi * nh + hi) * t + ti) * t;
                            for si in 0..=ti {
                                let gs = g.data[grow + si] * inv;
                                if gs == 0.0 {
                                    continue;
                                }
                                let qb = idx4(bi, ti, hi);
                                let kb = idx4(bi, si, hi);
                                for c in 0..hd {
                                    dq.data[qb + c] += gs * kv.data[kb + c];
                                    dk.data[kb + c] += gs * qv.data[qb + c];
                                }
                            }
                        }
                    }
                }
                vec![(q, dq), (k, dk)]
            })),
        )
    }

    /// Row-wise softmax over the causal prefix of each (b, h, t, :) row.
    pub fn causal_softmax(&mut self, s: NodeId) -> NodeId {
        let sv = self.vals[s].clone();
        let (b, nh, t) = (sv.shape[0], sv.shape[1], sv.shape[2]);
        let mut p = Tensor::zeros(&sv.shape);
        for bi in 0..b {
            for hi in 0..nh {
                for ti in 0..t {
                    let row = ((bi * nh + hi) * t + ti) * t;
                    let mx = sv.data[row..=row + ti]
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for si in 0..=ti {
                        let e = (sv.data[row + si] - mx).exp();
                        p.data[row + si] = e;
                        z += e;
                    }
                    for si in 0..=ti {
                        p.data[row + si] /= z;
                    }
                }
            }
        }
        let pv = p.clone();
        self.push(
            p,
            Some(Box::new(move |g| {
                let mut ds = Tensor::zeros(&pv.shape);
                for bi in 0..b {
                    for hi in 0..nh {
                        for ti in 0..t {
                            let row = ((bi * nh + hi) * t + ti) * t;
                            let mut dot = 0.0f32;
                            for si in 0..=ti {
                                dot += g.data[row + si] * pv.data[row + si];
                            }
                            for si in 0..=ti {
                                ds.data[row + si] =
                                    pv.data[row + si] * (g.data[row + si] - dot);
                            }
                        }
                    }
                }
                vec![(s, ds)]
            })),
        )
    }

    /// ctx[b,t,h,c] = sum_s p[b,h,t,s] * v[b,s,h,c].
    pub fn attn_ctx(&mut self, p: NodeId, v: NodeId) -> NodeId {
        let pv = self.vals[p].clone();
        let vv = self.vals[v].clone();
        let (b, nh, t) = (pv.shape[0], pv.shape[1], pv.shape[2]);
        let hd = vv.shape[3];
        let idx4 = move |bi: usize, ti: usize, hi: usize| ((bi * t + ti) * nh + hi) * hd;
        let mut ctx = Tensor::zeros(&vv.shape);
        for bi in 0..b {
            for hi in 0..nh {
                for ti in 0..t {
                    let prow = ((bi * nh + hi) * t + ti) * t;
                    let cb = idx4(bi, ti, hi);
                    for si in 0..=ti {
                        let pij = pv.data[prow + si];
                        if pij == 0.0 {
                            continue;
                        }
                        let vb = idx4(bi, si, hi);
                        for c in 0..hd {
                            ctx.data[cb + c] += pij * vv.data[vb + c];
                        }
                    }
                }
            }
        }
        self.push(
            ctx,
            Some(Box::new(move |g| {
                let mut dp = Tensor::zeros(&pv.shape);
                let mut dv = Tensor::zeros(&vv.shape);
                for bi in 0..b {
                    for hi in 0..nh {
                        for ti in 0..t {
                            let prow = ((bi * nh + hi) * t + ti) * t;
                            let gb = idx4(bi, ti, hi);
                            for si in 0..=ti {
                                let vb = idx4(bi, si, hi);
                                let mut acc = 0.0f32;
                                let pij = pv.data[prow + si];
                                for c in 0..hd {
                                    let gc = g.data[gb + c];
                                    acc += gc * vv.data[vb + c];
                                    dv.data[vb + c] += pij * gc;
                                }
                                dp.data[prow + si] = acc;
                            }
                        }
                    }
                }
                vec![(p, dp), (v, dv)]
            })),
        )
    }

    // ------------------------------------------------------------------
    // losses
    // ------------------------------------------------------------------

    /// Sum of next-token NLL over all (b, t-1) positions; logits
    /// (b, t, vocab), targets tokens[b, pos+1]. Returns a scalar node.
    pub fn nll_sum(&mut self, logits: NodeId, tokens: &[i32], b: usize, t: usize) -> NodeId {
        let lv = self.vals[logits].clone();
        let vocab = lv.shape[2];
        assert_eq!(tokens.len(), b * t, "nll token count");
        let toks: Vec<i32> = tokens.to_vec();
        let mut nll = 0.0f64;
        for bi in 0..b {
            for pos in 0..t - 1 {
                let row = &lv.data[(bi * t + pos) * vocab..(bi * t + pos + 1) * vocab];
                let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse =
                    row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
                let tgt = (toks[bi * t + pos + 1].max(0) as usize).min(vocab - 1);
                nll += (lse - row[tgt]) as f64;
            }
        }
        let y = Tensor::from_vec(&[], vec![nll as f32]);
        self.push(
            y,
            Some(Box::new(move |g| {
                let gs = g.data[0];
                let mut dl = Tensor::zeros(&lv.shape);
                for bi in 0..b {
                    for pos in 0..t - 1 {
                        let base = (bi * t + pos) * vocab;
                        let row = &lv.data[base..base + vocab];
                        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let z: f32 = row.iter().map(|&x| (x - mx).exp()).sum();
                        let tgt = (toks[bi * t + pos + 1].max(0) as usize).min(vocab - 1);
                        let drow = &mut dl.data[base..base + vocab];
                        for v in 0..vocab {
                            drow[v] = gs * (row[v] - mx).exp() / z;
                        }
                        drow[tgt] -= gs;
                    }
                }
                vec![(logits, dl)]
            })),
        )
    }

    /// Eq. 5 distance to a constant target: MSE + nlc_w * (-log cos-sim).
    pub fn distance(&mut self, f2: NodeId, target: &Tensor, nlc_w: f32) -> NodeId {
        let av = self.vals[f2].clone();
        assert_eq!(av.shape, target.shape, "distance shape");
        let tv = target.clone();
        let n = av.numel() as f32;
        let mse: f32 = av
            .data
            .iter()
            .zip(&tv.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n;
        let dot: f32 = av.data.iter().zip(&tv.data).map(|(a, b)| a * b).sum();
        let na = av.frob_norm();
        let nb = tv.frob_norm();
        let denom = (na * nb).max(1e-8);
        let cos = dot / denom;
        let cc = cos.clamp(1e-3, 1.0);
        let loss = mse + nlc_w * -cc.ln();
        let y = Tensor::from_vec(&[], vec![loss]);
        self.push(
            y,
            Some(Box::new(move |g| {
                let gs = g.data[0];
                let mut da = Tensor::zeros(&av.shape);
                let dnlc_dcos = if cos > 1e-3 && cos < 1.0 { -1.0 / cos } else { 0.0 };
                let na2 = (na * na).max(1e-12);
                for i in 0..av.data.len() {
                    let dmse = 2.0 * (av.data[i] - tv.data[i]) / n;
                    let dcos = tv.data[i] / denom - cos * av.data[i] / na2;
                    da.data[i] = gs * (dmse + nlc_w * dnlc_dcos * dcos);
                }
                vec![(f2, da)]
            })),
        )
    }

    // ------------------------------------------------------------------
    // quantization ops
    // ------------------------------------------------------------------

    /// PTQ1.61 fake quantization with a straight-through estimator:
    /// forward is the analytic decomposition's dequantized weight, the
    /// gradient passes through unchanged (paper section 3.4).
    pub fn ste_quant(&mut self, w: NodeId, mask: Vec<bool>) -> NodeId {
        let wv = &self.vals[w];
        let y = crate::quant::ptq161::initial_parts(wv, &mask).dequantize();
        self.push(y, Some(Box::new(move |g| vec![(w, g.clone())])))
    }

    /// Fused PTQ1.61 quantized linear (the Pallas kernel's semantics):
    /// y = x @ Wq'^T + (x @ |sign_ns|[0]) ⊗ mu with
    /// Wq' = w_sal + (r1 a_s)[:,None] * r2[None,:] * sign_ns.
    /// Gradients flow to x and the four learnable vectors; w_sal / sign_ns
    /// are constants of the block-wise optimization.
    #[allow(clippy::too_many_arguments)]
    pub fn qlinear(
        &mut self,
        x: NodeId,
        a_s: NodeId,
        r1: NodeId,
        r2: NodeId,
        mu: NodeId,
        w_sal: &Tensor,
        sign: &Tensor,
    ) -> NodeId {
        let xv = self.vals[x].clone();
        let asv = self.vals[a_s].clone();
        let r1v = self.vals[r1].clone();
        let r2v = self.vals[r2].clone();
        let muv = self.vals[mu].clone();
        let wsal = w_sal.clone();
        let signv = sign.clone();
        let (out, inn) = (wsal.shape[0], wsal.shape[1]);
        assert_eq!(*xv.shape.last().unwrap(), inn, "qlinear contraction");
        let rows = xv.numel() / inn;
        // reconstruct Wq' once (Eq. 9), project x onto the binarized
        // columns, then run the fused matmul — shared with qlinear_fwd
        let wq = qlinear_weight(&asv, &r1v, &r2v, &wsal, &signv);
        let (ns, xs) = qlinear_xsal(&xv, &signv);
        let y = qlinear_matmul(&xv, &wq, &xs, &muv);
        let xshape = xv.shape.clone();
        self.push(
            y,
            Some(Box::new(move |g| {
                // dwq = g^T x, shared by all alpha gradients
                let mut dwq = Tensor::zeros(&[out, inn]);
                {
                    let gd = &g.data;
                    let xd = &xv.data;
                    par_rows(&mut dwq.data, inn, &|o, dwr| {
                        for r in 0..rows {
                            let go = gd[r * out + o];
                            if go == 0.0 {
                                continue;
                            }
                            let xr = &xd[r * inn..(r + 1) * inn];
                            for (a, b) in dwr.iter_mut().zip(xr) {
                                *a += go * b;
                            }
                        }
                    });
                }
                let mut da_s = Tensor::zeros(&[out]);
                let mut dr1 = Tensor::zeros(&[out]);
                let mut dr2 = Tensor::zeros(&[inn]);
                let mut dmu = Tensor::zeros(&[out]);
                for o in 0..out {
                    let sr = &signv.data[o * inn..(o + 1) * inn];
                    let dwr = &dwq.data[o * inn..(o + 1) * inn];
                    let mut gr2_sum = 0.0f32;
                    let c = r1v.data[o] * asv.data[o];
                    for i in 0..inn {
                        let gi = dwr[i] * sr[i];
                        gr2_sum += gi * r2v.data[i];
                        dr2.data[i] += gi * c;
                    }
                    da_s.data[o] = gr2_sum * r1v.data[o];
                    dr1.data[o] = gr2_sum * asv.data[o];
                }
                for r in 0..rows {
                    let gr = &g.data[r * out..(r + 1) * out];
                    for (o, &go) in gr.iter().enumerate() {
                        dmu.data[o] += go * xs[r];
                    }
                }
                // dx = g @ wq + (g . mu) * ns
                let mut dx = Tensor::zeros(&xshape);
                {
                    let gd = &g.data;
                    let wd = &wq.data;
                    let mud = &muv.data;
                    let nsd = &ns;
                    par_rows(&mut dx.data, inn, &|r, dxr| {
                        let gr = &gd[r * out..(r + 1) * out];
                        let mut gmu = 0.0f32;
                        for (o, &go) in gr.iter().enumerate() {
                            if go != 0.0 {
                                let wr = &wd[o * inn..(o + 1) * inn];
                                for (a, b) in dxr.iter_mut().zip(wr) {
                                    *a += go * b;
                                }
                                gmu += go * mud[o];
                            }
                        }
                        if gmu != 0.0 {
                            for (a, b) in dxr.iter_mut().zip(nsd) {
                                *a += gmu * b;
                            }
                        }
                    });
                }
                vec![(x, dx), (a_s, da_s), (r1, dr1), (r2, dr2), (mu, dmu)]
            })),
        )
    }
}

// ---------------------------------------------------------------------
// forward-only kernels
//
// Tape-free forwards shared by the tape ops above and by the KV-cached
// incremental-decode artifacts (`*_decode` in runtime::native). Keeping
// one implementation per op — same loop order, same accumulation order —
// is what guarantees cached decode is bit-identical to full-window
// decode for the dense and PTQ1.61-fused paths.
// ---------------------------------------------------------------------

/// Forward of [`Tape::linear`]: y = x @ w^T over the last axis.
pub fn linear_fwd(x: &Tensor, w: &Tensor) -> Tensor {
    let inn = *x.shape.last().unwrap();
    let (out, w_in) = (w.shape[0], w.shape[1]);
    assert_eq!(inn, w_in, "linear contraction {inn} vs {w_in}");
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    let xd = &x.data;
    let wd = &w.data;
    time_kernel(|| {
        par_matvec(
            &mut y.data,
            out,
            inn * 4,
            |_r| (),
            |_, r, o0, ys| {
                let xr = &xd[r * inn..(r + 1) * inn];
                for (k, yo) in ys.iter_mut().enumerate() {
                    let o = o0 + k;
                    let wr = &wd[o * inn..(o + 1) * inn];
                    *yo = xr.iter().zip(wr).map(|(a, b)| a * b).sum();
                }
            },
        )
    });
    y
}

/// Forward of [`Tape::rmsnorm`] plus the per-row `1/rms` factors the
/// backward pass reuses.
pub(crate) fn rmsnorm_fwd_with_inv(x: &Tensor, gain: &Tensor) -> (Tensor, Vec<f32>) {
    let d = *x.shape.last().unwrap();
    assert_eq!(gain.shape, vec![d], "rmsnorm gain shape");
    let rows = x.numel() / d;
    let mut y = Tensor::zeros(&x.shape);
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x.data[r * d..(r + 1) * d];
        let ms = xr.iter().map(|v| v * v).sum::<f32>() / d as f32 + EPS;
        let rinv = 1.0 / ms.sqrt();
        inv[r] = rinv;
        let yr = &mut y.data[r * d..(r + 1) * d];
        for i in 0..d {
            yr[i] = xr[i] * gain.data[i] * rinv;
        }
    }
    (y, inv)
}

/// Forward of [`Tape::rmsnorm`]: y = x * gain / rms(x, last axis).
pub fn rmsnorm_fwd(x: &Tensor, gain: &Tensor) -> Tensor {
    rmsnorm_fwd_with_inv(x, gain).0
}

/// Reconstruct the PTQ1.61 fused weight Wq' (Eq. 9):
/// `w_sal + (r1 ⊙ a_s)[:,None] * r2[None,:] * sign_ns`.
pub(crate) fn qlinear_weight(
    a_s: &Tensor,
    r1: &Tensor,
    r2: &Tensor,
    w_sal: &Tensor,
    sign: &Tensor,
) -> Tensor {
    QLINEAR_RECONSTRUCTIONS.fetch_add(1, Ordering::Relaxed);
    let (out, inn) = (w_sal.shape[0], w_sal.shape[1]);
    let mut wq = Tensor::zeros(&[out, inn]);
    for o in 0..out {
        let c = r1.data[o] * a_s.data[o];
        let wr = &mut wq.data[o * inn..(o + 1) * inn];
        let sr = &sign.data[o * inn..(o + 1) * inn];
        let wsr = &w_sal.data[o * inn..(o + 1) * inn];
        for i in 0..inn {
            wr[i] = wsr[i] + c * r2.data[i] * sr[i];
        }
    }
    wq
}

/// Binarized-column indicator `|sign_ns|[0]` and the per-row projection
/// `x · ns` that feeds the mean-shift term of the fused qlinear.
pub(crate) fn qlinear_xsal(x: &Tensor, sign: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let inn = sign.shape[1];
    let rows = x.numel() / inn;
    let ns: Vec<f32> = sign.data[..inn].iter().map(|v| v.abs()).collect();
    let mut xs = vec![0.0f32; rows];
    for (r, x_s) in xs.iter_mut().enumerate() {
        let xr = &x.data[r * inn..(r + 1) * inn];
        *x_s = xr.iter().zip(&ns).map(|(a, b)| a * b).sum();
    }
    (ns, xs)
}

/// The fused qlinear matmul: y = x @ Wq'^T + xs ⊗ mu.
pub(crate) fn qlinear_matmul(x: &Tensor, wq: &Tensor, xs: &[f32], mu: &Tensor) -> Tensor {
    let (out, inn) = (wq.shape[0], wq.shape[1]);
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    let xd = &x.data;
    let wd = &wq.data;
    let mud = &mu.data;
    time_kernel(|| {
        par_matvec(
            &mut y.data,
            out,
            inn * 4,
            |_r| (),
            |_, r, o0, ys| {
                let xr = &xd[r * inn..(r + 1) * inn];
                for (k, yo) in ys.iter_mut().enumerate() {
                    let o = o0 + k;
                    let wr = &wd[o * inn..(o + 1) * inn];
                    *yo = xr.iter().zip(wr).map(|(a, b)| a * b).sum::<f32>()
                        + xs[r] * mud[o];
                }
            },
        )
    });
    y
}

/// Forward of [`Tape::qlinear`]: the PTQ1.61 fused quantized linear
/// without a tape node (decode path).
pub fn qlinear_fwd(
    x: &Tensor,
    a_s: &Tensor,
    r1: &Tensor,
    r2: &Tensor,
    mu: &Tensor,
    w_sal: &Tensor,
    sign: &Tensor,
) -> Tensor {
    assert_eq!(*x.shape.last().unwrap(), w_sal.shape[1], "qlinear contraction");
    let wq = qlinear_weight(a_s, r1, r2, w_sal, sign);
    let (_, xs) = qlinear_xsal(x, sign);
    qlinear_matmul(x, &wq, &xs, mu)
}

/// Per-input-row operands shared by both packed kernels: the
/// binarized-branch vector `z = x ⊙ alpha_r2` over the non-salient
/// channels with its total, the plain x sum feeding the mu term, the
/// salient x pre-scaled by the nibble step, and the row-constant min
/// term.
fn packed_row_operands(
    xr: &[f32],
    pl: &PackedLinear,
) -> (Vec<f32>, f32, f32, Vec<f32>, f32) {
    // `z` is padded to whole 64-lane sign words so the SIMD tiers can
    // issue full-width loads; tail lanes stay 0.0 and their sign bits are
    // never set, so every tier ignores them
    let n_ns = pl.ns_cols().len();
    let mut z = vec![0.0f32; n_ns.div_ceil(64) * 64];
    let mut ztot = 0.0f32;
    let mut xs = 0.0f32;
    for (c, &j) in pl.ns_cols().iter().enumerate() {
        let v = xr[j as usize];
        let zv = v * pl.r2_ns()[c];
        z[c] = zv;
        ztot += zv;
        xs += v;
    }
    let mut xq = vec![0.0f32; pl.sal_cols().len()];
    let mut xmin = 0.0f32;
    for (c, &j) in pl.sal_cols().iter().enumerate() {
        let v = xr[j as usize];
        xq[c] = v * pl.col_scale()[c];
        xmin += v * pl.col_min()[c];
    }
    (z, ztot, xs, xq, xmin)
}

/// One output of the scalar packed contraction: serial set-bit walk over
/// the row's sign words plus the fused nibble-decode dot product. This is
/// the reference accumulation order the blocked kernel must reproduce
/// bit-for-bit.
#[inline]
fn packed_row_scalar(
    pl: &PackedLinear,
    o: usize,
    z: &[f32],
    ztot: f32,
    xs: f32,
    xq: &[f32],
    xmin: f32,
) -> f32 {
    let mut pos = 0.0f32;
    for (wi, &w0) in pl.sign_words(o).iter().enumerate() {
        let mut w = w0;
        let base = wi * 64;
        while w != 0 {
            pos += z[base + w.trailing_zeros() as usize];
            w &= w - 1;
        }
    }
    let bin = pl.row_scale()[o] * (2.0 * pos - ztot);
    let n_sal = xq.len();
    let mut sal = xmin;
    let cbase = o * n_sal;
    for (c, &xv) in xq.iter().enumerate() {
        sal += pl.code(cbase + c) as f32 * xv;
    }
    sal + bin + xs * pl.mu()[o]
}

/// Reference scalar kernel: PTQ1.61 quantized linear straight from the
/// packed 1.61-bit containers with **zero** dense `Wq'` reconstruction.
///
/// Per input row the binarized branch is rearranged as
/// `sum_j sign(o,j) * z[j] = 2 * sum_{set bits} z[j] - sum_j z[j]` with
/// `z = x ⊙ alpha_r2` over the non-salient channels, so one output costs
/// a ±1 accumulation over the row's sign *words* (iterating set bits)
/// instead of `inn` multiplies against a freshly rebuilt weight row. The
/// salient branch folds the nibble decode into the contraction:
/// `sum_c code(o,c) * (scale_c * x[j_c]) + sum_c min_c * x[j_c]`, whose
/// second term is row-constant and hoisted out of the output loop.
/// Numerically this matches [`qlinear_fwd`] up to float re-association
/// (the engine's greedy decode stays token-identical; gated in
/// `tests/packed_serve.rs`).
///
/// The serve path runs the blocked [`packed_qlinear_fwd`]; this kernel is
/// kept as the bit-identity oracle it is gated against (and the
/// `bench_packing` baseline the blocked delta is measured from).
pub fn packed_qlinear_fwd_scalar(x: &Tensor, pl: &PackedLinear) -> Tensor {
    let (out, inn) = (pl.out(), pl.inn());
    assert_eq!(*x.shape.last().unwrap(), inn, "packed qlinear contraction");
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    let xd = &x.data;
    par_matvec(
        &mut y.data,
        out,
        packed_bytes_per_out(pl),
        |r| packed_row_operands(&xd[r * inn..(r + 1) * inn], pl),
        |ops, _r, o0, ys| {
            let (z, ztot, xs, xq, xmin) = ops;
            for (k, yo) in ys.iter_mut().enumerate() {
                *yo = packed_row_scalar(pl, o0 + k, z, *ztot, *xs, xq, *xmin);
            }
        },
    );
    y
}

/// Approximate container bytes one packed output row touches (sign words
/// + nibble codes + per-row floats) — the bytes-of-work hint the split
/// heuristics run on.
fn packed_bytes_per_out(pl: &PackedLinear) -> usize {
    pl.ns_cols().len() / 8 + pl.sal_cols().len() / 2 + 16
}

/// Blocked packed contraction: the serve-path kernel. Outputs are
/// processed in 4-row tiles — one whole-`u64` pass over the tile's sign
/// words, guided by the OR of the four rows' words, accumulates all four
/// binarized branches at once. Each `z` load and bit scan is amortized
/// across the tile, and the four accumulator chains are independent, so
/// the serial add-chain bottleneck of the per-row walk turns into
/// instruction-level parallelism; the salient nibble contraction is tiled
/// the same way (one `xq` stream feeds four code rows).
///
/// Bit-identity with [`packed_qlinear_fwd_scalar`] is preserved by
/// construction and gated in `tests/packed_serve.rs`: per row, set bits
/// contribute in the same ascending order, and the masked add
/// `z * ((w >> j) & 1)` contributes exactly `±0.0` for unset bits, which
/// is an exact no-op on the accumulator (the partial sums can never be
/// `-0.0`: they start at `+0.0` and IEEE-754 round-to-nearest addition
/// only yields `-0.0` from two negative-zero operands). The same no-op
/// argument makes each row's value independent of which rows share its
/// tile, so the output split [`par_matvec`] applies may start a tile at
/// any offset without changing a single bit.
pub fn packed_qlinear_fwd(x: &Tensor, pl: &PackedLinear) -> Tensor {
    let (out, inn) = (pl.out(), pl.inn());
    assert_eq!(*x.shape.last().unwrap(), inn, "packed qlinear contraction");
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    let xd = &x.data;
    par_matvec(
        &mut y.data,
        out,
        packed_bytes_per_out(pl),
        |r| packed_row_operands(&xd[r * inn..(r + 1) * inn], pl),
        |ops, _r, o0, ys| {
            let (z, ztot, xs, xq, xmin) = ops;
            packed_fill_blocked(pl, z, *ztot, *xs, xq, *xmin, o0, ys);
        },
    );
    y
}

/// The blocked 4-row tile over one chunk `[o0, o0 + ys.len())` of output
/// rows; `ys[k]` receives output row `o0 + k`.
fn packed_fill_blocked(
    pl: &PackedLinear,
    z: &[f32],
    ztot: f32,
    xs: f32,
    xq: &[f32],
    xmin: f32,
    o0: usize,
    ys: &mut [f32],
) {
    let n_sal = pl.sal_cols().len();
    let out_hi = o0 + ys.len();
    let mut o = o0;
    while o + 4 <= out_hi {
        let w0 = pl.sign_words(o);
        let w1 = pl.sign_words(o + 1);
        let w2 = pl.sign_words(o + 2);
        let w3 = pl.sign_words(o + 3);
        let (mut p0, mut p1, mut p2, mut p3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for wi in 0..w0.len() {
            let (a, b, c, d) = (w0[wi], w1[wi], w2[wi], w3[wi]);
            let mut any = a | b | c | d;
            let base = wi * 64;
            while any != 0 {
                let j = any.trailing_zeros() as usize;
                let zv = z[base + j];
                p0 += zv * ((a >> j) & 1) as f32;
                p1 += zv * ((b >> j) & 1) as f32;
                p2 += zv * ((c >> j) & 1) as f32;
                p3 += zv * ((d >> j) & 1) as f32;
                any &= any - 1;
            }
        }
        let (mut s0, mut s1, mut s2, mut s3) = (xmin, xmin, xmin, xmin);
        let cb = o * n_sal;
        for (c, &xv) in xq.iter().enumerate() {
            s0 += pl.code(cb + c) as f32 * xv;
            s1 += pl.code(cb + n_sal + c) as f32 * xv;
            s2 += pl.code(cb + 2 * n_sal + c) as f32 * xv;
            s3 += pl.code(cb + 3 * n_sal + c) as f32 * xv;
        }
        ys[o - o0] = s0 + pl.row_scale()[o] * (2.0 * p0 - ztot) + xs * pl.mu()[o];
        ys[o - o0 + 1] =
            s1 + pl.row_scale()[o + 1] * (2.0 * p1 - ztot) + xs * pl.mu()[o + 1];
        ys[o - o0 + 2] =
            s2 + pl.row_scale()[o + 2] * (2.0 * p2 - ztot) + xs * pl.mu()[o + 2];
        ys[o - o0 + 3] =
            s3 + pl.row_scale()[o + 3] * (2.0 * p3 - ztot) + xs * pl.mu()[o + 3];
        o += 4;
    }
    // remainder rows (chunk length % 4): the scalar walk, same order
    while o < out_hi {
        ys[o - o0] = packed_row_scalar(pl, o, z, ztot, xs, xq, xmin);
        o += 1;
    }
}

/// The SIMD deployment tier: same [`par_matvec`] split as the blocked
/// kernel, but each chunk runs the detected ISA's vector kernel
/// ([`simd::packed_fill`]); chunks fall back to the blocked tile when no
/// tier is compiled in or detected at runtime. Lane reduction order is
/// fixed, so results are deterministic run-to-run, but the wider adds
/// re-associate the scalar chain — this tier is epsilon-gated against
/// [`packed_qlinear_fwd_scalar`], never bit-compared.
fn packed_qlinear_fwd_simd(x: &Tensor, pl: &PackedLinear) -> Tensor {
    let (out, inn) = (pl.out(), pl.inn());
    assert_eq!(*x.shape.last().unwrap(), inn, "packed qlinear contraction");
    let mut yshape = x.shape.clone();
    *yshape.last_mut().unwrap() = out;
    let mut y = Tensor::zeros(&yshape);
    let xd = &x.data;
    par_matvec(
        &mut y.data,
        out,
        packed_bytes_per_out(pl),
        |r| packed_row_operands(&xd[r * inn..(r + 1) * inn], pl),
        |ops, _r, o0, ys| {
            let (z, ztot, xs, xq, xmin) = ops;
            if !simd::packed_fill(pl, z, *ztot, *xs, xq, *xmin, o0, ys) {
                packed_fill_blocked(pl, z, *ztot, *xs, xq, *xmin, o0, ys);
            }
        },
    );
    y
}

/// The packed decode entry point the serve path calls: dispatches to the
/// tier [`kernel_tier`] selects (scalar oracle, blocked, or SIMD) and
/// charges the wall time to the per-thread kernel counter.
pub fn packed_decode_fwd(x: &Tensor, pl: &PackedLinear) -> Tensor {
    time_kernel(|| match kernel_tier() {
        "scalar" => packed_qlinear_fwd_scalar(x, pl),
        "blocked" => packed_qlinear_fwd(x, pl),
        _ => packed_qlinear_fwd_simd(x, pl),
    })
}

/// The per-lane rotary frequencies `1 / theta^(i/half)` — hoisted out of
/// the position loops so `powf` runs once per lane index, not once per
/// (lane, position, index) triple. Same expression as the in-loop form,
/// so the rotation stays bit-identical.
fn rope_freqs(half: usize, theta: f32) -> Vec<f32> {
    (0..half)
        .map(|i| 1.0 / theta.powf(i as f32 / half as f32))
        .collect()
}

/// Rotary embedding over `(b, t_new, h, hd)` where lane `bi`'s row `j`
/// sits at absolute position `starts[bi] + j`. With `starts = [0; b]`
/// and `t_new = t` this is exactly [`Tape::rope`]'s forward.
pub fn rope_at(x: &Tensor, starts: &[usize], theta: f32) -> Tensor {
    let (b, tn, nh, hd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(starts.len(), b, "rope_at: one start per lane");
    let half = hd / 2;
    let freqs = rope_freqs(half, theta);
    // per-(position, i) cos/sin table for the current row, filled once
    // and reused across every head — no trig inside the lane×head loops
    let mut cos = vec![0.0f32; half];
    let mut sin = vec![0.0f32; half];
    let mut y = Tensor::zeros(&x.shape);
    for bi in 0..b {
        for j in 0..tn {
            let pos = (starts[bi] + j) as f32;
            for i in 0..half {
                let ang = pos * freqs[i];
                cos[i] = ang.cos();
                sin[i] = ang.sin();
            }
            for hi in 0..nh {
                let base = ((bi * tn + j) * nh + hi) * hd;
                for i in 0..half {
                    let (c, s) = (cos[i], sin[i]);
                    let x1 = x.data[base + i];
                    let x2 = x.data[base + half + i];
                    y.data[base + i] = x1 * c - x2 * s;
                    y.data[base + half + i] = x1 * s + x2 * c;
                }
            }
        }
    }
    y
}

/// Causal attention of new positions against cached + new K/V.
///
/// `q`, `k_new`, `v_new` are `(b, t_new, h, hd)` (q and k_new already
/// roped); `k_cache`/`v_cache` are `(b, capacity, h, hd)` with `lens[bi]`
/// valid positions. New row `j` of lane `bi` attends to cached positions
/// `0..lens[bi]` and new positions `0..=j` — the same score, softmax and
/// context accumulation order as the full-window
/// [`Tape::attn_scores`] → [`Tape::causal_softmax`] → [`Tape::attn_ctx`]
/// pipeline, so the result is bit-identical.
pub fn attn_decode(
    q: &Tensor,
    k_new: &Tensor,
    v_new: &Tensor,
    k_cache: &Tensor,
    v_cache: &Tensor,
    lens: &[usize],
) -> Tensor {
    let (b, tn, nh, hd) = (q.shape[0], q.shape[1], q.shape[2], q.shape[3]);
    let cap = k_cache.shape[1];
    assert_eq!(lens.len(), b, "attn_decode: one length per lane");
    let inv = 1.0 / (hd as f32).sqrt();
    let idx_new = |bi: usize, ti: usize, hi: usize| ((bi * tn + ti) * nh + hi) * hd;
    let idx_cache = |bi: usize, si: usize, hi: usize| ((bi * cap + si) * nh + hi) * hd;
    let mut ctx = Tensor::zeros(&q.shape);
    let mut scores = vec![0.0f32; cap + tn];
    for bi in 0..b {
        let past = lens[bi];
        assert!(past + tn <= cap, "attn_decode: window overflow");
        for hi in 0..nh {
            for j in 0..tn {
                let total = past + j + 1;
                let qb = idx_new(bi, j, hi);
                let qr = &q.data[qb..qb + hd];
                for (s, sc) in scores.iter_mut().enumerate().take(total) {
                    let kb = if s < past {
                        idx_cache(bi, s, hi)
                    } else {
                        idx_new(bi, s - past, hi)
                    };
                    let kr = if s < past { &k_cache.data } else { &k_new.data };
                    *sc = qr
                        .iter()
                        .zip(&kr[kb..kb + hd])
                        .map(|(a, c)| a * c)
                        .sum::<f32>()
                        * inv;
                }
                let mx = scores[..total]
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0.0f32;
                for sc in scores.iter_mut().take(total) {
                    let e = (*sc - mx).exp();
                    *sc = e;
                    z += e;
                }
                for sc in scores.iter_mut().take(total) {
                    *sc /= z;
                }
                let cb = idx_new(bi, j, hi);
                for (s, &p) in scores.iter().enumerate().take(total) {
                    if p == 0.0 {
                        continue;
                    }
                    let vb = if s < past {
                        idx_cache(bi, s, hi)
                    } else {
                        idx_new(bi, s - past, hi)
                    };
                    let vd = if s < past { &v_cache.data } else { &v_new.data };
                    for c in 0..hd {
                        ctx.data[cb + c] += p * vd[vb + c];
                    }
                }
            }
        }
    }
    ctx
}

/// Forward of [`Tape::silu`] followed by [`Tape::mul`]:
/// `silu(gate) * up`, the SwiGLU gate of the MLP.
pub fn silu_mul_fwd(gate: &Tensor, up: &Tensor) -> Tensor {
    gate.zip(up, |x, u| x / (1.0 + (-x).exp()) * u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Directional finite-difference check of d(loss)/d(input) for a graph
    /// builder `f`: perturb `x` along a random direction and compare the
    /// numeric slope against the tape gradient.
    fn fd_check(shape: &[usize], seed: u64, f: impl Fn(&mut Tape, NodeId) -> NodeId) {
        let mut rng = Rng::new(seed);
        let x0 = Tensor::randn(shape, 1.0, &mut rng);
        let dir = Tensor::randn(shape, 1.0, &mut rng);
        let norm = dir.frob_norm().max(1e-8);
        let dir = dir.scale(1.0 / norm);
        let loss_at = |xt: &Tensor| -> f32 {
            let mut tp = Tape::new();
            let xid = tp.input(xt.clone());
            let root = f(&mut tp, xid);
            tp.val(root).data[0]
        };
        let mut tp = Tape::new();
        let xid = tp.input(x0.clone());
        let root = f(&mut tp, xid);
        let grads = tp.backward(root);
        let gx = Tape::grad(&grads, xid, shape);
        let analytic: f64 = gx
            .data
            .iter()
            .zip(&dir.data)
            .map(|(a, b)| (*a as f64) * (*b as f64))
            .sum();
        let eps = 1e-2f32;
        let lp = loss_at(&x0.add(&dir.scale(eps)));
        let lm = loss_at(&x0.sub(&dir.scale(eps)));
        let numeric = ((lp - lm) as f64) / (2.0 * eps as f64);
        let tol = 0.05 * numeric.abs().max(analytic.abs()).max(0.05);
        assert!(
            (numeric - analytic).abs() < tol,
            "fd {numeric} vs analytic {analytic}"
        );
    }

    #[test]
    fn linear_gradient_matches_fd() {
        let mut rng = Rng::new(11);
        let w = Tensor::randn(&[5, 6], 1.0, &mut rng);
        let tgt = Tensor::zeros(&[2, 3, 5]);
        fd_check(&[2, 3, 6], 1, move |tp, x| {
            let wid = tp.input(w.clone());
            let y = tp.linear(x, wid);
            tp.distance(y, &tgt, 0.0)
        });
    }

    #[test]
    fn rmsnorm_gradient_matches_fd() {
        let mut rng = Rng::new(12);
        let gain = Tensor::randn(&[8], 0.5, &mut rng).map(|v| v + 1.0);
        let tgt = Tensor::zeros(&[3, 8]);
        fd_check(&[3, 8], 2, move |tp, x| {
            let gid = tp.input(gain.clone());
            let y = tp.rmsnorm(x, gid);
            tp.distance(y, &tgt, 0.0)
        });
    }

    #[test]
    fn attention_pipeline_gradient_matches_fd() {
        // q -> rope -> scores -> softmax -> ctx against fixed k, v
        let (b, t, nh, hd) = (1, 4, 2, 4);
        let mut rng = Rng::new(13);
        let k = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let v = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let tgt = Tensor::zeros(&[b, t, nh, hd]);
        fd_check(&[b, t, nh, hd], 3, move |tp, q| {
            let kid = tp.input(k.clone());
            let vid = tp.input(v.clone());
            let qr = tp.rope(q, ROPE_THETA);
            let kr = tp.rope(kid, ROPE_THETA);
            let s = tp.attn_scores(qr, kr);
            let p = tp.causal_softmax(s);
            let ctx = tp.attn_ctx(p, vid);
            tp.distance(ctx, &tgt, 0.0)
        });
    }

    #[test]
    fn silu_mul_gradient_matches_fd() {
        let mut rng = Rng::new(14);
        let other = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let tgt = Tensor::zeros(&[4, 5]);
        fd_check(&[4, 5], 4, move |tp, x| {
            let oid = tp.input(other.clone());
            let s = tp.silu(x);
            let y = tp.mul(s, oid);
            tp.distance(y, &tgt, 0.0)
        });
    }

    #[test]
    fn nll_gradient_matches_fd() {
        let (b, t, vocab) = (2, 4, 7);
        let mut rng = Rng::new(15);
        let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(vocab) as i32).collect();
        fd_check(&[b, t, vocab], 5, move |tp, logits| {
            let n = tp.nll_sum(logits, &tokens, b, t);
            tp.scale(n, 0.25)
        });
    }

    #[test]
    fn distance_with_angular_term_matches_fd() {
        // bias the input toward the target so cos sits well inside the
        // differentiable band of the clip (away from 1e-3 and 1.0)
        let mut rng = Rng::new(16);
        let tgt = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let bias = tgt.scale(3.0);
        fd_check(&[3, 4], 6, move |tp, x| {
            let bid = tp.input(bias.clone());
            let y = tp.add(x, bid);
            tp.distance(y, &tgt, 1.0)
        });
    }

    #[test]
    fn qlinear_matches_dense_reconstruction_and_fd() {
        let (out, inn) = (5, 6);
        let mut rng = Rng::new(17);
        let w = Tensor::randn(&[out, inn], 0.5, &mut rng);
        let mask: Vec<bool> = (0..inn).map(|i| i % 3 == 0).collect();
        let parts = crate::quant::ptq161::initial_parts(&w, &mask);
        let deq = parts.dequantize();
        let x = Tensor::randn(&[2, 3, inn], 1.0, &mut rng);
        // forward agreement with the dense dequantized weight
        let mut tp = Tape::new();
        let xid = tp.input(x.clone());
        let asid = tp.input(Tensor::from_vec(&[out], parts.alpha_s.clone()));
        let r1id = tp.input(Tensor::from_vec(&[out], parts.alpha_r1.clone()));
        let r2id = tp.input(Tensor::from_vec(&[inn], parts.alpha_r2.clone()));
        let muid = tp.input(Tensor::from_vec(&[out], parts.mu.clone()));
        let y = tp.qlinear(xid, asid, r1id, r2id, muid, &parts.w_sal, &parts.sign_ns);
        let wid = tp.input(deq);
        let ydense = tp.linear(xid, wid);
        let a = tp.val(y).clone();
        let bland = tp.val(ydense).clone();
        assert!(a.mse(&bland) < 1e-9, "fused vs dense {}", a.mse(&bland));
        // gradient wrt alpha_s via FD
        let w_sal = parts.w_sal.clone();
        let sign = parts.sign_ns.clone();
        let r1v = Tensor::from_vec(&[out], parts.alpha_r1.clone());
        let r2v = Tensor::from_vec(&[inn], parts.alpha_r2.clone());
        let muv = Tensor::from_vec(&[out], parts.mu.clone());
        let tgt = Tensor::zeros(&[2, 3, out]);
        fd_check(&[out], 7, move |tp, a_s| {
            let xid = tp.input(x.clone());
            let r1 = tp.input(r1v.clone());
            let r2 = tp.input(r2v.clone());
            let mu = tp.input(muv.clone());
            let y = tp.qlinear(xid, a_s, r1, r2, mu, &w_sal, &sign);
            tp.distance(y, &tgt, 0.5)
        });
    }

    #[test]
    fn rope_at_matches_tape_rope() {
        let (b, t, nh, hd) = (2, 5, 2, 4);
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let mut tp = Tape::new();
        let xid = tp.input(x.clone());
        let rid = tp.rope(xid, ROPE_THETA);
        let full = tp.val(rid).clone();
        // zero starts over the full window reproduce the tape op exactly
        assert_eq!(rope_at(&x, &[0, 0], ROPE_THETA).data, full.data);
        // per-lane offsets: row j of a chunk starting at position s must
        // equal row s+j of the full-window rotation
        let chunk = Tensor::from_vec(
            &[1, 2, nh, hd],
            x.data[(t - 2) * nh * hd..t * nh * hd].to_vec(),
        );
        let shifted = rope_at(&chunk, &[t - 2], ROPE_THETA);
        assert_eq!(shifted.data[..], full.data[(t - 2) * nh * hd..t * nh * hd]);
    }

    #[test]
    fn attn_decode_matches_full_window_pipeline() {
        let (b, t, nh, hd) = (2, 6, 2, 4);
        let mut rng = Rng::new(32);
        let q = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let k = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let v = Tensor::randn(&[b, t, nh, hd], 1.0, &mut rng);
        let mut tp = Tape::new();
        let qid = tp.input(q.clone());
        let kid = tp.input(k.clone());
        let vid = tp.input(v.clone());
        let s = tp.attn_scores(qid, kid);
        let p = tp.causal_softmax(s);
        let cid = tp.attn_ctx(p, vid);
        let full = tp.val(cid).clone();
        // split the window: first `past` positions cached, rest new
        let past = 4;
        let tn = t - past;
        let re = nh * hd;
        let mut kc = Tensor::zeros(&[b, t, nh, hd]);
        let mut vc = Tensor::zeros(&[b, t, nh, hd]);
        let mut qn = Tensor::zeros(&[b, tn, nh, hd]);
        let mut kn = Tensor::zeros(&[b, tn, nh, hd]);
        let mut vn = Tensor::zeros(&[b, tn, nh, hd]);
        for bi in 0..b {
            let w0 = bi * t * re;
            kc.data[bi * t * re..bi * t * re + past * re]
                .copy_from_slice(&k.data[w0..w0 + past * re]);
            vc.data[bi * t * re..bi * t * re + past * re]
                .copy_from_slice(&v.data[w0..w0 + past * re]);
            let n0 = bi * tn * re;
            qn.data[n0..n0 + tn * re]
                .copy_from_slice(&q.data[w0 + past * re..w0 + t * re]);
            kn.data[n0..n0 + tn * re]
                .copy_from_slice(&k.data[w0 + past * re..w0 + t * re]);
            vn.data[n0..n0 + tn * re]
                .copy_from_slice(&v.data[w0 + past * re..w0 + t * re]);
        }
        let ctx = attn_decode(&qn, &kn, &vn, &kc, &vc, &[past, past]);
        // incremental rows must equal the full pipeline's last tn rows
        for bi in 0..b {
            let got = &ctx.data[bi * tn * re..(bi + 1) * tn * re];
            let want = &full.data[(bi * t + past) * re..(bi + 1) * t * re];
            for (a, e) in got.iter().zip(want) {
                assert_eq!(a, e, "attn_decode deviates from full window");
            }
        }
    }

    #[test]
    fn gather_accumulates_repeated_tokens() {
        let mut tp = Tape::new();
        let embed = tp.input(Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]));
        let h = tp.gather(embed, &[0, 2, 0, 1], 1, 4);
        assert_eq!(tp.val(h).shape, vec![1, 4, 2]);
        assert_eq!(tp.val(h).data, vec![1., 2., 5., 6., 1., 2., 3., 4.]);
        let tgt = Tensor::zeros(&[1, 4, 2]);
        let loss = tp.distance(h, &tgt, 0.0);
        let grads = tp.backward(loss);
        let ge = Tape::grad(&grads, embed, &[3, 2]);
        // token 0 used twice -> its gradient row accumulates both positions
        let n = 8.0f32;
        assert!((ge.data[0] - 2.0 * (1.0 + 1.0) / n).abs() < 1e-6);
        assert!((ge.data[2] - 2.0 * 3.0 / n).abs() < 1e-6);
    }
}
