//! PB-LLM (Shang et al., 2023): partially-binarized LLM. An *unstructured*
//! element-wise mask keeps the top-ρ weights by |magnitude| at 8-bit
//! (per-row RTN) and binarizes the rest — the 2.7-effective-bit baseline
//! whose mask cost motivates the paper's structured alternative.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::packing::BitVec;
use crate::quant::container::PbLlmPacked;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct PbLlm {
    pub salient_ratio: f64,
}

impl PbLlm {
    pub fn new(salient_ratio: f64) -> PbLlm {
        PbLlm { salient_ratio }
    }
}

impl Quantizer for PbLlm {
    fn name(&self) -> &'static str {
        "PB-LLM"
    }

    fn bits_label(&self) -> String {
        "1.7(+1)".into()
    }

    fn quantize_linear(&self, w: &Tensor, _calib: &LinearCalib) -> QuantizedLinear {
        let (n, m) = (w.rows(), w.cols());
        let total = n * m;
        let k = ((total as f64) * self.salient_ratio).round() as usize;
        // global top-k by |w| (unstructured mask)
        let mut idx: Vec<usize> = (0..total).collect();
        idx.sort_by(|&a, &b| {
            w.data[b].abs().partial_cmp(&w.data[a].abs()).unwrap()
        });
        let mut salient = vec![false; total];
        for &i in &idx[..k] {
            salient[i] = true;
        }
        let mut deq = Tensor::zeros(&[n, m]);
        // packed planes, carried from this pass: compacted salient codes
        // and non-salient sign bits in row-major walk order
        let mut codes: Vec<u16> = Vec::with_capacity(k);
        let mut sign_bools: Vec<bool> = Vec::with_capacity(total - k);
        let mut row_scale = Vec::with_capacity(n);
        let mut row_min = Vec::with_capacity(n);
        let mut row_alpha = Vec::with_capacity(n);
        for r in 0..n {
            // 8-bit asymmetric grid over the salient entries of this row
            let row = w.row(r);
            let sal_vals: Vec<f32> = (0..m)
                .filter(|&c| salient[r * m + c])
                .map(|c| row[c])
                .collect();
            let (mn, mx) = if sal_vals.is_empty() {
                (0.0, 0.0)
            } else {
                (
                    sal_vals.iter().cloned().fold(f32::INFINITY, f32::min),
                    sal_vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
                )
            };
            let scale = ((mx - mn) / 255.0).max(1e-8);
            // binarization alpha over the non-salient entries
            let ns: Vec<f32> = (0..m)
                .filter(|&c| !salient[r * m + c])
                .map(|c| row[c].abs())
                .collect();
            let alpha = if ns.is_empty() {
                0.0
            } else {
                ns.iter().sum::<f32>() / ns.len() as f32
            };
            row_scale.push(scale);
            row_min.push(mn);
            row_alpha.push(alpha);
            for c in 0..m {
                let x = row[c];
                deq.data[r * m + c] = if salient[r * m + c] {
                    let q = ((x - mn) / scale).round().clamp(0.0, 255.0);
                    codes.push(q as u16);
                    q * scale + mn
                } else {
                    sign_bools.push(x >= 0.0);
                    if x >= 0.0 {
                        alpha
                    } else {
                        -alpha
                    }
                };
            }
        }
        let container = PbLlmPacked::new(
            &salient,
            codes,
            row_scale,
            row_min,
            row_alpha,
            BitVec::from_bools(&sign_bools),
            &deq,
        );
        QuantizedLinear {
            deq,
            scheme: BitScheme::PbLlm { salient_ratio: self.salient_ratio },
            parts: None,
            container: Some(std::sync::Arc::new(container)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binarize::PlainBinarize;
    use crate::quant::testutil::{demo, output_mse};

    #[test]
    fn better_than_plain_binarization() {
        let (w, calib) = demo(32, 48, 10);
        let p = PbLlm::new(0.1).quantize_linear(&w, &calib);
        let b = PlainBinarize.quantize_linear(&w, &calib);
        assert!(output_mse(&w, &p.deq, 5) < output_mse(&w, &b.deq, 5));
    }

    #[test]
    fn largest_weights_preserved_well() {
        let (w, calib) = demo(16, 32, 11);
        let p = PbLlm::new(0.1).quantize_linear(&w, &calib);
        // the single largest |weight| should be nearly exact (8-bit)
        let (mut bi, mut bv) = (0, 0.0f32);
        for (i, &x) in w.data.iter().enumerate() {
            if x.abs() > bv {
                bv = x.abs();
                bi = i;
            }
        }
        assert!((p.deq.data[bi] - w.data[bi]).abs() < 0.05 * bv);
    }

    #[test]
    fn bits_label_matches_paper() {
        assert_eq!(PbLlm::new(0.1).bits_label(), "1.7(+1)");
    }
}
