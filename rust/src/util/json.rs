//! Minimal JSON parser/serializer (substrate: no serde available offline).
//!
//! Covers the full JSON grammar we exchange with the Python build step
//! (artifacts/manifest.json) plus report emission. Numbers are f64;
//! object key order is preserved (Vec of pairs) so emitted reports diff
//! cleanly.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: object -> BTreeMap view of top-level keys.
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(kv) => kv.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(true) => s.push_str("true"),
            Json::Bool(false) => s.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{}", n));
                }
            }
            Json::Str(x) => write_escaped(s, x),
            Json::Arr(a) => {
                s.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    v.write(s);
                }
                s.push(']');
            }
            Json::Obj(kv) => {
                s.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    write_escaped(s, k);
                    s.push(':');
                    v.write(s);
                }
                s.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn boolean(b: bool) -> Json {
    Json::Bool(b)
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

fn write_escaped(s: &mut String, x: &str) {
    s.push('"');
    for c in x.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let mut end = start + 1;
                    while end < self.b.len() && self.b[end] & 0xc0 == 0x80 {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap(),
                   &Json::Str("x".into()));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"m","shape":[4,128,128],"ok":true,"x":0.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes_round_trip() {
        let v = Json::Str("π \"q\" \t tab \\ ü".into());
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }
}
