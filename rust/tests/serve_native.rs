//! Native-backend + serve-engine integration tests. These need no
//! artifacts directory: `Runtime::native()` serves the built-in manifest
//! and the pure-Rust executor, so they run in every environment — they are
//! the tier-1 proof that the gradient stack and the continuous-batching
//! engine actually work.

use ptq161::coordinator::pretrain::lm_grad;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::initial_parts;
use ptq161::runtime::{Runtime, Value};
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{generate_batch, Engine, GenRequest, MetricsRegistry};
use ptq161::tensor::Tensor;
use ptq161::util::rng::Rng;

fn demo_tokens(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(256) as i32).collect()
}

#[test]
fn native_forward_is_deterministic_and_near_uniform_at_init() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(3);
    let tokens = demo_tokens(pipe.cfg.b_eval * pipe.cfg.seq, 4);
    let n1 = pipe.nll_sum(&params, &tokens).unwrap();
    let n2 = pipe.nll_sum(&params, &tokens).unwrap();
    assert_eq!(n1, n2);
    // random init => near-uniform next-token distribution
    let per_tok = n1 / pipe.tokens_per_batch() as f32;
    assert!((per_tok - (256f32).ln()).abs() < 0.5, "per-token nll {per_tok}");
}

#[test]
fn lm_grad_descends_loss() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let mut params = pipe.init_params(5);
    let tokens = demo_tokens(pipe.cfg.b_train * pipe.cfg.seq, 6);
    let (l0, grads) = lm_grad(&pipe, &params, &tokens).unwrap();
    for (p, g) in params.tensors.iter_mut().zip(&grads) {
        for (x, gx) in p.data.iter_mut().zip(&g.data) {
            *x -= 0.5 * gx;
        }
    }
    let (l1, _) = lm_grad(&pipe, &params, &tokens).unwrap();
    assert!(l1 < l0, "{l1} !< {l0}");
}

#[test]
fn lm_grad_matches_directional_finite_difference() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(7);
    let tokens = demo_tokens(pipe.cfg.b_train * pipe.cfg.seq, 8);
    let (_, grads) = lm_grad(&pipe, &params, &tokens).unwrap();
    // random unit direction over the full parameter vector
    let mut rng = Rng::new(9);
    let dirs: Vec<Tensor> = params
        .tensors
        .iter()
        .map(|t| Tensor::randn(&t.shape, 1.0, &mut rng))
        .collect();
    let norm: f64 = dirs
        .iter()
        .flat_map(|d| d.data.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt();
    let analytic: f64 = grads
        .iter()
        .zip(&dirs)
        .flat_map(|(g, d)| g.data.iter().zip(&d.data))
        .map(|(&g, &d)| (g as f64) * (d as f64))
        .sum::<f64>()
        / norm;
    let loss_at = |eps: f32| -> f64 {
        let mut p = params.clone();
        for (t, d) in p.tensors.iter_mut().zip(&dirs) {
            for (x, dx) in t.data.iter_mut().zip(&d.data) {
                *x += eps * dx / norm as f32;
            }
        }
        lm_grad(&pipe, &p, &tokens).unwrap().0 as f64
    };
    let eps = 1e-2f32;
    let numeric = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps as f64);
    let tol = 0.1 * numeric.abs().max(analytic.abs()).max(0.02);
    assert!(
        (numeric - analytic).abs() < tol,
        "finite diff {numeric} vs analytic {analytic}"
    );
}

/// Build PTQ1.61 parts for every linear of one micro layer with a fixed
/// structured mask (every 4th input channel salient).
fn layer_parts(params: &Params, l: usize) -> Vec<[Tensor; 6]> {
    LINEARS
        .iter()
        .map(|lin| {
            let w = params.get(&format!("l{l}.{lin}"));
            let mask: Vec<bool> = (0..w.cols()).map(|j| j % 4 == 0).collect();
            let p = initial_parts(w, &mask);
            let out = p.alpha_s.len();
            let inn = p.alpha_r2.len();
            [
                p.w_sal.clone(),
                p.sign_ns.clone(),
                Tensor::from_vec(&[out], p.alpha_s.clone()),
                Tensor::from_vec(&[out], p.alpha_r1.clone()),
                Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                Tensor::from_vec(&[out], p.mu.clone()),
            ]
        })
        .collect()
}

#[test]
fn fused_qblock_matches_dense_dequantized_block() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(11);
    let mut rng = Rng::new(12);
    let h = Tensor::randn(&[pipe.cfg.b_eval, pipe.cfg.seq, pipe.cfg.d], 1.0, &mut rng);
    let qparts = layer_parts(&params, 0);
    // dense path: same block with the dequantized weights substituted
    let mut dense = params.clone();
    for lin in LINEARS {
        let w = params.get(&format!("l0.{lin}"));
        let mask: Vec<bool> = (0..w.cols()).map(|j| j % 4 == 0).collect();
        *dense.get_mut(&format!("l0.{lin}")) = initial_parts(w, &mask).dequantize();
    }
    let fused = pipe
        .qblock_fwd(&h, params.get("l0.attn_norm"), params.get("l0.mlp_norm"), &qparts)
        .unwrap();
    let ref_out = pipe.block_fwd(&h, &dense.block(0)).unwrap();
    let rel = fused.mse(&ref_out) / ref_out.frob_norm().powi(2).max(1e-9)
        * ref_out.numel() as f32;
    assert!(rel < 1e-6, "fused vs dense relative mse {rel}");
}

#[test]
fn block_opt_grad_matches_directional_finite_difference() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(13);
    let mut rng = Rng::new(14);
    let x_q =
        Tensor::randn(&[pipe.cfg.b_eval, pipe.cfg.seq, pipe.cfg.d], 1.0, &mut rng);
    let block = params.block(0);
    let f1 = pipe.block_fwd(&x_q, &block).unwrap();
    let f3 = f1.scale(1.05);
    let attn_norm = params.get("l0.attn_norm").clone();
    let mlp_norm = params.get("l0.mlp_norm").clone();
    let qparts = layer_parts(&params, 0);
    let learn: Vec<Tensor> = qparts
        .iter()
        .flat_map(|p| [p[2].clone(), p[3].clone(), p[4].clone(), p[5].clone()])
        .collect();
    let consts: Vec<Tensor> =
        qparts.iter().flat_map(|p| [p[0].clone(), p[1].clone()]).collect();
    let run = |learn: &[Tensor]| -> (f32, Vec<Tensor>) {
        let mut inputs: Vec<Value> = learn.iter().map(Value::from).collect();
        inputs.push((&x_q).into());
        inputs.push((&f1).into());
        inputs.push((&f3).into());
        inputs.push((&attn_norm).into());
        inputs.push((&mlp_norm).into());
        inputs.extend(consts.iter().map(Value::from));
        inputs.push(Tensor::from_vec(&[], vec![1.0]).into());
        let mut out = rt.run_cfg("block_opt_grad", "micro", &inputs).unwrap();
        let grads = out.split_off(1);
        (out[0].data[0], grads)
    };
    let (_, grads) = run(&learn);
    let dirs: Vec<Tensor> = learn
        .iter()
        .map(|t| Tensor::randn(&t.shape, 1.0, &mut rng))
        .collect();
    let norm: f64 = dirs
        .iter()
        .flat_map(|d| d.data.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt();
    let analytic: f64 = grads
        .iter()
        .zip(&dirs)
        .flat_map(|(g, d)| g.data.iter().zip(&d.data))
        .map(|(&g, &d)| (g as f64) * (d as f64))
        .sum::<f64>()
        / norm;
    let loss_at = |eps: f32| -> f64 {
        let shifted: Vec<Tensor> = learn
            .iter()
            .zip(&dirs)
            .map(|(t, d)| {
                t.zip(&d.scale(eps / norm as f32), |a, b| a + b)
            })
            .collect();
        run(&shifted).0 as f64
    };
    let eps = 5e-3f32;
    let numeric = (loss_at(eps) - loss_at(-eps)) / (2.0 * eps as f64);
    let tol = 0.1 * numeric.abs().max(analytic.abs()).max(0.02);
    assert!(
        (numeric - analytic).abs() < tol,
        "finite diff {numeric} vs analytic {analytic}"
    );
}

#[test]
fn engine_refills_lanes_mid_flight() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(21);
    let me = ModelEval::Dense(&params);
    assert_eq!(pipe.cfg.b_eval, 2);
    let lens = [1usize, 6, 1, 1, 2];
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for (i, &n) in lens.iter().enumerate() {
        batcher.submit(GenRequest {
            prompt: format!("ab{i}"),
            max_new_tokens: n,
        });
    }
    let mut metrics = MetricsRegistry::new("refill");
    let mut engine = Engine::new(&pipe, &me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), lens.len());
    for (r, &want) in resps.iter().zip(&lens) {
        assert_eq!(r.new_tokens, want, "request {} token count", r.id);
        // the latency split reported by Engine::finish must be consistent
        assert!((r.queue_ms + r.decode_ms - r.latency_ms).abs() < 1e-6);
        assert!(r.queue_ms >= 0.0 && r.decode_ms >= 0.0);
    }
    let total: usize = lens.iter().sum();
    // every decode step produced one token per active lane
    assert_eq!(metrics.total_tokens, total);
    assert_eq!(metrics.active_lane_steps, total);
    // continuous batching: finished lanes refill mid-flight, so the whole
    // workload fits in far fewer steps than the drained equivalent
    // (batches of (1,6), (1,1), (2) -> 6+1+2 = 9 fixed-width steps)
    assert!(metrics.steps >= total.div_ceil(pipe.cfg.b_eval));
    assert!(metrics.steps <= 7, "steps {}", metrics.steps);
    assert!(metrics.lane_occupancy() > 0.7, "occupancy {}", metrics.lane_occupancy());
}

#[test]
fn engine_zero_token_requests_complete_immediately() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(22);
    let me = ModelEval::Dense(&params);
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    batcher.submit(GenRequest { prompt: "hi".into(), max_new_tokens: 0 });
    batcher.submit(GenRequest { prompt: "yo".into(), max_new_tokens: 3 });
    let mut metrics = MetricsRegistry::new("zero");
    let mut engine = Engine::new(&pipe, &me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), 2);
    assert_eq!(resps[0].new_tokens, 0);
    assert_eq!(resps[0].text, "hi");
    assert_eq!(resps[1].new_tokens, 3);
    // an all-zero-token workload must terminate without a forward pass
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for _ in 0..3 {
        batcher.submit(GenRequest { prompt: "p".into(), max_new_tokens: 0 });
    }
    let mut m2 = MetricsRegistry::new("zero-only");
    let resps = engine.run(&mut batcher, &mut m2).unwrap();
    assert_eq!(resps.len(), 3);
    assert_eq!(m2.steps, 0);
}

#[test]
fn generate_batch_keeps_request_order() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(23);
    let me = ModelEval::Dense(&params);
    let reqs: Vec<GenRequest> = [3usize, 1]
        .iter()
        .map(|&n| GenRequest { prompt: "q".into(), max_new_tokens: n })
        .collect();
    let resps = generate_batch(&pipe, &me, &reqs).unwrap();
    assert_eq!(resps.len(), 2);
    assert_eq!(resps[0].new_tokens, 3);
    assert_eq!(resps[1].new_tokens, 1);
}
