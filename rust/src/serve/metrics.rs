//! Serving metrics registry: per-request latency split (queue vs decode),
//! decode throughput, latency percentiles, lane occupancy, and per-step
//! wall times — exported as JSON into `runs_dir()` so sustained-traffic
//! runs leave an auditable record next to the experiment CSVs.
//!
//! The per-step series ([`MetricsRegistry::step_ms`]) is what
//! `benches/bench_serve.rs` uses to show KV-cached decode staying flat in
//! sequence position while the full-window baseline grows.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// Empirical percentile with nearest-rank rounding. Empty input -> 0,
/// single element -> that element.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * p.clamp(0.0, 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// One finished request's accounting.
#[derive(Debug, Clone)]
pub struct RequestMetric {
    /// request id assigned at submit
    pub id: u64,
    /// submit -> lane admission
    pub queue_ms: f64,
    /// lane admission -> last token
    pub decode_ms: f64,
    /// submit -> last token
    pub total_ms: f64,
    /// tokens generated for this request
    pub new_tokens: usize,
    /// high-water mark of KV-cached positions held by this request's slot
    /// (0 on the full-window path, which caches nothing)
    pub cached_positions: usize,
}

/// Accumulates one engine run's serving metrics (see module docs).
#[derive(Debug)]
pub struct MetricsRegistry {
    /// run label, also written into the JSON snapshot
    pub label: String,
    created: Instant,
    first_step: Option<Instant>,
    last_step: Option<Instant>,
    /// decode steps recorded so far
    pub steps: usize,
    /// sum over steps of the number of active lanes (== decoded tokens)
    pub active_lane_steps: usize,
    /// lane capacity observed (max over recorded steps)
    pub capacity: usize,
    /// total new tokens decoded
    pub total_tokens: usize,
    /// per-request accounting, in finish order
    pub requests: Vec<RequestMetric>,
    /// requests dropped because their queue deadline lapsed
    pub expired: usize,
    /// wall time of each decode step, in recording order
    pub step_ms: Vec<f64>,
    /// weight representation the engine decoded from (dense/fused/packed)
    pub backend: Option<String>,
    /// resident bytes of the engine's KV page pool (capacity, not fill)
    pub kv_reserved_bytes: Option<usize>,
    /// high-water bytes of pages actually referenced (shared pages once)
    pub kv_live_bytes: Option<usize>,
    /// positions per KV page
    pub kv_page_size: Option<usize>,
    /// pages in the KV pool
    pub kv_pages_total: Option<usize>,
    /// copy-on-write page splits performed by the cache
    pub kv_cow_splits: Option<u64>,
    /// physical pages allocated over the cache's lifetime (fresh + CoW
    /// copies; adopted shared pages are *not* allocated, so for a fixed
    /// workload this drops when prefix sharing works)
    pub kv_page_allocs: Option<u64>,
    /// prompt positions prefilled (adopted + computed)
    pub prefill_positions: usize,
    /// prompt positions satisfied by shared-prefix page adoption
    pub prefix_reused_positions: usize,
    /// admission attempts deferred because the page pool could not cover
    /// the queue head's reservation (one per engine step spent waiting,
    /// so the count also measures how long backpressure lasted)
    pub kv_backpressure_events: usize,
    /// resident bytes of the prepared packed model (packed backend only)
    pub packed_model_bytes: Option<usize>,
    /// measured effective bits/weight of the packed containers
    pub packed_bits_per_weight: Option<f64>,
}

impl MetricsRegistry {
    /// An empty registry labeled `label`.
    pub fn new(label: &str) -> MetricsRegistry {
        MetricsRegistry {
            label: label.to_string(),
            created: Instant::now(),
            first_step: None,
            last_step: None,
            steps: 0,
            active_lane_steps: 0,
            capacity: 0,
            total_tokens: 0,
            requests: Vec::new(),
            expired: 0,
            step_ms: Vec::new(),
            backend: None,
            kv_reserved_bytes: None,
            kv_live_bytes: None,
            kv_page_size: None,
            kv_pages_total: None,
            kv_cow_splits: None,
            kv_page_allocs: None,
            prefill_positions: 0,
            prefix_reused_positions: 0,
            kv_backpressure_events: 0,
            packed_model_bytes: None,
            packed_bits_per_weight: None,
        }
    }

    /// Record which weight representation served this run.
    pub fn set_backend(&mut self, backend: &str) {
        self.backend = Some(backend.to_string());
    }

    /// Record the paged KV cache's memory split: `reserved` is the page
    /// pool's resident capacity, `live` the high-water bytes of pages
    /// actually referenced (shared pages counted once), plus the paging
    /// geometry, copy-on-write split count, and lifetime page-allocation
    /// count (the sharing-sensitive metric: adopted pages are referenced,
    /// never allocated).
    pub fn set_kv_paging(
        &mut self,
        reserved: usize,
        live: usize,
        page_size: usize,
        pages_total: usize,
        cow_splits: u64,
        page_allocs: u64,
    ) {
        self.kv_reserved_bytes = Some(reserved);
        self.kv_live_bytes = Some(live);
        self.kv_page_size = Some(page_size);
        self.kv_pages_total = Some(pages_total);
        self.kv_cow_splits = Some(cow_splits);
        self.kv_page_allocs = Some(page_allocs);
    }

    /// Record one lane's prefill: `total` prompt positions, of which
    /// `reused` were satisfied by shared-prefix page adoption.
    pub fn record_prefill(&mut self, total: usize, reused: usize) {
        self.prefill_positions += total;
        self.prefix_reused_positions += reused;
    }

    /// Count one admission deferred by page-pool backpressure.
    pub fn record_backpressure(&mut self) {
        self.kv_backpressure_events += 1;
    }

    /// Fraction of prompt positions served from shared prefix pages
    /// instead of the prefill forward (0 when nothing prefilled).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefill_positions == 0 {
            return 0.0;
        }
        self.prefix_reused_positions as f64 / self.prefill_positions as f64
    }

    /// Record the packed model's resident bytes and measured effective
    /// bits/weight (packed backend only).
    pub fn set_packed_model(&mut self, bytes: usize, bits_per_weight: f64) {
        self.packed_model_bytes = Some(bytes);
        self.packed_bits_per_weight = Some(bits_per_weight);
    }

    /// Largest per-request cached-position high-water mark seen (0 when
    /// nothing was cached).
    pub fn peak_cached_positions(&self) -> usize {
        self.requests
            .iter()
            .map(|r| r.cached_positions)
            .max()
            .unwrap_or(0)
    }

    /// Record a decode step observed "now" (zero-duration step window).
    pub fn record_step(&mut self, active: usize, capacity: usize) {
        self.record_step_from(Instant::now(), active, capacity);
    }

    /// Record a step whose forward began at `started` — the decode window
    /// then includes the first step's duration, so single-step runs don't
    /// report a near-zero window (and absurd throughput).
    pub fn record_step_from(&mut self, started: Instant, active: usize, capacity: usize) {
        let now = Instant::now();
        self.first_step.get_or_insert(started);
        self.last_step = Some(now);
        self.steps += 1;
        self.active_lane_steps += active;
        self.capacity = capacity.max(self.capacity);
        self.step_ms.push(now.duration_since(started).as_secs_f64() * 1000.0);
    }

    /// Mean decode-step wall time in ms (0 before the first step).
    pub fn mean_step_ms(&self) -> f64 {
        if self.step_ms.is_empty() {
            return 0.0;
        }
        self.step_ms.iter().sum::<f64>() / self.step_ms.len() as f64
    }

    /// Count `n` newly decoded tokens.
    pub fn record_tokens(&mut self, n: usize) {
        self.total_tokens += n;
    }

    /// Record a finished request's latency split.
    pub fn record_request(&mut self, m: RequestMetric) {
        self.requests.push(m);
    }

    /// Count `n` requests dropped at admission (deadline lapsed).
    pub fn record_expired(&mut self, n: usize) {
        self.expired += n;
    }

    /// Wall-clock of the decode loop in ms (first step -> now-ish).
    pub fn decode_window_ms(&self) -> f64 {
        match (self.first_step, self.last_step) {
            (Some(a), Some(b)) => b.duration_since(a).as_secs_f64() * 1000.0,
            _ => self.created.elapsed().as_secs_f64() * 1000.0,
        }
    }

    /// Decoded tokens per second over the decode window.
    pub fn throughput_tok_s(&self) -> f64 {
        1000.0 * self.total_tokens as f64 / self.decode_window_ms().max(1e-6)
    }

    /// Mean fraction of lanes busy per decode step (1.0 = every lane busy
    /// every step — what continuous batching buys on skewed workloads).
    pub fn lane_occupancy(&self) -> f64 {
        let denom = (self.steps * self.capacity.max(1)) as f64;
        if denom == 0.0 {
            return 0.0;
        }
        self.active_lane_steps as f64 / denom
    }

    fn totals_ms(&self) -> Vec<f64> {
        self.requests.iter().map(|r| r.total_ms).collect()
    }

    /// Median end-to-end request latency (ms).
    pub fn p50_ms(&self) -> f64 {
        percentile(&self.totals_ms(), 0.50)
    }

    /// 95th-percentile end-to-end request latency (ms).
    pub fn p95_ms(&self) -> f64 {
        percentile(&self.totals_ms(), 0.95)
    }

    /// 99th-percentile end-to-end request latency (ms).
    pub fn p99_ms(&self) -> f64 {
        percentile(&self.totals_ms(), 0.99)
    }

    /// Mean submit→admission wait across finished requests (ms).
    pub fn mean_queue_ms(&self) -> f64 {
        let n = self.requests.len().max(1) as f64;
        self.requests.iter().map(|r| r.queue_ms).sum::<f64>() / n
    }

    /// Mean admission→last-token time across finished requests (ms).
    pub fn mean_decode_ms(&self) -> f64 {
        let n = self.requests.len().max(1) as f64;
        self.requests.iter().map(|r| r.decode_ms).sum::<f64>() / n
    }

    /// The full registry as a JSON object (what `write_json` persists).
    /// Memory-accounting entries (backend, KV-cache bytes, packed-model
    /// bytes + effective bits) appear when the engine recorded them.
    pub fn snapshot(&self) -> Json {
        let mut fields = vec![
            ("label", s(&self.label)),
            ("requests", num(self.requests.len() as f64)),
            ("expired", num(self.expired as f64)),
            ("total_new_tokens", num(self.total_tokens as f64)),
            ("decode_steps", num(self.steps as f64)),
            ("lane_capacity", num(self.capacity as f64)),
            ("lane_occupancy", num(self.lane_occupancy())),
            ("decode_window_ms", num(self.decode_window_ms())),
            ("mean_step_ms", num(self.mean_step_ms())),
            ("throughput_tok_s", num(self.throughput_tok_s())),
            ("p50_ms", num(self.p50_ms())),
            ("p95_ms", num(self.p95_ms())),
            ("p99_ms", num(self.p99_ms())),
            ("mean_queue_ms", num(self.mean_queue_ms())),
            ("mean_decode_ms", num(self.mean_decode_ms())),
            ("peak_cached_positions", num(self.peak_cached_positions() as f64)),
            ("prefill_positions", num(self.prefill_positions as f64)),
            (
                "prefix_reused_positions",
                num(self.prefix_reused_positions as f64),
            ),
            ("prefix_hit_rate", num(self.prefix_hit_rate())),
            (
                "kv_backpressure_events",
                num(self.kv_backpressure_events as f64),
            ),
        ];
        if let Some(b) = &self.backend {
            fields.push(("backend", s(b)));
        }
        if let Some(n) = self.kv_reserved_bytes {
            fields.push(("kv_reserved_bytes", num(n as f64)));
        }
        if let Some(n) = self.kv_live_bytes {
            fields.push(("kv_live_bytes", num(n as f64)));
        }
        if let Some(n) = self.kv_page_size {
            fields.push(("kv_page_size", num(n as f64)));
        }
        if let Some(n) = self.kv_pages_total {
            fields.push(("kv_pages_total", num(n as f64)));
        }
        if let Some(n) = self.kv_cow_splits {
            fields.push(("kv_cow_splits", num(n as f64)));
        }
        if let Some(n) = self.kv_page_allocs {
            fields.push(("kv_page_allocs", num(n as f64)));
        }
        if let Some(n) = self.packed_model_bytes {
            fields.push(("packed_model_bytes", num(n as f64)));
        }
        if let Some(b) = self.packed_bits_per_weight {
            fields.push(("packed_bits_per_weight", num(b)));
        }
        fields.push((
            "per_request",
            arr(self.requests.iter().map(|r| {
                obj(vec![
                    ("id", num(r.id as f64)),
                    ("queue_ms", num(r.queue_ms)),
                    ("decode_ms", num(r.decode_ms)),
                    ("total_ms", num(r.total_ms)),
                    ("new_tokens", num(r.new_tokens as f64)),
                    ("cached_positions", num(r.cached_positions as f64)),
                ])
            })),
        ));
        obj(fields)
    }

    /// Write the JSON snapshot to `path`.
    pub fn write_json(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.snapshot().dump())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One-line human summary (tok/s, occupancy, percentiles) to stdout.
    pub fn print_summary(&self) {
        println!(
            "[{}] {} reqs ({} expired) | {} tok in {} steps | {:.1} tok/s | \
             occupancy {:.2} | p50 {:.0} ms p95 {:.0} ms p99 {:.0} ms | \
             queue {:.0} ms avg",
            self.label,
            self.requests.len(),
            self.expired,
            self.total_tokens,
            self.steps,
            self.throughput_tok_s(),
            self.lane_occupancy(),
            self.p50_ms(),
            self.p95_ms(),
            self.p99_ms(),
            self.mean_queue_ms(),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_empty_is_zero() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[], 0.99), 0.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[42.0], 0.0), 42.0);
        assert_eq!(percentile(&[42.0], 0.5), 42.0);
        assert_eq!(percentile(&[42.0], 1.0), 42.0);
    }

    #[test]
    fn percentile_orders_input() {
        let xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
    }

    #[test]
    fn percentile_clamps_p() {
        let xs = vec![1.0, 2.0];
        assert_eq!(percentile(&xs, -1.0), 1.0);
        assert_eq!(percentile(&xs, 2.0), 2.0);
    }

    #[test]
    fn registry_accounting() {
        let mut m = MetricsRegistry::new("test");
        m.record_step(2, 4);
        m.record_step(4, 4);
        m.record_tokens(6);
        m.record_request(RequestMetric {
            id: 0,
            queue_ms: 10.0,
            decode_ms: 30.0,
            total_ms: 40.0,
            new_tokens: 6,
            cached_positions: 9,
        });
        assert_eq!(m.steps, 2);
        assert!((m.lane_occupancy() - 0.75).abs() < 1e-9);
        assert_eq!(m.p50_ms(), 40.0);
        assert_eq!(m.p99_ms(), 40.0);
        assert!((m.mean_queue_ms() - 10.0).abs() < 1e-9);
        assert_eq!(m.peak_cached_positions(), 9);
    }

    #[test]
    fn memory_accounting_round_trips_through_json() {
        let mut m = MetricsRegistry::new("mem");
        m.set_backend("packed");
        m.set_kv_paging(4096, 512, 16, 8, 3, 6);
        m.set_packed_model(4096, 1.61);
        let back = Json::parse(&m.snapshot().dump()).unwrap();
        assert_eq!(back.get("backend").and_then(Json::as_str), Some("packed"));
        assert_eq!(
            back.get("kv_reserved_bytes").and_then(Json::as_usize),
            Some(4096)
        );
        assert_eq!(
            back.get("kv_live_bytes").and_then(Json::as_usize),
            Some(512)
        );
        assert_eq!(back.get("kv_page_size").and_then(Json::as_usize), Some(16));
        assert_eq!(back.get("kv_pages_total").and_then(Json::as_usize), Some(8));
        assert_eq!(back.get("kv_cow_splits").and_then(Json::as_usize), Some(3));
        assert_eq!(back.get("kv_page_allocs").and_then(Json::as_usize), Some(6));
        assert_eq!(
            back.get("packed_model_bytes").and_then(Json::as_usize),
            Some(4096)
        );
        let bits = back
            .get("packed_bits_per_weight")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((bits - 1.61).abs() < 1e-9);
        // absent until the engine records them
        let empty = Json::parse(&MetricsRegistry::new("x").snapshot().dump()).unwrap();
        assert!(empty.get("backend").is_none());
        assert!(empty.get("kv_reserved_bytes").is_none());
        assert!(empty.get("packed_model_bytes").is_none());
    }

    #[test]
    fn prefix_hit_rate_accounting() {
        let mut m = MetricsRegistry::new("prefix");
        assert_eq!(m.prefix_hit_rate(), 0.0, "no prefill yet");
        m.record_prefill(16, 0);
        m.record_prefill(16, 12);
        m.record_backpressure();
        assert_eq!(m.prefill_positions, 32);
        assert_eq!(m.prefix_reused_positions, 12);
        assert!((m.prefix_hit_rate() - 12.0 / 32.0).abs() < 1e-12);
        let back = Json::parse(&m.snapshot().dump()).unwrap();
        assert_eq!(
            back.get("prefix_reused_positions").and_then(Json::as_usize),
            Some(12)
        );
        assert_eq!(
            back.get("kv_backpressure_events").and_then(Json::as_usize),
            Some(1)
        );
        let rate = back.get("prefix_hit_rate").and_then(Json::as_f64).unwrap();
        assert!((rate - 0.375).abs() < 1e-9);
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let mut m = MetricsRegistry::new("snap");
        m.record_step(1, 2);
        m.record_tokens(3);
        let dumped = m.snapshot().dump();
        let back = Json::parse(&dumped).unwrap();
        assert_eq!(back.get("label").and_then(Json::as_str), Some("snap"));
        assert_eq!(back.get("total_new_tokens").and_then(Json::as_usize), Some(3));
        assert!(back.get("throughput_tok_s").and_then(Json::as_f64).is_some());
        assert!(back.get("p95_ms").is_some());
    }

    #[test]
    fn write_json_creates_file() {
        let m = MetricsRegistry::new("file");
        let path = std::env::temp_dir().join("ptq161_metrics_test.json");
        m.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        std::fs::remove_file(path).ok();
    }
}
