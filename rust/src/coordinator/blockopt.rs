//! Block-wise scaling-factor optimization (paper section 3.3).
//!
//! For each transformer block in order, the coordinator:
//!   1. precomputes the two FP branches of Eq. 7 per calibration batch:
//!      f1 = F(X, W) on FP inputs, f3 = F(X_q, W) on quantized-prefix
//!      inputs (error-propagation branch),
//!   2. runs the AOT `block_opt_grad` executable (loss Eq. 5-7, gradients
//!      wrt alpha_s / alpha_r1 / alpha_r2 / mu through the Pallas kernel's
//!      custom VJP) for `epochs` passes over the batches with AdamW on the
//!      host,
//!   3. writes the learned factors back into the `Ptq161Parts` and
//!      propagates the quantized-prefix inputs through the optimized
//!      quantized block (fused-kernel artifact).
//!
//! `nlc_w = 0` drops the angular (-log cos) term (Table 7 ablation);
//! `learn_mu` enables the QA-LoRA-style learnable row mean (Table 9).

use anyhow::Result;

use super::capture::ModelCalib;
use super::quantize::QuantModel;
use super::Pipeline;
use crate::model::{Params, LINEARS};
use crate::opt::AdamW;
use crate::quant::ptq161::{initial_parts, structured_mask, MaskCriterion};
use crate::quant::Ptq161Parts;
use crate::runtime::Value;
use crate::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct BlockOptCfg {
    pub epochs: usize,
    pub lr: f32,
    /// weight of the angular loss term (paper: on; Table 7 w/o: 0.0)
    pub nlc_w: f32,
    /// learn the per-row mean mu (Table 9; off in standard PTQ1.61)
    pub learn_mu: bool,
    pub salient_ratio: f64,
    pub criterion: MaskCriterion,
    pub verbose: bool,
}

impl Default for BlockOptCfg {
    fn default() -> Self {
        BlockOptCfg {
            epochs: 12,
            lr: 1e-3,
            nlc_w: 1.0,
            learn_mu: false,
            salient_ratio: 0.2,
            criterion: MaskCriterion::ActivationMagnitude,
            verbose: false,
        }
    }
}

fn parts_to_qparts(parts: &[Ptq161Parts]) -> Vec<[Tensor; 6]> {
    parts
        .iter()
        .map(|p| {
            let out = p.alpha_s.len();
            let inn = p.alpha_r2.len();
            [
                p.w_sal.clone(),
                p.sign_ns.clone(),
                Tensor::from_vec(&[out], p.alpha_s.clone()),
                Tensor::from_vec(&[out], p.alpha_r1.clone()),
                Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                Tensor::from_vec(&[out], p.mu.clone()),
            ]
        })
        .collect()
}

/// Full PTQ1.61 with block-wise optimization. Returns the QuantModel with
/// learned scaling factors and the per-block final losses.
pub fn ptq161_optimize(
    pipe: &Pipeline,
    params: &Params,
    calib: &ModelCalib,
    cfg: &BlockOptCfg,
) -> Result<(QuantModel, Vec<f32>)> {
    let n_layers = pipe.cfg.n_layers;
    let n_batches = calib.block_inputs[0].len();
    // initial analytic decomposition per layer
    let mut parts_all: Vec<Vec<Ptq161Parts>> = (0..n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let c = calib.get(l, lin);
                    let mask = structured_mask(
                        &c.act_abs_mean,
                        &c.act_sq_mean,
                        cfg.salient_ratio,
                        cfg.criterion,
                    );
                    initial_parts(params.get(&format!("l{l}.{lin}")), &mask)
                })
                .collect()
        })
        .collect();

    // FP and quantized-prefix block-input streams
    let mut h_fp: Vec<Tensor> = calib.block_inputs[0].clone();
    let mut h_q: Vec<Tensor> = h_fp.clone();
    let mut final_losses = Vec::new();

    for l in 0..n_layers {
        let block = params.block(l);
        let attn_norm = block[0].clone();
        let mlp_norm = block[5].clone();
        // precompute the FP branches once per batch
        let mut f1 = Vec::with_capacity(n_batches);
        let mut f3 = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            f1.push(pipe.block_fwd(&h_fp[b], &block)?);
            f3.push(pipe.block_fwd(&h_q[b], &block)?);
        }
        // learnable tensors in artifact order: per linear [a_s, r1, r2, mu]
        let mut learn: Vec<Tensor> = Vec::with_capacity(4 * LINEARS.len());
        for p in &parts_all[l] {
            let out = p.alpha_s.len();
            let inn = p.alpha_r2.len();
            learn.push(Tensor::from_vec(&[out], p.alpha_s.clone()));
            learn.push(Tensor::from_vec(&[out], p.alpha_r1.clone()));
            learn.push(Tensor::from_vec(&[inn], p.alpha_r2.clone()));
            learn.push(Tensor::from_vec(&[out], p.mu.clone()));
        }
        let consts: Vec<Tensor> = parts_all[l]
            .iter()
            .flat_map(|p| [p.w_sal.clone(), p.sign_ns.clone()])
            .collect();
        let mut opt = AdamW::new(cfg.lr, learn.len());
        let mut last_loss = 0.0;
        for epoch in 0..cfg.epochs {
            let mut epoch_loss = 0.0;
            for b in 0..n_batches {
                let mut inputs: Vec<Value> =
                    learn.iter().map(Value::from).collect();
                inputs.push((&h_q[b]).into());
                inputs.push((&f1[b]).into());
                inputs.push((&f3[b]).into());
                inputs.push((&attn_norm).into());
                inputs.push((&mlp_norm).into());
                inputs.extend(consts.iter().map(Value::from));
                inputs.push(Tensor::from_vec(&[], vec![cfg.nlc_w]).into());
                let mut out =
                    pipe.rt.run_cfg("block_opt_grad", pipe.cname(), &inputs)?;
                let grads = out.split_off(1);
                epoch_loss += out[0].data[0];
                let mut grads = grads;
                if !cfg.learn_mu {
                    // freeze mu at zero: kill its gradient slots (every 4th)
                    for (i, g) in grads.iter_mut().enumerate() {
                        if i % 4 == 3 {
                            for x in g.data.iter_mut() {
                                *x = 0.0;
                            }
                        }
                    }
                }
                opt.step(&mut learn, &grads);
            }
            last_loss = epoch_loss / n_batches as f32;
            if cfg.verbose {
                eprintln!(
                    "[blockopt l{l}] epoch {epoch:>3} loss {last_loss:.5}"
                );
            }
        }
        final_losses.push(last_loss);
        // write back learned factors
        for (i, p) in parts_all[l].iter_mut().enumerate() {
            p.alpha_s = learn[4 * i].data.clone();
            p.alpha_r1 = learn[4 * i + 1].data.clone();
            p.alpha_r2 = learn[4 * i + 2].data.clone();
            p.mu = learn[4 * i + 3].data.clone();
        }
        // propagate both streams past this block
        let qparts = parts_to_qparts(&parts_all[l]);
        for b in 0..n_batches {
            h_q[b] =
                pipe.qblock_fwd(&h_q[b], &attn_norm, &mlp_norm, &qparts)?;
            h_fp[b] = f1[b].clone();
        }
    }

    // materialize the dense fake-quant model
    let mut out_params = params.clone();
    for (l, layer) in parts_all.iter().enumerate() {
        for (i, lin) in LINEARS.iter().enumerate() {
            *out_params.get_mut(&format!("l{l}.{lin}")) =
                layer[i].dequantize();
        }
    }
    let avg_bits = crate::packing::bitwidth::average_bits(
        crate::packing::bitwidth::BitScheme::Ptq161 {
            salient_ratio: cfg.salient_ratio,
        },
        4096,
        4096,
    );
    Ok((
        QuantModel {
            method: "PTQ1.61".into(),
            bits_label: "1.61".into(),
            params: out_params,
            parts: Some(parts_all),
            // packed lazily from the optimized parts (PackedModel::pack)
            containers: None,
            avg_bits,
        },
        final_losses,
    ))
}
