//! Property-based testing substrate (proptest unavailable offline).
//!
//! `check(name, cases, gen, prop)` draws `cases` random inputs from `gen`,
//! asserts `prop` on each, and on failure performs greedy shrinking via the
//! input's `Shrink` implementation before panicking with the minimized
//! counterexample. Coordinator invariants (batching, packing, masking) are
//! tested through this module.

use super::rng::Rng;

pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate strictly-smaller inputs; empty when fully minimized.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            vec![self / 2, self - 1]
        }
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            vec![]
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve the vector, drop one element, shrink one element
        out.push(self[..self.len() / 2].to_vec());
        let mut drop_last = self.clone();
        drop_last.pop();
        out.push(drop_last);
        if let Some(smaller) = self[0].shrink().into_iter().next() {
            let mut v = self.clone();
            v[0] = smaller;
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs; shrink on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut generate: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let seed = 0xC0FFEE ^ name.len() as u64;
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let minimized = shrink_loop(input, &prop);
            panic!(
                "property '{name}' failed (case {case}): {msg}\n\
                 minimized counterexample: {minimized:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut failing: T,
    prop: &P,
) -> T {
    'outer: for _ in 0..200 {
        for cand in failing.shrink() {
            if prop(&cand).is_err() {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check("sum-commutes", 100, |r| (r.below(100), r.below(100)),
              |(a, b)| {
                  if a + b == b + a { Ok(()) } else { Err("!".into()) }
              });
    }

    #[test]
    #[should_panic(expected = "minimized counterexample")]
    fn failing_property_shrinks() {
        check("always-small", 50, |r| r.below(1000) + 10, |x| {
            if *x < 5 { Ok(()) } else { Err(format!("{x} too big")) }
        });
    }

    #[test]
    fn shrink_vec_reduces_len() {
        let v = vec![5usize, 6, 7, 8];
        let cands = v.shrink();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }
}
