//! Calibration sampling — the paper's setup: "128 random 2048-token
//! segments sampled from WikiText2". At reproduction scale we default to
//! 32 random seq-length segments from the wiki train split, grouped into
//! (b_eval, t) batches for the capture pipeline.

use super::Corpus;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct CalibSet {
    /// batches of flattened (b, t) token windows
    pub batches: Vec<Vec<i32>>,
    pub b: usize,
    pub t: usize,
}

pub fn sample(
    corpus: &Corpus,
    n_segments: usize,
    b: usize,
    t: usize,
    seed: u64,
) -> CalibSet {
    assert!(n_segments % b == 0, "segments must fill whole batches");
    let mut rng = Rng::new(seed);
    let mut batches = Vec::with_capacity(n_segments / b);
    for _ in 0..n_segments / b {
        batches.push(corpus.batch(b, t, &mut rng));
    }
    CalibSet { batches, b, t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Style;

    #[test]
    fn shapes_and_determinism() {
        let c = Corpus::build(Style::Wiki, 100_000, 5);
        let a = sample(&c, 16, 4, 128, 9);
        let b = sample(&c, 16, 4, 128, 9);
        assert_eq!(a.batches.len(), 4);
        assert_eq!(a.batches[0].len(), 4 * 128);
        assert_eq!(a.batches, b.batches);
        let d = sample(&c, 16, 4, 128, 10);
        assert_ne!(a.batches, d.batches);
    }

    #[test]
    #[should_panic(expected = "whole batches")]
    fn rejects_partial_batches() {
        let c = Corpus::build(Style::Wiki, 50_000, 5);
        let _ = sample(&c, 10, 4, 128, 1);
    }
}
