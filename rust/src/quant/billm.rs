//! BiLLM (Huang et al., 2024): Hessian-guided salient binarization.
//!
//! Salient weights (top by s_ij = h_jj * w_ij^2) get *residual* (order-2)
//! binarization: w ≈ a1 sign(w) + a2 sign(w - a1 sign(w)). Non-salient
//! weights are split per-row into a "concentrated" and a "sparse" magnitude
//! group (optimal |w| threshold by split search), each with its own alpha —
//! the paper's finer-grained multi-group scheme whose unstructured masks
//! cost it an effective 2.1 bits.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::packing::BitVec;
use crate::quant::container::BiLlmPacked;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct BiLlm {
    pub salient_ratio: f64,
    /// candidate split percentiles for the non-salient bell split
    pub split_grid: usize,
}

impl Default for BiLlm {
    fn default() -> Self {
        BiLlm { salient_ratio: 0.1, split_grid: 8 }
    }
}

/// order-2 residual binarization of a value set: returns (a1, a2)
fn residual_alphas(vals: &[f32]) -> (f32, f32) {
    if vals.is_empty() {
        return (0.0, 0.0);
    }
    let n = vals.len() as f32;
    let a1 = vals.iter().map(|x| x.abs()).sum::<f32>() / n;
    let a2 = vals.iter().map(|x| (x.abs() - a1).abs()).sum::<f32>() / n;
    (a1, a2)
}

fn residual_deq(x: f32, a1: f32, a2: f32) -> f32 {
    let s1 = if x >= 0.0 { a1 } else { -a1 };
    let r = x - s1;
    let s2 = if r >= 0.0 { a2 } else { -a2 };
    s1 + s2
}

impl Quantizer for BiLlm {
    fn name(&self) -> &'static str {
        "BiLLM"
    }

    fn bits_label(&self) -> String {
        "1(+1.1)".into()
    }

    fn needs_hessian(&self) -> bool {
        true
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let (n, m) = (w.rows(), w.cols());
        let hdiag: Vec<f32> = match &calib.hessian {
            Some(h) => (0..m).map(|j| h.at2(j, j)).collect(),
            None => calib.act_sq_mean.clone(),
        };
        // element sensitivity h_jj * w^2, global top-k salient
        let total = n * m;
        let k = ((total as f64) * self.salient_ratio).round() as usize;
        let mut idx: Vec<usize> = (0..total).collect();
        idx.sort_by(|&a, &b| {
            let sa = hdiag[a % m] * w.data[a] * w.data[a];
            let sb = hdiag[b % m] * w.data[b] * w.data[b];
            sb.partial_cmp(&sa).unwrap()
        });
        let mut salient = vec![false; total];
        for &i in &idx[..k] {
            salient[i] = true;
        }
        let mut deq = Tensor::zeros(&[n, m]);
        // packed planes carried from this pass, compacted in row-major
        // walk order: two sign bits per salient entry (order-1 +
        // residual), sign + group-select bits per non-salient entry
        let mut sal_sign1 = Vec::with_capacity(k);
        let mut sal_sign2 = Vec::with_capacity(k);
        let mut ns_sign = Vec::with_capacity(total - k);
        let mut ns_group = Vec::with_capacity(total - k);
        let mut row_a1 = Vec::with_capacity(n);
        let mut row_a2 = Vec::with_capacity(n);
        let mut row_alo = Vec::with_capacity(n);
        let mut row_ahi = Vec::with_capacity(n);
        for r in 0..n {
            let row = w.row(r);
            // salient entries: residual binarization
            let sal: Vec<f32> = (0..m)
                .filter(|&c| salient[r * m + c])
                .map(|c| row[c])
                .collect();
            let (a1, a2) = residual_alphas(&sal);
            // non-salient: bell split by |w| threshold, two alphas; pick
            // the split minimizing row reconstruction error
            let ns: Vec<f32> = (0..m)
                .filter(|&c| !salient[r * m + c])
                .map(|c| row[c])
                .collect();
            let mut mags: Vec<f32> = ns.iter().map(|x| x.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut best = (f32::INFINITY, 0.0f32, 0.0f32, 0.0f32);
            for g in 1..self.split_grid {
                let t = if mags.is_empty() {
                    0.0
                } else {
                    mags[(mags.len() - 1) * g / self.split_grid]
                };
                let (lo, hi): (Vec<f32>, Vec<f32>) =
                    ns.iter().partition(|x| x.abs() <= t);
                let alo = if lo.is_empty() {
                    0.0
                } else {
                    lo.iter().map(|x| x.abs()).sum::<f32>() / lo.len() as f32
                };
                let ahi = if hi.is_empty() {
                    0.0
                } else {
                    hi.iter().map(|x| x.abs()).sum::<f32>() / hi.len() as f32
                };
                let err: f32 = ns
                    .iter()
                    .map(|&x| {
                        let a = if x.abs() <= t { alo } else { ahi };
                        let s = if x >= 0.0 { a } else { -a };
                        (x - s) * (x - s)
                    })
                    .sum();
                if err < best.0 {
                    best = (err, t, alo, ahi);
                }
            }
            let (_, t, alo, ahi) = best;
            row_a1.push(a1);
            row_a2.push(a2);
            row_alo.push(alo);
            row_ahi.push(ahi);
            for c in 0..m {
                let x = row[c];
                deq.data[r * m + c] = if salient[r * m + c] {
                    sal_sign1.push(x >= 0.0);
                    let s1 = if x >= 0.0 { a1 } else { -a1 };
                    sal_sign2.push(x - s1 >= 0.0);
                    residual_deq(x, a1, a2)
                } else {
                    ns_group.push(x.abs() <= t);
                    ns_sign.push(x >= 0.0);
                    let a = if x.abs() <= t { alo } else { ahi };
                    if x >= 0.0 {
                        a
                    } else {
                        -a
                    }
                };
            }
        }
        let container = BiLlmPacked::new(
            &salient,
            BitVec::from_bools(&sal_sign1),
            BitVec::from_bools(&sal_sign2),
            BitVec::from_bools(&ns_sign),
            BitVec::from_bools(&ns_group),
            row_a1,
            row_a2,
            row_alo,
            row_ahi,
            &deq,
        );
        QuantizedLinear {
            deq,
            scheme: BitScheme::BiLlm,
            parts: None,
            container: Some(std::sync::Arc::new(container)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::binarize::PlainBinarize;
    use crate::quant::pbllm::PbLlm;
    use crate::quant::testutil::{demo, output_mse};

    #[test]
    fn residual_binarization_reduces_error() {
        let vals = vec![0.5f32, -1.5, 2.0, -0.2, 0.9];
        let (a1, a2) = residual_alphas(&vals);
        let e1: f32 = vals
            .iter()
            .map(|&x| {
                let s = if x >= 0.0 { a1 } else { -a1 };
                (x - s) * (x - s)
            })
            .sum();
        let e2: f32 =
            vals.iter().map(|&x| (x - residual_deq(x, a1, a2)).powi(2)).sum();
        assert!(e2 <= e1);
    }

    #[test]
    fn billm_beats_plain_binarization() {
        let (w, calib) = demo(32, 48, 12);
        let b = BiLlm::default().quantize_linear(&w, &calib);
        let p = PlainBinarize.quantize_linear(&w, &calib);
        assert!(output_mse(&w, &b.deq, 6) < output_mse(&w, &p.deq, 6));
    }

    #[test]
    fn billm_weight_mse_beats_pbllm_weight_payload() {
        // BiLLM's multi-group binarization should beat PB-LLM's plain
        // binarized 90% on pure weight reconstruction of that portion;
        // end-to-end we just check both are sane and BiLLM is competitive.
        let (w, calib) = demo(24, 40, 13);
        let b = BiLlm::default().quantize_linear(&w, &calib);
        let p = PbLlm::new(0.1).quantize_linear(&w, &calib);
        let rb = b.deq.mse(&w);
        let rp = p.deq.mse(&w);
        assert!(rb < rp * 1.5, "billm {rb} vs pbllm {rp}");
    }

    #[test]
    fn bits_label_matches_paper() {
        assert_eq!(BiLlm::default().bits_label(), "1(+1.1)");
    }
}
