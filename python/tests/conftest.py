"""Collection guards: the build-time Python layer needs JAX (and the
kernel sweep needs hypothesis). CI runners and minimal dev machines may
have neither — skip those modules gracefully instead of erroring at
collection, so `pytest python/tests` is green everywhere and simply runs
more of the suite where the deps exist."""

import importlib.util

collect_ignore = []

if importlib.util.find_spec("jax") is None:
    collect_ignore += [
        "test_aot.py",
        "test_kernel.py",
        "test_kernels.py",
        "test_model.py",
    ]
elif importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_kernels.py"]
