//! Packing benches: the bit-exact containers on a real LLaMA layer slice —
//! pack/unpack throughput bounds the (de)serialization cost of a deployed
//! 1.61-bit checkpoint, and the prepared-container matvec is the packed
//! serve path's per-token inner loop (vs the fused path's rebuild-Wq'
//! matmul).

use ptq161::packing::bitpack::BitVec;
use ptq161::packing::nibble::{quantize_column, NibbleVec};
use ptq161::quant::ptq161::{initial_parts, PackedLinear};
use ptq161::runtime::autodiff::{
    packed_qlinear_fwd, packed_qlinear_fwd_scalar, qlinear_fwd,
};
use ptq161::tensor::Tensor;
use ptq161::util::bench::Bencher;
use ptq161::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let n = 4096 * 64; // 64 rows of a 4096-wide layer
    let weights: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let b = Bencher::quick();
    b.run("packing/bitpack_signs_256k", || BitVec::from_signs(&weights));
    let bv = BitVec::from_signs(&weights);
    b.run("packing/unpack_signs_256k", || bv.to_signs());
    let col: Vec<f32> = weights[..4096].to_vec();
    b.run("packing/quant4_column_4096", || quantize_column(&col));
    let (codes, _, _) = quantize_column(&col);
    b.run("packing/nibble_pack_4096", || NibbleVec::from_codes(&codes));

    // prepared packed-weight containers: pack once, then the serve-path
    // matvec against a reconstruction-free 1.61-bit layer
    let (out, inn) = (512, 512);
    let w = Tensor::randn(&[out, inn], 0.1, &mut rng);
    let mask: Vec<bool> = (0..inn).map(|j| j % 5 == 0).collect();
    let parts = initial_parts(&w, &mask);
    b.run("packing/packed_linear_pack_512x512", || {
        PackedLinear::pack(&parts)
    });
    let pl = PackedLinear::pack(&parts);
    let x = Tensor::randn(&[1, inn], 1.0, &mut rng);
    let a_s = Tensor::from_vec(&[out], parts.alpha_s.clone());
    let r1 = Tensor::from_vec(&[out], parts.alpha_r1.clone());
    let r2 = Tensor::from_vec(&[inn], parts.alpha_r2.clone());
    let mu = Tensor::from_vec(&[out], parts.mu.clone());
    b.run("packing/fused_matvec_rebuild_512", || {
        qlinear_fwd(&x, &a_s, &r1, &r2, &mu, &parts.w_sal, &parts.sign_ns)
    });
    // scalar set-bit walk vs the 4-row-tiled whole-word kernel the serve
    // path runs: same containers, bit-identical outputs, the delta is the
    // blocked accumulation's win
    let scalar =
        b.run("packing/packed_matvec_512_scalar", || {
            packed_qlinear_fwd_scalar(&x, &pl)
        });
    let blocked =
        b.run("packing/packed_matvec_512_blocked", || packed_qlinear_fwd(&x, &pl));
    assert_eq!(
        packed_qlinear_fwd(&x, &pl).data,
        packed_qlinear_fwd_scalar(&x, &pl).data,
        "blocked kernel must stay bit-identical to the scalar walk"
    );
    println!(
        "blocked/scalar packed matvec mean: {:.2}x (below 1.0 = blocked wins)",
        blocked.mean_ns / scalar.mean_ns.max(1e-9)
    );
    println!(
        "packed 512x512: {} bytes resident, {:.3} bits/weight",
        pl.resident_bytes(),
        pl.effective_bits()
    );
}
