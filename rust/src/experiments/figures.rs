//! Figure regenerators (paper Figures 1, 3a, 4/10, 5/8, 6, 7 + Appendix A).
//! Each prints the series and dumps a CSV under runs/ for plotting.

use anyhow::Result;

use super::ExperimentCtx;
use crate::coordinator::blockopt::{ptq161_optimize, BlockOptCfg};
use crate::coordinator::preprocess::row_concentration;
use crate::data::tasks::TaskKind;
use crate::eval::zeroshot::run_suite;
use crate::eval::ModelEval;
use crate::packing::bitwidth::{average_bits, BitScheme};
use crate::report::{fmt_ppl, write_csv, Table};

/// Figure 1: PPL vs effective bit-width scatter.
pub fn f1_ppl_vs_bits(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Figure 1: PPL (wiki) vs effective bits",
        &["Method", "Bits/weight", "PPL"],
    );
    let mut rows = Vec::new();
    for (method, scheme) in [
        ("gptq2", BitScheme::Uniform { bits: 2.0 }),
        ("omniquant2", BitScheme::Uniform { bits: 2.0 }),
        ("pbllm", BitScheme::PbLlm { salient_ratio: 0.1 }),
        ("billm", BitScheme::BiLlm),
        ("ptq161", BitScheme::Ptq161 { salient_ratio: 0.2 }),
    ] {
        let bits = average_bits(scheme, 4096, 4096);
        let qm = ctx.quantized(&m, method, method == "ptq161")?;
        let ppl = ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?;
        tbl.row(vec![
            method.to_string(),
            format!("{bits:.2}"),
            fmt_ppl(ppl),
        ]);
        rows.push(format!("{method},{bits:.3},{ppl:.4}"));
    }
    tbl.print();
    write_csv(&crate::runs_dir().join("f1.csv"), "method,bits,ppl", &rows)?;
    Ok(())
}

/// Figure 3a: activation vs weight channel magnitudes (layer 0, wq input).
pub fn f3_activation_stats(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let params = ctx.pretrained(&m)?;
    let mc = ctx.calib(&m, false)?;
    let c = mc.get(0, "wq");
    let w = params.get("l0.wq");
    let mut rows = Vec::new();
    let mut act_sorted = c.act_abs_mean.clone();
    act_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let wmag = w.col_abs_mean();
    let mut w_sorted = wmag.clone();
    w_sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    for j in 0..c.act_abs_mean.len() {
        rows.push(format!(
            "{j},{:.6},{:.6}",
            act_sorted[j], w_sorted[j]
        ));
    }
    let top20 = (act_sorted.len() as f64 * 0.2) as usize;
    let hot: f32 = act_sorted[..top20].iter().sum::<f32>() / top20 as f32;
    let wavg: f32 = wmag.iter().sum::<f32>() / wmag.len() as f32;
    println!("\n== Figure 3a: channel magnitudes (l0.wq) ==");
    println!("top-20% activation channel mean |x| = {hot:.4}");
    println!("weight mean |w|                     = {wavg:.4}");
    println!("ratio                               = {:.1}x", hot / wavg);
    ctx.cache_calib(&m, false, mc);
    write_csv(
        &crate::runs_dir().join("f3a.csv"),
        "rank,act_abs_mean,weight_abs_mean",
        &rows,
    )?;
    Ok(())
}

/// Figures 4/10: salient-weight row concentration before/after preprocess.
pub fn f4_row_concentration(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let pre = ctx.pretrained(&m)?;
    let post = ctx.preprocessed(&m)?;
    let mut tbl = Table::new(
        "Figure 4: salient-weight row concentration (top-20% rows share)",
        &["Linear", "Pretrained", "Preprocessed"],
    );
    let mut rows = Vec::new();
    let n_layers = ctx.pipeline(&m)?.cfg.n_layers;
    for l in 0..n_layers {
        for lin in ["wq", "w_gate"] {
            let name = format!("l{l}.{lin}");
            let a = row_concentration(pre.get(&name), 0.2, 0.2);
            let b = row_concentration(post.get(&name), 0.2, 0.2);
            tbl.row(vec![
                name.clone(),
                format!("{a:.3}"),
                format!("{b:.3}"),
            ]);
            rows.push(format!("{name},{a:.4},{b:.4}"));
        }
    }
    tbl.print();
    write_csv(
        &crate::runs_dir().join("f4.csv"),
        "linear,pretrained,preprocessed",
        &rows,
    )?;
    Ok(())
}

/// Figures 5/8: preprocessing applied under the baselines.
pub fn f5_preprocess_baselines(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Figure 5: baselines with/without preprocessing (PPL wiki)",
        &["Method", "Pretrained", "Preprocessed"],
    );
    let mut rows = Vec::new();
    for method in ["gptq2", "omniquant2", "pbllm", "billm"] {
        let q0 = ctx.quantized(&m, method, false)?;
        let q1 = ctx.quantized(&m, method, true)?;
        let a = ctx.ppl(&m, &q0.params, &ctx.wiki.clone())?;
        let b = ctx.ppl(&m, &q1.params, &ctx.wiki.clone())?;
        tbl.row(vec![method.to_string(), fmt_ppl(a), fmt_ppl(b)]);
        rows.push(format!("{method},{a:.4},{b:.4}"));
    }
    tbl.print();
    write_csv(
        &crate::runs_dir().join("f5.csv"),
        "method,pretrained,preprocessed",
        &rows,
    )?;
    Ok(())
}

/// Figure 6: salient-ratio sweep with achieved bit-width.
pub fn f6_ratio_sweep(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let params = ctx.pretrained(&m)?;
    let mc = ctx.calib(&m, false)?;
    let pipe = ctx.pipeline(&m)?;
    let mut tbl = Table::new(
        "Figure 6: salient ratio sweep",
        &["Ratio", "Bits/weight", "PPL wiki"],
    );
    let mut rows = Vec::new();
    for ratio in [0.0, 0.1, 0.2, 0.3] {
        let (qm, _) = ptq161_optimize(
            &pipe,
            &params,
            &mc,
            &BlockOptCfg {
                epochs: ctx.blockopt_epochs,
                salient_ratio: ratio,
                ..Default::default()
            },
        )?;
        let bits =
            average_bits(BitScheme::Ptq161 { salient_ratio: ratio }, 4096, 4096);
        let ppl = ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?;
        tbl.row(vec![
            format!("{:.0}%", ratio * 100.0),
            format!("{bits:.2}"),
            fmt_ppl(ppl),
        ]);
        rows.push(format!("{ratio},{bits:.3},{ppl:.4}"));
    }
    ctx.cache_calib(&m, false, mc);
    tbl.print();
    write_csv(&crate::runs_dir().join("f6.csv"), "ratio,bits,ppl", &rows)?;
    Ok(())
}

/// Figure 7: zero-shot with vs without preprocessing (PTQ1.61).
pub fn f7_zeroshot_preprocess(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let kinds = [
        TaskKind::Collocation,
        TaskKind::VerbAgreement,
        TaskKind::Cloze,
        TaskKind::Retrieval,
    ];
    let mut header = vec!["Variant"];
    header.extend(kinds.iter().map(|k| k.label()));
    let mut tbl = Table::new("Figure 7: PTQ1.61 zero-shot, preprocessing", &header);
    let mut rows = Vec::new();
    let mut variants = Vec::new();
    for (label, pre) in [("pretrained", false), ("preprocessed", true)] {
        variants.push((label, ctx.quantized(&m, "ptq161", pre)?.params));
    }
    let n_tasks = ctx.tasks_per_suite;
    let pipe = ctx.pipeline(&m)?;
    for (label, params) in &variants {
        let accs = run_suite(
            &pipe,
            &ModelEval::Dense(params),
            &kinds,
            n_tasks,
            81,
        )?;
        let mut cells = vec![label.to_string()];
        cells.extend(accs.iter().map(|(_, a)| format!("{a:.1}")));
        tbl.row(cells);
        rows.push(format!(
            "{label},{}",
            accs.iter()
                .map(|(_, a)| format!("{a:.2}"))
                .collect::<Vec<_>>()
                .join(",")
        ));
    }
    tbl.print();
    write_csv(
        &crate::runs_dir().join("f7.csv"),
        "variant,colloc,verb,cloze,retrieval",
        &rows,
    )?;
    Ok(())
}

/// Appendix A: the closed-form bit accounting at real LLaMA size.
pub fn app_a_bitwidth(_ctx: &mut ExperimentCtx) -> Result<()> {
    let mut tbl = Table::new(
        "Appendix A: average bits/weight, 4096x4096 layer",
        &["Method", "Bits", "Paper"],
    );
    for (label, scheme, paper) in [
        (
            "PTQ1.61 (20% @ 4-bit)",
            BitScheme::Ptq161 { salient_ratio: 0.2 },
            "1.61",
        ),
        ("PB-LLM (10% @ 8-bit)", BitScheme::PbLlm { salient_ratio: 0.1 }, "2.7"),
        ("BiLLM", BitScheme::BiLlm, "2.1"),
        (
            "PTQ1.61 @ 30% salient",
            BitScheme::Ptq161 { salient_ratio: 0.3 },
            "1.91",
        ),
    ] {
        tbl.row(vec![
            label.to_string(),
            format!("{:.3}", average_bits(scheme, 4096, 4096)),
            paper.to_string(),
        ]);
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("appA.csv"))?;
    Ok(())
}
