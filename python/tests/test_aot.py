"""AOT contract tests: the manifest must exactly describe the lowered HLO."""

import json
import os
import re

import pytest

from compile import aot, model as M


def test_artifact_registry_complete():
    arts = aot.build_artifacts(M.CONFIGS["tiny"])
    assert set(arts) == {
        "embed_fwd", "block_fwd", "block_capture", "qblock_fwd",
        "qblock_w4a4_fwd", "head_fwd", "lm_grad", "lora_grad",
        "block_opt_grad",
    }


@pytest.mark.parametrize("cname", ["tiny", "small"])
def test_io_counts(cname):
    cfg = M.CONFIGS[cname]
    arts = aot.build_artifacts(cfg)
    n_params = len(M.param_spec(cfg))
    nlin = cfg["n_layers"] * len(M.LINEARS)
    _, ins, outs = arts["lm_grad"]
    assert len(ins) == n_params + 1 and len(outs) == n_params + 1
    _, ins, outs = arts["lora_grad"]
    assert len(ins) == n_params + 3 * nlin + 1
    assert len(outs) == 1 + 2 * nlin
    _, ins, outs = arts["block_opt_grad"]
    assert len(ins) == 4 * 7 + 5 + 2 * 7 + 1
    assert len(outs) == 1 + 4 * 7
    _, ins, outs = arts["qblock_fwd"]
    assert len(ins) == 3 + 6 * 7


def test_lowered_entry_layout_matches_manifest(tmp_path):
    """Lower one artifact and check the HLO entry layout agrees with the
    manifest's declared shapes (the contract the Rust loader relies on)."""
    cfg = M.CONFIGS["tiny"]
    arts = aot.build_artifacts(cfg)
    fn, ins, outs = arts["block_fwd"]
    text = aot.lower_artifact(fn, ins)
    header = text.splitlines()[0]
    m = re.search(r"entry_computation_layout=\{\((.*)\)->", header)
    assert m, header
    arg_types = re.findall(r"(f32|s32)\[([0-9,]*)\]", m.group(1))
    assert len(arg_types) == len(ins)
    for (ty, dims), io in zip(arg_types, ins):
        want = "s32" if io["dtype"] == "i32" else "f32"
        assert ty == want
        got = [int(x) for x in dims.split(",")] if dims else []
        assert got == io["shape"]


def test_manifest_on_disk_if_built():
    """If `make artifacts` has run, the manifest must list every HLO file."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    man = json.load(open(mpath))
    assert man["linears"] == M.LINEARS
    for art in man["artifacts"]:
        assert os.path.exists(os.path.join(root, art["file"])), art["name"]
        header = open(os.path.join(root, art["file"])).readline()
        n_args = len(re.findall(r"(?:f32|s32|pred)\[", header.split("->")[0]))
        assert n_args == len(art["inputs"]), art["name"]
    for cname, spec in man["param_spec"].items():
        cfg = M.CONFIGS[cname]
        assert [tuple(s) for _, s in spec] == \
            [tuple(s) for _, s in M.param_spec(cfg)]
