//! Admission control for the serve engine: a FIFO queue with deadline and
//! max-wait awareness.
//!
//! Both engine modes admit through `expire_overdue` + `pop_ready` (the
//! engine's `admit`): continuous mode per freed lane, drain mode whenever
//! all lanes are free. `next_batch`/`next_batch_timed` pop whole batches
//! for one-shot callers, and `batch_ready`/`max_wait` are the admission
//! gate for an asynchronous front-end that has to choose between waiting
//! for a full batch and cutting a partial one — the synchronous engine's
//! pre-queued workloads never wait, so nothing in-process consults them.
//!
//! The coordinator invariants tested here (capacity, no starvation, FIFO)
//! are the property-test surface for the serving layer.
//!
//! The multi-worker engine admits through [`ShardedQueue`] instead: the
//! same deadline/max-wait semantics, but with one FIFO shard per worker,
//! placement-aware submission, and work stealing between shards.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::GenRequest;

#[derive(Debug, Clone)]
struct Queued {
    id: u64,
    req: GenRequest,
    submitted: Instant,
    deadline: Option<Duration>,
}

/// A request evicted from a running lane by the scheduler (page pressure
/// or a forced-preemption tick). It parks here — queued-but-not-in-flight
/// — carrying everything needed to restore it by recompute: the full
/// committed token sequence (prompt + tokens generated so far), the
/// original lane shape, and its latency-accounting timestamps. Restore
/// re-prefills `seq` as if it were a prompt; greedy decode is
/// deterministic, so the continuation is byte-identical to a run that was
/// never preempted.
#[derive(Debug, Clone)]
pub struct PreemptedReq {
    /// global request id (unchanged across preempt/restore cycles)
    pub id: u64,
    /// the original request, kept for deadline-expiry reporting
    pub req: GenRequest,
    /// committed tokens: prompt plus everything generated before eviction
    pub seq: Vec<i32>,
    /// prompt span of `seq` (prefix registration + response slicing)
    pub prompt_len: usize,
    /// original generation budget — the page reservation on restore is
    /// `prompt_len + max_new`, same as first admission
    pub max_new: usize,
    /// original submit time (deadline expiry keeps counting while parked)
    pub submitted: Instant,
    /// first admission time (queue-latency accounting spans preemptions)
    pub admitted: Instant,
    pub deadline: Option<Duration>,
    /// when the lane last emitted a token, so the restore's first token
    /// honestly records the parked gap as inter-token latency
    pub last_token_at: Option<Instant>,
    /// admission→first-token wall time if the lane emitted before it was
    /// preempted — a victim's TTFT is its *first* first-token time, so
    /// the restore must not restart the clock
    pub ttft_ms: Option<f64>,
}

impl PreemptedReq {
    fn overdue(&self, now: Instant) -> bool {
        self.deadline
            .map(|d| now.duration_since(self.submitted) >= d)
            .unwrap_or(false)
    }
}

/// FIFO admission queue with deadline expiry and a max-wait batch cut.
#[derive(Debug)]
pub struct Batcher {
    /// widest batch the engine can take (== its lane count)
    pub capacity: usize,
    /// drain-mode cut: launch a partial batch once the oldest request has
    /// waited this long
    pub max_wait: Duration,
    queue: VecDeque<Queued>,
    /// preempted requests, restored before anything in the fresh queue
    parked: VecDeque<PreemptedReq>,
    next_id: u64,
}

impl Batcher {
    /// A queue for an engine of `capacity` lanes (default 50 ms max-wait).
    pub fn new(capacity: usize) -> Batcher {
        assert!(capacity > 0);
        Batcher {
            capacity,
            max_wait: Duration::from_millis(50),
            queue: VecDeque::new(),
            parked: VecDeque::new(),
            next_id: 0,
        }
    }

    /// Builder-style override of the max-wait cut interval.
    pub fn with_max_wait(mut self, max_wait: Duration) -> Batcher {
        self.max_wait = max_wait;
        self
    }

    /// Enqueue a request (no deadline); returns its id.
    pub fn submit(&mut self, req: GenRequest) -> u64 {
        self.submit_with_deadline(req, None)
    }

    /// Submit with a queue-time deadline: if the request is still waiting
    /// for a lane after `deadline`, admission drops it (`expire_overdue`).
    pub fn submit_with_deadline(
        &mut self,
        req: GenRequest,
        deadline: Option<Duration>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Queued {
            id,
            req,
            submitted: Instant::now(),
            deadline,
        });
        id
    }

    /// Requests currently waiting for a lane — fresh and parked alike,
    /// so the engine's run loop cannot exit while a preempted request
    /// still awaits restoration.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.parked.len()
    }

    /// Park a preempted request. Parked requests restore before any
    /// fresh admission ("restore-to-front"): a victim never loses its
    /// place to work that arrived after it.
    pub fn park(&mut self, p: PreemptedReq) {
        self.parked.push_back(p);
    }

    /// Preempted requests awaiting restoration.
    pub fn parked(&self) -> usize {
        self.parked.len()
    }

    /// Borrow the next request to restore (FIFO among parked). The
    /// engine peeks to size the page reservation first; on backpressure
    /// the request stays parked at the head.
    pub fn peek_parked(&self) -> Option<&PreemptedReq> {
        self.parked.front()
    }

    /// Dequeue the request `peek_parked` advertised.
    pub fn pop_parked(&mut self) -> Option<PreemptedReq> {
        self.parked.pop_front()
    }

    /// Pop the next batch (up to capacity, FIFO). Empty queue -> None.
    pub fn next_batch(&mut self) -> Option<Vec<(u64, GenRequest)>> {
        self.next_batch_timed().map(|batch| {
            batch.into_iter().map(|(id, req, _)| (id, req)).collect()
        })
    }

    /// Like `next_batch` but also returns each request's submit time so
    /// the engine can account queue latency.
    pub fn next_batch_timed(&mut self) -> Option<Vec<(u64, GenRequest, Instant)>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.capacity.min(self.queue.len());
        Some(
            self.queue
                .drain(..n)
                .map(|q| (q.id, q.req, q.submitted))
                .collect(),
        )
    }

    /// Drain-mode admission gate: a batch is worth launching when it is
    /// full, or when the oldest waiter has exceeded `max_wait`.
    pub fn batch_ready(&self, now: Instant) -> bool {
        self.queue.len() >= self.capacity
            || self
                .queue
                .front()
                .map(|q| now.duration_since(q.submitted) >= self.max_wait)
                .unwrap_or(false)
    }

    /// Continuous admission: pop the oldest queued request for a freed
    /// lane. FIFO; deadline filtering is done by `expire_overdue` first.
    /// The deadline rides along so a later preemption can park it with
    /// the lane and expiry still covers the parked state.
    pub fn pop_ready(
        &mut self,
        _now: Instant,
    ) -> Option<(u64, GenRequest, Instant, Option<Duration>)> {
        self.queue.pop_front().map(|q| (q.id, q.req, q.submitted, q.deadline))
    }

    /// Look at the request `pop_ready` would return without dequeuing it
    /// — the engine peeks first so admission that fails page-budget
    /// reservation (pool backpressure) leaves the request queued, FIFO
    /// position and deadline intact. Borrowed, not cloned: a
    /// backpressured engine peeks the same head every step.
    pub fn peek_ready(&self, _now: Instant) -> Option<(u64, &GenRequest, Instant)> {
        self.queue.front().map(|q| (q.id, &q.req, q.submitted))
    }

    /// Remove and return every waiting request whose deadline elapsed
    /// before it was (re)admitted. Covers *every* parked state: a
    /// request preempted past its deadline is expired here, not
    /// silently restored — deadlines keep counting from the original
    /// submit time while a request sits preempted.
    pub fn expire_overdue(&mut self, now: Instant) -> Vec<(u64, GenRequest)> {
        let mut kept = VecDeque::with_capacity(self.queue.len());
        let mut expired = Vec::new();
        for q in self.queue.drain(..) {
            let overdue = q
                .deadline
                .map(|d| now.duration_since(q.submitted) >= d)
                .unwrap_or(false);
            if overdue {
                expired.push((q.id, q.req));
            } else {
                kept.push_back(q);
            }
        }
        self.queue = kept;
        let mut kept_parked = VecDeque::with_capacity(self.parked.len());
        for p in self.parked.drain(..) {
            if p.overdue(now) {
                expired.push((p.id, p.req));
            } else {
                kept_parked.push_back(p);
            }
        }
        self.parked = kept_parked;
        expired.sort_by_key(|(id, _)| *id);
        expired
    }
}

#[derive(Debug)]
struct Shards {
    shards: Vec<VecDeque<Queued>>,
    /// per-shard parked (preempted) requests, restored shard-locally
    /// first so a victim's still-registered prefix pages are re-adopted
    /// from the same worker's partition
    parked: Vec<VecDeque<PreemptedReq>>,
    next_id: u64,
}

/// Shared work-stealing admission queue for the sharded engine: one FIFO
/// shard per worker behind a single mutex. Submission places a request on
/// its preferred worker's shard (the prefix-affinity hook) or the
/// least-loaded shard; a worker claims from its own shard first and
/// *steals the oldest request of the most-loaded other shard* when its
/// own is empty, so queued work survives an idle — or dead — worker.
/// Deadline expiry ([`ShardedQueue::expire_overdue`]) and the `max_wait`
/// idle-backoff bound keep [`Batcher`]'s admission semantics.
#[derive(Debug)]
pub struct ShardedQueue {
    /// idle-backoff bound, same semantics as [`Batcher::max_wait`]
    pub max_wait: Duration,
    state: Mutex<Shards>,
}

impl ShardedQueue {
    /// A queue with one shard per worker (default 50 ms max-wait).
    pub fn new(workers: usize) -> ShardedQueue {
        assert!(workers > 0);
        ShardedQueue {
            max_wait: Duration::from_millis(50),
            state: Mutex::new(Shards {
                shards: (0..workers).map(|_| VecDeque::new()).collect(),
                parked: (0..workers).map(|_| VecDeque::new()).collect(),
                next_id: 0,
            }),
        }
    }

    /// Builder-style override of the max-wait bound.
    pub fn with_max_wait(mut self, max_wait: Duration) -> ShardedQueue {
        self.max_wait = max_wait;
        self
    }

    /// Number of shards (== worker count).
    pub fn workers(&self) -> usize {
        self.state.lock().unwrap().shards.len()
    }

    /// Requests waiting across every shard, fresh and parked alike —
    /// worker loops must not exit while a preempted request awaits
    /// restoration somewhere.
    pub fn pending(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.shards.iter().map(|s| s.len()).sum::<usize>()
            + st.parked.iter().map(|s| s.len()).sum::<usize>()
    }

    /// Fresh requests waiting on `worker`'s own shard (stealable by
    /// others). Parked requests are counted by [`ShardedQueue::parked`].
    pub fn pending_for(&self, worker: usize) -> usize {
        self.state.lock().unwrap().shards[worker].len()
    }

    /// Preempted requests parked across every shard.
    pub fn parked(&self) -> usize {
        self.state.lock().unwrap().parked.iter().map(|s| s.len()).sum()
    }

    /// Enqueue with no deadline or placement preference.
    pub fn submit(&self, req: GenRequest) -> u64 {
        self.submit_placed(req, None, None)
    }

    /// Enqueue with placement: `preferred` worker's shard when given and
    /// valid (the prefix-cache routing hook), otherwise the least-loaded
    /// shard, ties to the lowest worker id. Returns the request id —
    /// ids are global across shards, so deadline expiry and response
    /// merging stay totally ordered.
    pub fn submit_placed(
        &self,
        req: GenRequest,
        deadline: Option<Duration>,
        preferred: Option<usize>,
    ) -> u64 {
        let mut st = self.state.lock().unwrap();
        let n = st.shards.len();
        let shard = match preferred {
            Some(w) if w < n => w,
            _ => (0..n).min_by_key(|&w| st.shards[w].len()).unwrap(),
        };
        let id = st.next_id;
        st.next_id += 1;
        st.shards[shard].push_back(Queued {
            id,
            req,
            submitted: Instant::now(),
            deadline,
        });
        id
    }

    /// Claim the next request for `worker`: its own shard's head first
    /// (FIFO), else the *oldest* request of the most-loaded other shard
    /// (work stealing). `None` means every shard is empty. The claim is
    /// atomic under the queue lock — two workers can never pop the same
    /// request.
    pub fn claim(
        &self,
        worker: usize,
    ) -> Option<(u64, GenRequest, Instant, Option<Duration>)> {
        let mut st = self.state.lock().unwrap();
        if let Some(q) = st.shards[worker].pop_front() {
            return Some((q.id, q.req, q.submitted, q.deadline));
        }
        let victim = (0..st.shards.len())
            .filter(|&w| w != worker && !st.shards[w].is_empty())
            .max_by_key(|&w| st.shards[w].len())?;
        let q = st.shards[victim].pop_front().unwrap();
        Some((q.id, q.req, q.submitted, q.deadline))
    }

    /// Park a preempted request on `worker`'s shard. The owning worker
    /// restores it before claiming fresh work; idle siblings (or the
    /// survivors of a worker panic) can adopt it via
    /// [`ShardedQueue::claim_parked`] with `steal`.
    pub fn park(&self, worker: usize, p: PreemptedReq) {
        self.state.lock().unwrap().parked[worker].push_back(p);
    }

    /// Return a claimed-but-inadmissible parked request to the *front*
    /// of `worker`'s shard, keeping restore-to-front ordering across a
    /// page-budget backpressure round trip.
    pub fn park_front(&self, worker: usize, p: PreemptedReq) {
        self.state.lock().unwrap().parked[worker].push_front(p);
    }

    /// Claim the next preempted request to restore: `worker`'s own
    /// parked shard first (FIFO). With `steal`, an otherwise-idle worker
    /// also adopts the oldest parked request of the most-loaded other
    /// shard — this is how a dead worker's preempted lanes survive it.
    /// Atomic under the queue lock, like [`ShardedQueue::claim`].
    pub fn claim_parked(&self, worker: usize, steal: bool) -> Option<PreemptedReq> {
        let mut st = self.state.lock().unwrap();
        if let Some(p) = st.parked[worker].pop_front() {
            return Some(p);
        }
        if !steal {
            return None;
        }
        let victim = (0..st.parked.len())
            .filter(|&w| w != worker && !st.parked[w].is_empty())
            .max_by_key(|&w| st.parked[w].len())?;
        st.parked[victim].pop_front()
    }

    /// Return a claimed-but-inadmissible request to the *front* of
    /// `worker`'s shard (page-pool backpressure): the worker retries it
    /// first on its next admission pass, and an idle sibling can still
    /// steal it. The original submit time (and so deadline accounting)
    /// is preserved.
    pub fn restore(
        &self,
        worker: usize,
        id: u64,
        req: GenRequest,
        submitted: Instant,
        deadline: Option<Duration>,
    ) {
        let mut st = self.state.lock().unwrap();
        st.shards[worker].push_front(Queued { id, req, submitted, deadline });
    }

    /// Remove and return every waiting request (any shard, fresh or
    /// parked) whose deadline elapsed before admission, sorted by id.
    /// Parked coverage matters: a request preempted past its deadline
    /// must be expired, not silently restored.
    pub fn expire_overdue(&self, now: Instant) -> Vec<(u64, GenRequest)> {
        let mut st = self.state.lock().unwrap();
        let mut expired = Vec::new();
        for shard in st.shards.iter_mut() {
            let mut kept = VecDeque::with_capacity(shard.len());
            for q in shard.drain(..) {
                let overdue = q
                    .deadline
                    .map(|d| now.duration_since(q.submitted) >= d)
                    .unwrap_or(false);
                if overdue {
                    expired.push((q.id, q.req));
                } else {
                    kept.push_back(q);
                }
            }
            *shard = kept;
        }
        for shard in st.parked.iter_mut() {
            let mut kept = VecDeque::with_capacity(shard.len());
            for p in shard.drain(..) {
                if p.overdue(now) {
                    expired.push((p.id, p.req));
                } else {
                    kept.push_back(p);
                }
            }
            *shard = kept;
        }
        expired.sort_by_key(|(id, _)| *id);
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn req(n: usize) -> GenRequest {
        GenRequest { prompt: "x".repeat(n % 40 + 1), max_new_tokens: 4 }
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let mut b = Batcher::new(3);
        let ids: Vec<u64> = (0..7).map(|i| b.submit(req(i))).collect();
        let mut drained = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 3);
            drained.extend(batch.into_iter().map(|(id, _)| id));
        }
        assert_eq!(drained, ids);
    }

    #[test]
    fn batcher_invariants_property() {
        // invariant: across any submit/drain interleaving, every request is
        // delivered exactly once, in order, and no batch exceeds capacity
        check(
            "batcher-exactly-once-fifo",
            40,
            |r: &mut Rng| {
                let ops = r.below(60) + 5;
                (0..ops).map(|_| r.below(3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut b = Batcher::new(4);
                let mut submitted = Vec::new();
                let mut delivered = Vec::new();
                for &op in ops {
                    if op < 2 {
                        submitted.push(b.submit(req(op)));
                    } else if let Some(batch) = b.next_batch() {
                        if batch.len() > 4 {
                            return Err("over capacity".into());
                        }
                        delivered.extend(batch.into_iter().map(|(i, _)| i));
                    }
                }
                while let Some(batch) = b.next_batch() {
                    delivered.extend(batch.into_iter().map(|(i, _)| i));
                }
                if delivered != submitted {
                    return Err(format!(
                        "delivered {delivered:?} != submitted {submitted:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b = Batcher::new(2);
        assert!(b.next_batch().is_none());
        b.submit(req(1));
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_wait_cut() {
        let mut b = Batcher::new(4).with_max_wait(Duration::from_millis(20));
        let now = Instant::now();
        // empty queue is never ready
        assert!(!b.batch_ready(now + Duration::from_secs(1)));
        b.submit(req(1));
        // fresh and underfull: wait for more work
        assert!(!b.batch_ready(Instant::now()));
        // the oldest waiter ages past max_wait: cut a partial batch
        assert!(b.batch_ready(Instant::now() + Duration::from_millis(25)));
        // a full batch is ready regardless of age
        for i in 0..3 {
            b.submit(req(i));
        }
        assert!(b.batch_ready(Instant::now()));
    }

    #[test]
    fn deadline_expiry_drops_only_overdue() {
        let mut b = Batcher::new(2);
        let slow = b.submit_with_deadline(req(1), Some(Duration::from_millis(5)));
        let patient = b.submit(req(2));
        let lenient =
            b.submit_with_deadline(req(3), Some(Duration::from_secs(3600)));
        let expired = b.expire_overdue(Instant::now() + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, slow);
        assert_eq!(b.pending(), 2);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch[0].0, patient);
        assert_eq!(batch[1].0, lenient);
    }

    #[test]
    fn pop_ready_is_fifo() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let c = b.submit(req(2));
        let now = Instant::now();
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert_eq!(b.pop_ready(now).unwrap().0, c);
        assert!(b.pop_ready(now).is_none());
    }

    #[test]
    fn peek_ready_does_not_dequeue() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let now = Instant::now();
        // peeking twice sees the same head; the queue is untouched
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.pending(), 1);
        // pop returns exactly what peek advertised
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert!(b.peek_ready(now).is_none());
    }

    #[test]
    fn sharded_empty_steal_returns_none() {
        let q = ShardedQueue::new(3);
        assert!(q.claim(0).is_none(), "empty queue claims nothing");
        let id = q.submit_placed(req(1), None, Some(2));
        assert_eq!(q.pending_for(2), 1);
        // worker 0's shard is empty: the claim steals from shard 2
        assert_eq!(q.claim(0).unwrap().0, id);
        assert_eq!(q.pending(), 0);
        assert!(q.claim(1).is_none(), "stolen work is gone for everyone");
    }

    #[test]
    fn sharded_claim_prefers_local_then_steals_oldest_of_most_loaded() {
        let q = ShardedQueue::new(3);
        let own = q.submit_placed(req(1), None, Some(0));
        let other_a = q.submit_placed(req(2), None, Some(1));
        let other_b = q.submit_placed(req(3), None, Some(1));
        let lone = q.submit_placed(req(4), None, Some(2));
        // local first, FIFO
        assert_eq!(q.claim(0).unwrap().0, own);
        // then steal from the most-loaded shard (1 holds two), oldest first
        assert_eq!(q.claim(0).unwrap().0, other_a);
        // shards 1 and 2 now hold one each; ties steal the lowest id shard
        assert_eq!(q.claim(0).unwrap().0, other_b);
        assert_eq!(q.claim(0).unwrap().0, lone);
        assert!(q.claim(0).is_none());
    }

    #[test]
    fn sharded_contended_claim_is_exactly_once() {
        // the satellite case: N workers race for the last queued request
        let q = ShardedQueue::new(4);
        let id = q.submit(req(1));
        let winners: Vec<u64> = std::thread::scope(|s| {
            let q = &q;
            let handles: Vec<_> =
                (0..4).map(|w| s.spawn(move || q.claim(w))).collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().unwrap())
                .map(|(got, _, _, _)| got)
                .collect()
        });
        assert_eq!(winners, vec![id], "exactly one worker wins the claim");
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn sharded_placement_falls_back_to_least_loaded() {
        let q = ShardedQueue::new(3);
        // no preference: fills shards round-robin via least-loaded + low id
        q.submit(req(1));
        q.submit(req(2));
        q.submit(req(3));
        assert_eq!(
            (q.pending_for(0), q.pending_for(1), q.pending_for(2)),
            (1, 1, 1)
        );
        // an out-of-range preference also falls back to least-loaded
        q.submit_placed(req(4), None, Some(99));
        assert_eq!(q.pending_for(0), 2);
    }

    #[test]
    fn sharded_restore_keeps_fifo_head_and_submit_time() {
        let q = ShardedQueue::new(2);
        let first = q.submit_placed(req(1), None, Some(0));
        let second = q.submit_placed(req(2), None, Some(0));
        let (id, r, submitted, deadline) = q.claim(0).unwrap();
        assert_eq!(id, first);
        // backpressure: the claim goes back to the front, not the back
        q.restore(0, id, r, submitted, deadline);
        assert_eq!(q.claim(0).unwrap().0, first, "restored head claims first");
        assert_eq!(q.claim(0).unwrap().0, second);
    }

    fn parked(id: u64, deadline: Option<Duration>) -> PreemptedReq {
        let now = Instant::now();
        PreemptedReq {
            id,
            req: req(id as usize),
            seq: vec![1, 2, 3],
            prompt_len: 2,
            max_new: 4,
            submitted: now,
            admitted: now,
            deadline,
            last_token_at: None,
            ttft_ms: None,
        }
    }

    #[test]
    fn parked_requests_restore_before_fresh_and_count_as_pending() {
        let mut b = Batcher::new(2);
        b.submit(req(1));
        b.park(parked(7, None));
        // parked work is pending (the run loop must not exit on it) and
        // restores ahead of the fresh FIFO
        assert_eq!(b.pending(), 2);
        assert_eq!(b.parked(), 1);
        assert_eq!(b.peek_parked().unwrap().id, 7);
        assert_eq!(b.peek_parked().unwrap().id, 7, "peek does not dequeue");
        assert_eq!(b.pop_parked().unwrap().id, 7);
        assert!(b.pop_parked().is_none());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn expire_overdue_covers_parked_requests() {
        // regression: a request preempted past its deadline must be
        // expired, not silently restored
        let mut b = Batcher::new(2);
        let fresh_overdue =
            b.submit_with_deadline(req(1), Some(Duration::from_millis(5)));
        let mut gone = parked(90, Some(Duration::from_millis(5)));
        gone.submitted = Instant::now();
        b.park(gone);
        b.park(parked(91, None));
        b.park(parked(92, Some(Duration::from_secs(3600))));
        let expired = b.expire_overdue(Instant::now() + Duration::from_millis(10));
        let ids: Vec<u64> = expired.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![fresh_overdue, 90], "parked overdue expires too");
        assert_eq!(b.parked(), 2, "patient parked requests survive");
        assert_eq!(b.pop_parked().unwrap().id, 91, "parked FIFO intact");
    }

    #[test]
    fn sharded_parked_claims_local_first_then_steals() {
        let q = ShardedQueue::new(3);
        q.park(0, parked(10, None));
        q.park(1, parked(20, None));
        q.park(1, parked(21, None));
        assert_eq!(q.parked(), 3);
        assert_eq!(q.pending(), 3, "parked counts as pending");
        // own shard first, FIFO
        assert_eq!(q.claim_parked(1, false).unwrap().id, 20);
        // no stealing unless asked (a busy worker leaves siblings' parked
        // work to them — restore affinity keeps prefix pages local)
        assert!(q.claim_parked(2, false).is_none());
        // an idle worker adopts orphans from the most-loaded parked shard
        assert_eq!(q.claim_parked(2, true).unwrap().id, 10);
        assert_eq!(q.claim_parked(2, true).unwrap().id, 21);
        assert!(q.claim_parked(2, true).is_none());
    }

    #[test]
    fn sharded_park_front_keeps_restore_ordering() {
        let q = ShardedQueue::new(2);
        q.park(0, parked(30, None));
        q.park(0, parked(31, None));
        let head = q.claim_parked(0, false).unwrap();
        assert_eq!(head.id, 30);
        // backpressured restore goes back to the front, not the back
        q.park_front(0, head);
        assert_eq!(q.claim_parked(0, false).unwrap().id, 30);
        assert_eq!(q.claim_parked(0, false).unwrap().id, 31);
    }

    #[test]
    fn sharded_expire_overdue_covers_parked_shards() {
        let q = ShardedQueue::new(2);
        let fresh = q.submit_placed(req(1), Some(Duration::from_millis(5)), Some(0));
        q.park(0, parked(80, Some(Duration::from_millis(5))));
        q.park(1, parked(81, None));
        let expired = q.expire_overdue(Instant::now() + Duration::from_millis(10));
        let ids: Vec<u64> = expired.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![fresh, 80], "parked overdue expires across shards");
        assert_eq!(q.parked(), 1);
        assert_eq!(q.claim_parked(1, false).unwrap().id, 81);
    }

    #[test]
    fn sharded_deadline_expiry_spans_all_shards() {
        let q = ShardedQueue::new(2);
        let gone_a = q.submit_placed(req(1), Some(Duration::from_millis(5)), Some(0));
        let kept = q.submit_placed(req(2), None, Some(0));
        let gone_b = q.submit_placed(req(3), Some(Duration::from_millis(5)), Some(1));
        let expired = q.expire_overdue(Instant::now() + Duration::from_millis(10));
        let ids: Vec<u64> = expired.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![gone_a, gone_b], "both shards expire, id order");
        assert_eq!(q.pending(), 1);
        assert_eq!(q.claim(1).unwrap().0, kept, "survivor is still stealable");
    }
}
