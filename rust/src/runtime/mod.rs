//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The contract with the Python build step is `artifacts/manifest.json`:
//! every artifact's input/output names, shapes and dtypes in positional
//! order. The executor binds inputs by name, validates shapes eagerly (a
//! mis-ordered literal would otherwise produce silent garbage), compiles
//! each HLO module once, and caches the loaded executable.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use crate::tensor::Tensor;

/// A host-side input value: f32 tensor or i32 token array.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(s, _) => s,
        }
    }

    pub fn tokens(shape: &[usize], data: Vec<i32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Value::I32(shape.to_vec(), data)
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        // single-copy construction (perf iteration 1, EXPERIMENTS.md §Perf):
        // vec1().reshape() costs two copies + a reshape allocation, which
        // dominates input binding on the 40-tensor lm_grad upload path.
        // PTQ161_SLOW_LITERALS=1 re-enables the old path for A/B timing.
        if std::env::var_os("PTQ161_SLOW_LITERALS").is_some() {
            let dims: Vec<i64> =
                self.shape().iter().map(|&d| d as i64).collect();
            return Ok(match self {
                Value::F32(t) => xla::Literal::vec1(&t.data).reshape(&dims)?,
                Value::I32(_, v) => xla::Literal::vec1(v).reshape(&dims)?,
            });
        }
        let lit = match self {
            Value::F32(t) => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &t.shape,
                bytes_of(&t.data),
            )?,
            Value::I32(s, v) => {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    s,
                    bytes_of(v),
                )?
            }
        };
        Ok(lit)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<&Tensor> for Value {
    fn from(t: &Tensor) -> Value {
        Value::F32(t.clone())
    }
}

fn bytes_of<T: Copy>(xs: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            xs.as_ptr() as *const u8,
            std::mem::size_of_val(xs),
        )
    }
}

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// execution counter per artifact, for the perf report
    pub exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open the artifact directory (reads manifest.json, creates the CPU
    /// PJRT client; executables compile lazily on first use).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            dir: dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile an artifact ahead of time (e.g. before a timed section).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.load(name).map(|_| ())
    }

    /// Execute `name` with positionally-ordered inputs; validates count,
    /// shape and dtype against the manifest, returns outputs as Tensors in
    /// manifest order (all our artifact outputs are f32).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        // borrow (not clone) the spec: allocation-free validation on the
        // hot loop (perf iteration 2, EXPERIMENTS.md §Perf)
        let spec = self.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if v.shape() != io.shape.as_slice() {
                bail!(
                    "{name}: input '{}' shape {:?} != manifest {:?}",
                    io.name,
                    v.shape(),
                    io.shape
                );
            }
            let want_i32 = io.dtype == "i32";
            let got_i32 = matches!(v, Value::I32(..));
            if want_i32 != got_i32 {
                bail!("{name}: input '{}' dtype mismatch", io.name);
            }
        }
        let exe = self.load(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        *self
            .exec_counts
            .borrow_mut()
            .entry(name.to_string())
            .or_insert(0) += 1;
        let bufs = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs, manifest wants {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        let mut tensors = Vec::with_capacity(outs.len());
        for (lit, io) in outs.iter().zip(&spec.outputs) {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {}: {e:?}", io.name))?;
            tensors.push(Tensor::from_vec(&io.shape, data));
        }
        Ok(tensors)
    }

    /// Run by (base, config) pair, the common call-site pattern.
    pub fn run_cfg(
        &self,
        base: &str,
        config: &str,
        inputs: &[Value],
    ) -> Result<Vec<Tensor>> {
        self.run(&format!("{base}_{config}"), inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes() {
        let t = Tensor::zeros(&[2, 3]);
        let v: Value = t.into();
        assert_eq!(v.shape(), &[2, 3]);
        let tok = Value::tokens(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(tok.shape(), &[2, 2]);
    }

    #[test]
    #[should_panic]
    fn token_shape_checked() {
        let _ = Value::tokens(&[2, 2], vec![1, 2, 3]);
    }
}
