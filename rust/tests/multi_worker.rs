//! Multi-worker sharded serve tests (tier-1, no artifacts needed): for a
//! fixed request set, `run_sharded` must produce byte-identical
//! per-request responses for every worker count and every backend
//! (greedy decode is per-lane deterministic — scheduling may reorder
//! completion, never tokens); a panicking worker must fail only its own
//! in-flight requests; exhausted per-worker page partitions must
//! backpressure (not lose or corrupt requests); placement must route
//! published prefixes to the owning worker; and the merged metrics must
//! carry the per-worker schema.

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::{initial_parts, PackedModel};
use ptq161::quant::{by_name, LinearCalib, Ptq161Parts};
use ptq161::runtime::kv::PrefixRouter;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::{Batcher, ShardedQueue};
use ptq161::serve::{
    place_request, run_sharded, Engine, EngineCfg, GenRequest,
    MetricsRegistry, ShardRun, ShardSpec,
};
use ptq161::tensor::Tensor;
use ptq161::util::json::Json;
use ptq161::util::rng::Rng;

/// PTQ1.61 parts for every linear with a fixed structured mask.
fn fused_parts(params: &Params, pipe: &Pipeline) -> Vec<Vec<Ptq161Parts>> {
    (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> = (0..w.cols()).map(|j| j % 4 == 0).collect();
                    initial_parts(w, &mask)
                })
                .collect()
        })
        .collect()
}

/// Shared-prefix micro workload, small enough for debug-mode CI.
fn micro_requests() -> Vec<GenRequest> {
    let lens = [4usize, 1, 2, 3, 1, 2];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| GenRequest {
            prompt: format!("SYSTEM: be terse. req {i}"),
            max_new_tokens: n,
        })
        .collect()
}

/// Classic single-loop engine run — the identity baseline. Responses
/// sorted by id (ids are assigned in submit order, like the queue's).
fn baseline(
    pipe: &Pipeline,
    me: &ModelEval,
    reqs: &[GenRequest],
) -> Vec<String> {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new("baseline");
    let mut engine = Engine::new(pipe, me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), reqs.len());
    resps.into_iter().map(|r| r.text).collect()
}

/// Run the workload sharded over `workers` threads; panics propagate
/// into the returned report, never out of this call.
fn sharded(
    pipe: &Pipeline,
    me: &ModelEval,
    reqs: &[GenRequest],
    workers: usize,
    kv_pages: Option<usize>,
    panic_on: Option<u64>,
) -> ShardRun {
    let queue = ShardedQueue::new(workers);
    for r in reqs {
        queue.submit(r.clone());
    }
    let router = PrefixRouter::new(16);
    let cfg = EngineCfg {
        workers,
        panic_on_request: panic_on,
        ..EngineCfg::default()
    };
    let spec = ShardSpec { label: "sharded", page_size: 16, kv_pages };
    run_sharded(pipe, me, &cfg, &queue, &router, &spec).unwrap()
}

#[test]
fn responses_identical_across_worker_counts_and_backends() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(91);
    let parts = fused_parts(&params, &pipe);
    let packed = PackedModel::pack(&parts);
    let reqs = micro_requests();
    let backends: Vec<(&str, ModelEval)> = vec![
        ("dense", ModelEval::Dense(&params)),
        ("packed", ModelEval::Packed { params: &params, packed: &packed }),
    ];
    for (name, me) in &backends {
        let base = baseline(&pipe, me, &reqs);
        // micro has b_eval = 2, so 2 is the max effective worker count
        for workers in [1usize, 2] {
            let run = sharded(&pipe, me, &reqs, workers, None, None);
            assert_eq!(run.worker_panics, 0, "{name}/w{workers}: panicked");
            assert!(run.failed_requests.is_empty());
            assert_eq!(run.responses.len(), reqs.len());
            let texts: Vec<String> =
                run.responses.into_iter().map(|r| r.text).collect();
            assert_eq!(
                texts, base,
                "{name}/w{workers}: tokens diverge from single-loop engine"
            );
        }
    }
}

/// Quantize every block linear with `method` (synthetic calibration) into
/// a dense-baseline params clone plus the prepared container model.
fn quantized_model(
    pipe: &Pipeline,
    params: &Params,
    method: &str,
    seed: u64,
) -> (Params, PackedModel) {
    let mut rng = Rng::new(seed);
    let q = by_name(method).unwrap();
    let mut dense = params.clone();
    let mut layers = Vec::new();
    for l in 0..pipe.cfg.n_layers {
        let mut layer = Vec::new();
        for lin in LINEARS {
            let name = format!("l{l}.{lin}");
            let w = params.get(&name);
            let inn = w.cols();
            let x = Tensor::randn(&[2 * inn, inn], 1.0, &mut rng);
            let mut calib = LinearCalib::empty(inn);
            calib.accumulate(&x, true);
            let ql = q.quantize_linear(w, &calib);
            *dense.get_mut(&name) = ql.deq;
            layer.push(ql.container.unwrap_or_else(|| {
                panic!("{method} must emit a container for {name}")
            }));
        }
        layers.push(layer);
    }
    (dense, PackedModel::from_containers(method, &layers))
}

#[test]
fn cross_method_packed_identical_across_worker_counts() {
    // Non-PTQ1.61 containers through the sharded engine: the packed
    // backend must stay byte-identical to the dense single-loop baseline
    // for every worker count, over the shared-prefix workload (exercises
    // prefix-page adoption against the rank-scan/group-bit decode paths).
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(95);
    let reqs = micro_requests();
    for method in ["billm", "pbllm"] {
        let (dense, packed) = quantized_model(&pipe, &params, method, 96);
        let base = baseline(&pipe, &ModelEval::Dense(&dense), &reqs);
        let pe = ModelEval::Packed { params: &dense, packed: &packed };
        for workers in [1usize, 2] {
            let run = sharded(&pipe, &pe, &reqs, workers, None, None);
            assert_eq!(run.worker_panics, 0, "{method}/w{workers}: panicked");
            assert!(run.failed_requests.is_empty());
            assert_eq!(run.responses.len(), reqs.len());
            let texts: Vec<String> =
                run.responses.into_iter().map(|r| r.text).collect();
            assert_eq!(
                texts, base,
                "{method}/w{workers}: packed shards diverge from dense"
            );
        }
    }
}

#[test]
fn worker_panic_fails_only_its_in_flight_requests() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(92);
    let me = ModelEval::Dense(&params);
    let reqs = micro_requests();
    let base = baseline(&pipe, &me, &reqs);
    // poison request id 2: whichever worker claims it dies at admission
    let run = sharded(&pipe, &me, &reqs, 2, None, Some(2));
    assert_eq!(run.worker_panics, 1, "exactly one worker must die");
    assert_eq!(run.failed_requests, vec![2], "only the poisoned request fails");
    assert_eq!(
        run.responses.len() + run.failed_requests.len(),
        reqs.len(),
        "every request is either answered or reported failed"
    );
    // survivors are untouched — token-identical to the baseline
    for r in &run.responses {
        assert_ne!(r.id, 2);
        assert_eq!(
            r.text,
            base[r.id as usize],
            "request {} corrupted by the sibling's panic",
            r.id
        );
    }
    // the merged metrics carry the containment report
    assert_eq!(run.metrics.worker_panics, 1);
    assert!(run.metrics.worker_stats.iter().any(|w| w.panicked));
}

#[test]
fn worker_panic_while_holding_a_preempted_lane_loses_no_tokens() {
    // Fault injection for the preemption path: the worker that claims
    // the target request preempts it (parking it on its shard) and dies
    // immediately — the exact window where a request is queued-but-not-
    // in-flight. The parked request must survive the panic: a survivor
    // steals it from the dead worker's parked shard and finishes it
    // token-identically. Only requests the dead worker actually held
    // in-flight may fail.
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(97);
    let me = ModelEval::Dense(&params);
    // the target (id 1, shard 1's head) needs several tokens so it is
    // mid-decode when preempted; id 0 decodes long so worker 0 stays
    // pinned on its own shard and cannot race to steal the target —
    // the claimer of the target is then deterministically worker 1, at
    // its first claim, with no completed responses to lose
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| GenRequest {
            prompt: format!("SYSTEM: be terse. req {i}"),
            max_new_tokens: match i {
                0 => 8,
                1 => 5,
                _ => 2,
            },
        })
        .collect();
    let base = baseline(&pipe, &me, &reqs);
    let target = 1u64;
    let queue = ShardedQueue::new(2);
    for r in &reqs {
        queue.submit(r.clone());
    }
    let router = PrefixRouter::new(16);
    let cfg = EngineCfg {
        workers: 2,
        panic_on_preempt_of: Some(target),
        ..EngineCfg::default()
    };
    let spec = ShardSpec { label: "preempt-panic", page_size: 16, kv_pages: None };
    let run = run_sharded(&pipe, &me, &cfg, &queue, &router, &spec).unwrap();
    assert_eq!(run.worker_panics, 1, "exactly one worker must die");
    assert!(
        !run.failed_requests.contains(&target),
        "the preempted request was parked, not in-flight — it must not fail"
    );
    assert_eq!(
        run.responses.len() + run.failed_requests.len(),
        reqs.len(),
        "every request is either answered or reported failed"
    );
    let got = run
        .responses
        .iter()
        .find(|r| r.id == target)
        .expect("the preempted request must be restored by a survivor");
    assert_eq!(
        got.text, base[target as usize],
        "restore on a survivor changed the preempted request's tokens"
    );
    // every survivor response matches the oracle
    for r in &run.responses {
        assert_eq!(r.text, base[r.id as usize], "request {} corrupted", r.id);
    }
    // the survivor's restore shows up in the merged accounting (the dead
    // worker's registry is discarded, so count the restore, which the
    // survivor records)
    assert!(
        run.metrics.restored_positions > 0,
        "the stolen restore must account its recomputed positions"
    );
}

#[test]
fn exhausted_partitions_backpressure_without_losing_requests() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(93);
    let me = ModelEval::Dense(&params);
    // tiny: b_eval 4, seq 128 → 2 lanes per worker at w = 2. With 16
    // aggregate pages each partition gets 8 (the one-window floor), and
    // each request budgets 5 pages — a worker's second admission cannot
    // fit and must backpressure until its first request frees pages.
    let head = "SYSTEM: you are the terse assistant of the upper alda river desk";
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest {
            prompt: format!("{head} {i}"),
            max_new_tokens: 2,
        })
        .collect();
    let run = sharded(&pipe, &me, &reqs, 2, Some(16), None);
    assert_eq!(run.worker_panics, 0);
    assert_eq!(run.responses.len(), reqs.len(), "backpressure lost requests");
    assert!(
        run.metrics.kv_backpressure_events >= 1,
        "undersized partitions must defer admissions"
    );
    // deferral must not change a single token: compare to a run with
    // fully provisioned partitions
    let free = sharded(&pipe, &me, &reqs, 2, None, None);
    assert_eq!(free.metrics.kv_backpressure_events, 0);
    for (a, b) in run.responses.iter().zip(&free.responses) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.text, b.text, "backpressure changed request {}", a.id);
    }
}

#[test]
fn placement_routes_published_prefixes_to_the_owning_worker() {
    // queue + router without an engine: once worker 1 publishes a prompt's
    // prefix pages, submission steers matching prompts to worker 1's
    // shard — and an idle sibling can still steal them
    let router = PrefixRouter::new(4);
    let queue = ShardedQueue::new(2);
    let req = GenRequest {
        prompt: "abcdefgh unique tail".into(),
        max_new_tokens: 2,
    };
    // nothing published yet: no placement hint
    assert_eq!(place_request(&router, &req), None);
    let tokens: Vec<i32> =
        req.prompt.bytes().map(|b| b as i32).collect();
    router.publish(1, &tokens);
    assert_eq!(place_request(&router, &req), Some(1));
    let id = queue.submit_placed(req.clone(), None, place_request(&router, &req));
    assert_eq!(queue.pending_for(1), 1, "placed on the publishing worker");
    assert_eq!(queue.pending_for(0), 0);
    // the owner claims locally
    let (got, _, _, _) = queue.claim(1).unwrap();
    assert_eq!(got, id);
    // … but a starved sibling steals rather than idling
    let id2 = queue.submit_placed(req, None, place_request(&router, &req));
    let (stolen, _, _, _) = queue.claim(0).unwrap();
    assert_eq!(stolen, id2, "worker 0 must steal worker 1's queued work");
}

#[test]
fn merged_metrics_export_per_worker_schema() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(94);
    let me = ModelEval::Dense(&params);
    let reqs = micro_requests();
    let run = sharded(&pipe, &me, &reqs, 2, None, None);
    let m = &run.metrics;
    assert_eq!(m.workers, Some(2));
    assert_eq!(m.worker_stats.len(), 2);
    let total: usize = m.worker_stats.iter().map(|w| w.requests).sum();
    assert_eq!(total, reqs.len(), "per-worker requests must sum to the run");
    let back = Json::parse(&m.snapshot().dump()).unwrap();
    assert_eq!(back.get("workers").and_then(Json::as_usize), Some(2));
    assert_eq!(back.get("worker_panics").and_then(Json::as_usize), Some(0));
    let per = back.get("per_worker").and_then(Json::as_arr).unwrap();
    assert_eq!(per.len(), 2);
    for row in per {
        for key in ["worker", "requests", "steps", "tokens"] {
            assert!(row.get(key).and_then(Json::as_usize).is_some(), "{key}");
        }
        for key in ["occupancy", "mean_step_ms", "p50_ms", "p95_ms", "p99_ms"] {
            assert!(row.get(key).and_then(Json::as_f64).is_some(), "{key}");
        }
    }
    // aggregate percentiles come from the union of per-request rows
    assert_eq!(m.requests.len(), reqs.len());
    assert!(m.p95_ms() >= m.p50_ms());
}
