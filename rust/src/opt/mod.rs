//! Optimizers. The paper uses AdamW for the learnable scaling factors; the
//! same implementation drives pretraining and restorative-LoRA training.

pub mod adamw;

pub use adamw::AdamW;
