//! The paper's structured mask (section 3.2): one bit per *input channel*.
//!
//! Eq. 4 bounds the layer quantization error by
//!   sum_i |x_i| * sum_j |w_ij^q - w_ij|,
//! so channels with large activation magnitude dominate the bound; keeping
//! the top-ρ such channels at 4-bit shrinks it at ~0.0002 extra bits/weight.
//! The Hessian-based variant (OWQ-style diag(H) ranking) exists for the
//! Table 5 comparison, where the paper shows it collapses under
//! binarization.

use crate::packing::bitpack::BitVec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MaskCriterion {
    /// paper's criterion: mean |x| per input channel
    ActivationMagnitude,
    /// OWQ-style: diag(H) = mean x^2 per channel (Table 5 ablation)
    HessianDiag,
}

/// Select exactly round(ratio * m) salient channels by the criterion.
pub fn structured_mask(
    act_abs_mean: &[f32],
    act_sq_mean: &[f32],
    ratio: f64,
    criterion: MaskCriterion,
) -> Vec<bool> {
    let m = act_abs_mean.len();
    let scores = match criterion {
        MaskCriterion::ActivationMagnitude => act_abs_mean,
        MaskCriterion::HessianDiag => act_sq_mean,
    };
    let k = ((m as f64) * ratio).round() as usize;
    let mut idx: Vec<usize> = (0..m).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let mut mask = vec![false; m];
    for &j in idx.iter().take(k) {
        mask[j] = true;
    }
    mask
}

/// Pack the mask into its storage bitmap (the 0.0002-bit/weight artifact).
pub fn pack_mask(mask: &[bool]) -> BitVec {
    BitVec::from_bools(mask)
}

/// Extra bits per weight this mask costs on an (n, m) layer.
pub fn mask_overhead_bits_per_weight(n: usize, m: usize) -> f64 {
    m as f64 / (n as f64 * m as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn selects_exact_count_property() {
        check(
            "mask-selects-exact-count",
            50,
            |r: &mut Rng| {
                let m = r.below(500) + 10;
                (0..m).map(|_| r.f32()).collect::<Vec<f32>>()
            },
            |scores| {
                for ratio in [0.0, 0.1, 0.2, 0.3, 0.5] {
                    let mask = structured_mask(
                        scores,
                        scores,
                        ratio,
                        MaskCriterion::ActivationMagnitude,
                    );
                    let want = ((scores.len() as f64) * ratio).round() as usize;
                    let got = mask.iter().filter(|&&b| b).count();
                    if got != want {
                        return Err(format!("ratio {ratio}: {got} != {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn picks_largest_channels() {
        let abs = vec![0.1, 5.0, 0.2, 4.0, 0.3];
        let mask = structured_mask(
            &abs, &abs, 0.4, MaskCriterion::ActivationMagnitude,
        );
        assert_eq!(mask, vec![false, true, false, true, false]);
    }

    #[test]
    fn criteria_can_differ() {
        // abs-mean favors ch 0; sq-mean (outlier-sensitive) favors ch 1
        let abs = vec![1.0, 0.9, 0.0, 0.0];
        let sq = vec![1.0, 4.0, 0.0, 0.0]; // rare big spikes on ch 1
        let a = structured_mask(&abs, &sq, 0.25, MaskCriterion::ActivationMagnitude);
        let h = structured_mask(&abs, &sq, 0.25, MaskCriterion::HessianDiag);
        assert!(a[0] && !a[1]);
        assert!(h[1] && !h[0]);
    }

    #[test]
    fn overhead_matches_paper_magnitude() {
        let o = mask_overhead_bits_per_weight(4096, 4096);
        assert!((o - 0.000244).abs() < 1e-5); // paper rounds to 0.0002
    }

    #[test]
    fn packs_to_one_bit_per_channel() {
        let mask = vec![true, false, true, true];
        let packed = pack_mask(&mask);
        assert_eq!(packed.storage_bits(), 4);
        assert_eq!(packed.to_bools(), mask);
    }
}
