//! Host-side f32 tensor substrate.
//!
//! All quantizers (GPTQ's Hessian/Cholesky math, AWQ's grid search, PTQ1.61's
//! mask + analytic scaling factors) operate on host weights through this
//! type; the XLA device is only used for model-graph execution. Row-major,
//! shape-checked, with exactly the linear-algebra surface the repo needs.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(numel(shape), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(numel(shape), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on non-2D");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on non-2D");
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn at2_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.shape[1] + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(numel(shape), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// 2D transpose.
    pub fn t(&self) -> Tensor {
        let (n, m) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..n {
            for j in 0..m {
                out.data[j * n + i] = self.data[i * m + j];
            }
        }
        out
    }

    /// Dense matmul (n,k)x(k,m). Host-side only — device math goes via XLA.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (n, k) = (self.rows(), self.cols());
        let (k2, m) = (other.rows(), other.cols());
        assert_eq!(k, k2, "matmul inner dim {k} vs {k2}");
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..n {
            let a_row = self.row(i);
            let o_row = &mut out.data[i * m..(i + 1) * m];
            for (l, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(l);
                for j in 0..m {
                    o_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }

    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn mse(&self, o: &Tensor) -> f32 {
        assert_eq!(self.shape, o.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&o.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n as f32
    }

    pub fn cosine(&self, o: &Tensor) -> f32 {
        let dot: f32 = self.data.iter().zip(&o.data).map(|(a, b)| a * b).sum();
        let d = self.frob_norm() * o.frob_norm();
        if d < 1e-12 {
            0.0
        } else {
            dot / d
        }
    }

    /// Column means of |x| — activation channel saliency statistic (Fig 3a).
    pub fn col_abs_mean(&self) -> Vec<f32> {
        let (n, m) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m];
        for i in 0..n {
            for (j, &v) in self.row(i).iter().enumerate() {
                out[j] += v.abs();
            }
        }
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        out
    }

    /// Column means of x^2 — diag(H)/n for GPTQ-style Hessians.
    pub fn col_sq_mean(&self) -> Vec<f32> {
        let (n, m) = (self.rows(), self.cols());
        let mut out = vec![0.0f32; m];
        for i in 0..n {
            for (j, &v) in self.row(i).iter().enumerate() {
                out[j] += v * v;
            }
        }
        for v in out.iter_mut() {
            *v /= n as f32;
        }
        out
    }

    /// X^T X accumulated into `acc` (m x m) — GPTQ Hessian accumulation.
    pub fn xtx_into(&self, acc: &mut Tensor) {
        let (n, m) = (self.rows(), self.cols());
        assert_eq!(acc.shape, vec![m, m]);
        for i in 0..n {
            let r = self.row(i);
            for a in 0..m {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let acc_row = &mut acc.data[a * m..(a + 1) * m];
                for b in 0..m {
                    acc_row[b] += ra * r[b];
                }
            }
        }
    }
}

pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product::<usize>().max(if shape.is_empty() { 1 } else { 0 })
}

/// In-place Cholesky decomposition of a symmetric positive-definite matrix;
/// returns lower-triangular L with A = L L^T. Used by GPTQ.
pub fn cholesky(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at2(i, j);
            for k in 0..j {
                sum -= l.at2(i, k) * l.at2(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("not SPD at {i}: {sum}"));
                }
                *l.at2_mut(i, j) = sum.sqrt();
            } else {
                *l.at2_mut(i, j) = sum / l.at2(j, j);
            }
        }
    }
    Ok(l)
}

/// Invert an SPD matrix via Cholesky (A^-1 = L^-T L^-1). Used by GPTQ's
/// error-compensation recursion.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    let l = cholesky(a)?;
    // invert L (lower triangular) by forward substitution
    let mut linv = Tensor::zeros(&[n, n]);
    for col in 0..n {
        linv.data[col * n + col] = 1.0 / l.at2(col, col);
        for i in col + 1..n {
            let mut sum = 0.0;
            for k in col..i {
                sum += l.at2(i, k) * linv.at2(k, col);
            }
            *linv.at2_mut(i, col) = -sum / l.at2(i, i);
        }
    }
    // A^-1 = L^-T L^-1
    let mut inv = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0;
            for k in i.max(j)..n {
                sum += linv.at2(k, i) * linv.at2(k, j);
            }
            *inv.at2_mut(i, j) = sum;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[5, 9], 1.0, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = M M^T + n*I is SPD
        let mut rng = Rng::new(2);
        let m = Tensor::randn(&[6, 6], 1.0, &mut rng);
        let mut a = m.matmul(&m.t());
        for i in 0..6 {
            *a.at2_mut(i, i) += 6.0;
        }
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.t());
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let mut rng = Rng::new(3);
        let m = Tensor::randn(&[5, 5], 1.0, &mut rng);
        let mut a = m.matmul(&m.t());
        for i in 0..5 {
            *a.at2_mut(i, i) += 5.0;
        }
        let inv = spd_inverse(&a).unwrap();
        let id = a.matmul(&inv);
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at2(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn col_stats() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 0.0, 3.0, 2.0, 0.0]);
        assert_eq!(x.col_abs_mean(), vec![2.0, 2.0, 0.0]);
        assert_eq!(x.col_sq_mean(), vec![5.0, 4.0, 0.0]);
    }

    #[test]
    fn xtx_matches_matmul() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[7, 4], 1.0, &mut rng);
        let mut acc = Tensor::zeros(&[4, 4]);
        x.xtx_into(&mut acc);
        let want = x.t().matmul(&x);
        for (a, b) in acc.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn cosine_of_self_is_one() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(&[3, 3], 1.0, &mut rng);
        assert!((x.cosine(&x) - 1.0).abs() < 1e-6);
        assert!((x.cosine(&x.scale(-1.0)) + 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "matmul inner dim")]
    fn matmul_shape_checked() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        let _ = a.matmul(&b);
    }
}
