//! Deterministic RNG substrate (no `rand` crate offline): SplitMix64 core
//! with normal / uniform / choice / Zipf helpers. Every stochastic component
//! in the repo (init, corpus, calibration sampling, quantizer search seeds)
//! goes through this so runs are reproducible from a single seed.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (word frequencies
    /// for the synthetic corpus follow this, like natural language).
    pub fn zipf(&mut self, n: usize, s: f64, cdf: &[f64]) -> usize {
        debug_assert_eq!(cdf.len(), n);
        let _ = s;
        let u = self.f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(n - 1),
        }
    }
}

/// Precompute the Zipf CDF once (zipf() does a binary search per draw).
pub fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..20000).map(|_| r.f32()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f32> = (0..40000).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(3);
        let picked = r.choose_k(100, 20);
        assert_eq!(picked.len(), 20);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_is_head_heavy() {
        let n = 1000;
        let cdf = zipf_cdf(n, 1.1);
        let mut r = Rng::new(4);
        let mut head = 0;
        for _ in 0..10000 {
            if r.zipf(n, 1.1, &cdf) < 20 {
                head += 1;
            }
        }
        assert!(head > 4000, "head draws: {head}");
    }
}
