//! Integration tests over the real artifact set (skipped with a clear
//! message when `make artifacts` hasn't run). These exercise the Python→
//! Rust contract end to end: artifact load + compile + execute, the layer
//! pipeline, training-step plumbing, quantization, and dense-vs-fused
//! agreement.

use ptq161::coordinator::capture::capture;
use ptq161::coordinator::pretrain::lm_grad;
use ptq161::coordinator::quantize::quantize_model;
use ptq161::coordinator::Pipeline;
use ptq161::data::{calib, Corpus, Style};
use ptq161::eval::ppl::perplexity;
use ptq161::eval::ModelEval;
use ptq161::model::Params;
use ptq161::runtime::Runtime;
use ptq161::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = ptq161::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping integration test: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(&dir).expect("runtime"))
}

fn demo_tokens(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(256) as i32).collect()
}

#[test]
fn manifest_covers_both_configs() {
    let Some(rt) = runtime() else { return };
    for c in ["tiny", "small"] {
        assert!(rt.manifest.configs.contains_key(c));
        for base in [
            "embed_fwd", "block_fwd", "block_capture", "qblock_fwd",
            "qblock_w4a4_fwd", "head_fwd", "lm_grad", "lora_grad",
            "block_opt_grad",
        ] {
            assert!(
                rt.manifest.artifacts.contains_key(&format!("{base}_{c}")),
                "{base}_{c} missing"
            );
        }
    }
}

#[test]
fn layer_pipeline_runs_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(3);
    let tokens = demo_tokens(pipe.cfg.b_eval * pipe.cfg.seq, 4);
    let n1 = pipe.nll_sum(&params, &tokens).unwrap();
    let n2 = pipe.nll_sum(&params, &tokens).unwrap();
    assert_eq!(n1, n2);
    // random init => near-uniform next-token distribution
    let per_tok = n1 / pipe.tokens_per_batch() as f32;
    assert!((per_tok - (256f32).ln()).abs() < 0.5, "per-token nll {per_tok}");
}

#[test]
fn lm_grad_descends_loss() {
    let Some(rt) = runtime() else { return };
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let mut params = pipe.init_params(5);
    let tokens = demo_tokens(pipe.cfg.b_train * pipe.cfg.seq, 6);
    let (l0, grads) = lm_grad(&pipe, &params, &tokens).unwrap();
    for (p, g) in params.tensors.iter_mut().zip(&grads) {
        for (x, gx) in p.data.iter_mut().zip(&g.data) {
            *x -= 0.5 * gx;
        }
    }
    let (l1, _) = lm_grad(&pipe, &params, &tokens).unwrap();
    assert!(l1 < l0, "{l1} !< {l0}");
}

#[test]
fn quantized_model_ppl_ordering() {
    let Some(rt) = runtime() else { return };
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    // a lightly-trained model so quantization error is meaningful
    let corpus = Corpus::build(Style::Wiki, 200_000, 50);
    let mut params = pipe.init_params(7);
    let mut opt = ptq161::opt::AdamW::new(3e-3, params.tensors.len());
    let mut rng = Rng::new(8);
    for _ in 0..30 {
        let batch = corpus.batch(pipe.cfg.b_train, pipe.cfg.seq, &mut rng);
        let (_, grads) = lm_grad(&pipe, &params, &batch).unwrap();
        opt.step(&mut params.tensors, &grads);
    }
    let cal = calib::sample(&corpus, 8, pipe.cfg.b_eval, pipe.cfg.seq, 9);
    let mc = capture(&pipe, &params, &cal, true).unwrap();
    let fp_ppl =
        perplexity(&pipe, &ModelEval::Dense(&params), &corpus, 2).unwrap();
    let rtn1 = ptq161::quant::by_name("rtn1").unwrap();
    let q_bin = quantize_model(&pipe, &params, &mc, rtn1.as_ref()).unwrap();
    let bin_ppl =
        perplexity(&pipe, &ModelEval::Dense(&q_bin.params), &corpus, 2).unwrap();
    let p161 = ptq161::quant::ptq161::Ptq161::default();
    let q161 = quantize_model(&pipe, &params, &mc, &p161).unwrap();
    let p161_ppl =
        perplexity(&pipe, &ModelEval::Dense(&q161.params), &corpus, 2).unwrap();
    // a 30-step model sits near its entropy floor, so quantization noise
    // can land within ±epsilon of FP — the hard invariants are that
    // PTQ1.61 stays close to FP and clearly beats plain binarization
    assert!(
        p161_ppl < fp_ppl * 1.15,
        "ptq161 {p161_ppl} must stay near fp {fp_ppl}"
    );
    assert!(
        p161_ppl < bin_ppl,
        "ptq161 {p161_ppl} must beat plain binarization {bin_ppl}"
    );
}

#[test]
fn fused_kernel_path_matches_dense() {
    let Some(rt) = runtime() else { return };
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(11);
    let corpus = Corpus::build(Style::Wiki, 120_000, 51);
    let cal = calib::sample(&corpus, 4, pipe.cfg.b_eval, pipe.cfg.seq, 12);
    let mc = capture(&pipe, &params, &cal, false).unwrap();
    let p161 = ptq161::quant::ptq161::Ptq161::default();
    let qm = quantize_model(&pipe, &params, &mc, &p161).unwrap();
    let dense =
        perplexity(&pipe, &ModelEval::Dense(&qm.params), &corpus, 2).unwrap();
    let fused = perplexity(
        &pipe,
        &ModelEval::Fused {
            params: &qm.params,
            parts: qm.parts.as_ref().unwrap(),
        },
        &corpus,
        2,
    )
    .unwrap();
    assert!(
        (dense - fused).abs() / dense < 1e-3,
        "dense {dense} vs fused {fused}"
    );
}

#[test]
fn params_save_load_via_pipeline_shapes() {
    let Some(rt) = runtime() else { return };
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(13);
    let path = std::env::temp_dir().join("ptq161_integration_params.bin");
    params.save(&path).unwrap();
    let loaded = Params::load(&path).unwrap();
    assert_eq!(params.spec, loaded.spec);
    std::fs::remove_file(path).ok();
}
