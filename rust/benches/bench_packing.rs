//! Packing benches: the bit-exact containers on a real LLaMA layer slice —
//! pack/unpack throughput bounds the (de)serialization cost of a deployed
//! 1.61-bit checkpoint.

use ptq161::packing::bitpack::BitVec;
use ptq161::packing::nibble::{quantize_column, NibbleVec};
use ptq161::util::bench::Bencher;
use ptq161::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let n = 4096 * 64; // 64 rows of a 4096-wide layer
    let weights: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let b = Bencher::quick();
    b.run("packing/bitpack_signs_256k", || BitVec::from_signs(&weights));
    let bv = BitVec::from_signs(&weights);
    b.run("packing/unpack_signs_256k", || bv.to_signs());
    let col: Vec<f32> = weights[..4096].to_vec();
    b.run("packing/quant4_column_4096", || quantize_column(&col));
    let (codes, _, _) = quantize_column(&col);
    b.run("packing/nibble_pack_4096", || NibbleVec::from_codes(&codes));
}
