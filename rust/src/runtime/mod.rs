//! Runtime: execute the AOT artifact contract.
//!
//! The contract with the Python build step is `artifacts/manifest.json`:
//! every artifact's input/output names, shapes and dtypes in positional
//! order. Execution is served by the native pure-Rust backend
//! (`runtime::native`), which implements every artifact base — forwards
//! and gradients — with the exact semantics of python/compile/model.py.
//! When no artifacts directory exists the manifest itself falls back to
//! the built-in one (`Manifest::builtin`), so the whole system runs with
//! zero build-time dependencies; a PJRT/XLA execution path can be added
//! back behind the same `Runtime::run` contract.
//!
//! Inputs are validated eagerly against the manifest. The leading batch
//! dimension of activation/token inputs is *flexible*: the serve engine
//! compacts finished lanes out of the batch, so decode cost scales with
//! the number of active lanes instead of the manifest's full `b_eval`.
//! For the `*_decode` bases (KV-cached incremental decode, PR 2) the
//! *time* dimension may shrink too: `tokens`/`h_new` carry a prefill
//! chunk or a single decode position, and `k_cache`/`v_cache` carry only
//! the live prefix of the window. `runtime::kv` holds the per-lane K/V
//! store those bases read from and append to.

pub mod autodiff;
pub mod kv;
pub mod manifest;
pub mod native;
pub mod pool;
pub mod simd;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use crate::tensor::Tensor;

/// A host-side input value: f32 tensor or i32 token array.
#[derive(Debug, Clone)]
pub enum Value {
    /// Dense f32 tensor (activations, parameters).
    F32(Tensor),
    /// Integer array (token ids, cache lengths) as (shape, data).
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    /// The value's shape, whichever dtype it holds.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32(s, _) => s,
        }
    }

    /// An i32 input of the given shape; panics when the element count
    /// does not match.
    pub fn tokens(shape: &[usize], data: Vec<i32>) -> Value {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Value::I32(shape.to_vec(), data)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<&Tensor> for Value {
    fn from(t: &Tensor) -> Value {
        Value::F32(t.clone())
    }
}

/// Inputs whose leading dimension is a batch axis and may legally shrink
/// below the manifest shape (continuous batching compacts finished lanes).
/// Larger-than-manifest batches are rejected: a fixed-shape PJRT
/// executable behind the same contract could never run them.
const BATCH_FLEX: [&str; 9] =
    ["tokens", "h", "x_q", "f1", "f3", "h_new", "k_cache", "v_cache", "pos"];

/// Inputs of `*_decode` bases whose *time* axis (dim 1) may also shrink:
/// prefill runs a prompt-length chunk, a decode step runs one position,
/// and the cache tensors carry only the live prefix of the window
/// (`KvCache::gather`). A PJRT path would serve these from a small set of
/// bucketed shapes.
const TIME_FLEX: [&str; 4] = ["tokens", "h_new", "k_cache", "v_cache"];

fn shape_ok(base: &str, io: &IoSpec, got: &[usize]) -> bool {
    if got == io.shape.as_slice() {
        return true;
    }
    if !BATCH_FLEX.contains(&io.name.as_str())
        || got.len() != io.shape.len()
        || got.is_empty()
        || got[0] < 1
        || got[0] > io.shape[0]
    {
        return false;
    }
    let time_flex = base.ends_with("_decode")
        && TIME_FLEX.contains(&io.name.as_str())
        && io.shape.len() >= 2;
    if time_flex {
        got[1] >= 1 && got[1] <= io.shape[1] && got[2..] == io.shape[2..]
    } else {
        got[1..] == io.shape[1..]
    }
}

/// The execution layer: a manifest plus the native backend behind it.
/// Every model computation in the crate goes through [`Runtime::run`].
///
/// `Runtime` is `Sync`: the multi-worker serve engine shares one runtime
/// (through `&Pipeline`) across its OS worker threads, so the per-artifact
/// execution counter sits behind a `Mutex` rather than a `RefCell`.
pub struct Runtime {
    /// the artifact contract this runtime validates against
    pub manifest: Manifest,
    /// execution counter per artifact, for the perf report
    pub exec_counts: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    /// Open the artifact directory. When `manifest.json` exists it is
    /// parsed and honored (shape/dtype validation against the Python
    /// build); otherwise the built-in manifest backs everything.
    pub fn open(dir: &Path) -> Result<Runtime> {
        let mpath = dir.join("manifest.json");
        let manifest = if mpath.exists() {
            let text = std::fs::read_to_string(&mpath)
                .with_context(|| format!("reading {}", mpath.display()))?;
            let mut m = Manifest::parse(&text)?;
            // older python builds predate the KV-cached decode contract;
            // the decode bases execute natively, so back-fill their specs
            m.ensure_decode_artifacts();
            m
        } else {
            Manifest::builtin()
        };
        Ok(Runtime { manifest, exec_counts: Mutex::new(HashMap::new()) })
    }

    /// A runtime backed purely by the built-in manifest (tests, serving
    /// without an artifacts directory).
    pub fn native() -> Runtime {
        Runtime {
            manifest: Manifest::builtin(),
            exec_counts: Mutex::new(HashMap::new()),
        }
    }

    /// Look up an artifact spec by full name (`{base}_{config}`).
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Kept for API compatibility: the native backend has nothing to
    /// precompile, so warming is a manifest lookup.
    pub fn warm(&self, name: &str) -> Result<()> {
        self.artifact(name).map(|_| ())
    }

    /// Execute `name` with positionally-ordered inputs; validates count,
    /// shape (flexible leading batch dim) and dtype against the manifest,
    /// returns outputs as Tensors in artifact order.
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let spec = self.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (v, io) in inputs.iter().zip(&spec.inputs) {
            if !shape_ok(&spec.base, io, v.shape()) {
                bail!(
                    "{name}: input '{}' shape {:?} != manifest {:?}",
                    io.name,
                    v.shape(),
                    io.shape
                );
            }
            let want_i32 = io.dtype == "i32";
            let got_i32 = matches!(v, Value::I32(..));
            if want_i32 != got_i32 {
                bail!("{name}: input '{}' dtype mismatch", io.name);
            }
        }
        *self
            .exec_counts
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += 1;
        let cfg = self
            .manifest
            .configs
            .get(&spec.config)
            .ok_or_else(|| anyhow!("{name}: unknown config '{}'", spec.config))?;
        native::execute(spec, cfg, inputs)
    }

    /// Run by (base, config) pair, the common call-site pattern.
    pub fn run_cfg(
        &self,
        base: &str,
        config: &str,
        inputs: &[Value],
    ) -> Result<Vec<Tensor>> {
        self.run(&format!("{base}_{config}"), inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shapes() {
        let t = Tensor::zeros(&[2, 3]);
        let v: Value = t.into();
        assert_eq!(v.shape(), &[2, 3]);
        let tok = Value::tokens(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(tok.shape(), &[2, 2]);
    }

    #[test]
    #[should_panic]
    fn token_shape_checked() {
        let _ = Value::tokens(&[2, 2], vec![1, 2, 3]);
    }

    #[test]
    fn open_falls_back_to_builtin_manifest() {
        let dir = std::env::temp_dir().join("ptq161_no_artifacts_here");
        std::fs::create_dir_all(&dir).unwrap();
        let rt = Runtime::open(&dir).unwrap();
        assert!(rt.manifest.configs.contains_key("tiny"));
        assert!(rt.manifest.artifacts.contains_key("lm_grad_tiny"));
    }

    #[test]
    fn run_validates_inputs() {
        let rt = Runtime::native();
        // wrong input count
        assert!(rt.run("embed_fwd_micro", &[]).is_err());
        // wrong dtype: embed slot fed tokens
        let cfg = rt.manifest.configs["micro"].clone();
        let toks = Value::tokens(&[cfg.b_eval, cfg.seq], vec![0; cfg.b_eval * cfg.seq]);
        let bad = rt.run("embed_fwd_micro", &[toks.clone(), toks.clone()]);
        assert!(bad.is_err());
        // wrong non-batch shape on the embed table
        let bad_embed = Value::from(Tensor::zeros(&[cfg.vocab, cfg.d + 1]));
        assert!(rt.run("embed_fwd_micro", &[toks, bad_embed]).is_err());
    }

    #[test]
    fn decode_bases_accept_shrunk_time_axis() {
        let rt = Runtime::native();
        let cfg = rt.manifest.configs["micro"].clone();
        let embed = Value::from(Tensor::zeros(&[cfg.vocab, cfg.d]));
        // prefill chunk: 1 lane, 5 of the window's positions
        let toks = Value::tokens(&[1, 5], vec![0; 5]);
        let out = rt
            .run("embed_fwd_decode_micro", &[toks, embed.clone()])
            .unwrap();
        assert_eq!(out[0].shape, vec![1, 5, cfg.d]);
        // the full-window base still rejects a shrunk time axis
        let toks = Value::tokens(&[1, 5], vec![0; 5]);
        assert!(rt.run("embed_fwd_micro", &[toks, embed]).is_err());
    }

    #[test]
    fn embed_accepts_smaller_batch() {
        let rt = Runtime::native();
        let cfg = rt.manifest.configs["micro"].clone();
        let embed = Value::from(Tensor::zeros(&[cfg.vocab, cfg.d]));
        // one lane instead of b_eval lanes: leading dim is flexible
        let toks = Value::tokens(&[1, cfg.seq], vec![0; cfg.seq]);
        let out = rt.run("embed_fwd_micro", &[toks, embed]).unwrap();
        assert_eq!(out[0].shape, vec![1, cfg.seq, cfg.d]);
    }
}
