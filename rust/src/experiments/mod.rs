//! Experiment harness: one entry per table/figure of the paper (DESIGN.md
//! section 5 maps each ID to its modules). `run(id, ...)` is what the CLI's
//! `experiment` subcommand and the e2e example dispatch to.

pub mod ctx;
pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

pub use ctx::ExperimentCtx;

pub const ALL_IDS: [&str; 17] = [
    "t1", "t2", "t3", "t4", "t5", "t6", "t7", "t8", "t9", "t10", "t11",
    "t12", "t13", "f1", "f3", "f4", "f6",
];
// f5 == t6-style sweep over baselines and f7 reuse t2 machinery; they are
// runnable individually as well:
pub const EXTRA_IDS: [&str; 2] = ["f5", "f7"];

pub fn run(ctx: &mut ExperimentCtx, id: &str) -> Result<()> {
    match id {
        "t1" => tables::t1_perplexity(ctx),
        "t2" => tables::t2_reasoning(ctx),
        "t3" => tables::t3_ablation(ctx),
        "t4" => tables::t4_owq(ctx),
        "t5" => tables::t5_mask_criterion(ctx),
        "t6" => tables::t6_preprocess_gain(ctx),
        "t7" => tables::t7_angular(ctx),
        "t8" => tables::t8_resources(ctx),
        "t9" => tables::t9_learnable_mean(ctx),
        "t10" => tables::t10_hard_tasks(ctx),
        "t11" => tables::t11_long_context(ctx),
        "t12" => tables::t12_memory(ctx),
        "t13" => tables::t13_w4a4(ctx),
        "f1" => figures::f1_ppl_vs_bits(ctx),
        "f3" => figures::f3_activation_stats(ctx),
        "f4" => figures::f4_row_concentration(ctx),
        "f5" => figures::f5_preprocess_baselines(ctx),
        "f6" => figures::f6_ratio_sweep(ctx),
        "f7" => figures::f7_zeroshot_preprocess(ctx),
        "appA" => figures::app_a_bitwidth(ctx),
        other => bail!("unknown experiment id '{other}'"),
    }
}
