//! Generic b-bit code plane: the INT2/INT4/INT8 container behind the
//! uniform-grid quantizers (RTN, GPTQ) and the salient plane of PB-LLM.
//!
//! [`CodeVec`] packs fixed-width unsigned codes into `u64` words, little
//! end first, for any width that divides 64 (1, 2, 4, 8, 16) — the same
//! storage convention as [`super::bitpack::BitVec`] (width 1) and
//! [`super::nibble::NibbleVec`] (width 4), generalized so one container
//! serves every integer plane a [`crate::quant::PackedContainer`] needs.

#[derive(Debug, Clone, PartialEq)]
pub struct CodeVec {
    /// code width in bits (must divide 64)
    pub bits: u32,
    /// number of codes stored
    pub len: usize,
    words: Vec<u64>,
}

impl CodeVec {
    pub fn zeros(bits: u32, len: usize) -> CodeVec {
        assert!(bits >= 1 && bits <= 16 && 64 % bits == 0, "width {bits}");
        let per = (64 / bits) as usize;
        CodeVec { bits, len, words: vec![0; len.div_ceil(per)] }
    }

    pub fn from_codes(bits: u32, codes: &[u16]) -> CodeVec {
        let mut v = CodeVec::zeros(bits, codes.len());
        for (i, &c) in codes.iter().enumerate() {
            v.set(i, c);
        }
        v
    }

    #[inline]
    pub fn get(&self, i: usize) -> u16 {
        debug_assert!(i < self.len);
        let per = (64 / self.bits) as usize;
        let shift = (i % per) as u32 * self.bits;
        let mask = if self.bits == 64 { u64::MAX } else { (1u64 << self.bits) - 1 };
        ((self.words[i / per] >> shift) & mask) as u16
    }

    #[inline]
    pub fn set(&mut self, i: usize, code: u16) {
        debug_assert!(i < self.len);
        let mask = (1u64 << self.bits) - 1;
        assert!(
            (code as u64) <= mask,
            "code {code} exceeds {}-bit range",
            self.bits
        );
        let per = (64 / self.bits) as usize;
        let shift = (i % per) as u32 * self.bits;
        let w = &mut self.words[i / per];
        *w = (*w & !(mask << shift)) | ((code as u64) << shift);
    }

    pub fn to_codes(&self) -> Vec<u16> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Storage in bits (what the accounting layer charges).
    pub fn storage_bits(&self) -> u64 {
        self.len as u64 * self.bits as u64
    }

    /// Actual resident bytes of the word buffer.
    pub fn storage_bytes_padded(&self) -> usize {
        self.words.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn set_get_round_trip_all_widths() {
        for bits in [1u32, 2, 4, 8, 16] {
            let n = 97;
            let top = (1u32 << bits) - 1;
            let codes: Vec<u16> =
                (0..n).map(|i| ((i * 7) as u32 % (top + 1)) as u16).collect();
            let v = CodeVec::from_codes(bits, &codes);
            assert_eq!(v.to_codes(), codes, "width {bits}");
            assert_eq!(v.storage_bits(), n as u64 * bits as u64);
        }
    }

    #[test]
    fn set_overwrites_cleanly() {
        let mut v = CodeVec::zeros(2, 40);
        v.set(7, 3);
        v.set(7, 1);
        assert_eq!(v.get(7), 1);
        assert_eq!(v.get(6), 0);
        assert_eq!(v.get(8), 0);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn out_of_range_code_rejected() {
        let mut v = CodeVec::zeros(2, 4);
        v.set(0, 4);
    }

    #[test]
    fn random_round_trip_property() {
        check(
            "codevec-roundtrip",
            60,
            |r: &mut Rng| {
                let bits = [1u32, 2, 4, 8][r.below(4)] as usize;
                let n = r.below(200) + 1;
                let top = (1usize << bits) - 1;
                let codes: Vec<usize> =
                    (0..n).map(|_| r.below(top + 1)).collect();
                (bits, codes)
            },
            |(bits, codes)| {
                let c16: Vec<u16> = codes.iter().map(|&c| c as u16).collect();
                let v = CodeVec::from_codes(*bits as u32, &c16);
                if v.to_codes() != c16 {
                    return Err("round trip deviates".into());
                }
                Ok(())
            },
        );
    }
}
