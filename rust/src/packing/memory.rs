//! Table 12 inference-memory model: packed size of quantized LLaMA-family
//! checkpoints under PB-LLM / BiLLM / PTQ1.61 accounting, computed from the
//! real layer shapes (this is an analytic experiment — exact, no GPU).

use super::bitwidth::{average_bits, BitScheme};

/// (hidden, ffn, layers) for real LLaMA models.
#[derive(Debug, Clone, Copy)]
pub struct LlamaShape {
    pub hidden: usize,
    pub ffn: usize,
    pub layers: usize,
}

pub const LLAMA_7B: LlamaShape =
    LlamaShape { hidden: 4096, ffn: 11008, layers: 32 };
pub const LLAMA_13B: LlamaShape =
    LlamaShape { hidden: 5120, ffn: 13824, layers: 40 };

/// Quantized linear-weight bits of one transformer block.
fn block_bits(shape: LlamaShape, scheme: BitScheme) -> f64 {
    let d = shape.hidden;
    let f = shape.ffn;
    // q, k, v, o: (d, d); gate, up: (f, d); down: (d, f)
    let linears: [(usize, usize); 7] =
        [(d, d), (d, d), (d, d), (d, d), (f, d), (f, d), (d, f)];
    linears
        .iter()
        .map(|&(n, m)| average_bits(scheme, n, m) * (n as f64) * (m as f64))
        .sum()
}

/// Total packed model size in GiB. Block linears quantized per `scheme`;
/// embedding + head counted at 4-bit (the paper's Table 12 numbers are
/// only reproducible with compressed embeddings — fp16 embeddings alone
/// exceed the gap between its methods).
pub fn model_gib(shape: LlamaShape, scheme: BitScheme, vocab: usize) -> f64 {
    let quantized_bits = block_bits(shape, scheme) * shape.layers as f64;
    let embed_bits = 2.0 * (vocab * shape.hidden) as f64 * 4.0;
    let norm_bits =
        ((2 * shape.layers + 1) * shape.hidden) as f64 * 16.0;
    (quantized_bits + embed_bits + norm_bits) / 8.0 / (1u64 << 30) as f64
}

pub fn table12_row(scheme: BitScheme) -> (f64, f64) {
    (
        model_gib(LLAMA_7B, scheme, 32000),
        model_gib(LLAMA_13B, scheme, 32000),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ptq161_7b_matches_paper_table12() {
        // paper: 1.41 GB for LLaMA-7B, 2.68 GB for 13B (±10%: the paper
        // does not spell out its embedding/zero-point accounting)
        let (gb7, gb13) =
            table12_row(BitScheme::Ptq161 { salient_ratio: 0.2 });
        assert!((gb7 - 1.41).abs() < 0.15, "7B: {gb7}");
        assert!((gb13 - 2.68).abs() < 0.27, "13B: {gb13}");
    }

    #[test]
    fn ordering_matches_paper() {
        let ptq = table12_row(BitScheme::Ptq161 { salient_ratio: 0.2 }).0;
        let billm = table12_row(BitScheme::BiLlm).0;
        let pbllm = table12_row(BitScheme::PbLlm { salient_ratio: 0.1 }).0;
        assert!(ptq < billm && billm < pbllm, "{ptq} {billm} {pbllm}");
    }

    #[test]
    fn pbllm_7b_magnitude() {
        let (gb7, _) = table12_row(BitScheme::PbLlm { salient_ratio: 0.1 });
        assert!((gb7 - 2.36).abs() < 0.25, "7B: {gb7}");
    }
}
