//! Continuous-batching serve engine with paged, KV-cached incremental
//! decode and batched prefill.
//!
//! A slot-based scheduler over the pipeline's `b_eval` lanes. Each lane
//! binds to a lane of the paged [`KvCache`] for the life of a request:
//! admission reserves the request's worst-case *page* budget (prompt +
//! generation budget, in `--page-size` position pages) and backpressures
//! on **pool exhaustion** rather than lane count — with a pool smaller
//! than `lanes × window`, short requests still admit because pages, not
//! whole windows, are the unit of accounting. The first decode step after
//! admission prefills the prompt; subsequent steps run the model over
//! exactly *one new token per lane* against the cached K/V, so per-token
//! cost is flat in sequence position. Lanes are compacted out of the
//! batch when they finish, their pages are released (shared pages when
//! the last reader finishes), and freed lanes refill from the queue on
//! the next step — a request never waits for the rest of its batch.
//!
//! **Batched prefill**: newly admitted lanes are prefilled together, not
//! one `b=1` forward at a time — prompts are bucketed by the length still
//! to compute and each bucket runs as one chunked `*_decode` forward (the
//! decode kernels take per-lane past lengths, so lanes with different
//! amounts of adopted prefix batch together as long as their new chunks
//! are the same length).
//!
//! **Shared-prefix reuse**: before prefilling, each lane adopts the
//! longest registered whole-page token prefix of its prompt from the
//! cache's content-keyed index ([`KvCache::adopt_prefix`]) — positions
//! covered by adopted pages skip the forward entirely, and after prefill
//! the lane registers its own full prompt pages for later requests.
//! Identical system prompts are therefore cached once, not once per lane,
//! and the metrics' `prefix_hit_rate` reports the fraction of prompt
//! positions served from shared pages.
//!
//! `EngineCfg::use_kv_cache = false` selects the legacy full-window step
//! (re-running the entire padded window every token); both paths produce
//! token-identical output for the dense, PTQ1.61-fused and packed models,
//! which `benches/bench_serve.rs` and `tests/paged_kv.rs` gate on.
//!
//! The weight representation is the [`ModelEval`] handed to
//! [`Engine::new`] — for PTQ1.61 the production choice is
//! `ModelEval::Packed` over a `PackedModel` built **once** from the
//! quantizer's parts, so every decode step contracts the 1.61-bit
//! containers directly instead of reconstructing dense weights
//! (`tests/packed_serve.rs` gates the token identity and the
//! zero-reconstruction invariant). `EngineCfg::backend` records the
//! choice and the run's metrics carry the resident-memory split (KV
//! reserved/live bytes, packed-model bytes, effective bits/weight).
//!
//! [`Engine::run_drain`] is the classic static-batching baseline for
//! comparison: it admits whole batches and only takes the next batch when
//! every lane has finished — exactly what a deployment without in-flight
//! refill pays. (With the KV cache enabled, drain mode still decodes
//! compacted active lanes; the fixed-width padding cost model only exists
//! on the full-window path.)
//!
//! **Multi-worker sharding** ([`run_sharded`]): the lane pool and the
//! page pool split across N OS threads (std scoped threads, no extra
//! deps), each running its own engine loop over a private [`KvCache`]
//! partition, all pulling from one work-stealing [`ShardedQueue`].
//! Submission routes prefix-cache hits to the worker holding the pages
//! ([`PrefixRouter`]); greedy decode is per-lane deterministic, so
//! `--workers N` produces byte-identical per-request tokens to
//! `--workers 1` — scheduling may reorder completion, never tokens
//! (`tests/multi_worker.rs` gates this). A worker panic is contained:
//! its in-flight requests are reported failed, its queued shard is
//! stolen by the survivors, and the process lives on.

use std::collections::{BTreeMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Batcher, PreemptedReq, ShardedQueue};
use super::metrics::{MetricsRegistry, RequestMetric};
use super::stream::EmitHub;
use super::{GenRequest, GenResponse};
use crate::coordinator::Pipeline;
use crate::eval::ModelEval;
use crate::model::tokenizer::ByteTokenizer;
use crate::runtime::autodiff::{kernel_nanos, kernel_tier};
use crate::runtime::kv::{partition_pages, KvCache, PrefixRouter};
use crate::runtime::pool;

pub use crate::runtime::kv::DEFAULT_PAGE_SIZE;

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineCfg {
    /// hard cap on decode steps per run (runaway guard)
    pub max_steps: usize,
    /// decode incrementally against per-lane cached K/V (the production
    /// path); `false` re-runs the full padded window every step (the
    /// baseline `bench_serve` compares against)
    pub use_kv_cache: bool,
    /// which weight representation this engine decodes from — derived
    /// from the [`ModelEval`] at construction (`dense` / `fused` /
    /// `packed` / `w4a4`; the CLI's `--backend` flag selects which
    /// `ModelEval` gets built) and exported into the metrics JSON
    pub backend: &'static str,
    /// OS worker threads a [`run_sharded`] deployment fans the lane pool
    /// over, clamped to `[1, b_eval]` (each worker needs at least one
    /// lane). The in-process `run`/`run_drain` loops ignore it.
    pub workers: usize,
    /// cap on prefill tokens computed per engine step (`--prefill-chunk`).
    /// `None` prefills whole prompts in one step (the legacy behavior);
    /// with a cap, a long prompt is spread over several steps and decode
    /// lanes keep emitting between its chunks — the tail-latency lever
    /// under overload. Token-identical either way: chained
    /// `forward_h_incremental` calls over the same positions produce the
    /// same K/V as one call.
    pub prefill_chunk: Option<usize>,
    /// preempt running lanes under page pressure (`--preempt`): when an
    /// admissible request would backpressure, evict the lowest-progress
    /// victim lanes, park them in the batcher's `Preempted` state, and
    /// restore-by-recompute once pages free up. Off by default — the
    /// no-preemption engine is the identity baseline the torture tests
    /// compare against.
    pub preempt: bool,
    /// fault-injection hook for the panic-containment tests: the worker
    /// that claims this request id panics at admission
    #[doc(hidden)]
    pub panic_on_request: Option<u64>,
    /// torture-test hook: forcibly preempt the policy victim every N
    /// decode steps regardless of page pressure (KV path only; skipped
    /// when fewer than two lanes are active so a lone request cannot
    /// livelock against itself)
    #[doc(hidden)]
    pub preempt_every: Option<usize>,
    /// fault-injection hook (sharded): the worker holding this request
    /// preempts it, parks it on its shard, and panics — exercising the
    /// "panic while holding a preempted lane" window. Fires once per
    /// deployment.
    #[doc(hidden)]
    pub panic_on_preempt_of: Option<u64>,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg {
            max_steps: 100_000,
            use_kv_cache: true,
            backend: "dense",
            workers: 1,
            prefill_chunk: None,
            preempt: false,
            panic_on_request: None,
            preempt_every: None,
            panic_on_preempt_of: None,
        }
    }
}

/// One in-flight request bound to a lane (and, when the KV cache is on,
/// to a cache lane from admission until finish).
#[derive(Debug, Clone)]
struct Lane {
    id: u64,
    /// original request, kept so a preempted lane can be parked with the
    /// full submission intact (deadline expiry reports through it)
    req: GenRequest,
    seq: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    submitted: Instant,
    admitted: Instant,
    deadline: Option<Duration>,
    /// paged-cache lane, reserved at admission (KV path only)
    slot: Option<usize>,
    /// prompt has been prefilled (first token emitted)
    prefilled: bool,
    /// positions adopted from the shared-prefix index on first touch
    /// (`None` until the first prefill step reaches this lane); doubles
    /// as the adopt-once flag — `adopt_prefix` requires an empty lane, so
    /// chunked prefill must only adopt on the first chunk
    adopted: Option<usize>,
    /// lane is a preemption restore: its "prompt" replay covers prompt +
    /// already-generated tokens, and its recomputed positions are
    /// reported as `restored_positions`, not a fresh prefill
    restored: bool,
    /// when this lane last emitted a token (inter-token latency); carried
    /// across preemption so the parked gap lands in the p99
    last_token_at: Option<Instant>,
    /// admission→first-token wall time, stamped once at the first emit
    /// and carried across preemption (a victim's TTFT is its *first*
    /// first-token time)
    ttft_ms: Option<f64>,
}

/// Shared-state handles a sharded worker's engine carries: its worker
/// id, the deployment-wide prefix placement index, and the in-flight
/// request registry ([`run_sharded`] reads the latter to name the
/// requests a panicked worker took down).
struct ShardCtx<'a> {
    worker: usize,
    router: &'a PrefixRouter,
    in_flight: &'a Mutex<Vec<HashSet<u64>>>,
    /// one-shot arm for `panic_on_preempt_of` (deployment-wide, so the
    /// injected panic fires exactly once even if the request is restored
    /// onto another worker that also matches)
    preempt_armed: &'a AtomicBool,
}

/// Continuous-batching decode loop over the lane pool (see module docs).
pub struct Engine<'a> {
    pipe: &'a Pipeline<'a>,
    model: &'a ModelEval<'a>,
    /// engine tunables (step cap, KV cache on/off)
    pub cfg: EngineCfg,
    lanes: Vec<Option<Lane>>,
    cache: KvCache,
    /// present only on engines spawned by [`run_sharded`]
    shard: Option<ShardCtx<'a>>,
    /// live-streaming hub ([`run_sharded_live`] / the HTTP front door):
    /// tokens are pushed per decode step, client cancels are swept each
    /// loop iteration, and the worker loop runs until shutdown instead
    /// of until the queue drains
    hub: Option<&'a EmitHub>,
}

impl<'a> Engine<'a> {
    /// An engine over `pipe.cfg.b_eval` lanes with a fully provisioned
    /// page pool (one window per lane, [`DEFAULT_PAGE_SIZE`] positions
    /// per page), decoding `model`.
    pub fn new(pipe: &'a Pipeline<'a>, model: &'a ModelEval<'a>) -> Engine<'a> {
        Self::with_cache_geometry(pipe, model, DEFAULT_PAGE_SIZE, None)
    }

    /// An engine with explicit cache geometry: `page_size` positions per
    /// page and `kv_pages` pool pages (`None` = one full window per
    /// lane). The pool is floored at one full window so a maximal
    /// request stays admissible; an undersized pool trades concurrency
    /// for memory and surfaces as admission backpressure in the metrics.
    pub fn with_cache_geometry(
        pipe: &'a Pipeline<'a>,
        model: &'a ModelEval<'a>,
        page_size: usize,
        kv_pages: Option<usize>,
    ) -> Engine<'a> {
        let cfg = &pipe.cfg;
        let ps = page_size.clamp(1, cfg.seq);
        let per_lane = cfg.seq.div_ceil(ps);
        let pages = kv_pages.unwrap_or(cfg.b_eval * per_lane).max(per_lane);
        Self::with_shard_geometry(pipe, model, cfg.b_eval, ps, pages)
    }

    /// An engine owning exactly `lanes` lanes over its own private
    /// `pool_pages`-page cache — one sharded worker's slice of a
    /// deployment ([`run_sharded`] partitions lanes and pages with this;
    /// `new`/`with_cache_geometry` are the whole-pool specializations).
    /// The pool is floored at one full window per the cache's invariant.
    pub fn with_shard_geometry(
        pipe: &'a Pipeline<'a>,
        model: &'a ModelEval<'a>,
        lanes: usize,
        page_size: usize,
        pool_pages: usize,
    ) -> Engine<'a> {
        let cfg = &pipe.cfg;
        assert!(lanes >= 1 && lanes <= cfg.b_eval, "lanes out of [1, b_eval]");
        let ps = page_size.clamp(1, cfg.seq);
        let pages = pool_pages.max(cfg.seq.div_ceil(ps));
        let cache = KvCache::with_geometry(
            lanes,
            cfg.n_layers,
            cfg.seq,
            cfg.n_heads,
            cfg.d / cfg.n_heads,
            ps,
            pages,
        );
        let ecfg = EngineCfg { backend: model.label(), ..EngineCfg::default() };
        Engine {
            pipe,
            model,
            cfg: ecfg,
            lanes: (0..lanes).map(|_| None).collect(),
            cache,
            shard: None,
            hub: None,
        }
    }

    /// Attach a live-streaming [`EmitHub`]: every emitted token is pushed
    /// to the request's channel, cancelled requests are torn down
    /// mid-flight, and [`Engine::run_worker`] switches to its
    /// long-running (shutdown-latched) mode.
    pub fn set_hub(&mut self, hub: &'a EmitHub) {
        self.hub = Some(hub);
    }

    /// Record the run's resident-memory accounting (KV reserved/live
    /// bytes and paging stats, packed-model bytes + effective
    /// bits/weight, backend label) into the metrics registry. Called at
    /// the top of every run loop and again after it drains, so the JSON
    /// carries the final live high-water mark and CoW count.
    fn export_memory(&self, metrics: &mut MetricsRegistry) {
        metrics.set_backend(self.cfg.backend);
        metrics.set_kernel_dispatch(kernel_tier(), pool::local_intra());
        if self.cfg.use_kv_cache {
            metrics.set_kv_paging(
                self.cache.bytes(),
                self.cache.peak_live_bytes(),
                self.cache.page_size(),
                self.cache.total_pages(),
                self.cache.cow_splits(),
                self.cache.page_alloc_count(),
            );
        }
        if let Some(pm) = self.model.packed() {
            metrics.set_packed_model(
                pm.method(),
                pm.resident_bytes(),
                pm.effective_bits(),
            );
        }
    }

    /// Number of lanes (== max concurrent requests).
    pub fn capacity(&self) -> usize {
        self.lanes.len()
    }

    /// The engine's paged KV cache (occupancy / sharing accounting).
    pub fn kv_cache(&self) -> &KvCache {
        &self.cache
    }

    fn active_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Greedy next token from one vocab row — shared by the cached and
    /// full-window paths so tie-breaking is identical in both.
    fn argmax(row: &[f32]) -> i32 {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j as i32)
            .unwrap()
    }

    /// The tokenized shape of a request: `(prompt_len, max_new)` after
    /// window truncation and empty-prompt seeding. Shared by admission's
    /// page-budget reservation and [`Self::make_lane`] so the reserved
    /// budget always matches the lane that decodes against it.
    fn lane_shape(&self, req: &GenRequest) -> (usize, usize) {
        let t = self.pipe.cfg.seq;
        // the byte tokenizer is one token per byte; empty prompts are
        // seeded with a single space, long ones truncate to the window
        let prompt_len = req.prompt.len().clamp(1, t - 1);
        let max_new = req.max_new_tokens.min(t - prompt_len);
        (prompt_len, max_new)
    }

    fn make_lane(
        &self,
        id: u64,
        req: &GenRequest,
        submitted: Instant,
        admitted: Instant,
        deadline: Option<Duration>,
    ) -> Lane {
        let t = self.pipe.cfg.seq;
        let tk = ByteTokenizer;
        let mut seq = tk.encode(&req.prompt);
        seq.truncate(t - 1);
        if seq.is_empty() {
            seq.push(b' ' as i32);
        }
        let (prompt_len, max_new) = self.lane_shape(req);
        assert_eq!(
            prompt_len,
            seq.len(),
            "lane_shape must match the tokenized prompt"
        );
        Lane {
            id,
            req: req.clone(),
            seq,
            prompt_len,
            max_new,
            submitted,
            admitted,
            deadline,
            slot: None,
            prefilled: false,
            adopted: None,
            restored: false,
            last_token_at: None,
            ttft_ms: None,
        }
    }

    /// Rebuild a lane from a parked preemption victim. The already-
    /// generated tokens ride along as part of the "prompt" replay, so the
    /// restore recomputes `seq` positions (minus whatever the prefix
    /// index re-adopts) and then continues decoding bit-identically —
    /// greedy argmax over the same K/V is the same token.
    fn lane_from_parked(p: PreemptedReq, slot: usize) -> Lane {
        Lane {
            id: p.id,
            req: p.req,
            seq: p.seq,
            prompt_len: p.prompt_len,
            max_new: p.max_new,
            submitted: p.submitted,
            admitted: p.admitted,
            deadline: p.deadline,
            slot: Some(slot),
            prefilled: false,
            adopted: None,
            restored: true,
            last_token_at: p.last_token_at,
            ttft_ms: p.ttft_ms,
        }
    }

    /// Evict lane `li`: release its pages back to the pool and return the
    /// parked form (caller decides which parked store it lands in).
    /// Shared prefix pages survive the free inside the cache's index, so
    /// a shared-prefix victim's restore re-adopts them for free.
    fn preempt_lane(
        &mut self,
        li: usize,
        metrics: &mut MetricsRegistry,
    ) -> PreemptedReq {
        let lane = self.lanes[li].take().expect("preempting an empty lane");
        self.deregister_in_flight(lane.id);
        let slot = lane.slot.expect("preemption is a KV-path operation");
        self.cache.free(slot);
        metrics.record_preemption();
        PreemptedReq {
            id: lane.id,
            req: lane.req,
            seq: lane.seq,
            prompt_len: lane.prompt_len,
            max_new: lane.max_new,
            submitted: lane.submitted,
            admitted: lane.admitted,
            deadline: lane.deadline,
            last_token_at: lane.last_token_at,
            ttft_ms: lane.ttft_ms,
        }
    }

    /// Active lanes in victim order: lowest progress first (fewest
    /// generated tokens — the cheapest recompute), non-shared-prefix
    /// lanes before prefix adopters (an adopter's pages mostly stay
    /// resident in the index, so evicting it recovers less), newest
    /// request last-admitted first on ties.
    fn victim_order(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.lanes.len())
            .filter(|&i| {
                self.lanes[i].as_ref().is_some_and(|l| l.slot.is_some())
            })
            .collect();
        order.sort_by_key(|&i| {
            let l = self.lanes[i].as_ref().unwrap();
            let progress = l.seq.len() - l.prompt_len;
            let shared = usize::from(l.adopted.unwrap_or(0) > 0);
            (progress, shared, std::cmp::Reverse(l.id))
        });
        order
    }

    /// Victims whose reserved budgets cover the pool deficit blocking a
    /// `need_positions`-position admission, or `None` when either there
    /// is no deficit or even evicting everything would not cover it
    /// (e.g. the pages are pinned by shared refs outside this pool's
    /// reservations — then backpressure is the only option).
    fn pick_victims(&self, need_positions: usize) -> Option<Vec<usize>> {
        let need = self.cache.pages_needed(need_positions);
        let deficit = (self.cache.reserved_page_count() + need)
            .checked_sub(self.cache.total_pages())?;
        if deficit == 0 {
            return None;
        }
        let mut victims = Vec::new();
        let mut freed = 0usize;
        for li in self.victim_order() {
            if freed >= deficit {
                break;
            }
            let l = self.lanes[li].as_ref().unwrap();
            freed += self.cache.pages_needed(l.prompt_len + l.max_new);
            victims.push(li);
        }
        (freed >= deficit).then_some(victims)
    }

    /// Forced-preemption tick target ([`EngineCfg::preempt_every`]): the
    /// lowest-progress lane that has **completed prefill**, or `None`
    /// with fewer than two active lanes (a lone request must be allowed
    /// to finish or nothing ever completes). Unprefilled lanes are never
    /// tick victims: such a lane restarts its replay from position zero
    /// on every restore, so a tick cadence at or below its replay length
    /// would evict it before it ever completes — with the step's chunk
    /// budget spent on it each round, the whole scheduler livelocks.
    /// (Page-pressure preemption may evict unprefilled lanes safely: the
    /// parked head gates all fresh admissions there, so the admitted
    /// lane always runs to completion and frees the victim's pages.)
    fn forced_victim(&self) -> Option<usize> {
        if self.active_lanes() < 2 {
            return None;
        }
        self.victim_order()
            .into_iter()
            .find(|&li| self.lanes[li].as_ref().unwrap().prefilled)
    }

    fn finish(
        lane: Lane,
        cached_positions: usize,
        now: Instant,
        metrics: &mut MetricsRegistry,
    ) -> GenResponse {
        let tk = ByteTokenizer;
        let queue_ms =
            lane.admitted.duration_since(lane.submitted).as_secs_f64() * 1000.0;
        let decode_ms = now.duration_since(lane.admitted).as_secs_f64() * 1000.0;
        let new_tokens = lane.seq.len() - lane.prompt_len;
        metrics.record_request(RequestMetric {
            id: lane.id,
            queue_ms,
            decode_ms,
            total_ms: queue_ms + decode_ms,
            ttft_ms: lane.ttft_ms.unwrap_or(0.0),
            new_tokens,
            cached_positions,
        });
        GenResponse {
            id: lane.id,
            text: tk.decode(&lane.seq),
            new_tokens,
            queue_ms,
            decode_ms,
            latency_ms: queue_ms + decode_ms,
        }
    }

    /// Take lane `li` out of the pool, release its cache pages, and emit
    /// the response (recording the lane's cached-position high-water mark
    /// before the free resets it).
    fn finish_lane(
        &mut self,
        li: usize,
        now: Instant,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let lane = self.lanes[li].take().unwrap();
        self.deregister_in_flight(lane.id);
        let cached_positions =
            lane.slot.map(|slot| self.cache.len(slot)).unwrap_or(0);
        if let Some(slot) = lane.slot {
            self.cache.free(slot);
        }
        let resp = Self::finish(lane, cached_positions, now, metrics);
        self.notify_finish(&resp);
        out.push(resp);
    }

    /// Live mode: deliver the terminal `Done` event (no-op without a hub).
    fn notify_finish(&self, resp: &GenResponse) {
        if let Some(hub) = self.hub {
            hub.finish(resp.clone());
        }
    }

    /// Live mode: push one decoded token to the request's consumer.
    /// `true` means keep going; `false` means the consumer is gone and
    /// the lane should be cancelled. Without a hub, always `true`.
    fn emit_live(&self, id: u64, index: usize, token: i32) -> bool {
        match self.hub {
            Some(hub) => hub.emit_token(id, index, token),
            None => true,
        }
    }

    /// Live mode: deliver terminal `Failed` events for expired requests.
    fn notify_expired(&self, expired: &[(u64, GenRequest)]) {
        if let Some(hub) = self.hub {
            for (id, _) in expired {
                hub.fail(*id, "expired");
            }
        }
    }

    /// Tear down lane `li` without a response: free its slot's pages,
    /// drop it from the in-flight registry, and count the cancel. Used
    /// when the lane's client disconnected (its emit channel is gone).
    fn cancel_lane(&mut self, li: usize, metrics: &mut MetricsRegistry) {
        let lane = self.lanes[li].take().expect("cancelling an empty lane");
        self.deregister_in_flight(lane.id);
        if let Some(slot) = lane.slot {
            self.cache.free(slot);
        }
        metrics.record_cancelled();
        if let Some(hub) = self.hub {
            // idempotent: covers the engine-detected (emit-failure) path
            // as well as an explicit consumer cancel
            hub.cancel(lane.id);
        }
    }

    /// Live mode: tear down any active lane whose consumer cancelled
    /// (client disconnect noticed by the connection handler). Swept once
    /// per loop iteration, before admission, so freed pages are
    /// immediately reusable.
    fn sweep_cancelled(&mut self, metrics: &mut MetricsRegistry) {
        let Some(hub) = self.hub else { return };
        for li in 0..self.lanes.len() {
            let gone = self.lanes[li]
                .as_ref()
                .is_some_and(|l| hub.is_cancelled(l.id));
            if gone {
                self.cancel_lane(li, metrics);
            }
        }
    }

    /// Live mode: publish this worker's occupancy gauges (active lanes,
    /// KV live bytes) so `/stats` observes admission and teardown.
    fn publish_gauges(&self) {
        if let Some(hub) = self.hub {
            let w = self.shard.as_ref().map_or(0, |c| c.worker);
            let live = if self.cfg.use_kv_cache { self.cache.live_bytes() } else { 0 };
            hub.publish(w, self.active_lanes(), live);
        }
    }

    /// Sharded runs track which requests each worker holds so a panic can
    /// be attributed; no-ops on single-loop engines.
    fn register_in_flight(&self, id: u64) {
        if let Some(ctx) = &self.shard {
            ctx.in_flight.lock().unwrap()[ctx.worker].insert(id);
        }
    }

    fn deregister_in_flight(&self, id: u64) {
        if let Some(ctx) = &self.shard {
            ctx.in_flight.lock().unwrap()[ctx.worker].remove(&id);
        }
    }

    /// Admit queued requests into free lanes (continuous mode). Requests
    /// whose deadline lapsed in the queue are dropped; zero-token requests
    /// complete immediately without occupying a lane. On the KV path each
    /// admission reserves the request's worst-case page budget — when the
    /// pool cannot cover it, admission stops (backpressure) and the
    /// request stays queued until finishing lanes release pages.
    fn admit(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let now = Instant::now();
        let expired = batcher.expire_overdue(now);
        self.notify_expired(&expired);
        metrics.record_expired(expired.len());
        for i in 0..self.lanes.len() {
            while self.lanes[i].is_none() {
                // restore-to-front: parked preemption victims re-admit
                // before anything in the fresh queue, and a restore never
                // preempts (it caused the pressure — evicting for it
                // would livelock the scheduler)
                if self.cfg.use_kv_cache {
                    if let Some(p) = batcher.peek_parked() {
                        let need = p.prompt_len + p.max_new;
                        match self.cache.alloc_with_budget(need) {
                            Some(slot) => {
                                let p = batcher
                                    .pop_parked()
                                    .expect("peeked parked vanished");
                                self.register_in_flight(p.id);
                                self.lanes[i] =
                                    Some(Self::lane_from_parked(p, slot));
                                continue;
                            }
                            None => {
                                metrics.record_backpressure();
                                return;
                            }
                        }
                    }
                }
                // peek first (borrowed, no clone): the page budget comes
                // from `lane_shape` without tokenizing, so a rejected
                // admission leaves the request queued at zero cost
                let Some((_, peeked, _)) = batcher.peek_ready(now) else {
                    return;
                };
                let (prompt_len, max_new) = self.lane_shape(peeked);
                let mut slot = None;
                if max_new > 0 && self.cfg.use_kv_cache {
                    loop {
                        match self.cache.alloc_with_budget(prompt_len + max_new) {
                            Some(s) => {
                                slot = Some(s);
                                break;
                            }
                            None if self.cfg.preempt => {
                                // page pressure with an admissible head:
                                // evict enough low-progress victims to
                                // cover the deficit, park them, retry
                                let Some(victims) =
                                    self.pick_victims(prompt_len + max_new)
                                else {
                                    metrics.record_backpressure();
                                    return;
                                };
                                for li in victims {
                                    let p = self.preempt_lane(li, metrics);
                                    batcher.park(p);
                                }
                            }
                            None => {
                                // pool exhausted: leave the request queued
                                metrics.record_backpressure();
                                return;
                            }
                        }
                    }
                }
                let (id, req, submitted, deadline) =
                    batcher.pop_ready(now).expect("peeked head vanished");
                let mut lane = self.make_lane(id, &req, submitted, now, deadline);
                if lane.max_new == 0 {
                    let resp = Self::finish(lane, 0, now, metrics);
                    self.notify_finish(&resp);
                    out.push(resp);
                    continue;
                }
                lane.slot = slot;
                self.lanes[i] = Some(lane);
            }
        }
    }

    /// `true` once the lane produced its budget of new tokens or filled
    /// the window — same rule on both decode paths.
    fn lane_done(&self, li: usize) -> bool {
        let lane = self.lanes[li].as_ref().unwrap();
        lane.seq.len() - lane.prompt_len >= lane.max_new
            || lane.seq.len() >= self.pipe.cfg.seq
    }

    /// One full-window decode step (`use_kv_cache = false`). In compact
    /// mode only active lanes enter the forward (cost scales with load);
    /// in fixed-width mode every lane slot is computed, finished-lane rows
    /// as padding — the static batching cost model.
    fn decode_step_full(
        &mut self,
        fixed_width: bool,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let (t, vocab) = (self.pipe.cfg.seq, self.pipe.cfg.vocab);
        let layout: Vec<Option<usize>> = if fixed_width {
            (0..self.lanes.len())
                .map(|i| self.lanes[i].is_some().then_some(i))
                .collect()
        } else {
            (0..self.lanes.len())
                .filter(|&i| self.lanes[i].is_some())
                .map(Some)
                .collect()
        };
        let n_active = layout.iter().filter(|r| r.is_some()).count();
        if n_active == 0 {
            return Ok(());
        }
        let b = layout.len();
        let mut tokens = vec![0i32; b * t];
        for (row, slot) in layout.iter().enumerate() {
            if let Some(li) = slot {
                let lane = self.lanes[*li].as_ref().unwrap();
                tokens[row * t..row * t + lane.seq.len()].copy_from_slice(&lane.seq);
            }
        }
        let step_started = Instant::now();
        let h = self.model.forward_h(self.pipe, &tokens)?;
        let (_, logits) = self.pipe.head(self.model.params(), &h, &tokens)?;
        metrics.record_step_from(step_started, n_active, self.lanes.len());
        let now = Instant::now();
        for (row, slot) in layout.iter().enumerate() {
            let Some(li) = slot else { continue };
            let (id, index, token) = {
                let lane = self.lanes[*li].as_mut().unwrap();
                let pos = lane.seq.len() - 1;
                let base = (row * t + pos) * vocab;
                let next = Self::argmax(&logits.data[base..base + vocab]);
                lane.seq.push(next);
                if let Some(prev) = lane.last_token_at {
                    metrics
                        .record_itl(now.duration_since(prev).as_secs_f64() * 1000.0);
                }
                lane.last_token_at = Some(now);
                if lane.ttft_ms.is_none() {
                    lane.ttft_ms = Some(
                        now.duration_since(lane.admitted).as_secs_f64() * 1000.0,
                    );
                }
                (lane.id, lane.seq.len() - lane.prompt_len - 1, next)
            };
            metrics.record_tokens(1);
            if !self.emit_live(id, index, token) {
                self.cancel_lane(*li, metrics);
                continue;
            }
            if self.lane_done(*li) {
                self.finish_lane(*li, now, metrics, out);
            }
        }
        Ok(())
    }

    /// One KV-cached decode step. Newly admitted lanes adopt any shared
    /// whole-page prompt prefix from the cache's index, then prefill in
    /// *batched* buckets — lanes whose remaining (post-adoption) chunks
    /// are the same length run as one chunked forward instead of one
    /// `b=1` forward each. With [`EngineCfg::prefill_chunk`] set, at most
    /// that many prefill tokens are computed per step (chunks carry over
    /// to later steps), so decode lanes keep emitting between a long
    /// prompt's chunks instead of stalling behind it. Lanes already
    /// prefilled decode their single newest token as one compacted batch;
    /// every *decoding* lane yields exactly one token per step, and a
    /// prefilling lane yields its first token on the step its last chunk
    /// completes.
    fn decode_step_cached(
        &mut self,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let vocab = self.pipe.cfg.vocab;
        let active: Vec<usize> =
            (0..self.lanes.len()).filter(|&i| self.lanes[i].is_some()).collect();
        if active.is_empty() {
            return Ok(());
        }
        let n_active = active.len();
        let (pipe, model) = (self.pipe, self.model);
        let step_started = Instant::now();
        let decoding: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&li| self.lanes[li].as_ref().unwrap().prefilled)
            .collect();
        // chunked batched prefill: adopt shared prefixes on a lane's
        // FIRST touch (the cache requires an empty lane to adopt), then
        // spend this step's prefill-token budget over unprefilled lanes
        // in lane order — lanes the budget does not reach simply wait
        // while decode lanes keep emitting, which is the whole point.
        // Within the budget, lanes are bucketed by chunk length (BTreeMap
        // for deterministic order) and each bucket runs as one chunked
        // forward, exactly the PR 5 batched-prefill path per chunk.
        let mut emitted = vec![false; self.lanes.len()];
        // floor the chunk at 1: a zero budget would starve prefill forever
        let mut budget = self.cfg.prefill_chunk.map_or(usize::MAX, |c| c.max(1));
        let mut buckets: BTreeMap<usize, Vec<(usize, Vec<i32>)>> = BTreeMap::new();
        for &li in &active {
            if self.lanes[li].as_ref().unwrap().prefilled {
                continue;
            }
            if budget == 0 {
                break;
            }
            let (slot, seq, adopted, restored) = {
                let lane = self.lanes[li].as_ref().unwrap();
                (
                    lane.slot.expect("cached lane without a slot"),
                    lane.seq.clone(),
                    lane.adopted,
                    lane.restored,
                )
            };
            if adopted.is_none() {
                let reused = self.cache.adopt_prefix(slot, &seq);
                if restored {
                    // restore-by-recompute: only the suffix the index
                    // could not re-adopt is actually recomputed — the
                    // cheapness of shared-prefix victims shows up here
                    metrics.record_restored(seq.len() - reused);
                } else {
                    metrics.record_prefill(seq.len(), reused);
                }
                self.lanes[li].as_mut().unwrap().adopted = Some(reused);
            }
            let done = self.cache.len(slot);
            let remaining = seq.len() - done;
            let take = remaining.min(budget);
            budget -= take;
            if take < remaining {
                metrics.record_prefill_chunk();
            }
            buckets
                .entry(take)
                .or_default()
                .push((li, seq[done..done + take].to_vec()));
        }
        for (&t_new, group) in &buckets {
            let slots: Vec<usize> = group
                .iter()
                .map(|(li, _)| self.lanes[*li].as_ref().unwrap().slot.unwrap())
                .collect();
            let tokens: Vec<i32> =
                group.iter().flat_map(|(_, s)| s.iter().copied()).collect();
            let h = model.forward_h_incremental(pipe, &mut self.cache, &slots, &tokens)?;
            // a chunk reaching its sequence's end emits the first token;
            // a mid-prompt chunk only extends the cache, so the head runs
            // only when some lane in the bucket completes
            let completes: Vec<(usize, usize)> = group
                .iter()
                .enumerate()
                .filter_map(|(row, (li, _))| {
                    let lane = self.lanes[*li].as_ref().unwrap();
                    (self.cache.len(lane.slot.unwrap()) == lane.seq.len())
                        .then_some((row, *li))
                })
                .collect();
            if completes.is_empty() {
                continue;
            }
            let logits = pipe.head_decode(model.params(), &h)?;
            for &(row, li) in &completes {
                let base = (row * t_new + (t_new - 1)) * vocab;
                let next = Self::argmax(&logits.data[base..base + vocab]);
                let lane = self.lanes[li].as_mut().unwrap();
                lane.seq.push(next);
                lane.prefilled = true;
                emitted[li] = true;
            }
            // register after the forward so the pages hold the prompt K/V
            for &(_, li) in &completes {
                let lane = self.lanes[li].as_ref().unwrap();
                let (slot, plen) = (lane.slot.unwrap(), lane.prompt_len);
                let prompt = lane.seq[..plen].to_vec();
                self.cache.register_prefix(slot, &prompt);
                // sharded: advertise the chains deployment-wide so later
                // submissions route to this worker's partition
                if let Some(ctx) = &self.shard {
                    ctx.router.publish(ctx.worker, &prompt);
                }
            }
        }
        if !decoding.is_empty() {
            let slots: Vec<usize> = decoding
                .iter()
                .map(|&li| self.lanes[li].as_ref().unwrap().slot.unwrap())
                .collect();
            let toks: Vec<i32> = decoding
                .iter()
                .map(|&li| *self.lanes[li].as_ref().unwrap().seq.last().unwrap())
                .collect();
            let h = model.forward_h_incremental(pipe, &mut self.cache, &slots, &toks)?;
            let logits = pipe.head_decode(model.params(), &h)?;
            for (row, &li) in decoding.iter().enumerate() {
                let next = Self::argmax(&logits.data[row * vocab..(row + 1) * vocab]);
                self.lanes[li].as_mut().unwrap().seq.push(next);
                emitted[li] = true;
            }
        }
        metrics.record_step_from(step_started, n_active, self.lanes.len());
        let now = Instant::now();
        for &li in &active {
            if !emitted[li] {
                continue;
            }
            metrics.record_tokens(1);
            let (id, index, token) = {
                let lane = self.lanes[li].as_mut().unwrap();
                if let Some(prev) = lane.last_token_at {
                    metrics
                        .record_itl(now.duration_since(prev).as_secs_f64() * 1000.0);
                }
                lane.last_token_at = Some(now);
                if lane.ttft_ms.is_none() {
                    lane.ttft_ms = Some(
                        now.duration_since(lane.admitted).as_secs_f64() * 1000.0,
                    );
                }
                (
                    lane.id,
                    lane.seq.len() - lane.prompt_len - 1,
                    *lane.seq.last().unwrap(),
                )
            };
            if !self.emit_live(id, index, token) {
                self.cancel_lane(li, metrics);
                continue;
            }
            if self.lane_done(li) {
                self.finish_lane(li, now, metrics, out);
            }
        }
        Ok(())
    }

    /// One decode step on whichever path `cfg.use_kv_cache` selects.
    fn decode_step(
        &mut self,
        fixed_width: bool,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        if self.cfg.use_kv_cache {
            self.decode_step_cached(metrics, out)
        } else {
            self.decode_step_full(fixed_width, metrics, out)
        }
    }

    /// How long to sleep when requests are queued but none is admissible
    /// (page-pool backpressure with idle lanes, or a deadline/max-wait
    /// gated batcher): bounded by the batcher's own cut interval so a
    /// ready batch is picked up promptly, floored so an aggressive
    /// `max_wait` cannot turn the wait back into a hot spin.
    fn idle_backoff(batcher: &Batcher) -> Duration {
        batcher
            .max_wait
            .min(Duration::from_millis(1))
            .max(Duration::from_micros(50))
    }

    /// Continuous batching: a finished sequence's lane is refilled from
    /// the queue on the next decode step.
    pub fn run(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        self.export_memory(metrics);
        let k0 = kernel_nanos();
        let mut step = 0usize;
        for _ in 0..self.cfg.max_steps {
            self.sweep_cancelled(metrics);
            self.admit(batcher, metrics, &mut out);
            self.publish_gauges();
            if self.active_lanes() == 0 {
                if batcher.pending() == 0 {
                    break;
                }
                // reachable only if admission is gated with every lane
                // idle — an empty pool always covers one full window, so
                // back off briefly rather than burning the step budget
                std::thread::sleep(Self::idle_backoff(batcher));
                continue;
            }
            self.decode_step(false, metrics, &mut out)?;
            self.publish_gauges();
            step += 1;
            // torture-test hook: forced preemption every N steps
            if let Some(n) = self.cfg.preempt_every {
                if n > 0 && self.cfg.use_kv_cache && step % n == 0 {
                    if let Some(li) = self.forced_victim() {
                        let p = self.preempt_lane(li, metrics);
                        batcher.park(p);
                    }
                }
            }
        }
        self.publish_gauges();
        metrics.record_kernel_ns(kernel_nanos() - k0);
        self.export_memory(metrics);
        Ok(out)
    }

    /// Drain (static) batching baseline: admit a full batch, decode until
    /// every lane finishes, only then take the next batch. Admission goes
    /// through the same deadline-aware `admit` as continuous mode (called
    /// only when every lane is free, which is exactly batch admission), so
    /// oversized queues and lapsed deadlines are handled per batch, not
    /// just once up front. Cache pages release at each lane's finish and
    /// are reused by the next batch.
    pub fn run_drain(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        self.export_memory(metrics);
        let k0 = kernel_nanos();
        let mut total_steps = 0;
        while total_steps < self.cfg.max_steps {
            self.admit(batcher, metrics, &mut out);
            if self.active_lanes() == 0 {
                break;
            }
            while self.active_lanes() > 0 && total_steps < self.cfg.max_steps {
                self.decode_step(true, metrics, &mut out)?;
                total_steps += 1;
            }
        }
        metrics.record_kernel_ns(kernel_nanos() - k0);
        self.export_memory(metrics);
        Ok(out)
    }

    /// One-shot drain over an explicit request list (the legacy
    /// `generate_batch` contract): responses in request order.
    pub fn run_drain_batch(
        &mut self,
        requests: &[GenRequest],
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        assert!(requests.len() <= self.capacity(), "batch too wide");
        let mut batcher = Batcher::new(self.capacity());
        for r in requests {
            batcher.submit(r.clone());
        }
        let mut out = self.run_drain(&mut batcher, metrics)?;
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    /// Restore a parked preemption victim into free lane `i`. Returns
    /// `false` (after re-parking it at our shard's head and recording
    /// backpressure) when the partition cannot cover its budget yet —
    /// the caller stops admitting; a restore never preempts.
    fn try_restore_parked(
        &mut self,
        i: usize,
        p: PreemptedReq,
        queue: &ShardedQueue,
        worker: usize,
        metrics: &mut MetricsRegistry,
    ) -> bool {
        match self.cache.alloc_with_budget(p.prompt_len + p.max_new) {
            Some(slot) => {
                self.register_in_flight(p.id);
                self.lanes[i] = Some(Self::lane_from_parked(p, slot));
                true
            }
            None => {
                queue.park_front(worker, p);
                metrics.record_backpressure();
                false
            }
        }
    }

    /// Sharded admission: restore our own parked preemption victims
    /// first, then claim from the work-stealing queue (own shard first,
    /// then the most-loaded sibling), and only when both are empty adopt
    /// a sibling's parked victim (that steal is what lets survivors
    /// finish a dead worker's preempted requests). Page budgets come
    /// from this worker's **private** partition — on exhaustion the
    /// claimed request is restored to our shard's head (so FIFO order and
    /// the submit timestamp survive) and admission backpressures exactly
    /// like the single-engine path, unless `cfg.preempt` can cover the
    /// deficit by evicting low-progress victims.
    fn admit_sharded(
        &mut self,
        queue: &ShardedQueue,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let worker =
            self.shard.as_ref().expect("sharded admission without ctx").worker;
        let now = Instant::now();
        let expired = queue.expire_overdue(now);
        self.notify_expired(&expired);
        metrics.record_expired(expired.len());
        for i in 0..self.lanes.len() {
            while self.lanes[i].is_none() {
                if self.cfg.use_kv_cache {
                    if let Some(p) = queue.claim_parked(worker, false) {
                        if !self.try_restore_parked(i, p, queue, worker, metrics)
                        {
                            return;
                        }
                        continue;
                    }
                }
                let Some((id, req, submitted, deadline)) = queue.claim(worker)
                else {
                    // fresh queue drained: adopt an orphaned parked
                    // victim (a busy — or dead — sibling's) rather than
                    // idle with a free lane
                    if self.cfg.use_kv_cache {
                        if let Some(p) = queue.claim_parked(worker, true) {
                            if !self
                                .try_restore_parked(i, p, queue, worker, metrics)
                            {
                                return;
                            }
                            continue;
                        }
                    }
                    return;
                };
                self.register_in_flight(id);
                if self.cfg.panic_on_request == Some(id) {
                    panic!("injected worker panic on request {id}");
                }
                let (prompt_len, max_new) = self.lane_shape(&req);
                let mut slot = None;
                if max_new > 0 && self.cfg.use_kv_cache {
                    loop {
                        match self.cache.alloc_with_budget(prompt_len + max_new) {
                            Some(s) => {
                                slot = Some(s);
                                break;
                            }
                            None if self.cfg.preempt => {
                                let Some(victims) =
                                    self.pick_victims(prompt_len + max_new)
                                else {
                                    self.deregister_in_flight(id);
                                    queue.restore(
                                        worker, id, req, submitted, deadline,
                                    );
                                    metrics.record_backpressure();
                                    return;
                                };
                                for li in victims {
                                    let p = self.preempt_lane(li, metrics);
                                    queue.park(worker, p);
                                }
                            }
                            None => {
                                // partition exhausted: hand the request back
                                // and wait for our own lanes to free pages
                                self.deregister_in_flight(id);
                                queue.restore(worker, id, req, submitted, deadline);
                                metrics.record_backpressure();
                                return;
                            }
                        }
                    }
                }
                let mut lane = self.make_lane(id, &req, submitted, now, deadline);
                if lane.max_new == 0 {
                    self.deregister_in_flight(id);
                    let resp = Self::finish(lane, 0, now, metrics);
                    self.notify_finish(&resp);
                    out.push(resp);
                    continue;
                }
                lane.slot = slot;
                self.lanes[i] = Some(lane);
            }
        }
    }

    /// One sharded worker's continuous-batching loop: [`Engine::run`]
    /// against the shared [`ShardedQueue`] instead of a private
    /// [`Batcher`]. Exits once the queue is drained *and* every one of
    /// this worker's lanes has finished — siblings may still be decoding
    /// their own lanes. [`run_sharded`] drives one of these per worker;
    /// it is public so tests can run a single worker in isolation.
    ///
    /// **Live mode** (a hub attached via [`Engine::set_hub`]): the step
    /// cap is ignored and an idle worker *waits* for mid-flight
    /// submissions instead of exiting — the loop ends only when the hub
    /// signals shutdown and nothing is queued or active. Client cancels
    /// are swept each iteration and occupancy gauges published each step.
    pub fn run_worker(
        &mut self,
        queue: &ShardedQueue,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        self.export_memory(metrics);
        let k0 = kernel_nanos();
        let live = self.hub.is_some();
        let mut step = 0usize;
        let mut steps_left = self.cfg.max_steps;
        loop {
            if !live {
                if steps_left == 0 {
                    break;
                }
                steps_left -= 1;
            }
            self.sweep_cancelled(metrics);
            self.admit_sharded(queue, metrics, &mut out);
            self.publish_gauges();
            if self.active_lanes() == 0 {
                if queue.pending() == 0 {
                    if !live
                        || self.hub.is_some_and(|h| h.shutting_down())
                    {
                        break;
                    }
                }
                // queued work exists but nothing was admissible (raced
                // with a sibling's claim, or our partition backpressured
                // with every lane idle) — or a live worker is idling for
                // the next submission: back off briefly, then re-claim
                std::thread::sleep(
                    queue
                        .max_wait
                        .min(Duration::from_millis(1))
                        .max(Duration::from_micros(50)),
                );
                continue;
            }
            self.decode_step(false, metrics, &mut out)?;
            self.publish_gauges();
            step += 1;
            if self.cfg.use_kv_cache {
                self.forced_preempt_sharded(step, queue, metrics);
            }
        }
        self.publish_gauges();
        metrics.record_kernel_ns(kernel_nanos() - k0);
        self.export_memory(metrics);
        Ok(out)
    }

    /// Test hooks on the sharded step loop: the `panic_on_preempt_of`
    /// fault injection (preempt the target, park it on our shard, die —
    /// the "panic while holding a preempted lane" window the containment
    /// test exercises) and the `preempt_every` forced-preemption tick.
    fn forced_preempt_sharded(
        &mut self,
        step: usize,
        queue: &ShardedQueue,
        metrics: &mut MetricsRegistry,
    ) {
        let worker = self.shard.as_ref().unwrap().worker;
        if let Some(tid) = self.cfg.panic_on_preempt_of {
            let held = (0..self.lanes.len()).find(|&i| {
                self.lanes[i]
                    .as_ref()
                    .is_some_and(|l| l.id == tid && l.slot.is_some())
            });
            if let Some(li) = held {
                let armed = self
                    .shard
                    .as_ref()
                    .is_some_and(|c| c.preempt_armed.swap(false, Ordering::SeqCst));
                if armed {
                    let p = self.preempt_lane(li, metrics);
                    queue.park(worker, p);
                    panic!(
                        "injected worker panic after preempting request {tid}"
                    );
                }
            }
        }
        if let Some(n) = self.cfg.preempt_every {
            if n > 0 && step % n == 0 {
                if let Some(li) = self.forced_victim() {
                    let p = self.preempt_lane(li, metrics);
                    queue.park(worker, p);
                }
            }
        }
    }
}

/// Deployment geometry for [`run_sharded`]: the merged-metrics label plus
/// the cache geometry `serve` would otherwise hand to
/// [`Engine::with_cache_geometry`]. `kv_pages` is the **aggregate** pool
/// across all workers; [`partition_pages`] splits it with a one-window
/// floor per worker so every partition can admit a maximal request.
#[derive(Debug, Clone)]
pub struct ShardSpec<'a> {
    /// label for the merged metrics registry (exported in the JSON)
    pub label: &'a str,
    /// positions per KV page (clamped to `[1, seq]`)
    pub page_size: usize,
    /// aggregate pool pages across workers (`None` = one full window per
    /// lane, the fully provisioned default)
    pub kv_pages: Option<usize>,
}

/// What a sharded deployment produced: responses sorted by request id,
/// the per-worker metrics merged into one deployment view, and the
/// panic-containment report.
#[derive(Debug)]
pub struct ShardRun {
    pub responses: Vec<GenResponse>,
    pub metrics: MetricsRegistry,
    /// workers that panicked (their lanes failed; the process survived)
    pub worker_panics: usize,
    /// request ids a panicked worker held when it died (no response)
    pub failed_requests: Vec<u64>,
}

/// Clamp a requested worker count to what the pipeline can shard: every
/// worker needs at least one of the `b_eval` lanes. The CLI sizes its
/// [`ShardedQueue`] with this so the queue's shard count always matches
/// the spawned workers.
pub fn effective_workers(requested: usize, b_eval: usize) -> usize {
    requested.clamp(1, b_eval.max(1))
}

/// Prefix-cache-aware placement: the worker whose KV partition holds the
/// longest *published* whole-page prefix of this prompt, or `None` when
/// no worker has seen it (submit least-loaded instead). Called at
/// submission time, before the request is tokenized by a lane.
pub fn place_request(router: &PrefixRouter, req: &GenRequest) -> Option<usize> {
    let tk = ByteTokenizer;
    router.route(&tk.encode(&req.prompt))
}

/// Run a sharded deployment to completion: `cfg.workers` OS threads
/// (clamped to `[1, b_eval]`), each owning `b_eval / workers` lanes
/// (remainder to the low ids) and a private partition of the aggregate
/// page pool, all claiming from one work-stealing `queue`. Placement
/// hits published via `router` steer prefix-sharing requests to the
/// worker holding the pages.
///
/// **Identity invariant**: greedy decode is per-lane deterministic — a
/// request's tokens depend only on its own prompt and the weights, never
/// on which worker ran it or what shared its batch — so for a fixed
/// request set the responses are byte-identical for every worker count
/// (`--verify-identity` and `tests/multi_worker.rs` gate this).
///
/// **Panic containment**: workers are joined *inside* the thread scope,
/// so a panicking worker is absorbed rather than re-raised — its
/// in-flight request ids are returned in `failed_requests`, its routing
/// entries are dropped, and every other worker finishes normally.
pub fn run_sharded(
    pipe: &Pipeline,
    model: &ModelEval,
    cfg: &EngineCfg,
    queue: &ShardedQueue,
    router: &PrefixRouter,
    spec: &ShardSpec,
) -> Result<ShardRun> {
    run_sharded_live(pipe, model, cfg, queue, router, spec, None)
}

/// [`run_sharded`] with an optional live-streaming [`EmitHub`]: with a
/// hub the workers run in long-lived server mode (mid-flight submission
/// in, per-token emit channels out, shutdown-latched exit) — this is the
/// engine half of the HTTP front door. Without one it is exactly
/// [`run_sharded`].
pub fn run_sharded_live(
    pipe: &Pipeline,
    model: &ModelEval,
    cfg: &EngineCfg,
    queue: &ShardedQueue,
    router: &PrefixRouter,
    spec: &ShardSpec,
    hub: Option<&EmitHub>,
) -> Result<ShardRun> {
    let b_eval = pipe.cfg.b_eval;
    let workers = effective_workers(cfg.workers, b_eval);
    assert_eq!(
        queue.workers(),
        workers,
        "queue shards must match the effective worker count"
    );
    let ps = spec.page_size.clamp(1, pipe.cfg.seq);
    let per_window = pipe.cfg.seq.div_ceil(ps);
    let total_pages = spec.kv_pages.unwrap_or(b_eval * per_window);
    let page_split = partition_pages(total_pages, workers, per_window);
    let lane_split: Vec<usize> = (0..workers)
        .map(|w| b_eval / workers + usize::from(w < b_eval % workers))
        .collect();
    let in_flight = Mutex::new(vec![HashSet::new(); workers]);
    let preempt_armed = AtomicBool::new(true);
    type WorkerOutput = (Vec<GenResponse>, MetricsRegistry);
    let joined: Vec<thread::Result<Result<WorkerOutput>>> = thread::scope(|s| {
        let in_flight = &in_flight;
        let preempt_armed = &preempt_armed;
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let (lanes, pages) = (lane_split[w], page_split[w]);
                s.spawn(move || -> Result<WorkerOutput> {
                    // split the global intra-op thread budget across the
                    // sharded workers so total threads stay ~constant as
                    // `--workers` scales (each worker keeps at least 1)
                    pool::set_local_intra(
                        (pool::thread_budget() / workers.max(1)).max(1),
                    );
                    let mut engine =
                        Engine::with_shard_geometry(pipe, model, lanes, ps, pages);
                    engine.cfg =
                        EngineCfg { backend: engine.cfg.backend, ..cfg.clone() };
                    engine.shard = Some(ShardCtx {
                        worker: w,
                        router,
                        in_flight,
                        preempt_armed,
                    });
                    if let Some(hub) = hub {
                        engine.set_hub(hub);
                    }
                    let mut metrics = MetricsRegistry::new(&format!("worker{w}"));
                    let out = engine.run_worker(queue, &mut metrics)?;
                    Ok((out, metrics))
                })
            })
            .collect();
        // join INSIDE the scope: a joined handle's panic is ours to
        // absorb — only unjoined handles re-raise when the scope exits
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut responses = Vec::new();
    let mut per_worker = Vec::with_capacity(workers);
    let mut worker_panics = 0;
    let mut failed_requests: Vec<u64> = Vec::new();
    for (w, res) in joined.into_iter().enumerate() {
        match res {
            Ok(run) => {
                let (out, m) = run?;
                responses.extend(out);
                per_worker.push((m, false));
            }
            Err(_) => {
                // the worker died: report its in-flight requests failed
                // and stop routing new prompts at a dead partition
                worker_panics += 1;
                router.forget_worker(w);
                let lost: Vec<u64> =
                    in_flight.lock().unwrap()[w].iter().copied().collect();
                if let Some(hub) = hub {
                    for id in &lost {
                        hub.fail(*id, "worker panic");
                    }
                }
                failed_requests.extend(lost);
                per_worker
                    .push((MetricsRegistry::new(&format!("worker{w}")), true));
            }
        }
    }
    failed_requests.sort_unstable();
    responses.sort_by_key(|r| r.id);
    let metrics = MetricsRegistry::merge_workers(spec.label, per_worker);
    Ok(ShardRun { responses, metrics, worker_panics, failed_requests })
}
