//! Continuous-batching serve engine.
//!
//! A slot-based scheduler over the pipeline's `b_eval` lanes: each decode
//! step runs one full-window forward over the *compacted* set of active
//! lanes (the native runtime accepts any leading batch dimension, so cost
//! scales with active lanes), appends one greedy token per lane, and frees
//! finished lanes. Freed lanes are refilled from the admission queue on
//! the next step — a request never waits for the rest of its batch to
//! drain. `run_drain` is the classic static-batching baseline for
//! comparison: it admits whole batches and keeps the fixed `b_eval` batch
//! shape until every lane in the batch finishes, exactly what a
//! fixed-shape deployment without in-flight refill pays.

use std::time::Instant;

use anyhow::Result;

use super::batcher::Batcher;
use super::metrics::{MetricsRegistry, RequestMetric};
use super::{GenRequest, GenResponse};
use crate::coordinator::Pipeline;
use crate::eval::ModelEval;
use crate::model::tokenizer::ByteTokenizer;

#[derive(Debug, Clone)]
pub struct EngineCfg {
    /// hard cap on decode steps per run (runaway guard)
    pub max_steps: usize,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg { max_steps: 100_000 }
    }
}

#[derive(Debug, Clone)]
struct Lane {
    id: u64,
    seq: Vec<i32>,
    prompt_len: usize,
    max_new: usize,
    submitted: Instant,
    admitted: Instant,
}

pub struct Engine<'a> {
    pipe: &'a Pipeline<'a>,
    model: &'a ModelEval<'a>,
    pub cfg: EngineCfg,
    lanes: Vec<Option<Lane>>,
}

impl<'a> Engine<'a> {
    pub fn new(pipe: &'a Pipeline<'a>, model: &'a ModelEval<'a>) -> Engine<'a> {
        let lanes = (0..pipe.cfg.b_eval).map(|_| None).collect();
        Engine { pipe, model, cfg: EngineCfg::default(), lanes }
    }

    pub fn capacity(&self) -> usize {
        self.lanes.len()
    }

    fn active_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    fn make_lane(
        &self,
        id: u64,
        req: &GenRequest,
        submitted: Instant,
        admitted: Instant,
    ) -> Lane {
        let t = self.pipe.cfg.seq;
        let tk = ByteTokenizer;
        let mut seq = tk.encode(&req.prompt);
        seq.truncate(t - 1);
        if seq.is_empty() {
            seq.push(b' ' as i32);
        }
        let prompt_len = seq.len();
        let max_new = req.max_new_tokens.min(t - prompt_len);
        Lane { id, seq, prompt_len, max_new, submitted, admitted }
    }

    fn finish(lane: Lane, now: Instant, metrics: &mut MetricsRegistry) -> GenResponse {
        let tk = ByteTokenizer;
        let queue_ms =
            lane.admitted.duration_since(lane.submitted).as_secs_f64() * 1000.0;
        let decode_ms = now.duration_since(lane.admitted).as_secs_f64() * 1000.0;
        let new_tokens = lane.seq.len() - lane.prompt_len;
        metrics.record_request(RequestMetric {
            id: lane.id,
            queue_ms,
            decode_ms,
            total_ms: queue_ms + decode_ms,
            new_tokens,
        });
        GenResponse {
            id: lane.id,
            text: tk.decode(&lane.seq),
            new_tokens,
            queue_ms,
            decode_ms,
            latency_ms: queue_ms + decode_ms,
        }
    }

    /// Admit queued requests into free lanes (continuous mode). Requests
    /// whose deadline lapsed in the queue are dropped; zero-token requests
    /// complete immediately without occupying a lane.
    fn admit(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) {
        let now = Instant::now();
        metrics.record_expired(batcher.expire_overdue(now).len());
        for i in 0..self.lanes.len() {
            while self.lanes[i].is_none() {
                let Some((id, req, submitted)) = batcher.pop_ready(now) else {
                    return;
                };
                let lane = self.make_lane(id, &req, submitted, now);
                if lane.max_new == 0 {
                    out.push(Self::finish(lane, now, metrics));
                } else {
                    self.lanes[i] = Some(lane);
                }
            }
        }
    }

    /// One decode step. In compact mode only active lanes enter the
    /// forward (cost scales with load); in fixed-width mode every lane
    /// slot is computed, finished-lane rows as padding — the static
    /// batching cost model.
    fn decode_step(
        &mut self,
        fixed_width: bool,
        metrics: &mut MetricsRegistry,
        out: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let (t, vocab) = (self.pipe.cfg.seq, self.pipe.cfg.vocab);
        let layout: Vec<Option<usize>> = if fixed_width {
            (0..self.lanes.len())
                .map(|i| self.lanes[i].is_some().then_some(i))
                .collect()
        } else {
            (0..self.lanes.len())
                .filter(|&i| self.lanes[i].is_some())
                .map(Some)
                .collect()
        };
        let n_active = layout.iter().filter(|r| r.is_some()).count();
        if n_active == 0 {
            return Ok(());
        }
        let b = layout.len();
        let mut tokens = vec![0i32; b * t];
        for (row, slot) in layout.iter().enumerate() {
            if let Some(li) = slot {
                let lane = self.lanes[*li].as_ref().unwrap();
                tokens[row * t..row * t + lane.seq.len()].copy_from_slice(&lane.seq);
            }
        }
        let step_started = Instant::now();
        let h = self.model.forward_h(self.pipe, &tokens)?;
        let (_, logits) = self.pipe.head(self.model.params(), &h, &tokens)?;
        metrics.record_step_from(step_started, n_active, self.lanes.len());
        let now = Instant::now();
        for (row, slot) in layout.iter().enumerate() {
            let Some(li) = slot else { continue };
            let done = {
                let lane = self.lanes[*li].as_mut().unwrap();
                let pos = lane.seq.len() - 1;
                let base = (row * t + pos) * vocab;
                let next = logits.data[base..base + vocab]
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap();
                lane.seq.push(next);
                lane.seq.len() - lane.prompt_len >= lane.max_new
                    || lane.seq.len() >= t
            };
            metrics.record_tokens(1);
            if done {
                let lane = self.lanes[*li].take().unwrap();
                out.push(Self::finish(lane, now, metrics));
            }
        }
        Ok(())
    }

    /// Continuous batching: a finished sequence's lane is refilled from
    /// the queue on the next decode step.
    pub fn run(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        for _ in 0..self.cfg.max_steps {
            self.admit(batcher, metrics, &mut out);
            if self.active_lanes() == 0 {
                if batcher.pending() == 0 {
                    break;
                }
                continue;
            }
            self.decode_step(false, metrics, &mut out)?;
        }
        Ok(out)
    }

    /// Drain (static) batching baseline: admit a full batch, decode at
    /// fixed width until every lane finishes, only then take the next
    /// batch. Admission goes through the same deadline-aware `admit` as
    /// continuous mode (called only when every lane is free, which is
    /// exactly batch admission), so oversized queues and lapsed deadlines
    /// are handled per batch, not just once up front.
    pub fn run_drain(
        &mut self,
        batcher: &mut Batcher,
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        let mut total_steps = 0;
        while total_steps < self.cfg.max_steps {
            self.admit(batcher, metrics, &mut out);
            if self.active_lanes() == 0 {
                break;
            }
            while self.active_lanes() > 0 && total_steps < self.cfg.max_steps {
                self.decode_step(true, metrics, &mut out)?;
                total_steps += 1;
            }
        }
        Ok(out)
    }

    /// One-shot drain over an explicit request list (the legacy
    /// `generate_batch` contract): responses in request order.
    pub fn run_drain_batch(
        &mut self,
        requests: &[GenRequest],
        metrics: &mut MetricsRegistry,
    ) -> Result<Vec<GenResponse>> {
        assert!(requests.len() <= self.capacity(), "batch too wide");
        let mut batcher = Batcher::new(self.capacity());
        for r in requests {
            batcher.submit(r.clone());
        }
        let mut out = self.run_drain(&mut batcher, metrics)?;
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}
