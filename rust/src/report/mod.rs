//! Report emission: aligned console tables (the paper-table regenerators
//! print through this) and CSV dumps for figure series.

use std::io::Write;
use std::path::Path;

pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!(
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--")
        );
        for row in &self.rows {
            line(row);
        }
    }

    /// Also persist as CSV next to the figure dumps.
    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format an effective bits/weight value for a table's "Bits" column.
/// Callers compute the value from real storage accounting — the packed
/// containers' `storage_bits()` for PTQ1.61, the Appendix-A closed form
/// for baselines — rather than printing a hardcoded label.
pub fn fmt_bits(b: f64) -> String {
    format!("{b:.2}")
}

pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "NAN".into()
    } else if p >= 1e4 {
        format!("{:.1e}", p)
    } else {
        format!("{:.2}", p)
    }
}

pub fn write_csv(path: &Path, header: &str, rows: &[String]) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for r in rows {
        writeln!(f, "{r}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join("ptq161_table.csv");
        t.save_csv(&dir).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert_eq!(text, "a,bb\n1,2\n");
        std::fs::remove_file(dir).ok();
        t.print();
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(12.5), "12.50");
        assert_eq!(fmt_ppl(2.5e5), "2.5e5");
        assert_eq!(fmt_ppl(f64::NAN), "NAN");
    }

    #[test]
    fn bits_formatting() {
        assert_eq!(fmt_bits(1.6135), "1.61");
        assert_eq!(fmt_bits(2.0), "2.00");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    /// What a table's "Bits" column prints must come from the containers'
    /// real storage accounting, and that accounting must tie back to the
    /// Appendix-A closed forms (`packing::bitwidth::average_bits`) for
    /// every packed method — exactly where the forms are exact, and
    /// within the documented mask/vector overheads where the paper
    /// amortizes them away.
    #[test]
    fn container_bits_tie_to_appendix_a_closed_forms() {
        use crate::packing::bitwidth::{average_bits, BitScheme};
        use crate::quant::{by_name, testutil};

        let (n, m) = (24usize, 32usize);
        let nm = (n * m) as f64;
        let eff = |method: &str| {
            let (w, calib) = testutil::demo(n, m, 7);
            let q = by_name(method).unwrap().quantize_linear(&w, &calib);
            q.container
                .unwrap_or_else(|| panic!("{method}: no container"))
                .effective_bits()
        };

        // Uniform INT-b (RTN/GPTQ): code plane + per-row fp16 scale/min
        // is exactly the closed form — no tolerance needed
        for (method, bits) in [("rtn2", 2.0), ("gptq2", 2.0), ("rtn4", 4.0)] {
            let b = eff(method);
            let form = average_bits(BitScheme::Uniform { bits }, n, m);
            assert!((b - form).abs() < 1e-9, "{method}: {b} vs {form}");
            assert_eq!(fmt_bits(b), fmt_bits(form), "{method} prints differently");
        }

        // PB-LLM: Appendix-A 2.7 plus the per-row fp16 params the paper
        // amortizes away (48/m), within the salient-count rounding slack
        // (k = round(0.1*n*m) shifts 7 bits per element of rounding)
        let b = eff("pbllm");
        let form = average_bits(BitScheme::PbLlm { salient_ratio: 0.1 }, n, m);
        let gap = b - form - 48.0 / m as f64;
        assert!(gap.abs() < 4.0 / nm, "pbllm: {b} vs {form}, gap {gap}");

        // BiLLM: the container charges the group-select plane honestly
        // where the paper folds it into "+0.1"; the gap over the paper's
        // 2.1 convention is exactly 0.9 plus the per-row fp16 vectors
        let b = eff("billm");
        let form = average_bits(BitScheme::BiLlm, n, m);
        let gap = b - form - 0.9 - 64.0 / m as f64;
        assert!(gap.abs() < 1e-9, "billm: {b} vs {form}, gap {gap}");
    }
}
