//! Paged KV cache with shared-prefix page reuse.
//!
//! The serve engine owns one [`KvCache`] sized to its lane pool. Storage
//! is no longer one monolithic full-window buffer per lane: K/V live in a
//! page-pool arena of fixed-size *pages* (`page_size` positions × all
//! layers × all heads), and each lane holds a *page table* mapping its
//! cached positions onto physical pages. Three properties fall out of the
//! paged layout:
//!
//! * **Occupancy-proportional memory** — a lane consumes pages for the
//!   positions it has actually cached, not a whole reserved window, so
//!   [`KvCache::live_bytes`] tracks real occupancy while
//!   [`KvCache::bytes`] is the pool's resident capacity.
//! * **Shared-prefix page reuse** — a content-keyed prefix index maps the
//!   token chain covering each *full, immutable* page to its physical
//!   page. Lanes whose prompts share a whole-page token prefix adopt the
//!   same pages (ref-counted) instead of recomputing and re-storing them;
//!   [`KvCache::adopt_prefix`] returns how many positions the prefill can
//!   skip. Pages are freed when the last referencing lane finishes, which
//!   also retires their index entries.
//! * **Copy-on-write divergence** — appending into a page that other
//!   lanes still read first splits it (the whole page is copied, the ref
//!   count drops), so divergence mid-page never corrupts a sibling's
//!   prefix. Writing into an *exclusively held* page that the prefix
//!   index still advertises retires the stale index entries instead.
//!
//! The chunk protocol is unchanged from the slot store this replaces:
//! rows for a new chunk are written by [`KvCache::append`] layer by layer
//! at the lane's current length (page allocation and CoW splits happen on
//! the first layer's append and are idempotent for the rest), the length
//! is bumped once per chunk by [`KvCache::advance`] after *all* layers
//! appended, and [`KvCache::gather`] materializes the compacted per-step
//! batch the native decode kernels consume — only live rows are copied
//! out of the page tables; the dead tail of the window is never touched.
//!
//! Admission control is page-granular: [`KvCache::alloc_with_budget`]
//! reserves the worst-case page count for a request (prompt + generation
//! budget) and fails when the pool cannot cover it, so the engine
//! backpressures on *pool exhaustion* rather than lane count and a decode
//! step can never run out of pages mid-flight (shared pages only make
//! live usage cheaper than the reservation, never dearer).

use std::sync::Mutex;

use crate::tensor::Tensor;

/// Default positions per page (the engine's `--page-size` default).
pub const DEFAULT_PAGE_SIZE: usize = 16;

/// Split `total` pool pages across `workers` per-worker cache partitions,
/// flooring every partition at `min_pages` (one full window) so a maximal
/// request stays admissible on every worker. Remainder pages go to the
/// lowest worker ids. The per-partition floor takes precedence over the
/// aggregate budget: each worker owns an independent arena, so when the
/// floor binds the partitions sum to more than `total` — an undersized
/// `--kv-pages` divides the *squeeze* across workers, it never produces a
/// partition that deadlocks admission.
pub fn partition_pages(total: usize, workers: usize, min_pages: usize) -> Vec<usize> {
    assert!(workers > 0, "partitioning for zero workers");
    let base = total / workers;
    let rem = total % workers;
    (0..workers)
        .map(|w| (base + usize::from(w < rem)).max(min_pages))
        .collect()
}

/// One routed whole-page prefix chain: the token chain plus the worker
/// whose cache partition holds its pages.
#[derive(Debug)]
struct RouteEntry {
    /// FNV-1a over `tokens` (pre-filter; same fold as [`KvCache`]'s index)
    hash: u64,
    /// the chain, a whole number of pages long
    tokens: Vec<i32>,
    /// worker whose partition holds the chain's pages (latest publisher)
    worker: usize,
}

/// Thread-safe placement index for the sharded serve engine: maps the
/// same whole-page token prefixes that [`KvCache`]'s content-keyed index
/// stores to the *worker* whose private cache partition holds those
/// pages. Workers publish after registering a prompt in their own cache;
/// submission routes a request whose prompt extends a published chain to
/// that worker's shard, so the prefix adoption happens inside the one
/// partition that can actually serve it (partitions share nothing).
///
/// The index is advisory: entries may outlive the cached pages (the
/// engine re-checks adoption against its own cache), and a panicked
/// worker's entries are dropped via [`PrefixRouter::forget_worker`].
#[derive(Debug)]
pub struct PrefixRouter {
    page_size: usize,
    entries: Mutex<Vec<RouteEntry>>,
}

impl PrefixRouter {
    /// An empty router over `page_size`-position pages (must match the
    /// engines' cache geometry or no published chain will ever match).
    pub fn new(page_size: usize) -> PrefixRouter {
        assert!(page_size > 0, "router page_size must be positive");
        PrefixRouter { page_size, entries: Mutex::new(Vec::new()) }
    }

    /// Positions per page this router keys on.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Published chains currently alive (test/introspection).
    pub fn entries(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Tag every whole-page prefix of `tokens` with `worker`. A chain
    /// published by several workers keeps the latest publisher — that is
    /// the partition with the freshest live copy of the pages.
    pub fn publish(&self, worker: usize, tokens: &[i32]) {
        let hashes = page_prefix_hashes(tokens, self.page_size);
        let mut entries = self.entries.lock().unwrap();
        for (m, &hash) in hashes.iter().enumerate() {
            let chain = &tokens[..(m + 1) * self.page_size];
            let found = entries
                .iter_mut()
                .find(|e| e.hash == hash && e.tokens == chain);
            match found {
                Some(e) => e.worker = worker,
                None => entries.push(RouteEntry {
                    hash,
                    tokens: chain.to_vec(),
                    worker,
                }),
            }
        }
    }

    /// The worker holding the longest published whole-page prefix of
    /// `tokens`, or `None` when no chain matches — the submission-side
    /// placement hook (`None` falls back to least-loaded).
    pub fn route(&self, tokens: &[i32]) -> Option<usize> {
        let hashes = page_prefix_hashes(tokens, self.page_size);
        if hashes.is_empty() {
            return None;
        }
        let entries = self.entries.lock().unwrap();
        let mut best: Option<(usize, usize)> = None;
        for e in entries.iter() {
            let m = e.tokens.len() / self.page_size;
            let longer = match best {
                Some((len, _)) => e.tokens.len() > len,
                None => true,
            };
            if longer
                && m >= 1
                && m <= hashes.len()
                && e.hash == hashes[m - 1]
                && e.tokens == tokens[..e.tokens.len()]
            {
                best = Some((e.tokens.len(), e.worker));
            }
        }
        best.map(|(_, worker)| worker)
    }

    /// Drop every chain published by `worker` — called when a worker
    /// panics (its partition, and the pages behind its chains, are gone).
    pub fn forget_worker(&self, worker: usize) {
        self.entries.lock().unwrap().retain(|e| e.worker != worker);
    }
}

/// One lane's view of the paged store: its page table, valid length, and
/// the admission-time page reservation backing it.
#[derive(Debug)]
struct LaneState {
    /// physical page ids covering positions `[0, ceil(len/page_size))`
    pages: Vec<usize>,
    /// valid cached positions
    len: usize,
    /// worst-case pages reserved at alloc time (released on free)
    reserved: usize,
}

/// One registered whole-page prefix chain: the first `pages.len() *
/// page_size` tokens of some prompt, mapped to the physical pages holding
/// their K/V. Entries are weak — they hold no ref count and retire when
/// any of their pages is freed or rewritten.
#[derive(Debug)]
struct PrefixEntry {
    /// FNV-1a over `tokens` (fast pre-filter; matches verify exactly)
    hash: u64,
    /// the token chain, `pages.len() * page_size` ids
    tokens: Vec<i32>,
    /// physical pages holding the chain's K/V, in position order
    pages: Vec<usize>,
}

/// Paged, ref-counted, prefix-sharing K/V store (see the module docs).
#[derive(Debug)]
pub struct KvCache {
    n_layers: usize,
    heads: usize,
    head_dim: usize,
    /// max positions per lane (the model window)
    capacity: usize,
    /// positions per page
    page_size: usize,
    /// page arena, `n_pages * page_elems` per side
    k: Vec<f32>,
    v: Vec<f32>,
    /// lane references per page; 0 = free
    ref_count: Vec<u32>,
    /// page appears in at least one prefix-index entry
    registered: Vec<bool>,
    /// free page ids, popped on allocation, pushed back when the last
    /// reference drops (LIFO, so a just-freed page is reused first)
    free_pages: Vec<usize>,
    /// sum of live lanes' worst-case reservations
    reserved_pages: usize,
    lanes: Vec<Option<LaneState>>,
    free_lanes: Vec<usize>,
    allocs: u64,
    index: Vec<PrefixEntry>,
    cow_splits: u64,
    prefix_hit_pages: u64,
    prefix_reused_positions: u64,
    peak_live_pages: usize,
    page_allocs: u64,
}

/// FNV-1a over a token chain is a running fold, so one pass over
/// `tokens` yields the hash of every page-aligned prefix: `out[m-1]`
/// covers `tokens[..m * page_size]`. Both `register_prefix` (stamping
/// entries) and `adopt_prefix` (the pre-filter) hash through this, so a
/// prompt is hashed once per call, never once per entry, and the two
/// sides agree by construction.
fn page_prefix_hashes(tokens: &[i32], page_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / page_size);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (i, &t) in tokens.iter().enumerate() {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        if (i + 1) % page_size == 0 {
            out.push(h);
        }
    }
    out
}

impl KvCache {
    /// A fully provisioned cache: `lanes` lanes over a pool that can hold
    /// one full window per lane (`ceil(capacity / page_size)` pages each,
    /// [`DEFAULT_PAGE_SIZE`] positions per page) — the drop-in equivalent
    /// of the old monolithic per-slot store, with sharing on top.
    pub fn new(
        lanes: usize,
        n_layers: usize,
        capacity: usize,
        heads: usize,
        head_dim: usize,
    ) -> KvCache {
        let page_size = DEFAULT_PAGE_SIZE.min(capacity.max(1));
        let per_lane = capacity.div_ceil(page_size);
        Self::with_geometry(
            lanes,
            n_layers,
            capacity,
            heads,
            head_dim,
            page_size,
            lanes * per_lane,
        )
    }

    /// A cache with explicit paging geometry: `page_size` positions per
    /// page and `n_pages` pages in the pool. The pool must hold at least
    /// one full window so a maximal request is always admissible.
    pub fn with_geometry(
        lanes: usize,
        n_layers: usize,
        capacity: usize,
        heads: usize,
        head_dim: usize,
        page_size: usize,
        n_pages: usize,
    ) -> KvCache {
        assert!(lanes > 0 && n_layers > 0 && capacity > 0);
        assert!(
            page_size > 0 && page_size <= capacity,
            "page_size {page_size} must be in 1..={capacity}"
        );
        assert!(
            n_pages >= capacity.div_ceil(page_size),
            "pool of {n_pages} pages cannot hold one {capacity}-position window"
        );
        let page_elems = n_layers * page_size * heads * head_dim;
        KvCache {
            n_layers,
            heads,
            head_dim,
            capacity,
            page_size,
            k: vec![0.0; n_pages * page_elems],
            v: vec![0.0; n_pages * page_elems],
            ref_count: vec![0; n_pages],
            registered: vec![false; n_pages],
            free_pages: (0..n_pages).rev().collect(),
            reserved_pages: 0,
            lanes: (0..lanes).map(|_| None).collect(),
            free_lanes: (0..lanes).rev().collect(),
            allocs: 0,
            index: Vec::new(),
            cow_splits: 0,
            prefix_hit_pages: 0,
            prefix_reused_positions: 0,
            peak_live_pages: 0,
            page_allocs: 0,
        }
    }

    /// Elements of one cached position (heads * head_dim).
    fn row_elems(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Elements of one page per side (all layers).
    fn page_elems(&self) -> usize {
        self.n_layers * self.page_size * self.row_elems()
    }

    /// Flat offset of `(page, layer, pos_in_page)` in the K/V arenas.
    fn at(&self, page: usize, layer: usize, pos: usize) -> usize {
        page * self.page_elems() + (layer * self.page_size + pos) * self.row_elems()
    }

    fn lane(&self, lane: usize) -> &LaneState {
        self.lanes[lane].as_ref().expect("lane is not in use")
    }

    /// Number of lanes (== the engine's lane capacity).
    pub fn slots(&self) -> usize {
        self.lanes.len()
    }

    /// Maximum cached positions per lane (the model window).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages in the pool.
    pub fn total_pages(&self) -> usize {
        self.ref_count.len()
    }

    /// Pages needed to hold `positions` cached positions.
    pub fn pages_needed(&self, positions: usize) -> usize {
        positions.max(1).div_ceil(self.page_size)
    }

    /// Valid cached positions of `lane`.
    pub fn len(&self, lane: usize) -> usize {
        self.lane(lane).len
    }

    /// Lanes currently allocated to live requests.
    pub fn in_use_count(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Lifetime lane-allocation count — strictly greater than
    /// [`Self::slots`] once freed lanes have been reused.
    pub fn total_allocs(&self) -> u64 {
        self.allocs
    }

    /// Resident size of the page pool in bytes (capacity, not fill) —
    /// what the serve metrics export as `kv_reserved_bytes`.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Bytes of one page, both sides.
    pub fn page_bytes(&self) -> usize {
        2 * self.page_elems() * std::mem::size_of::<f32>()
    }

    /// Physical pages currently referenced by at least one lane (shared
    /// pages count once).
    pub fn live_pages(&self) -> usize {
        self.total_pages() - self.free_pages.len()
    }

    /// Bytes of the currently referenced pages (shared pages once) — the
    /// occupancy counterpart of [`Self::bytes`].
    pub fn live_bytes(&self) -> usize {
        self.live_pages() * self.page_bytes()
    }

    /// High-water mark of [`Self::live_bytes`] over the cache's lifetime.
    pub fn peak_live_bytes(&self) -> usize {
        self.peak_live_pages * self.page_bytes()
    }

    /// Pages reserved by live lanes' admission budgets.
    pub fn reserved_page_count(&self) -> usize {
        self.reserved_pages
    }

    /// Copy-on-write page splits performed so far.
    pub fn cow_splits(&self) -> u64 {
        self.cow_splits
    }

    /// Pages adopted from the prefix index so far.
    pub fn prefix_hit_pages(&self) -> u64 {
        self.prefix_hit_pages
    }

    /// Cached positions that prefix adoption let prefills skip so far.
    pub fn prefix_reused_positions(&self) -> u64 {
        self.prefix_reused_positions
    }

    /// Lifetime count of physical page allocations (fresh pages + CoW
    /// copies). For a fixed workload this is the sharing-sensitive
    /// memory metric: adopted pages are referenced, not allocated, so a
    /// shared-prefix run allocates strictly fewer pages than the same
    /// workload with unique prompts — scheduling-independent, unlike the
    /// live-bytes peak.
    pub fn page_alloc_count(&self) -> u64 {
        self.page_allocs
    }

    /// Registered prefix chains currently alive (test/introspection).
    pub fn index_entries(&self) -> usize {
        self.index.len()
    }

    /// Claim a lane with a full-window page budget — the conservative
    /// equivalent of the old slot `alloc`.
    pub fn alloc(&mut self) -> Option<usize> {
        self.alloc_with_budget(self.capacity)
    }

    /// Claim a lane that will cache at most `positions` positions,
    /// reserving its worst-case page count. Returns `None` when every
    /// lane is held by a live request **or** the pool cannot cover the
    /// reservation — the engine's admission backpressure signal. The
    /// reservation guarantees appends never find the pool empty: shared
    /// pages satisfy several reservations with one physical page, so live
    /// usage only ever undershoots the reserved total.
    pub fn alloc_with_budget(&mut self, positions: usize) -> Option<usize> {
        assert!(
            positions <= self.capacity,
            "budget {positions} exceeds window {}",
            self.capacity
        );
        let need = self.pages_needed(positions);
        if self.reserved_pages + need > self.total_pages() {
            return None;
        }
        let lane = self.free_lanes.pop()?;
        debug_assert!(self.lanes[lane].is_none());
        self.lanes[lane] = Some(LaneState { pages: Vec::new(), len: 0, reserved: need });
        self.reserved_pages += need;
        self.allocs += 1;
        Some(lane)
    }

    /// Release `lane`: every page reference is dropped (pages whose last
    /// reference this was go back to the free list and retire their
    /// prefix-index entries — one retain pass for the whole lane, not one
    /// per page) and the admission reservation is returned.
    ///
    /// Returns the number of pages *physically* freed. The distinction
    /// carries the preemption economics: a preempted lane whose prefix
    /// pages are shared with other lanes (or pinned by the prefix index
    /// through them) frees fewer physical pages, but those surviving
    /// pages are exactly what `adopt_prefix` re-adopts for free when the
    /// victim restores.
    pub fn free(&mut self, lane: usize) -> usize {
        let ls = self.lanes[lane].take().expect("freeing a lane that is not in use");
        let mut stale = false;
        let mut physically_freed = 0;
        for &p in &ls.pages {
            debug_assert!(self.ref_count[p] > 0);
            self.ref_count[p] -= 1;
            if self.ref_count[p] == 0 {
                self.free_pages.push(p);
                physically_freed += 1;
                stale |= self.registered[p];
            }
        }
        if stale {
            // index entries only ever reference live pages (every free
            // and overwrite retires dead chains eagerly), so one pass
            // dropping entries with any now-unreferenced page suffices
            let rc = &self.ref_count;
            self.index.retain(|e| e.pages.iter().all(|&p| rc[p] > 0));
            self.rebuild_registered();
        }
        self.reserved_pages -= ls.reserved;
        self.free_lanes.push(lane);
        physically_freed
    }

    /// Drop every prefix-index entry referencing `page` and recompute the
    /// registered flags from the surviving entries (in-place overwrite of
    /// an exclusively held registered page).
    fn retire_entries_containing(&mut self, page: usize) {
        self.index.retain(|e| !e.pages.contains(&page));
        self.rebuild_registered();
    }

    /// Recompute the per-page registered flags from the surviving index
    /// entries.
    fn rebuild_registered(&mut self) {
        for r in self.registered.iter_mut() {
            *r = false;
        }
        for e in &self.index {
            for &p in &e.pages {
                self.registered[p] = true;
            }
        }
    }

    fn alloc_page(&mut self) -> usize {
        let p = self
            .free_pages
            .pop()
            .expect("page pool exhausted despite admission reservations");
        debug_assert_eq!(self.ref_count[p], 0);
        self.ref_count[p] = 1;
        self.page_allocs += 1;
        self.peak_live_pages = self.peak_live_pages.max(self.live_pages());
        p
    }

    /// Make positions `[len, len + t_new)` of `lane` writable: allocate
    /// missing pages, copy-on-write-split pages other lanes still read,
    /// and retire stale prefix-index entries for exclusively held pages
    /// about to be overwritten. Idempotent, so every layer's `append` of
    /// one chunk can call it; only the first does real work.
    fn ensure_writable(&mut self, lane: usize, t_new: usize) {
        if t_new == 0 {
            assert!(self.lanes[lane].is_some(), "append to a free lane");
            return;
        }
        let mut ls = self.lanes[lane].take().expect("append to a free lane");
        assert!(
            ls.len + t_new <= self.capacity,
            "KV lane overflow: {} + {t_new} > {}",
            ls.len,
            self.capacity
        );
        let first = ls.len / self.page_size;
        let last = (ls.len + t_new - 1) / self.page_size;
        for pi in first..=last {
            if pi == ls.pages.len() {
                ls.pages.push(self.alloc_page());
                continue;
            }
            let p = ls.pages[pi];
            if self.ref_count[p] > 1 {
                // divergence mid-page: split before writing
                let np = self.alloc_page();
                let pe = self.page_elems();
                self.k.copy_within(p * pe..(p + 1) * pe, np * pe);
                self.v.copy_within(p * pe..(p + 1) * pe, np * pe);
                self.ref_count[p] -= 1;
                ls.pages[pi] = np;
                self.cow_splits += 1;
            } else if self.registered[p] {
                // exclusive, but the index still advertises it: the write
                // invalidates the chain for future adopters
                self.retire_entries_containing(p);
            }
        }
        self.lanes[lane] = Some(ls);
    }

    /// Write one layer's K/V rows for a new chunk at the lane's current
    /// length. `k_rows`/`v_rows` are `t_new * heads * head_dim` elements
    /// (one compacted-batch row of the kernel's `k_new`/`v_new` outputs).
    /// The length is *not* bumped — call [`Self::advance`] once after all
    /// layers appended.
    pub fn append(&mut self, lane: usize, layer: usize, k_rows: &[f32], v_rows: &[f32]) {
        assert_eq!(k_rows.len(), v_rows.len());
        let re = self.row_elems();
        assert_eq!(k_rows.len() % re, 0, "append: ragged rows");
        let t_new = k_rows.len() / re;
        self.ensure_writable(lane, t_new);
        let len = self.lane(lane).len;
        for j in 0..t_new {
            let pos = len + j;
            let page = self.lane(lane).pages[pos / self.page_size];
            let dst = self.at(page, layer, pos % self.page_size);
            self.k[dst..dst + re].copy_from_slice(&k_rows[j * re..(j + 1) * re]);
            self.v[dst..dst + re].copy_from_slice(&v_rows[j * re..(j + 1) * re]);
        }
    }

    /// Bump `lane`'s valid length by `t_new` after every layer appended
    /// its rows for the chunk.
    pub fn advance(&mut self, lane: usize, t_new: usize) {
        let cap = self.capacity;
        let ls = self.lanes[lane].as_mut().expect("advance on a free lane");
        assert!(ls.len + t_new <= cap, "advance past capacity");
        ls.len += t_new;
        debug_assert!(ls.pages.len() * self.page_size >= ls.len);
    }

    /// Register the whole-page prefixes of `lane`'s cached `tokens` in
    /// the content-keyed index so later prompts sharing the prefix can
    /// adopt the pages. Only *full* pages are registered (they are never
    /// appended into again by their owner, so they are immutable until
    /// retired); duplicate chains are kept once. One entry is stored per
    /// prefix *length* — quadratic in a prompt's full pages, but that is
    /// what lets a prompt shorter than a registered chain still adopt
    /// its page-aligned prefix, and prompts are far smaller than the
    /// window here (a single longest-chain entry with prefix matching
    /// would be the scale-up representation).
    pub fn register_prefix(&mut self, lane: usize, tokens: &[i32]) {
        let ls = self.lane(lane);
        let full = ls.len.min(tokens.len()) / self.page_size;
        let pages: Vec<usize> = ls.pages.clone();
        let hashes = page_prefix_hashes(&tokens[..full * self.page_size], self.page_size);
        for m in 1..=full {
            let chain = &tokens[..m * self.page_size];
            let hash = hashes[m - 1];
            if self
                .index
                .iter()
                .any(|e| e.hash == hash && e.tokens == chain)
            {
                continue;
            }
            for &p in &pages[..m] {
                self.registered[p] = true;
            }
            self.index.push(PrefixEntry {
                hash,
                tokens: chain.to_vec(),
                pages: pages[..m].to_vec(),
            });
        }
    }

    /// Adopt the longest registered whole-page prefix of `tokens` into
    /// the (empty) `lane`: the matching pages are referenced instead of
    /// recomputed and the lane starts with that many positions already
    /// cached. Returns the reused position count, capped at
    /// `tokens.len() - 1` so the caller always runs at least the last
    /// prompt position through the model (its logits produce the first
    /// new token). A cap that lands mid-page leaves the last adopted page
    /// shared-and-partial; the next append copy-on-write-splits it.
    pub fn adopt_prefix(&mut self, lane: usize, tokens: &[i32]) -> usize {
        {
            let ls = self.lane(lane);
            assert!(ls.len == 0 && ls.pages.is_empty(), "adopt into a used lane");
        }
        let max_reuse = tokens.len().saturating_sub(1);
        if max_reuse == 0 {
            return 0;
        }
        // hash the prompt's page-aligned prefixes once; entries' chains
        // are always whole pages, so the table covers every candidate
        let hashes = page_prefix_hashes(tokens, self.page_size);
        let mut best: Option<usize> = None;
        let mut best_len = 0;
        for (i, e) in self.index.iter().enumerate() {
            let m = e.tokens.len() / self.page_size;
            if e.tokens.len() > best_len
                && m >= 1
                && m <= hashes.len()
                && e.hash == hashes[m - 1]
                && e.tokens == tokens[..e.tokens.len()]
            {
                best = Some(i);
                best_len = e.tokens.len();
            }
        }
        let Some(bi) = best else { return 0 };
        let reuse = (self.index[bi].pages.len() * self.page_size).min(max_reuse);
        let n_pages = reuse.div_ceil(self.page_size);
        let pages: Vec<usize> = self.index[bi].pages[..n_pages].to_vec();
        for &p in &pages {
            self.ref_count[p] += 1;
        }
        let ls = self.lanes[lane].as_mut().unwrap();
        ls.pages = pages;
        ls.len = reuse;
        self.prefix_hit_pages += n_pages as u64;
        self.prefix_reused_positions += reuse as u64;
        reuse
    }

    /// Materialize one layer's cached K/V for a compacted batch of lanes:
    /// `(k, v, lens)` with `lens[i]` the valid positions of `lanes[i]`.
    ///
    /// Only live rows are walked out of the page tables: `k`/`v` come
    /// back as `(lanes.len(), upto, heads, head_dim)` where `upto =
    /// max(lens) + headroom`, clamped to the window capacity — a
    /// one-token decode step passes `headroom = 1` and never pays for the
    /// dead tail of the window (the `_decode` bases accept the shrunk
    /// time axis). Rows at or beyond `lens[i]` are zero and must not be
    /// read.
    pub fn gather(
        &self,
        layer: usize,
        lanes: &[usize],
        headroom: usize,
    ) -> (Tensor, Tensor, Vec<usize>) {
        let b = lanes.len();
        let lens: Vec<usize> = lanes.iter().map(|&lane| self.lane(lane).len).collect();
        let max_len = lens.iter().max().copied().unwrap_or(0);
        let upto = (max_len + headroom).clamp(1, self.capacity);
        let re = self.row_elems();
        let shape = [b, upto, self.heads, self.head_dim];
        let mut k = Tensor::zeros(&shape);
        let mut v = Tensor::zeros(&shape);
        for (row, &lane) in lanes.iter().enumerate() {
            let ls = self.lane(lane);
            let live = ls.len.min(upto);
            let mut pos = 0;
            for &page in &ls.pages {
                if pos >= live {
                    break;
                }
                let n = self.page_size.min(live - pos);
                let src = self.at(page, layer, 0);
                let dst = (row * upto + pos) * re;
                k.data[dst..dst + n * re].copy_from_slice(&self.k[src..src + n * re]);
                v.data[dst..dst + n * re].copy_from_slice(&self.v[src..src + n * re]);
                pos += n;
            }
        }
        (k, v, lens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuses_lanes() {
        let mut c = KvCache::new(2, 1, 4, 1, 2);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert!(c.alloc().is_none(), "lane pool exhausted");
        assert_eq!(c.in_use_count(), 2);
        c.free(a);
        let a2 = c.alloc().unwrap();
        assert_eq!(a2, a, "freed lane is reused");
        assert_eq!(c.total_allocs(), 3);
    }

    #[test]
    fn append_advance_gather_round_trip() {
        // 1 lane, 2 layers, capacity 3, 1 head of dim 2
        let mut c = KvCache::new(1, 2, 3, 1, 2);
        let s = c.alloc().unwrap();
        // chunk of 2 positions: both layers append, then one advance
        c.append(s, 0, &[1.0, 2.0, 3.0, 4.0], &[-1.0, -2.0, -3.0, -4.0]);
        c.append(s, 1, &[5.0, 6.0, 7.0, 8.0], &[-5.0, -6.0, -7.0, -8.0]);
        c.advance(s, 2);
        assert_eq!(c.len(s), 2);
        let (k0, v0, lens) = c.gather(0, &[s], 1);
        // live prefix only: 2 cached + 1 headroom = 3 positions
        assert_eq!(k0.shape, vec![1, 3, 1, 2]);
        assert_eq!(lens, vec![2]);
        assert_eq!(&k0.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v0.data[..4], &[-1.0, -2.0, -3.0, -4.0]);
        let (k1, _, _) = c.gather(1, &[s], 1);
        assert_eq!(&k1.data[..4], &[5.0, 6.0, 7.0, 8.0]);
        // one more position lands after the first chunk
        c.append(s, 0, &[9.0, 10.0], &[0.0, 0.0]);
        c.append(s, 1, &[11.0, 12.0], &[0.0, 0.0]);
        c.advance(s, 1);
        let (k0, _, lens) = c.gather(0, &[s], 0);
        assert_eq!(lens, vec![3]);
        assert_eq!(&k0.data[4..6], &[9.0, 10.0]);
        // headroom past the window clamps to capacity
        let (k0, _, _) = c.gather(0, &[s], 5);
        assert_eq!(k0.shape, vec![1, 3, 1, 2]);
    }

    #[test]
    fn gather_orders_rows_by_request() {
        let mut c = KvCache::new(3, 1, 2, 1, 1);
        let s0 = c.alloc().unwrap();
        let s1 = c.alloc().unwrap();
        c.append(s0, 0, &[1.0], &[1.0]);
        c.advance(s0, 1);
        c.append(s1, 0, &[2.0], &[2.0]);
        c.advance(s1, 1);
        // batch order is the caller's order, not lane order; rows are
        // (1 cached + 1 headroom) wide
        let (k, _, lens) = c.gather(0, &[s1, s0], 1);
        assert_eq!(k.shape, vec![2, 2, 1, 1]);
        assert_eq!(k.data[0], 2.0);
        assert_eq!(k.data[2], 1.0);
        assert_eq!(lens, vec![1, 1]);
    }

    #[test]
    #[should_panic]
    fn append_past_capacity_panics() {
        let mut c = KvCache::new(1, 1, 2, 1, 1);
        let s = c.alloc().unwrap();
        c.append(s, 0, &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn freed_lane_restarts_at_zero() {
        let mut c = KvCache::new(1, 1, 4, 1, 1);
        let s = c.alloc().unwrap();
        c.append(s, 0, &[1.0, 2.0], &[1.0, 2.0]);
        c.advance(s, 2);
        assert!(c.live_pages() > 0);
        c.free(s);
        assert_eq!(c.live_pages(), 0, "pages return to the pool");
        let s2 = c.alloc().unwrap();
        assert_eq!(c.len(s2), 0, "reused lane starts empty");
    }

    #[test]
    fn pages_span_page_boundaries() {
        // page_size 2, so 5 positions need 3 pages
        let mut c = KvCache::with_geometry(1, 1, 8, 1, 1, 2, 4);
        let s = c.alloc_with_budget(5).unwrap();
        c.append(s, 0, &[1.0, 2.0, 3.0], &[-1.0, -2.0, -3.0]);
        c.advance(s, 3);
        c.append(s, 0, &[4.0, 5.0], &[-4.0, -5.0]);
        c.advance(s, 2);
        assert_eq!(c.len(s), 5);
        assert_eq!(c.live_pages(), 3);
        let (k, v, lens) = c.gather(0, &[s], 0);
        assert_eq!(lens, vec![5]);
        assert_eq!(&k.data[..5], &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(&v.data[..5], &[-1.0, -2.0, -3.0, -4.0, -5.0]);
    }

    #[test]
    fn budget_backpressure_and_reservation_release() {
        // pool of 4 pages, page_size 2: a 7-position budget takes all 4
        let mut c = KvCache::with_geometry(3, 1, 8, 1, 1, 2, 4);
        let a = c.alloc_with_budget(7).unwrap();
        assert_eq!(c.reserved_page_count(), 4);
        assert!(c.alloc_with_budget(1).is_none(), "pool fully reserved");
        c.free(a);
        assert_eq!(c.reserved_page_count(), 0);
        let b = c.alloc_with_budget(2).unwrap();
        let b2 = c.alloc_with_budget(2).unwrap();
        assert_ne!(b, b2);
        assert_eq!(c.reserved_page_count(), 2);
    }

    #[test]
    fn prefix_adoption_shares_pages() {
        // page_size 2: a 5-token prompt registers 2 full pages
        let mut c = KvCache::with_geometry(2, 1, 8, 1, 1, 2, 8);
        let toks = [10, 11, 12, 13, 14];
        let a = c.alloc_with_budget(6).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0, 2.0, 3.0, 4.0, 5.0]);
        c.advance(a, 5);
        c.register_prefix(a, &toks);
        assert_eq!(c.index_entries(), 2, "chains of 1 and 2 full pages");
        let live_before = c.live_pages();
        // same prompt: adopt 4 positions (both full pages), recompute 1
        let b = c.alloc_with_budget(6).unwrap();
        let reused = c.adopt_prefix(b, &toks);
        assert_eq!(reused, 4);
        assert_eq!(c.len(b), 4);
        assert_eq!(c.live_pages(), live_before, "no new pages for the prefix");
        let (k, _, lens) = c.gather(0, &[b], 1);
        assert_eq!(lens, vec![4]);
        assert_eq!(&k.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        // a shorter prompt sharing one page adopts only that page
        let longer = [10, 11, 99];
        c.free(b);
        let d = c.alloc_with_budget(6).unwrap();
        assert_eq!(c.adopt_prefix(d, &longer), 2);
        // a diverging prompt adopts nothing
        c.free(d);
        let e = c.alloc_with_budget(6).unwrap();
        assert_eq!(c.adopt_prefix(e, &[7, 7, 7, 7, 7]), 0);
    }

    #[test]
    fn divergence_mid_page_splits_copy_on_write() {
        // page_size 4: an 8-token prompt registers 2 full pages; a second
        // identical prompt adopts 7 positions (cap = len - 1), leaving
        // page 1 shared-and-partial — its first append must CoW-split
        let mut c = KvCache::with_geometry(2, 1, 16, 1, 1, 4, 8);
        let toks = [1, 2, 3, 4, 5, 6, 7, 8];
        let rows: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let a = c.alloc_with_budget(10).unwrap();
        c.append(a, 0, &rows, &rows);
        c.advance(a, 8);
        c.register_prefix(a, &toks);
        let b = c.alloc_with_budget(10).unwrap();
        assert_eq!(c.adopt_prefix(b, &toks), 7);
        assert_eq!(c.cow_splits(), 0);
        // b recomputes position 7 and appends: page 1 is shared → split
        c.append(b, 0, &[70.0], &[70.0]);
        c.advance(b, 1);
        assert_eq!(c.cow_splits(), 1, "shared partial page must split");
        // a's view is untouched, b sees its own divergent row
        let (ka, _, _) = c.gather(0, &[a], 0);
        assert_eq!(ka.data[7], 7.0);
        let (kb, _, _) = c.gather(0, &[b], 0);
        assert_eq!(kb.data[7], 70.0);
        assert_eq!(&kb.data[..7], &rows[..7], "CoW preserves the prefix");
    }

    #[test]
    fn freeing_last_reader_retires_index_entries() {
        let mut c = KvCache::with_geometry(2, 1, 8, 1, 1, 2, 8);
        let toks = [5, 6, 7, 8];
        let a = c.alloc_with_budget(4).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0, 4.0], &[1.0, 2.0, 3.0, 4.0]);
        c.advance(a, 4);
        c.register_prefix(a, &toks);
        assert_eq!(c.index_entries(), 2);
        let b = c.alloc_with_budget(4).unwrap();
        assert_eq!(c.adopt_prefix(b, &toks), 3);
        // owner finishes: pages survive via b's references
        c.free(a);
        assert!(c.index_entries() > 0, "entries live while a reader holds pages");
        // last reader finishes: pages free, index retires
        c.free(b);
        assert_eq!(c.live_pages(), 0);
        assert_eq!(c.index_entries(), 0, "freed pages retire their chains");
        // a later identical prompt starts cold
        let d = c.alloc_with_budget(4).unwrap();
        assert_eq!(c.adopt_prefix(d, &toks), 0);
    }

    #[test]
    fn write_into_exclusive_registered_page_retires_stale_chains() {
        // adopter writes mid-page into a registered page it now holds
        // exclusively (owner freed): the stale chain must retire so a
        // future adopter cannot see the overwritten rows
        let mut c = KvCache::with_geometry(3, 1, 8, 1, 1, 4, 8);
        let toks = [1, 2, 3, 4];
        let a = c.alloc_with_budget(5).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0, 4.0], &[1.0, 2.0, 3.0, 4.0]);
        c.advance(a, 4);
        c.register_prefix(a, &toks);
        let b = c.alloc_with_budget(5).unwrap();
        assert_eq!(c.adopt_prefix(b, &toks), 3);
        c.free(a); // b is now the only holder of the registered page
        c.append(b, 0, &[30.0], &[30.0]);
        c.advance(b, 1);
        assert_eq!(c.cow_splits(), 0, "exclusive page writes in place");
        assert_eq!(c.index_entries(), 0, "stale chain retired before write");
        let d = c.alloc_with_budget(5).unwrap();
        assert_eq!(c.adopt_prefix(d, &toks), 0, "no adoption from retired chain");
    }

    #[test]
    fn freed_pages_are_reused_lifo() {
        let mut c = KvCache::with_geometry(2, 1, 4, 1, 1, 2, 4);
        let a = c.alloc_with_budget(4).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0], &[0.0; 3]);
        c.advance(a, 3);
        assert_eq!(c.live_pages(), 2);
        let peak = c.peak_live_bytes();
        assert_eq!(peak, 2 * c.page_bytes());
        c.free(a);
        // the next lane gets the just-freed pages back (LIFO free list)
        let b = c.alloc_with_budget(4).unwrap();
        c.append(b, 0, &[9.0], &[9.0]);
        c.advance(b, 1);
        assert_eq!(c.live_pages(), 1);
        assert_eq!(c.peak_live_bytes(), peak, "reuse does not grow the peak");
        let (k, _, _) = c.gather(0, &[b], 0);
        assert_eq!(k.data[0], 9.0);
    }

    #[test]
    fn partition_pages_splits_evenly_with_floor() {
        // even split
        assert_eq!(partition_pages(32, 4, 4), vec![8, 8, 8, 8]);
        // remainder goes to the lowest worker ids
        assert_eq!(partition_pages(10, 3, 1), vec![4, 3, 3]);
        // the one-window floor binds: partitions may sum past the total
        assert_eq!(partition_pages(8, 4, 8), vec![8, 8, 8, 8]);
        // single worker keeps the whole pool
        assert_eq!(partition_pages(7, 1, 2), vec![7]);
    }

    #[test]
    fn partition_pages_remainder_edge_cases() {
        // every remainder residue against the same worker count
        assert_eq!(partition_pages(12, 4, 1), vec![3, 3, 3, 3]);
        assert_eq!(partition_pages(13, 4, 1), vec![4, 3, 3, 3]);
        assert_eq!(partition_pages(14, 4, 1), vec![4, 4, 3, 3]);
        assert_eq!(partition_pages(15, 4, 1), vec![4, 4, 4, 3]);
        // fewer pages than workers: the floor carries every partition
        assert_eq!(partition_pages(2, 3, 1), vec![1, 1, 1]);
        assert_eq!(partition_pages(0, 3, 2), vec![2, 2, 2]);
        // remainder pages and a binding floor interact per worker: the
        // raw split [2,1,1] floors to the window, not the aggregate
        assert_eq!(partition_pages(4, 3, 2), vec![2, 2, 2]);
        // floor binds only where the raw share is short
        assert_eq!(partition_pages(7, 3, 2), vec![3, 2, 2]);
    }

    #[test]
    fn preempted_shared_prefix_lane_readopts_without_new_page_allocs() {
        // the preemption restore path: a victim whose prompt pages are
        // shared (still referenced by the registering lane) releases
        // only its private tail; on restore, adopt_prefix re-adopts the
        // surviving prefix pages without allocating any new page
        let mut c = KvCache::with_geometry(3, 1, 8, 1, 1, 2, 8);
        let toks = [10, 11, 12, 13, 14];
        let a = c.alloc_with_budget(6).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0, 4.0, 5.0], &[1.0, 2.0, 3.0, 4.0, 5.0]);
        c.advance(a, 5);
        c.register_prefix(a, &toks);
        let b = c.alloc_with_budget(6).unwrap();
        assert_eq!(c.adopt_prefix(b, &toks), 4);
        // preempt b: its two prefix pages survive through a's references
        let reserved_before = c.reserved_page_count();
        let freed = c.free(b);
        assert_eq!(freed, 0, "shared prefix pages are not physically freed");
        assert!(c.reserved_page_count() < reserved_before, "reservation returned");
        assert_eq!(c.index_entries(), 2, "prefix chains stay registered");
        // restore: re-adoption is free — no page allocations at all
        let allocs_before = c.page_alloc_count();
        let b2 = c.alloc_with_budget(6).unwrap();
        assert_eq!(c.adopt_prefix(b2, &toks), 4);
        assert_eq!(c.page_alloc_count(), allocs_before, "restore allocates no pages");
    }

    #[test]
    fn preempted_sole_holder_frees_pages_and_restores_cold() {
        // a victim holding the last reference physically frees its pages
        // and retires the index chains; restore recomputes from scratch
        let mut c = KvCache::with_geometry(2, 1, 8, 1, 1, 2, 8);
        let toks = [20, 21, 22, 23];
        let a = c.alloc_with_budget(5).unwrap();
        c.append(a, 0, &[1.0, 2.0, 3.0, 4.0], &[1.0, 2.0, 3.0, 4.0]);
        c.advance(a, 4);
        c.register_prefix(a, &toks);
        assert_eq!(c.free(a), 2, "sole holder frees both pages");
        assert_eq!(c.live_pages(), 0);
        assert_eq!(c.index_entries(), 0, "unreferenced chains retire");
        let a2 = c.alloc_with_budget(5).unwrap();
        assert_eq!(c.adopt_prefix(a2, &toks), 0, "cold restore recomputes");
    }

    #[test]
    fn router_routes_longest_published_prefix() {
        let r = PrefixRouter::new(2);
        assert_eq!(r.route(&[1, 2, 3, 4]), None, "empty router routes nothing");
        r.publish(0, &[1, 2, 3, 4]);
        r.publish(1, &[1, 2, 5, 6, 7, 8]);
        // chains of 1 page route to their publisher
        assert_eq!(r.route(&[1, 2, 9]), Some(1), "latest publisher of [1,2] wins");
        // the longest matching chain decides, not the shortest
        assert_eq!(r.route(&[1, 2, 3, 4, 9]), Some(0));
        assert_eq!(r.route(&[1, 2, 5, 6, 7, 8, 9]), Some(1));
        // diverging prompts and sub-page prompts route nowhere
        assert_eq!(r.route(&[9, 9, 9, 9]), None);
        assert_eq!(r.route(&[1]), None, "no whole page to match");
    }

    #[test]
    fn router_publish_is_idempotent_and_forgettable() {
        let r = PrefixRouter::new(2);
        r.publish(0, &[1, 2, 3, 4]);
        let n = r.entries();
        r.publish(0, &[1, 2, 3, 4]);
        assert_eq!(r.entries(), n, "re-publishing the same chains adds nothing");
        r.publish(1, &[1, 2, 3, 4]);
        assert_eq!(r.entries(), n, "re-tagging moves chains, never duplicates");
        assert_eq!(r.route(&[1, 2, 3, 4]), Some(1));
        r.forget_worker(1);
        assert_eq!(r.entries(), 0, "a panicked worker's chains all retire");
        assert_eq!(r.route(&[1, 2, 3, 4]), None);
    }

    #[test]
    fn router_page_size_matches_cache_hash_fold() {
        // the router and the cache key on the same page-aligned FNV fold,
        // so a chain registered in a cache is routable verbatim
        let toks = [7, 8, 9, 10, 11];
        let r = PrefixRouter::new(2);
        r.publish(3, &toks);
        assert_eq!(r.entries(), 2, "two whole pages publish two chains");
        assert_eq!(r.route(&toks), Some(3));
    }

    #[test]
    fn live_bytes_track_occupancy_not_capacity() {
        let mut c = KvCache::new(2, 2, 64, 2, 4);
        assert_eq!(c.live_bytes(), 0);
        let s = c.alloc().unwrap();
        let re = 2 * 4;
        c.append(s, 0, &vec![1.0; re], &vec![1.0; re]);
        c.append(s, 1, &vec![1.0; re], &vec![1.0; re]);
        c.advance(s, 1);
        assert_eq!(c.live_bytes(), c.page_bytes(), "one page for one position");
        assert!(c.live_bytes() < c.bytes(), "occupancy below pool capacity");
    }
}
