//! Admission control for the serve engine: a FIFO queue with deadline and
//! max-wait awareness.
//!
//! Both engine modes admit through `expire_overdue` + `pop_ready` (the
//! engine's `admit`): continuous mode per freed lane, drain mode whenever
//! all lanes are free. `next_batch`/`next_batch_timed` pop whole batches
//! for one-shot callers, and `batch_ready`/`max_wait` are the admission
//! gate for an asynchronous front-end that has to choose between waiting
//! for a full batch and cutting a partial one — the synchronous engine's
//! pre-queued workloads never wait, so nothing in-process consults them.
//!
//! The coordinator invariants tested here (capacity, no starvation, FIFO)
//! are the property-test surface for the serving layer.
//!
//! The multi-worker engine admits through [`ShardedQueue`] instead: the
//! same deadline/max-wait semantics, but with one FIFO shard per worker,
//! placement-aware submission, and work stealing between shards.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::GenRequest;

#[derive(Debug, Clone)]
struct Queued {
    id: u64,
    req: GenRequest,
    submitted: Instant,
    deadline: Option<Duration>,
}

/// FIFO admission queue with deadline expiry and a max-wait batch cut.
#[derive(Debug)]
pub struct Batcher {
    /// widest batch the engine can take (== its lane count)
    pub capacity: usize,
    /// drain-mode cut: launch a partial batch once the oldest request has
    /// waited this long
    pub max_wait: Duration,
    queue: VecDeque<Queued>,
    next_id: u64,
}

impl Batcher {
    /// A queue for an engine of `capacity` lanes (default 50 ms max-wait).
    pub fn new(capacity: usize) -> Batcher {
        assert!(capacity > 0);
        Batcher {
            capacity,
            max_wait: Duration::from_millis(50),
            queue: VecDeque::new(),
            next_id: 0,
        }
    }

    /// Builder-style override of the max-wait cut interval.
    pub fn with_max_wait(mut self, max_wait: Duration) -> Batcher {
        self.max_wait = max_wait;
        self
    }

    /// Enqueue a request (no deadline); returns its id.
    pub fn submit(&mut self, req: GenRequest) -> u64 {
        self.submit_with_deadline(req, None)
    }

    /// Submit with a queue-time deadline: if the request is still waiting
    /// for a lane after `deadline`, admission drops it (`expire_overdue`).
    pub fn submit_with_deadline(
        &mut self,
        req: GenRequest,
        deadline: Option<Duration>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Queued {
            id,
            req,
            submitted: Instant::now(),
            deadline,
        });
        id
    }

    /// Requests currently waiting for a lane.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next batch (up to capacity, FIFO). Empty queue -> None.
    pub fn next_batch(&mut self) -> Option<Vec<(u64, GenRequest)>> {
        self.next_batch_timed().map(|batch| {
            batch.into_iter().map(|(id, req, _)| (id, req)).collect()
        })
    }

    /// Like `next_batch` but also returns each request's submit time so
    /// the engine can account queue latency.
    pub fn next_batch_timed(&mut self) -> Option<Vec<(u64, GenRequest, Instant)>> {
        if self.queue.is_empty() {
            return None;
        }
        let n = self.capacity.min(self.queue.len());
        Some(
            self.queue
                .drain(..n)
                .map(|q| (q.id, q.req, q.submitted))
                .collect(),
        )
    }

    /// Drain-mode admission gate: a batch is worth launching when it is
    /// full, or when the oldest waiter has exceeded `max_wait`.
    pub fn batch_ready(&self, now: Instant) -> bool {
        self.queue.len() >= self.capacity
            || self
                .queue
                .front()
                .map(|q| now.duration_since(q.submitted) >= self.max_wait)
                .unwrap_or(false)
    }

    /// Continuous admission: pop the oldest queued request for a freed
    /// lane. FIFO; deadline filtering is done by `expire_overdue` first.
    pub fn pop_ready(&mut self, _now: Instant) -> Option<(u64, GenRequest, Instant)> {
        self.queue.pop_front().map(|q| (q.id, q.req, q.submitted))
    }

    /// Look at the request `pop_ready` would return without dequeuing it
    /// — the engine peeks first so admission that fails page-budget
    /// reservation (pool backpressure) leaves the request queued, FIFO
    /// position and deadline intact. Borrowed, not cloned: a
    /// backpressured engine peeks the same head every step.
    pub fn peek_ready(&self, _now: Instant) -> Option<(u64, &GenRequest, Instant)> {
        self.queue.front().map(|q| (q.id, &q.req, q.submitted))
    }

    /// Remove and return every queued request whose deadline elapsed
    /// before it was admitted.
    pub fn expire_overdue(&mut self, now: Instant) -> Vec<(u64, GenRequest)> {
        let mut kept = VecDeque::with_capacity(self.queue.len());
        let mut expired = Vec::new();
        for q in self.queue.drain(..) {
            let overdue = q
                .deadline
                .map(|d| now.duration_since(q.submitted) >= d)
                .unwrap_or(false);
            if overdue {
                expired.push((q.id, q.req));
            } else {
                kept.push_back(q);
            }
        }
        self.queue = kept;
        expired
    }
}

#[derive(Debug)]
struct Shards {
    shards: Vec<VecDeque<Queued>>,
    next_id: u64,
}

/// Shared work-stealing admission queue for the sharded engine: one FIFO
/// shard per worker behind a single mutex. Submission places a request on
/// its preferred worker's shard (the prefix-affinity hook) or the
/// least-loaded shard; a worker claims from its own shard first and
/// *steals the oldest request of the most-loaded other shard* when its
/// own is empty, so queued work survives an idle — or dead — worker.
/// Deadline expiry ([`ShardedQueue::expire_overdue`]) and the `max_wait`
/// idle-backoff bound keep [`Batcher`]'s admission semantics.
#[derive(Debug)]
pub struct ShardedQueue {
    /// idle-backoff bound, same semantics as [`Batcher::max_wait`]
    pub max_wait: Duration,
    state: Mutex<Shards>,
}

impl ShardedQueue {
    /// A queue with one shard per worker (default 50 ms max-wait).
    pub fn new(workers: usize) -> ShardedQueue {
        assert!(workers > 0);
        ShardedQueue {
            max_wait: Duration::from_millis(50),
            state: Mutex::new(Shards {
                shards: (0..workers).map(|_| VecDeque::new()).collect(),
                next_id: 0,
            }),
        }
    }

    /// Builder-style override of the max-wait bound.
    pub fn with_max_wait(mut self, max_wait: Duration) -> ShardedQueue {
        self.max_wait = max_wait;
        self
    }

    /// Number of shards (== worker count).
    pub fn workers(&self) -> usize {
        self.state.lock().unwrap().shards.len()
    }

    /// Requests waiting across every shard.
    pub fn pending(&self) -> usize {
        self.state.lock().unwrap().shards.iter().map(|s| s.len()).sum()
    }

    /// Requests waiting on `worker`'s own shard (stealable by others).
    pub fn pending_for(&self, worker: usize) -> usize {
        self.state.lock().unwrap().shards[worker].len()
    }

    /// Enqueue with no deadline or placement preference.
    pub fn submit(&self, req: GenRequest) -> u64 {
        self.submit_placed(req, None, None)
    }

    /// Enqueue with placement: `preferred` worker's shard when given and
    /// valid (the prefix-cache routing hook), otherwise the least-loaded
    /// shard, ties to the lowest worker id. Returns the request id —
    /// ids are global across shards, so deadline expiry and response
    /// merging stay totally ordered.
    pub fn submit_placed(
        &self,
        req: GenRequest,
        deadline: Option<Duration>,
        preferred: Option<usize>,
    ) -> u64 {
        let mut st = self.state.lock().unwrap();
        let n = st.shards.len();
        let shard = match preferred {
            Some(w) if w < n => w,
            _ => (0..n).min_by_key(|&w| st.shards[w].len()).unwrap(),
        };
        let id = st.next_id;
        st.next_id += 1;
        st.shards[shard].push_back(Queued {
            id,
            req,
            submitted: Instant::now(),
            deadline,
        });
        id
    }

    /// Claim the next request for `worker`: its own shard's head first
    /// (FIFO), else the *oldest* request of the most-loaded other shard
    /// (work stealing). `None` means every shard is empty. The claim is
    /// atomic under the queue lock — two workers can never pop the same
    /// request.
    pub fn claim(
        &self,
        worker: usize,
    ) -> Option<(u64, GenRequest, Instant, Option<Duration>)> {
        let mut st = self.state.lock().unwrap();
        if let Some(q) = st.shards[worker].pop_front() {
            return Some((q.id, q.req, q.submitted, q.deadline));
        }
        let victim = (0..st.shards.len())
            .filter(|&w| w != worker && !st.shards[w].is_empty())
            .max_by_key(|&w| st.shards[w].len())?;
        let q = st.shards[victim].pop_front().unwrap();
        Some((q.id, q.req, q.submitted, q.deadline))
    }

    /// Return a claimed-but-inadmissible request to the *front* of
    /// `worker`'s shard (page-pool backpressure): the worker retries it
    /// first on its next admission pass, and an idle sibling can still
    /// steal it. The original submit time (and so deadline accounting)
    /// is preserved.
    pub fn restore(
        &self,
        worker: usize,
        id: u64,
        req: GenRequest,
        submitted: Instant,
        deadline: Option<Duration>,
    ) {
        let mut st = self.state.lock().unwrap();
        st.shards[worker].push_front(Queued { id, req, submitted, deadline });
    }

    /// Remove and return every queued request (any shard) whose deadline
    /// elapsed before admission, sorted by id.
    pub fn expire_overdue(&self, now: Instant) -> Vec<(u64, GenRequest)> {
        let mut st = self.state.lock().unwrap();
        let mut expired = Vec::new();
        for shard in st.shards.iter_mut() {
            let mut kept = VecDeque::with_capacity(shard.len());
            for q in shard.drain(..) {
                let overdue = q
                    .deadline
                    .map(|d| now.duration_since(q.submitted) >= d)
                    .unwrap_or(false);
                if overdue {
                    expired.push((q.id, q.req));
                } else {
                    kept.push_back(q);
                }
            }
            *shard = kept;
        }
        expired.sort_by_key(|(id, _)| *id);
        expired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn req(n: usize) -> GenRequest {
        GenRequest { prompt: "x".repeat(n % 40 + 1), max_new_tokens: 4 }
    }

    #[test]
    fn fifo_order_within_and_across_batches() {
        let mut b = Batcher::new(3);
        let ids: Vec<u64> = (0..7).map(|i| b.submit(req(i))).collect();
        let mut drained = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(batch.len() <= 3);
            drained.extend(batch.into_iter().map(|(id, _)| id));
        }
        assert_eq!(drained, ids);
    }

    #[test]
    fn batcher_invariants_property() {
        // invariant: across any submit/drain interleaving, every request is
        // delivered exactly once, in order, and no batch exceeds capacity
        check(
            "batcher-exactly-once-fifo",
            40,
            |r: &mut Rng| {
                let ops = r.below(60) + 5;
                (0..ops).map(|_| r.below(3)).collect::<Vec<usize>>()
            },
            |ops| {
                let mut b = Batcher::new(4);
                let mut submitted = Vec::new();
                let mut delivered = Vec::new();
                for &op in ops {
                    if op < 2 {
                        submitted.push(b.submit(req(op)));
                    } else if let Some(batch) = b.next_batch() {
                        if batch.len() > 4 {
                            return Err("over capacity".into());
                        }
                        delivered.extend(batch.into_iter().map(|(i, _)| i));
                    }
                }
                while let Some(batch) = b.next_batch() {
                    delivered.extend(batch.into_iter().map(|(i, _)| i));
                }
                if delivered != submitted {
                    return Err(format!(
                        "delivered {delivered:?} != submitted {submitted:?}"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b = Batcher::new(2);
        assert!(b.next_batch().is_none());
        b.submit(req(1));
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn max_wait_cut() {
        let mut b = Batcher::new(4).with_max_wait(Duration::from_millis(20));
        let now = Instant::now();
        // empty queue is never ready
        assert!(!b.batch_ready(now + Duration::from_secs(1)));
        b.submit(req(1));
        // fresh and underfull: wait for more work
        assert!(!b.batch_ready(Instant::now()));
        // the oldest waiter ages past max_wait: cut a partial batch
        assert!(b.batch_ready(Instant::now() + Duration::from_millis(25)));
        // a full batch is ready regardless of age
        for i in 0..3 {
            b.submit(req(i));
        }
        assert!(b.batch_ready(Instant::now()));
    }

    #[test]
    fn deadline_expiry_drops_only_overdue() {
        let mut b = Batcher::new(2);
        let slow = b.submit_with_deadline(req(1), Some(Duration::from_millis(5)));
        let patient = b.submit(req(2));
        let lenient =
            b.submit_with_deadline(req(3), Some(Duration::from_secs(3600)));
        let expired = b.expire_overdue(Instant::now() + Duration::from_millis(10));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, slow);
        assert_eq!(b.pending(), 2);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch[0].0, patient);
        assert_eq!(batch[1].0, lenient);
    }

    #[test]
    fn pop_ready_is_fifo() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let c = b.submit(req(2));
        let now = Instant::now();
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert_eq!(b.pop_ready(now).unwrap().0, c);
        assert!(b.pop_ready(now).is_none());
    }

    #[test]
    fn peek_ready_does_not_dequeue() {
        let mut b = Batcher::new(2);
        let a = b.submit(req(1));
        let now = Instant::now();
        // peeking twice sees the same head; the queue is untouched
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.peek_ready(now).unwrap().0, a);
        assert_eq!(b.pending(), 1);
        // pop returns exactly what peek advertised
        assert_eq!(b.pop_ready(now).unwrap().0, a);
        assert!(b.peek_ready(now).is_none());
    }

    #[test]
    fn sharded_empty_steal_returns_none() {
        let q = ShardedQueue::new(3);
        assert!(q.claim(0).is_none(), "empty queue claims nothing");
        let id = q.submit_placed(req(1), None, Some(2));
        assert_eq!(q.pending_for(2), 1);
        // worker 0's shard is empty: the claim steals from shard 2
        assert_eq!(q.claim(0).unwrap().0, id);
        assert_eq!(q.pending(), 0);
        assert!(q.claim(1).is_none(), "stolen work is gone for everyone");
    }

    #[test]
    fn sharded_claim_prefers_local_then_steals_oldest_of_most_loaded() {
        let q = ShardedQueue::new(3);
        let own = q.submit_placed(req(1), None, Some(0));
        let other_a = q.submit_placed(req(2), None, Some(1));
        let other_b = q.submit_placed(req(3), None, Some(1));
        let lone = q.submit_placed(req(4), None, Some(2));
        // local first, FIFO
        assert_eq!(q.claim(0).unwrap().0, own);
        // then steal from the most-loaded shard (1 holds two), oldest first
        assert_eq!(q.claim(0).unwrap().0, other_a);
        // shards 1 and 2 now hold one each; ties steal the lowest id shard
        assert_eq!(q.claim(0).unwrap().0, other_b);
        assert_eq!(q.claim(0).unwrap().0, lone);
        assert!(q.claim(0).is_none());
    }

    #[test]
    fn sharded_contended_claim_is_exactly_once() {
        // the satellite case: N workers race for the last queued request
        let q = ShardedQueue::new(4);
        let id = q.submit(req(1));
        let winners: Vec<u64> = std::thread::scope(|s| {
            let q = &q;
            let handles: Vec<_> =
                (0..4).map(|w| s.spawn(move || q.claim(w))).collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().unwrap())
                .map(|(got, _, _, _)| got)
                .collect()
        });
        assert_eq!(winners, vec![id], "exactly one worker wins the claim");
        assert_eq!(q.pending(), 0);
    }

    #[test]
    fn sharded_placement_falls_back_to_least_loaded() {
        let q = ShardedQueue::new(3);
        // no preference: fills shards round-robin via least-loaded + low id
        q.submit(req(1));
        q.submit(req(2));
        q.submit(req(3));
        assert_eq!(
            (q.pending_for(0), q.pending_for(1), q.pending_for(2)),
            (1, 1, 1)
        );
        // an out-of-range preference also falls back to least-loaded
        q.submit_placed(req(4), None, Some(99));
        assert_eq!(q.pending_for(0), 2);
    }

    #[test]
    fn sharded_restore_keeps_fifo_head_and_submit_time() {
        let q = ShardedQueue::new(2);
        let first = q.submit_placed(req(1), None, Some(0));
        let second = q.submit_placed(req(2), None, Some(0));
        let (id, r, submitted, deadline) = q.claim(0).unwrap();
        assert_eq!(id, first);
        // backpressure: the claim goes back to the front, not the back
        q.restore(0, id, r, submitted, deadline);
        assert_eq!(q.claim(0).unwrap().0, first, "restored head claims first");
        assert_eq!(q.claim(0).unwrap().0, second);
    }

    #[test]
    fn sharded_deadline_expiry_spans_all_shards() {
        let q = ShardedQueue::new(2);
        let gone_a = q.submit_placed(req(1), Some(Duration::from_millis(5)), Some(0));
        let kept = q.submit_placed(req(2), None, Some(0));
        let gone_b = q.submit_placed(req(3), Some(Duration::from_millis(5)), Some(1));
        let expired = q.expire_overdue(Instant::now() + Duration::from_millis(10));
        let ids: Vec<u64> = expired.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![gone_a, gone_b], "both shards expire, id order");
        assert_eq!(q.pending(), 1);
        assert_eq!(q.claim(1).unwrap().0, kept, "survivor is still stealable");
    }
}
