//! Perplexity evaluation over the held-out corpus splits, matching the
//! paper's protocol (windowed NLL over the test set, exp of mean).

use anyhow::Result;

use super::ModelEval;
use crate::coordinator::Pipeline;
use crate::data::Corpus;

/// PPL over up to `max_batches` deterministic eval windows.
pub fn perplexity(
    pipe: &Pipeline,
    model: &ModelEval,
    corpus: &Corpus,
    max_batches: usize,
) -> Result<f64> {
    let batches =
        corpus.eval_batches(pipe.cfg.b_eval, pipe.cfg.seq, max_batches);
    assert!(!batches.is_empty(), "test split too small for eval window");
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for batch in &batches {
        let h = model.forward_h(pipe, batch)?;
        let (nll_sum, _) = pipe.head(model.params(), &h, batch)?;
        nll += nll_sum as f64;
        count += pipe.tokens_per_batch() as f64;
    }
    Ok((nll / count).exp())
}

#[cfg(test)]
mod tests {
    #[test]
    fn ppl_formula_sanity() {
        // uniform model over 256 symbols -> ppl == 256
        let nll_per_token = (256f64).ln();
        assert!(((nll_per_token).exp() - 256.0).abs() < 1e-9);
    }
}
