//! Criterion-like micro-bench harness substrate (criterion unavailable
//! offline). Used by every target in `rust/benches/` (`harness = false`).
//!
//! Warms up, runs timed iterations until a wall-clock budget or iteration
//! cap, and reports mean / p50 / p95 / min plus throughput. Deterministic
//! ordering, plain-text output that `cargo bench` streams.

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "{:<44} {:>7} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.min_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1}ns", ns)
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

pub struct Bencher {
    budget: Duration,
    max_iters: usize,
    min_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(3),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            budget: Duration::from_millis(800),
            max_iters: 2_000,
            min_iters: 3,
        }
    }

    pub fn with_budget(mut self, d: Duration) -> Self {
        self.budget = d;
        self
    }

    /// Time `f` repeatedly; a final `black_box`-ish sink prevents the
    /// closure's result from being optimized away.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        for _ in 0..2 {
            sink(f());
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget
            && samples_ns.len() < self.max_iters)
            || samples_ns.len() < self.min_iters
        {
            let t0 = Instant::now();
            sink(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let res = BenchResult {
            name: name.to_string(),
            iters: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: samples_ns[n / 2],
            p95_ns: samples_ns[(n as f64 * 0.95) as usize % n.max(1)],
            min_ns: samples_ns[0],
        };
        res.report();
        res
    }
}

#[inline]
pub fn sink<T>(x: T) {
    // volatile read through a pointer defeats dead-code elimination without
    // std::hint::black_box's unstable history.
    unsafe {
        std::ptr::read_volatile(&x as *const T as *const u8);
    }
    std::mem::forget(x);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let b = Bencher::quick().with_budget(Duration::from_millis(50));
        let r = b.run("noop", || 1 + 1);
        assert!(r.iters >= 3);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500.0ns");
        assert_eq!(fmt_ns(2_500.0), "2.50us");
        assert_eq!(fmt_ns(3_000_000.0), "3.00ms");
        assert_eq!(fmt_ns(2_000_000_000.0), "2.000s");
    }
}
