#!/usr/bin/env python3
"""Bench-regression gate for `benches/bench_serve.rs`.

Compares a freshly produced ``runs/BENCH_serve.json`` against the
committed ``runs/BENCH_baseline.json`` and fails (exit 1) when a tracked
metric regresses beyond the tolerance band:

* ``packed_fused_step_ratio`` — packed/fused mean decode-step ratio,
  lower is better.  A slowdown in the packed 1.61-bit decode path (e.g.
  ``packed_qlinear_fwd`` doubling in cost) shows up here.
* ``prefix_hit_rate`` — fraction of prompt positions served from shared
  prefix pages on the shared-system-prompt workload, higher is better.
* ``worker_scaling.factor_w4_over_w1`` — 4-worker over 1-worker
  throughput of the sharded engine, higher is better.  Compared only
  when the fresh run had >= 4 cores (``worker_scaling.parallelism``);
  a 2-core runner cannot scale and must not fail the gate for it.
* ``cross_method.<m>.bits_per_weight`` — measured storage accounting of
  each method's packed containers on the bench model, lower is better.
  Deterministic (shape-dependent only): a container growing a plane or
  mis-charging its scaling vectors moves this immediately.
* ``cross_method.identity`` — 1.0 when every packed method decoded
  byte-identical tokens to its dense baseline; higher is better (the
  bench aborts on divergence, so this also guards against the section
  being dropped from the summary).
* ``p99_itl_overload_ratio`` — p99 inter-token latency of the overload
  workload with chunked prefill + preemption on over the same workload
  with both off, lower is better.  Chunking bounds per-step prefill
  work, so the ratio sits well below 1.0; a scheduler change that lets
  monolithic stalls (or long preemption park times) back into the tail
  moves it up immediately.
* ``bench_packing.simd_speedup`` — deployed (SIMD) over blocked
  single-thread mean on the wide packed matvec, higher is better.
  Compared only when the fresh run actually dispatched a SIMD tier
  (``bench_packing.simd`` is ``avx2``/``neon``); a runner without the
  ISA, or one pinned to the scalar/blocked tiers, must not fail for it.
* ``bench_packing.intra_parallel_speedup`` — full-pool-budget over
  single-thread mean of the deployed kernel on the same matvec, higher
  is better.  Like ``worker_scaling``, skipped when the fresh run had
  fewer than ``MIN_PARALLELISM`` cores (``bench_packing.parallelism``).
* ``open_loop.identity`` — 1.0 when every request streamed over the
  HTTP front door during the open-loop sweep reassembled byte-identical
  to its own terminal response (token-id SSE events vs the done text);
  higher is better.
* ``open_loop.completion`` — fraction of offered open-loop requests
  that reached a terminal outcome (streamed or explicitly shed with
  429); higher is better — below 1.0 means the front door dropped
  requests on the floor.

Only ratios, rates and storage accounting are gated — absolute step
times depend on the runner and would make the gate flaky (the per-method
``packed_dense_step_ratio`` and the open-loop sweep's per-rate
``ttft_p99_ms`` / ``saturation_knee_req_s`` series are recorded for
tracking, not gated, since their baselines vary with the host).
Tolerance is +/-20% by default.

Because `bench_serve` also writes run-id-suffixed copies
(``BENCH_serve_<rid>.json``), ``--fresh`` may point at a directory (or a
missing stable file): the newest ``BENCH_serve*.json`` by mtime is
resolved automatically.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# (dotted key, direction): "lower" = fresh must not exceed baseline by
# more than the tolerance, "higher" = fresh must not undershoot it
CHECKS = [
    ("packed_fused_step_ratio", "lower"),
    ("prefix_hit_rate", "higher"),
    ("worker_scaling.factor_w4_over_w1", "higher"),
    ("cross_method.rtn2.bits_per_weight", "lower"),
    ("cross_method.gptq2.bits_per_weight", "lower"),
    ("cross_method.pbllm.bits_per_weight", "lower"),
    ("cross_method.billm.bits_per_weight", "lower"),
    ("cross_method.identity", "higher"),
    ("p99_itl_overload_ratio", "lower"),
    ("bench_packing.simd_speedup", "higher"),
    ("bench_packing.intra_parallel_speedup", "higher"),
    ("open_loop.identity", "higher"),
    ("open_loop.completion", "higher"),
]

# below this core count the scaling factor is hardware-bound, not a
# code property: skip the worker_scaling comparison entirely
MIN_PARALLELISM = 4


def resolve_fresh(path):
    """Resolve ``--fresh`` to a concrete summary file.

    A plain existing file is returned as-is.  A directory — or a missing
    file whose directory holds run-id-suffixed copies — resolves to the
    newest ``BENCH_serve*.json`` by mtime, so the gate keeps working when
    only suffixed run artifacts survive.
    """
    if os.path.isfile(path):
        return path
    directory = path if os.path.isdir(path) else os.path.dirname(path) or "."
    candidates = [
        os.path.join(directory, name)
        for name in os.listdir(directory)
        if name.startswith("BENCH_serve") and name.endswith(".json")
    ] if os.path.isdir(directory) else []
    if not candidates:
        raise FileNotFoundError(
            f"no fresh summary at {path!r} and no BENCH_serve*.json "
            f"candidates in {directory!r}"
        )
    return max(candidates, key=os.path.getmtime)


def get_path(d, dotted):
    """Walk a dotted key path through nested dicts; None when absent."""
    cur = d
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def run_check(baseline, fresh, tolerance=0.2):
    """Compare fresh vs baseline; return a list of failure strings."""
    failures = []
    parallelism = get_path(fresh, "worker_scaling.parallelism")
    kernel_parallelism = get_path(fresh, "bench_packing.parallelism")
    simd = get_path(fresh, "bench_packing.simd")
    for key, direction in CHECKS:
        if key.startswith("worker_scaling."):
            if parallelism is None or parallelism < MIN_PARALLELISM:
                print(
                    f"skip {key}: fresh run had parallelism="
                    f"{parallelism} (< {MIN_PARALLELISM} cores)"
                )
                continue
        if key == "bench_packing.simd_speedup":
            if simd not in ("avx2", "neon"):
                # no SIMD tier dispatched (missing ISA, or pinned to
                # scalar/blocked): the ratio measures nothing — skip
                print(f"skip {key}: fresh run dispatched simd={simd}")
                continue
        if key == "bench_packing.intra_parallel_speedup":
            if kernel_parallelism is None or kernel_parallelism < MIN_PARALLELISM:
                print(
                    f"skip {key}: fresh run had bench_packing.parallelism="
                    f"{kernel_parallelism} (< {MIN_PARALLELISM} cores)"
                )
                continue
        base = get_path(baseline, key)
        cur = get_path(fresh, key)
        if base is None:
            failures.append(f"{key}: missing from baseline JSON")
            continue
        if cur is None:
            failures.append(f"{key}: missing from fresh summary JSON")
            continue
        if direction == "lower":
            limit = base * (1.0 + tolerance)
            ok = cur <= limit
            bound = f"<= {limit:.4f}"
        else:
            limit = base * (1.0 - tolerance)
            ok = cur >= limit
            bound = f">= {limit:.4f}"
        verdict = "ok" if ok else "REGRESSION"
        print(f"{key}: fresh {cur:.4f} vs baseline {base:.4f} ({bound}) {verdict}")
        if not ok:
            failures.append(
                f"{key}: {cur:.4f} regressed past baseline {base:.4f} "
                f"(allowed {bound}, {direction} is better)"
            )
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    ap.add_argument(
        "--fresh",
        required=True,
        help="freshly benched summary JSON (a directory, or a missing "
        "file, resolves to the newest BENCH_serve*.json beside it)",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.2,
        help="relative regression band (default 0.2 = 20%%)",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    fresh_path = resolve_fresh(args.fresh)
    if fresh_path != args.fresh:
        print(f"resolved fresh summary: {fresh_path}")
    with open(fresh_path) as f:
        fresh = json.load(f)
    failures = run_check(baseline, fresh, args.tolerance)
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\nbench regression gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
