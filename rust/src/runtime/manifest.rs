//! Manifest: the typed view of artifacts/manifest.json (the Python↔Rust
//! contract). Parsed with the in-repo JSON substrate.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// One positional input or output of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    /// slot name (e.g. `h`, `tokens`, `wq.alpha_s`)
    pub name: String,
    /// manifest shape; see `runtime`'s shape flexibility rules
    pub shape: Vec<usize>,
    /// `"f32"` or `"i32"`
    pub dtype: String,
}

/// One executable of the contract: `{base}_{config}` with typed IO.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// full artifact name, `{base}_{config}`
    pub name: String,
    /// behavior key the native backend dispatches on (e.g. `block_fwd`)
    pub base: String,
    /// model config this artifact was specialized for
    pub config: String,
    /// HLO text file the build step would write (unused natively)
    pub file: String,
    /// positional input specs
    pub inputs: Vec<IoSpec>,
    /// positional output specs
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Index of an input by name (call sites assemble positionally but
    /// assert names when the ordering is subtle).
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|io| io.name == name)
    }
}

/// One model size (mirrors python/compile/model.py CONFIGS).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// config name (`tiny`, `small`, `micro`)
    pub name: String,
    /// vocabulary size (byte tokenizer: 256)
    pub vocab: usize,
    /// model width
    pub d: usize,
    /// attention heads (head_dim = d / n_heads)
    pub n_heads: usize,
    /// transformer blocks
    pub n_layers: usize,
    /// MLP hidden width
    pub ffn: usize,
    /// context window (also the KV-cache capacity per lane)
    pub seq: usize,
    /// training batch rows
    pub b_train: usize,
    /// eval/serve batch rows (the engine's lane count)
    pub b_eval: usize,
    /// restorative-LoRA rank
    pub lora_rank: usize,
}

/// The typed artifact contract (see module docs).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// model configs by name
    pub configs: HashMap<String, ModelConfig>,
    /// canonical parameter order per config: (name, shape)
    pub param_spec: HashMap<String, Vec<(String, Vec<usize>)>>,
    /// block linear names in canonical order (wq..w_down)
    pub linears: Vec<String>,
    /// artifact specs by full name
    pub artifacts: HashMap<String, ArtifactSpec>,
}

fn io_from_json(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("io missing name"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("io missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string(),
    })
}

impl Manifest {
    /// Parse `artifacts/manifest.json` text (the Python build's output).
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut configs = HashMap::new();
        for (cname, cj) in root
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs"))?
        {
            let u = |k: &str| -> Result<usize> {
                cj.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("config {cname} missing {k}"))
            };
            configs.insert(
                cname.clone(),
                ModelConfig {
                    name: cname.clone(),
                    vocab: u("vocab")?,
                    d: u("d")?,
                    n_heads: u("n_heads")?,
                    n_layers: u("n_layers")?,
                    ffn: u("ffn")?,
                    seq: u("seq")?,
                    b_train: u("b_train")?,
                    b_eval: u("b_eval")?,
                    lora_rank: u("lora_rank")?,
                },
            );
        }
        let mut param_spec = HashMap::new();
        for (cname, sj) in root
            .get("param_spec")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing param_spec"))?
        {
            let mut spec = Vec::new();
            for entry in sj.as_arr().ok_or_else(|| anyhow!("bad spec"))? {
                let name = entry
                    .idx(0)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("bad spec name"))?
                    .to_string();
                let shape: Vec<usize> = entry
                    .idx(1)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("bad spec shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                spec.push((name, shape));
            }
            param_spec.insert(cname.clone(), spec);
        }
        let linears = root
            .get("linears")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing linears"))?
            .iter()
            .filter_map(|j| j.as_str().map(str::to_string))
            .collect();
        let mut artifacts = HashMap::new();
        for aj in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let gets = |k: &str| -> Result<String> {
                aj.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            let spec = ArtifactSpec {
                name: gets("name")?,
                base: gets("base")?,
                config: gets("config")?,
                file: gets("file")?,
                inputs: aj
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing inputs"))?
                    .iter()
                    .map(io_from_json)
                    .collect::<Result<_>>()?,
                outputs: aj
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing outputs"))?
                    .iter()
                    .map(io_from_json)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { configs, param_spec, linears, artifacts })
    }
}

/// Built-in model configs mirroring python/compile/model.py CONFIGS, plus
/// a "micro" config used by the fast native-backend tests.
pub fn builtin_configs() -> Vec<ModelConfig> {
    vec![
        ModelConfig {
            name: "tiny".into(),
            vocab: 256,
            d: 128,
            n_heads: 4,
            n_layers: 4,
            ffn: 352,
            seq: 128,
            b_train: 8,
            b_eval: 4,
            lora_rank: 8,
        },
        ModelConfig {
            name: "small".into(),
            vocab: 256,
            d: 192,
            n_heads: 6,
            n_layers: 6,
            ffn: 512,
            seq: 128,
            b_train: 8,
            b_eval: 4,
            lora_rank: 8,
        },
        ModelConfig {
            name: "micro".into(),
            vocab: 256,
            d: 32,
            n_heads: 2,
            n_layers: 2,
            ffn: 64,
            seq: 32,
            b_train: 4,
            b_eval: 2,
            lora_rank: 4,
        },
    ]
}

/// Canonical full-model (name, shape) list (python model.param_spec).
pub fn param_spec_for(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let mut spec = vec![("embed".to_string(), vec![cfg.vocab, cfg.d])];
    for l in 0..cfg.n_layers {
        for name in crate::model::block_param_names(l) {
            let shape = if name.ends_with("_norm") {
                vec![cfg.d]
            } else {
                let lin = name.split('.').nth(1).unwrap();
                let (out, inn) = crate::model::linear_shape(cfg, lin);
                vec![out, inn]
            };
            spec.push((name, shape));
        }
    }
    spec.push(("norm_f".into(), vec![cfg.d]));
    spec.push(("w_out".into(), vec![cfg.vocab, cfg.d]));
    spec
}

fn io(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.into(), shape: shape.to_vec(), dtype: "f32".into() }
}

fn io_i32(name: &str, shape: &[usize]) -> IoSpec {
    IoSpec { name: name.into(), shape: shape.to_vec(), dtype: "i32".into() }
}

/// Block parameter IoSpecs without the layer prefix (aot.block_param_ios).
fn block_param_ios(cfg: &ModelConfig) -> Vec<IoSpec> {
    let mut v = vec![io("attn_norm", &[cfg.d])];
    for lin in ["wq", "wk", "wv", "wo"] {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        v.push(io(lin, &[out, inn]));
    }
    v.push(io("mlp_norm", &[cfg.d]));
    for lin in ["w_gate", "w_up", "w_down"] {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        v.push(io(lin, &[out, inn]));
    }
    v
}

/// The 9 artifact specs of one config (mirrors aot.build_artifacts).
fn artifact_specs(cfg: &ModelConfig) -> Vec<ArtifactSpec> {
    let (d, ffn, vocab) = (cfg.d, cfg.ffn, cfg.vocab);
    let (t, be, bt) = (cfg.seq, cfg.b_eval, cfg.b_train);
    let linears = crate::model::LINEARS;
    let mk = |base: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| ArtifactSpec {
        name: format!("{base}_{}", cfg.name),
        base: base.into(),
        config: cfg.name.clone(),
        file: format!("{base}_{}.hlo.txt", cfg.name),
        inputs,
        outputs,
    };
    let mut arts = Vec::new();
    arts.push(mk(
        "embed_fwd",
        vec![io_i32("tokens", &[be, t]), io("embed", &[vocab, d])],
        vec![io("h", &[be, t, d])],
    ));
    let mut bf_in = vec![io("h", &[be, t, d])];
    bf_in.extend(block_param_ios(cfg));
    arts.push(mk("block_fwd", bf_in.clone(), vec![io("h_out", &[be, t, d])]));
    arts.push(mk(
        "block_capture",
        bf_in.clone(),
        vec![
            io("x_attn", &[be, t, d]),
            io("x_o", &[be, t, d]),
            io("x_mlp", &[be, t, d]),
            io("x_down", &[be, t, ffn]),
            io("h_out", &[be, t, d]),
        ],
    ));
    let mut q_in =
        vec![io("h", &[be, t, d]), io("attn_norm", &[d]), io("mlp_norm", &[d])];
    for lin in linears {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        q_in.push(io(&format!("{lin}.w_sal"), &[out, inn]));
        q_in.push(io(&format!("{lin}.sign_ns"), &[out, inn]));
        q_in.push(io(&format!("{lin}.alpha_s"), &[out]));
        q_in.push(io(&format!("{lin}.alpha_r1"), &[out]));
        q_in.push(io(&format!("{lin}.alpha_r2"), &[inn]));
        q_in.push(io(&format!("{lin}.mu"), &[out]));
    }
    arts.push(mk("qblock_fwd", q_in, vec![io("h_out", &[be, t, d])]));
    let mut w4_in = bf_in.clone();
    w4_in.extend([
        io("s_attn", &[d]),
        io("s_o", &[d]),
        io("s_mlp", &[d]),
        io("s_down", &[ffn]),
    ]);
    arts.push(mk("qblock_w4a4_fwd", w4_in, vec![io("h_out", &[be, t, d])]));
    arts.push(mk(
        "head_fwd",
        vec![
            io("h", &[be, t, d]),
            io("norm_f", &[d]),
            io("w_out", &[vocab, d]),
            io_i32("tokens", &[be, t]),
        ],
        vec![io("nll_sum", &[]), io("logits", &[be, t, vocab])],
    ));
    let spec = param_spec_for(cfg);
    let mut lm_in: Vec<IoSpec> = spec.iter().map(|(n, s)| io(n, s)).collect();
    lm_in.push(io_i32("tokens", &[bt, t]));
    let mut lm_out = vec![io("loss", &[])];
    lm_out.extend(spec.iter().map(|(n, s)| io(&format!("g.{n}"), s)));
    arts.push(mk("lm_grad", lm_in, lm_out));
    let mut lo_in: Vec<IoSpec> = spec.iter().map(|(n, s)| io(n, s)).collect();
    let mut lo_out = vec![io("loss", &[])];
    for l in 0..cfg.n_layers {
        for lin in linears {
            let (out, inn) = crate::model::linear_shape(cfg, lin);
            lo_in.push(io(&format!("l{l}.{lin}.A"), &[cfg.lora_rank, inn]));
            lo_in.push(io(&format!("l{l}.{lin}.B"), &[out, cfg.lora_rank]));
            lo_out.push(io(&format!("g.l{l}.{lin}.A"), &[cfg.lora_rank, inn]));
            lo_out.push(io(&format!("g.l{l}.{lin}.B"), &[out, cfg.lora_rank]));
        }
    }
    for l in 0..cfg.n_layers {
        for lin in linears {
            let (_, inn) = crate::model::linear_shape(cfg, lin);
            lo_in.push(io(&format!("l{l}.{lin}.mask"), &[inn]));
        }
    }
    lo_in.push(io_i32("tokens", &[bt, t]));
    arts.push(mk("lora_grad", lo_in, lo_out));
    let mut bo_in = Vec::new();
    let mut bo_out = vec![io("loss", &[])];
    for lin in linears {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        bo_in.push(io(&format!("{lin}.alpha_s"), &[out]));
        bo_in.push(io(&format!("{lin}.alpha_r1"), &[out]));
        bo_in.push(io(&format!("{lin}.alpha_r2"), &[inn]));
        bo_in.push(io(&format!("{lin}.mu"), &[out]));
        bo_out.push(io(&format!("g.{lin}.alpha_s"), &[out]));
        bo_out.push(io(&format!("g.{lin}.alpha_r1"), &[out]));
        bo_out.push(io(&format!("g.{lin}.alpha_r2"), &[inn]));
        bo_out.push(io(&format!("g.{lin}.mu"), &[out]));
    }
    bo_in.extend([
        io("x_q", &[be, t, d]),
        io("f1", &[be, t, d]),
        io("f3", &[be, t, d]),
        io("attn_norm", &[d]),
        io("mlp_norm", &[d]),
    ]);
    for lin in linears {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        bo_in.push(io(&format!("{lin}.w_sal"), &[out, inn]));
        bo_in.push(io(&format!("{lin}.sign_ns"), &[out, inn]));
    }
    bo_in.push(io("nlc_w", &[]));
    arts.push(mk("block_opt_grad", bo_in, bo_out));
    arts.extend(decode_artifact_specs(cfg));
    arts
}

/// The 5 KV-cached incremental-decode artifact specs of one config.
///
/// Shapes are the worst case (full lane pool, full window); the runtime
/// additionally lets `_decode` bases shrink the time axis of
/// `tokens`/`h_new` (prefill chunks, one-token steps) on top of the
/// usual flexible leading batch dim. `pos` carries each lane's valid
/// cached length; `k_new`/`v_new` come back for the cache append. Kept
/// separate from `artifact_specs` so a parsed (Python-built) manifest
/// that predates the decode contract can be back-filled
/// ([`Manifest::ensure_decode_artifacts`]).
fn decode_artifact_specs(cfg: &ModelConfig) -> Vec<ArtifactSpec> {
    let (d, ffn, vocab) = (cfg.d, cfg.ffn, cfg.vocab);
    let (t, be) = (cfg.seq, cfg.b_eval);
    let linears = crate::model::LINEARS;
    let mk = |base: &str, inputs: Vec<IoSpec>, outputs: Vec<IoSpec>| ArtifactSpec {
        name: format!("{base}_{}", cfg.name),
        base: base.into(),
        config: cfg.name.clone(),
        file: format!("{base}_{}.hlo.txt", cfg.name),
        inputs,
        outputs,
    };
    let mut arts = Vec::new();
    let (nh, hd) = (cfg.n_heads, d / cfg.n_heads);
    let kv_in = |v: &mut Vec<IoSpec>| {
        v.push(io("k_cache", &[be, t, nh, hd]));
        v.push(io("v_cache", &[be, t, nh, hd]));
        v.push(io_i32("pos", &[be]));
    };
    let dec_out = vec![
        io("h_out", &[be, t, d]),
        io("k_new", &[be, t, nh, hd]),
        io("v_new", &[be, t, nh, hd]),
    ];
    arts.push(mk(
        "embed_fwd_decode",
        vec![io_i32("tokens", &[be, t]), io("embed", &[vocab, d])],
        vec![io("h", &[be, t, d])],
    ));
    let mut bd_in = vec![io("h_new", &[be, t, d])];
    kv_in(&mut bd_in);
    bd_in.extend(block_param_ios(cfg));
    arts.push(mk("block_fwd_decode", bd_in, dec_out.clone()));
    let mut qd_in = vec![io("h_new", &[be, t, d])];
    kv_in(&mut qd_in);
    qd_in.push(io("attn_norm", &[d]));
    qd_in.push(io("mlp_norm", &[d]));
    for lin in linears {
        let (out, inn) = crate::model::linear_shape(cfg, lin);
        qd_in.push(io(&format!("{lin}.w_sal"), &[out, inn]));
        qd_in.push(io(&format!("{lin}.sign_ns"), &[out, inn]));
        qd_in.push(io(&format!("{lin}.alpha_s"), &[out]));
        qd_in.push(io(&format!("{lin}.alpha_r1"), &[out]));
        qd_in.push(io(&format!("{lin}.alpha_r2"), &[inn]));
        qd_in.push(io(&format!("{lin}.mu"), &[out]));
    }
    arts.push(mk("qblock_fwd_decode", qd_in, dec_out.clone()));
    let mut wd_in = vec![io("h_new", &[be, t, d])];
    kv_in(&mut wd_in);
    wd_in.extend(block_param_ios(cfg));
    wd_in.extend([
        io("s_attn", &[d]),
        io("s_o", &[d]),
        io("s_mlp", &[d]),
        io("s_down", &[ffn]),
    ]);
    arts.push(mk("qblock_w4a4_fwd_decode", wd_in, dec_out));
    arts.push(mk(
        "head_fwd_decode",
        vec![
            io("h_new", &[be, t, d]),
            io("norm_f", &[d]),
            io("w_out", &[vocab, d]),
        ],
        vec![io("logits", &[be, t, vocab])],
    ));
    arts
}

impl Manifest {
    /// Back-fill the `*_decode` artifact specs for every config that lacks
    /// them. Manifests written by a python build that predates the
    /// KV-cached decode contract only carry the nine full-window bases;
    /// the decode variants execute natively regardless, so serving stays
    /// available against an older artifacts directory.
    pub fn ensure_decode_artifacts(&mut self) {
        for cfg in self.configs.values() {
            for spec in decode_artifact_specs(cfg) {
                self.artifacts.entry(spec.name.clone()).or_insert(spec);
            }
        }
    }

    /// Built-in manifest for the native backend: what aot.py would write
    /// for the built-in configs, constructed without any artifacts on disk.
    pub fn builtin() -> Manifest {
        let mut configs = HashMap::new();
        let mut param_spec = HashMap::new();
        let mut artifacts = HashMap::new();
        for cfg in builtin_configs() {
            param_spec.insert(cfg.name.clone(), param_spec_for(&cfg));
            for a in artifact_specs(&cfg) {
                artifacts.insert(a.name.clone(), a);
            }
            configs.insert(cfg.name.clone(), cfg);
        }
        Manifest {
            configs,
            param_spec,
            linears: crate::model::LINEARS.iter().map(|s| s.to_string()).collect(),
            artifacts,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "configs": {"tiny": {"vocab":256,"d":128,"n_heads":4,"n_layers":4,
        "ffn":352,"seq":128,"b_train":8,"b_eval":4,"rope_theta":10000.0,
        "lora_rank":8,"name":"tiny"}},
      "param_spec": {"tiny": [["embed",[256,128]],["norm_f",[128]]]},
      "linears": ["wq","wk","wv","wo","w_gate","w_up","w_down"],
      "artifacts": [{"name":"head_fwd_tiny","base":"head_fwd",
        "config":"tiny","file":"head_fwd_tiny.hlo.txt",
        "inputs":[{"name":"h","shape":[4,128,128],"dtype":"f32"},
                  {"name":"tokens","shape":[4,128],"dtype":"i32"}],
        "outputs":[{"name":"nll_sum","shape":[],"dtype":"f32"}]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.configs["tiny"].d, 128);
        assert_eq!(m.param_spec["tiny"][0].0, "embed");
        assert_eq!(m.linears.len(), 7);
        let art = &m.artifacts["head_fwd_tiny"];
        assert_eq!(art.inputs[1].dtype, "i32");
        assert_eq!(art.input_index("tokens"), Some(1));
        assert_eq!(art.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn ensure_decode_artifacts_backfills_old_manifests() {
        let mut m = Manifest::parse(SAMPLE).unwrap();
        assert!(!m.artifacts.contains_key("block_fwd_decode_tiny"));
        m.ensure_decode_artifacts();
        for base in [
            "embed_fwd_decode",
            "block_fwd_decode",
            "qblock_fwd_decode",
            "qblock_w4a4_fwd_decode",
            "head_fwd_decode",
        ] {
            assert!(m.artifacts.contains_key(&format!("{base}_tiny")), "{base}");
        }
        // pre-existing artifacts are left untouched
        assert!(m.artifacts.contains_key("head_fwd_tiny"));
    }

    #[test]
    fn builtin_covers_all_configs_and_artifacts() {
        let m = Manifest::builtin();
        for c in ["tiny", "small", "micro"] {
            assert!(m.configs.contains_key(c), "{c} missing");
            for base in [
                "embed_fwd",
                "block_fwd",
                "block_capture",
                "qblock_fwd",
                "qblock_w4a4_fwd",
                "head_fwd",
                "lm_grad",
                "lora_grad",
                "block_opt_grad",
                "embed_fwd_decode",
                "block_fwd_decode",
                "qblock_fwd_decode",
                "qblock_w4a4_fwd_decode",
                "head_fwd_decode",
            ] {
                assert!(
                    m.artifacts.contains_key(&format!("{base}_{c}")),
                    "{base}_{c} missing"
                );
            }
        }
        assert_eq!(m.linears.len(), 7);
    }

    #[test]
    fn builtin_io_counts_match_python_contract() {
        let m = Manifest::builtin();
        let cfg = &m.configs["tiny"];
        let n_params = 9 * cfg.n_layers + 3;
        assert_eq!(m.param_spec["tiny"].len(), n_params);
        let nlin = cfg.n_layers * 7;
        let lm = &m.artifacts["lm_grad_tiny"];
        assert_eq!(lm.inputs.len(), n_params + 1);
        assert_eq!(lm.outputs.len(), n_params + 1);
        let lo = &m.artifacts["lora_grad_tiny"];
        assert_eq!(lo.inputs.len(), n_params + 3 * nlin + 1);
        assert_eq!(lo.outputs.len(), 1 + 2 * nlin);
        let bo = &m.artifacts["block_opt_grad_tiny"];
        assert_eq!(bo.inputs.len(), 4 * 7 + 5 + 2 * 7 + 1);
        assert_eq!(bo.outputs.len(), 1 + 4 * 7);
        let qb = &m.artifacts["qblock_fwd_tiny"];
        assert_eq!(qb.inputs.len(), 3 + 6 * 7);
        assert_eq!(qb.input_index("wq.alpha_s"), Some(5));
    }

    #[test]
    fn builtin_decode_variant_io_counts() {
        let m = Manifest::builtin();
        let cfg = &m.configs["tiny"];
        let bd = &m.artifacts["block_fwd_decode_tiny"];
        assert_eq!(bd.inputs.len(), 4 + 9, "h_new + kv + pos + block params");
        assert_eq!(bd.outputs.len(), 3, "h_out + k_new + v_new");
        assert_eq!(bd.input_index("pos"), Some(3));
        assert_eq!(
            bd.inputs[1].shape,
            vec![cfg.b_eval, cfg.seq, cfg.n_heads, cfg.d / cfg.n_heads]
        );
        let qd = &m.artifacts["qblock_fwd_decode_tiny"];
        assert_eq!(qd.inputs.len(), 6 + 6 * 7);
        assert_eq!(qd.input_index("wq.w_sal"), Some(6));
        let wd = &m.artifacts["qblock_w4a4_fwd_decode_tiny"];
        assert_eq!(wd.inputs.len(), 4 + 9 + 4);
        assert_eq!(wd.input_index("s_attn"), Some(13));
        let hd = &m.artifacts["head_fwd_decode_tiny"];
        assert_eq!(hd.inputs.len(), 3);
        assert_eq!(hd.outputs[0].name, "logits");
    }

    #[test]
    fn builtin_param_spec_matches_model_init() {
        // Params::init must accept the builtin spec verbatim
        let m = Manifest::builtin();
        let p = crate::model::Params::init(&m.param_spec["micro"], 1);
        assert_eq!(p.get("embed").shape, vec![256, 32]);
        assert_eq!(p.get("l1.w_gate").shape, vec![64, 32]);
        assert_eq!(p.get("norm_f").shape, vec![32]);
    }
}
