//! Explicit SIMD tiers of the packed PTQ1.61 decode contraction.
//!
//! The blocked kernel (`autodiff::packed_qlinear_fwd`) is bound by scalar
//! `u64` bit scans: one `trailing_zeros` + masked add per set sign bit.
//! The tiers here instead execute the sign plane as wide bitwise ops —
//! broadcast a sign *byte*, compare it against the per-lane bit masks
//! `[1, 2, 4, 8, …]`, and mask-accumulate eight (AVX2) or four (NEON)
//! `z` lanes per instruction — and decode the salient nibble plane 16
//! codes per 8-byte load. Both passes reduce their vector accumulator in
//! a fixed ascending lane order, so a given ISA tier is deterministic
//! run-to-run; across tiers the accumulation is *re-associated*, which is
//! why the SIMD tiers are gated against the scalar oracle with an epsilon
//! bound (`tests/packed_serve.rs`) instead of the bit-identity gate the
//! blocked tier keeps.
//!
//! Dispatch lives in `autodiff::packed_decode_fwd`: runtime detection via
//! `is_x86_feature_detected!` / `is_aarch64_feature_detected!`, with
//! `PTQ161_FORCE_SCALAR=1` (or `PTQ161_KERNEL=scalar|blocked`) forcing
//! the fallback tiers so they stay exercised in CI.
//!
//! Safety note shared by both ISA modules: callers pass `z` padded to a
//! whole number of 64-lane sign words (`autodiff::packed_row_operands`
//! guarantees this), so every 8-float load inside a word is in bounds,
//! and the nibble loop only issues an 8-byte load when 16 codes remain.

/// The SIMD tier this build can actually run on this machine:
/// `"avx2"`, `"neon"`, or `"none"`.
pub fn detected() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return "neon";
        }
    }
    "none"
}

use crate::quant::ptq161::PackedLinear;

/// Fill `yr[k] = y[o0 + k]` of one packed matvec with the best available
/// SIMD tier. Returns `false` (computing nothing) when no tier is
/// available, in which case the caller must run the blocked kernel.
///
/// Operands are the per-input-row values of
/// `autodiff::packed_row_operands` (with `z` word-padded).
pub(crate) fn packed_fill(
    pl: &PackedLinear,
    z: &[f32],
    ztot: f32,
    xs: f32,
    xq: &[f32],
    xmin: f32,
    o0: usize,
    yr: &mut [f32],
) -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability just checked; operand layout per
            // the module docs.
            unsafe { x86::packed_fill(pl, z, ztot, xs, xq, xmin, o0, yr) };
            return true;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            // SAFETY: NEON availability just checked; operand layout per
            // the module docs.
            unsafe { arm::packed_fill(pl, z, ztot, xs, xq, xmin, o0, yr) };
            return true;
        }
    }
    let _ = (pl, z, ztot, xs, xq, xmin, o0, yr);
    false
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use core::arch::x86_64::*;

    use crate::quant::ptq161::PackedLinear;

    /// Sum the 8 lanes in ascending lane order (deterministic reduction).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        let mut s = 0.0f32;
        for l in lanes {
            s += l;
        }
        s
    }

    /// ±1 accumulation of one row's sign words: per nonzero sign byte,
    /// broadcast it, compare against the lane bit masks and accumulate
    /// the masked `z` lanes. Unset lanes contribute an exact `+0.0`.
    #[target_feature(enable = "avx2")]
    unsafe fn row_pos(z: &[f32], words: &[u64], bits: __m256i) -> f32 {
        let mut acc = _mm256_setzero_ps();
        for (wi, &w) in words.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let base = wi * 64;
            for k in 0..8 {
                let byte = ((w >> (8 * k)) & 0xff) as i32;
                if byte == 0 {
                    continue;
                }
                let zv = _mm256_loadu_ps(z.as_ptr().add(base + k * 8));
                let m = _mm256_cmpeq_epi32(
                    _mm256_and_si256(_mm256_set1_epi32(byte), bits),
                    bits,
                );
                acc = _mm256_add_ps(acc, _mm256_and_ps(zv, _mm256_castsi256_ps(m)));
            }
        }
        hsum(acc)
    }

    /// Salient contraction of one code row starting at nibble `cbase`:
    /// 16 codes per 8-byte load (low/high nibble split re-interleaved to
    /// source order), scalar prologue/epilogue for odd offsets and tails.
    #[target_feature(enable = "avx2")]
    unsafe fn row_sal(pl: &PackedLinear, xq: &[f32], cbase: usize) -> f32 {
        let n_sal = xq.len();
        let mut sum = 0.0f32;
        let mut c = 0usize;
        if n_sal > 0 && (cbase & 1) == 1 {
            sum += pl.code(cbase) as f32 * xq[0];
            c = 1;
        }
        let bytes = pl.code_bytes();
        let mut acc = _mm256_setzero_ps();
        while c + 16 <= n_sal {
            let p = bytes.as_ptr().add((cbase + c) / 2) as *const __m128i;
            let b8 = _mm_loadl_epi64(p);
            let lo = _mm_and_si128(b8, _mm_set1_epi8(0x0f));
            let hi = _mm_and_si128(_mm_srli_epi16::<4>(b8), _mm_set1_epi8(0x0f));
            let nib = _mm_unpacklo_epi8(lo, hi);
            let c0 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(nib));
            let c1 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(
                nib,
            )));
            let x0 = _mm256_loadu_ps(xq.as_ptr().add(c));
            let x1 = _mm256_loadu_ps(xq.as_ptr().add(c + 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(c0, x0));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(c1, x1));
            c += 16;
        }
        sum += hsum(acc);
        while c < n_sal {
            sum += pl.code(cbase + c) as f32 * xq[c];
            c += 1;
        }
        sum
    }

    /// AVX2 fill of `yr[k] = y[o0 + k]`. 4-row tiles share each `z` load
    /// across the tile's sign rows; remainder rows run the same passes
    /// one row at a time.
    ///
    /// # Safety
    /// AVX2 must be available, `z` must be padded to `words * 64` floats,
    /// and `o0 + yr.len() <= pl.out()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn packed_fill(
        pl: &PackedLinear,
        z: &[f32],
        ztot: f32,
        xs: f32,
        xq: &[f32],
        xmin: f32,
        o0: usize,
        yr: &mut [f32],
    ) {
        let bits = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
        let n_sal = pl.sal_cols().len();
        let out_hi = o0 + yr.len();
        let mut o = o0;
        while o + 4 <= out_hi {
            let ws = [
                pl.sign_words(o),
                pl.sign_words(o + 1),
                pl.sign_words(o + 2),
                pl.sign_words(o + 3),
            ];
            let mut acc = [_mm256_setzero_ps(); 4];
            for wi in 0..ws[0].len() {
                let w4 = [ws[0][wi], ws[1][wi], ws[2][wi], ws[3][wi]];
                let any = w4[0] | w4[1] | w4[2] | w4[3];
                if any == 0 {
                    continue;
                }
                let base = wi * 64;
                for k in 0..8 {
                    if (any >> (8 * k)) & 0xff == 0 {
                        continue;
                    }
                    let zv = _mm256_loadu_ps(z.as_ptr().add(base + k * 8));
                    for r in 0..4 {
                        let byte = ((w4[r] >> (8 * k)) & 0xff) as i32;
                        if byte == 0 {
                            continue;
                        }
                        let m = _mm256_cmpeq_epi32(
                            _mm256_and_si256(_mm256_set1_epi32(byte), bits),
                            bits,
                        );
                        acc[r] = _mm256_add_ps(
                            acc[r],
                            _mm256_and_ps(zv, _mm256_castsi256_ps(m)),
                        );
                    }
                }
            }
            for r in 0..4 {
                let oo = o + r;
                let pos = hsum(acc[r]);
                let sal = row_sal(pl, xq, oo * n_sal);
                yr[oo - o0] = xmin
                    + sal
                    + pl.row_scale()[oo] * (2.0 * pos - ztot)
                    + xs * pl.mu()[oo];
            }
            o += 4;
        }
        while o < out_hi {
            let pos = row_pos(z, pl.sign_words(o), bits);
            let sal = row_sal(pl, xq, o * n_sal);
            yr[o - o0] = xmin
                + sal
                + pl.row_scale()[o] * (2.0 * pos - ztot)
                + xs * pl.mu()[o];
            o += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use core::arch::aarch64::*;

    use crate::quant::ptq161::PackedLinear;

    /// Sum the 4 lanes in ascending lane order (deterministic reduction).
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: float32x4_t) -> f32 {
        let mut lanes = [0.0f32; 4];
        vst1q_f32(lanes.as_mut_ptr(), v);
        ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3]
    }

    /// ±1 accumulation of one row's sign words, two 4-lane masked adds
    /// per nonzero sign byte.
    #[target_feature(enable = "neon")]
    unsafe fn row_pos(z: &[f32], words: &[u64]) -> f32 {
        let bits_lo: [u32; 4] = [1, 2, 4, 8];
        let bits_hi: [u32; 4] = [16, 32, 64, 128];
        let blo = vld1q_u32(bits_lo.as_ptr());
        let bhi = vld1q_u32(bits_hi.as_ptr());
        let mut acc = vdupq_n_f32(0.0);
        for (wi, &w) in words.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let base = wi * 64;
            for k in 0..8 {
                let byte = ((w >> (8 * k)) & 0xff) as u32;
                if byte == 0 {
                    continue;
                }
                let bv = vdupq_n_u32(byte);
                let z0 = vld1q_f32(z.as_ptr().add(base + k * 8));
                let z1 = vld1q_f32(z.as_ptr().add(base + k * 8 + 4));
                let m0 = vceqq_u32(vandq_u32(bv, blo), blo);
                let m1 = vceqq_u32(vandq_u32(bv, bhi), bhi);
                acc = vaddq_f32(
                    acc,
                    vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(z0), m0)),
                );
                acc = vaddq_f32(
                    acc,
                    vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(z1), m1)),
                );
            }
        }
        hsum(acc)
    }

    /// Salient contraction of one code row: 16 codes per 8-byte load,
    /// nibbles re-interleaved to source order with `vzip1/vzip2`.
    #[target_feature(enable = "neon")]
    unsafe fn row_sal(pl: &PackedLinear, xq: &[f32], cbase: usize) -> f32 {
        let n_sal = xq.len();
        let mut sum = 0.0f32;
        let mut c = 0usize;
        if n_sal > 0 && (cbase & 1) == 1 {
            sum += pl.code(cbase) as f32 * xq[0];
            c = 1;
        }
        let bytes = pl.code_bytes();
        let mut acc = vdupq_n_f32(0.0);
        while c + 16 <= n_sal {
            let b8 = vld1_u8(bytes.as_ptr().add((cbase + c) / 2));
            let lo = vand_u8(b8, vdup_n_u8(0x0f));
            let hi = vshr_n_u8::<4>(b8);
            let n01 = vmovl_u8(vzip1_u8(lo, hi));
            let n23 = vmovl_u8(vzip2_u8(lo, hi));
            let a0 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(n01)));
            let a1 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(n01)));
            let a2 = vcvtq_f32_u32(vmovl_u16(vget_low_u16(n23)));
            let a3 = vcvtq_f32_u32(vmovl_u16(vget_high_u16(n23)));
            acc = vaddq_f32(acc, vmulq_f32(a0, vld1q_f32(xq.as_ptr().add(c))));
            acc =
                vaddq_f32(acc, vmulq_f32(a1, vld1q_f32(xq.as_ptr().add(c + 4))));
            acc =
                vaddq_f32(acc, vmulq_f32(a2, vld1q_f32(xq.as_ptr().add(c + 8))));
            acc =
                vaddq_f32(acc, vmulq_f32(a3, vld1q_f32(xq.as_ptr().add(c + 12))));
            c += 16;
        }
        sum += hsum(acc);
        while c < n_sal {
            sum += pl.code(cbase + c) as f32 * xq[c];
            c += 1;
        }
        sum
    }

    /// NEON fill of `yr[k] = y[o0 + k]`, one row at a time.
    ///
    /// # Safety
    /// NEON must be available, `z` must be padded to `words * 64` floats,
    /// and `o0 + yr.len() <= pl.out()`.
    #[target_feature(enable = "neon")]
    pub unsafe fn packed_fill(
        pl: &PackedLinear,
        z: &[f32],
        ztot: f32,
        xs: f32,
        xq: &[f32],
        xmin: f32,
        o0: usize,
        yr: &mut [f32],
    ) {
        let n_sal = pl.sal_cols().len();
        for (k, yo) in yr.iter_mut().enumerate() {
            let o = o0 + k;
            let pos = row_pos(z, pl.sign_words(o));
            let sal = row_sal(pl, xq, o * n_sal);
            *yo = xmin
                + sal
                + pl.row_scale()[o] * (2.0 * pos - ztot)
                + xs * pl.mu()[o];
        }
    }
}
