//! PTQ1.61 (the paper's method), layer-level stage.
//!
//! This module produces the *analytic* PTQ1.61 quantization (structured
//! mask + Eq. 2 scaling factors + identity angular factors). The learnable
//! refinement — block-wise AdamW over (alpha_s, alpha_r1, alpha_r2[, mu])
//! against the two-branch Eq. 7 objective — runs in
//! `coordinator::blockopt`, which updates the `Ptq161Parts` produced here
//! in place via the `block_opt_grad` AOT artifact.

pub mod mask;
pub mod packed;
pub mod scaling;

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::tensor::Tensor;

pub use mask::{structured_mask, MaskCriterion};
pub use packed::{parts_storage_bits, PackedLinear};
// back-compat: the model-level container moved to the method-agnostic
// `quant::container` in the PackedContainer refactor
pub use crate::quant::container::PackedModel;
pub use scaling::initial_parts;

#[derive(Debug, Clone, Copy)]
pub struct Ptq161 {
    pub salient_ratio: f64,
    pub criterion: MaskCriterion,
}

impl Default for Ptq161 {
    fn default() -> Self {
        // paper: 20% salient channels (Fig. 6 picks 20% to stay sub-2-bit)
        Ptq161 {
            salient_ratio: 0.2,
            criterion: MaskCriterion::ActivationMagnitude,
        }
    }
}

impl Ptq161 {
    pub fn with_ratio(ratio: f64) -> Ptq161 {
        Ptq161 { salient_ratio: ratio, ..Default::default() }
    }

    pub fn with_criterion(criterion: MaskCriterion) -> Ptq161 {
        Ptq161 { criterion, ..Default::default() }
    }
}

impl Quantizer for Ptq161 {
    fn name(&self) -> &'static str {
        "PTQ1.61"
    }

    fn bits_label(&self) -> String {
        "1.61".into()
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let mask = structured_mask(
            &calib.act_abs_mean,
            &calib.act_sq_mean,
            self.salient_ratio,
            self.criterion,
        );
        let parts = initial_parts(w, &mask);
        QuantizedLinear {
            deq: parts.dequantize(),
            scheme: BitScheme::Ptq161 { salient_ratio: self.salient_ratio },
            parts: Some(parts),
            // packed after block-wise optimization (PackedModel::pack),
            // not here — a container built now would go stale
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::billm::BiLlm;
    use crate::quant::pbllm::PbLlm;
    use crate::quant::testutil::{demo, output_mse};

    #[test]
    fn activation_weighted_error_beats_pbllm() {
        // under hot input channels the structured mask protects exactly the
        // channels that dominate Eq. 4's bound
        let (w, calib) = demo(48, 64, 23);
        let p = Ptq161::default().quantize_linear(&w, &calib);
        let pb = PbLlm::new(0.1).quantize_linear(&w, &calib);
        let werr = |deq: &Tensor| {
            let mut e = 0.0;
            for i in 0..w.rows() {
                for (j, (&x, &y)) in
                    w.row(i).iter().zip(deq.row(i)).enumerate()
                {
                    e += calib.act_sq_mean[j] * (x - y) * (x - y);
                }
            }
            e
        };
        assert!(werr(&p.deq) < werr(&pb.deq));
    }

    #[test]
    fn bits_below_billm_and_pbllm() {
        // storage ordering at real LLaMA layer size (Appendix A numbers);
        // tiny matrices inflate the fp16 scaling-vector share for PTQ1.61.
        use crate::packing::bitwidth::{average_bits, BitScheme};
        let p = average_bits(BitScheme::Ptq161 { salient_ratio: 0.2 }, 4096, 4096);
        let bi = average_bits(BitScheme::BiLlm, 4096, 4096);
        let pb = average_bits(BitScheme::PbLlm { salient_ratio: 0.1 }, 4096, 4096);
        assert!(p < bi && bi < pb, "{p} {bi} {pb}");
    }

    #[test]
    fn parts_present_and_dense_consistent() {
        let (w, calib) = demo(24, 40, 25);
        let q = Ptq161::default().quantize_linear(&w, &calib);
        let parts = q.parts.as_ref().unwrap();
        assert_eq!(parts.n_salient(), 8); // 20% of 40
        assert!(q.deq.mse(&parts.dequantize()) < 1e-12);
    }

    #[test]
    fn ratio_zero_is_pure_binarization() {
        let (w, calib) = demo(16, 20, 26);
        let q = Ptq161::with_ratio(0.0).quantize_linear(&w, &calib);
        let b = crate::quant::binarize::binarize_dense(&w);
        assert!(q.deq.mse(&b) < 1e-12);
        let _ = output_mse(&w, &q.deq, 7);
    }
}
