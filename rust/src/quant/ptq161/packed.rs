//! Prepared packed-weight containers: the 1.61-bit form PTQ1.61 actually
//! serves from.
//!
//! The paper's central storage claim (Appendix A/B) is that *every* stored
//! weight lives in a true INT container — 4-bit salient channels plus
//! binarized rest under a structured per-channel mask — yet the original
//! serve path reconstructed the dense `Wq'` from six float tensors on
//! every decode step. This module closes that gap: [`PackedLinear`] packs
//! one [`Ptq161Parts`] into sign [`BitVec`]s, a salient [`NibbleVec`] with
//! per-column `(scale, min)` pairs, the channel-mask bitmap, and the fp
//! scaling vectors; the method-agnostic
//! [`crate::quant::container::PackedModel`] holds one such container per
//! block linear and is built **once** at engine construction. The
//! decode-time contraction (`runtime::autodiff::packed_decode_fwd`, which
//! dispatches between the scalar oracle, the blocked kernel and the SIMD
//! tiers) then runs directly on these containers — ±1 accumulation over
//! sign words, nibble decode fused into the salient dot product — with
//! zero per-step weight reconstruction. [`PackedLinear`] implements
//! [`crate::quant::PackedContainer`], the trait the serve engine
//! dispatches on; note its kernel re-associates the float accumulation
//! (sign words first, salient nibbles second), so unlike the baseline
//! containers it is *token*-identical to the dense backend (gated in
//! `tests/packed_serve.rs` / `tests/multi_worker.rs`) rather than
//! bit-identical per linear.
//!
//! Packing is lossless: [`PackedLinear::unpack`] reproduces the source
//! parts bit-for-bit (gated in `tests/packed_serve.rs`), because the INT4
//! codes and affine params are carried from quantization time
//! (`Ptq161Parts::sal_q`) instead of being re-derived from dequantized
//! floats.

use crate::packing::{BitVec, NibbleVec};
use crate::quant::{Ptq161Parts, SalientQuant};
use crate::tensor::Tensor;

/// One block linear in packed 1.61-bit form (see the module docs).
///
/// Layout choices serve the decode kernel: sign bits are stored as one
/// [`BitVec`] *per output row* over the compacted non-salient columns
/// (word-aligned rows, so the ±1 accumulation walks whole `u64` words),
/// and the 4-bit codes are row-major over `(out, n_salient)` so one
/// output's salient contraction reads consecutive nibbles.
#[derive(Debug, Clone)]
pub struct PackedLinear {
    out: usize,
    inn: usize,
    /// salient input-channel bitmap (1 bit per input channel)
    mask: BitVec,
    /// salient channel indices, ascending (derived from `mask`)
    sal_cols: Vec<u32>,
    /// non-salient channel indices, ascending (derived from `mask`)
    ns_cols: Vec<u32>,
    /// 4-bit codes, row-major over `(out, n_salient)`
    codes: NibbleVec,
    /// per-salient-column quantization step
    col_scale: Vec<f32>,
    /// per-salient-column zero offset (the code-0 value)
    col_min: Vec<f32>,
    /// per-output-row sign bits over the non-salient columns (set = +1)
    signs: Vec<BitVec>,
    /// folded per-row binarized-branch scale `alpha_r1[o] * alpha_s[o]`
    row_scale: Vec<f32>,
    /// raw Eq. 2 row scale (kept so `unpack` is exact)
    alpha_s: Vec<f32>,
    /// raw angular row factor (kept so `unpack` is exact)
    alpha_r1: Vec<f32>,
    /// angular column factor over *all* input channels
    alpha_r2: Vec<f32>,
    /// `alpha_r2` compacted to the non-salient channels (kernel operand)
    r2_ns: Vec<f32>,
    /// learnable row mean (zeros unless the Table 9 variant is on)
    mu: Vec<f32>,
}

impl PackedLinear {
    /// Pack one layer's parts. When the INT4 container metadata
    /// (`parts.sal_q`) is present — true for everything the quantizer
    /// produces — packing is verified bit-exact against `w_sal`;
    /// hand-assembled parts without it fall back to re-quantizing the
    /// salient columns (best effort, not guaranteed exact).
    pub fn pack(parts: &Ptq161Parts) -> PackedLinear {
        let (out, inn) = (parts.sign_ns.rows(), parts.sign_ns.cols());
        assert_eq!(parts.mask.len(), inn, "mask width");
        assert_eq!(parts.alpha_s.len(), out, "alpha_s length");
        assert_eq!(parts.alpha_r1.len(), out, "alpha_r1 length");
        assert_eq!(parts.alpha_r2.len(), inn, "alpha_r2 length");
        assert_eq!(parts.mu.len(), out, "mu length");
        let mut sal_cols: Vec<u32> = Vec::new();
        let mut ns_cols: Vec<u32> = Vec::new();
        for (j, &m) in parts.mask.iter().enumerate() {
            if m {
                sal_cols.push(j as u32);
            } else {
                ns_cols.push(j as u32);
            }
        }
        let n_sal = sal_cols.len();
        let sq = match &parts.sal_q {
            Some(sq) => {
                assert_eq!(sq.codes.len(), n_sal * out, "sal_q code count");
                assert_eq!(sq.scale.len(), n_sal, "sal_q scale count");
                sq.clone()
            }
            None => requantize_salient(&parts.w_sal, &sal_cols),
        };
        // codes arrive column-major from the quantizer; transpose to
        // row-major so one output row reads consecutive nibbles
        let mut codes = NibbleVec::zeros(out * n_sal);
        for c in 0..n_sal {
            for i in 0..out {
                codes.set(i * n_sal + c, sq.codes[c * out + i]);
            }
        }
        if parts.sal_q.is_some() {
            // lossless-pack invariant: decoding a code must land exactly
            // on the dequantized float the fused path multiplies with
            for (c, &j) in sal_cols.iter().enumerate() {
                for i in 0..out {
                    let want = parts.w_sal.at2(i, j as usize);
                    let got = sq.codes[c * out + i] as f32 * sq.scale[c] + sq.min[c];
                    assert!(
                        got == want,
                        "pack not bit-exact at ({i},{j}): {got} vs {want}"
                    );
                }
            }
        }
        let signs: Vec<BitVec> = (0..out)
            .map(|i| {
                let row = parts.sign_ns.row(i);
                let mut v = BitVec::zeros(ns_cols.len());
                for (c, &j) in ns_cols.iter().enumerate() {
                    if row[j as usize] >= 0.0 {
                        v.set(c, true);
                    }
                }
                v
            })
            .collect();
        let row_scale: Vec<f32> = (0..out)
            .map(|i| parts.alpha_r1[i] * parts.alpha_s[i])
            .collect();
        let r2_ns: Vec<f32> = ns_cols
            .iter()
            .map(|&j| parts.alpha_r2[j as usize])
            .collect();
        PackedLinear {
            out,
            inn,
            mask: BitVec::from_bools(&parts.mask),
            sal_cols,
            ns_cols,
            codes,
            col_scale: sq.scale,
            col_min: sq.min,
            signs,
            row_scale,
            alpha_s: parts.alpha_s.clone(),
            alpha_r1: parts.alpha_r1.clone(),
            alpha_r2: parts.alpha_r2.clone(),
            r2_ns,
            mu: parts.mu.clone(),
        }
    }

    /// Reconstruct the source [`Ptq161Parts`] from the containers — the
    /// inverse of [`Self::pack`], bit-exact for quantizer-produced parts.
    pub fn unpack(&self) -> Ptq161Parts {
        let (out, inn) = (self.out, self.inn);
        let n_sal = self.sal_cols.len();
        let mut w_sal = Tensor::zeros(&[out, inn]);
        let mut sign_ns = Tensor::zeros(&[out, inn]);
        let mut codes_cm = vec![0u8; n_sal * out];
        for i in 0..out {
            for (c, &j) in self.sal_cols.iter().enumerate() {
                let code = self.codes.get(i * n_sal + c);
                codes_cm[c * out + i] = code;
                *w_sal.at2_mut(i, j as usize) =
                    code as f32 * self.col_scale[c] + self.col_min[c];
            }
            for (c, &j) in self.ns_cols.iter().enumerate() {
                *sign_ns.at2_mut(i, j as usize) =
                    if self.signs[i].get(c) { 1.0 } else { -1.0 };
            }
        }
        Ptq161Parts {
            mask: self.mask.to_bools(),
            w_sal,
            sign_ns,
            alpha_s: self.alpha_s.clone(),
            alpha_r1: self.alpha_r1.clone(),
            alpha_r2: self.alpha_r2.clone(),
            mu: self.mu.clone(),
            sal_q: Some(SalientQuant {
                codes: codes_cm,
                scale: self.col_scale.clone(),
                min: self.col_min.clone(),
            }),
        }
    }

    /// Output rows.
    pub fn out(&self) -> usize {
        self.out
    }

    /// Input channels.
    pub fn inn(&self) -> usize {
        self.inn
    }

    /// Number of salient (4-bit) input channels.
    pub fn n_salient(&self) -> usize {
        self.sal_cols.len()
    }

    // kernel operand accessors (crate-internal: the decode kernel in
    // `runtime::autodiff` reads these; layout documented on the fields)

    #[inline]
    pub(crate) fn sal_cols(&self) -> &[u32] {
        &self.sal_cols
    }

    #[inline]
    pub(crate) fn ns_cols(&self) -> &[u32] {
        &self.ns_cols
    }

    #[inline]
    pub(crate) fn sign_words(&self, o: usize) -> &[u64] {
        self.signs[o].words()
    }

    #[inline]
    pub(crate) fn code(&self, i: usize) -> u8 {
        self.codes.get(i)
    }

    /// Raw packed nibble bytes of the row-major code plane — the SIMD
    /// tiers decode 16 codes per 8-byte load instead of per-nibble gets.
    #[inline]
    pub(crate) fn code_bytes(&self) -> &[u8] {
        self.codes.bytes()
    }

    #[inline]
    pub(crate) fn col_scale(&self) -> &[f32] {
        &self.col_scale
    }

    #[inline]
    pub(crate) fn col_min(&self) -> &[f32] {
        &self.col_min
    }

    #[inline]
    pub(crate) fn row_scale(&self) -> &[f32] {
        &self.row_scale
    }

    #[inline]
    pub(crate) fn r2_ns(&self) -> &[f32] {
        &self.r2_ns
    }

    #[inline]
    pub(crate) fn mu(&self) -> &[f32] {
        &self.mu
    }

    /// Exact stored bits under the paper's accounting conventions: sign
    /// bits + nibbles + the channel bitmap, plus fp16 for the per-column
    /// `(scale, min)` pairs and the three scaling vectors (`alpha_s`,
    /// `alpha_r1`, `alpha_r2`). `mu` is charged only when the Table 9
    /// variant actually uses it (any nonzero entry); derived operands
    /// (`row_scale`, the column index lists) are free — they fold into or
    /// re-derive from counted containers.
    pub fn storage_bits(&self) -> u64 {
        let signs: u64 =
            self.signs.iter().map(|v| v.storage_bits() as u64).sum();
        let codes = self.codes.storage_bits() as u64;
        let mask = self.mask.storage_bits() as u64;
        let col_params = 2 * 16 * self.col_scale.len() as u64;
        let mut vectors =
            16 * (self.alpha_s.len() + self.alpha_r1.len() + self.alpha_r2.len()) as u64;
        if self.mu.iter().any(|&x| x != 0.0) {
            vectors += 16 * self.mu.len() as u64;
        }
        signs + codes + mask + col_params + vectors
    }

    /// Effective bits per weight including every overhead term — the
    /// measured counterpart of the Appendix-A closed form.
    pub fn effective_bits(&self) -> f64 {
        self.storage_bits() as f64 / (self.out * self.inn) as f64
    }

    /// Actual resident heap bytes of this container (what the process
    /// pays to keep the layer servable, f32 vectors and index lists
    /// included — distinct from the fp16 accounting of
    /// [`Self::storage_bits`]).
    pub fn resident_bytes(&self) -> usize {
        let signs: usize =
            self.signs.iter().map(BitVec::storage_bytes_padded).sum();
        let codes = self.codes.len.div_ceil(2);
        let mask = self.mask.storage_bytes_padded();
        let f32s = self.col_scale.len()
            + self.col_min.len()
            + self.row_scale.len()
            + self.alpha_s.len()
            + self.alpha_r1.len()
            + self.alpha_r2.len()
            + self.r2_ns.len()
            + self.mu.len();
        let idx = self.sal_cols.len() + self.ns_cols.len();
        signs + codes + mask + 4 * (f32s + idx)
    }
}

/// Storage bits of one layer's parts under exactly the accounting of
/// [`PackedLinear::storage_bits`], computed from the shapes alone —
/// cheap enough for table labels, no containers built. Consistency with
/// the packed containers is gated by a unit test below.
pub fn parts_storage_bits(p: &Ptq161Parts) -> u64 {
    let n = p.sign_ns.rows() as u64;
    let m = p.sign_ns.cols() as u64;
    let s = p.n_salient() as u64;
    let mut bits = n * (m - s) + 4 * n * s + m + 2 * 16 * s + 16 * (2 * n + m);
    if p.mu.iter().any(|&x| x != 0.0) {
        bits += 16 * n;
    }
    bits
}

/// Fallback for parts without carried codes: re-quantize the salient
/// columns of the dequantized `w_sal`. Not guaranteed bit-exact (the
/// affine params are re-derived from floats); quantizer-produced parts
/// never take this path.
fn requantize_salient(w_sal: &Tensor, sal_cols: &[u32]) -> SalientQuant {
    let mut mask = vec![false; w_sal.cols()];
    for &j in sal_cols {
        mask[j as usize] = true;
    }
    crate::quant::rtn::quant4_columns_coded(w_sal, &mask).1
}

impl crate::quant::PackedContainer for PackedLinear {
    fn method(&self) -> &str {
        "ptq161"
    }

    fn out(&self) -> usize {
        self.out
    }

    fn inn(&self) -> usize {
        self.inn
    }

    fn storage_bits(&self) -> u64 {
        PackedLinear::storage_bits(self)
    }

    fn resident_bytes(&self) -> usize {
        PackedLinear::resident_bytes(self)
    }

    fn decode_fwd(&self, x: &Tensor) -> Tensor {
        crate::runtime::autodiff::packed_decode_fwd(x, self)
    }

    fn dequantize(&self) -> Tensor {
        self.unpack().dequantize()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packing::bitwidth::ptq161_packed_bits;
    use crate::quant::ptq161::initial_parts;
    use crate::util::rng::Rng;

    fn demo_parts(out: usize, inn: usize, seed: u64) -> Ptq161Parts {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[out, inn], 0.1, &mut rng);
        let mask: Vec<bool> = (0..inn).map(|j| j % 5 == 0).collect();
        let mut p = initial_parts(&w, &mask);
        // blockopt-like learned factors: exercise the non-identity paths
        for v in p.alpha_r1.iter_mut() {
            *v = 1.0 + 0.1 * rng.normal();
        }
        for v in p.alpha_r2.iter_mut() {
            *v = 1.0 + 0.1 * rng.normal();
        }
        p
    }

    #[test]
    fn pack_unpack_round_trips_bit_exactly() {
        let p = demo_parts(24, 40, 71);
        let packed = PackedLinear::pack(&p);
        let back = packed.unpack();
        assert_eq!(back.mask, p.mask);
        assert_eq!(back.w_sal.data, p.w_sal.data, "w_sal deviates");
        assert_eq!(back.sign_ns.data, p.sign_ns.data, "signs deviate");
        assert_eq!(back.alpha_s, p.alpha_s);
        assert_eq!(back.alpha_r1, p.alpha_r1);
        assert_eq!(back.alpha_r2, p.alpha_r2);
        assert_eq!(back.mu, p.mu);
        assert_eq!(back.sal_q, p.sal_q);
    }

    #[test]
    fn formula_matches_container_accounting() {
        // parts_storage_bits must track the containers exactly, with and
        // without the mu vector charged
        let mut p = demo_parts(24, 40, 79);
        assert_eq!(parts_storage_bits(&p), PackedLinear::pack(&p).storage_bits());
        p.mu[3] = 0.25;
        assert_eq!(parts_storage_bits(&p), PackedLinear::pack(&p).storage_bits());
    }

    #[test]
    fn storage_bits_match_packed_formula_on_square_layer() {
        // a square layer makes the (2n + m) vector accounting coincide
        // with the formula's 3n convention exactly
        let p = demo_parts(40, 40, 72);
        let packed = PackedLinear::pack(&p);
        let want = ptq161_packed_bits(40, 40, packed.n_salient());
        assert_eq!(packed.storage_bits(), want);
    }

    #[test]
    fn effective_bits_sub_two_at_scale_shape() {
        // 20% salient at a production-ish aspect ratio stays sub-2-bit
        let p = demo_parts(256, 320, 73);
        let packed = PackedLinear::pack(&p);
        let b = packed.effective_bits();
        assert!(b > 1.5 && b < 2.0, "effective bits {b}");
        // and the packed container is far smaller than the f32 dense form
        assert!(packed.resident_bytes() < 256 * 320 * 4 / 8);
    }

    #[test]
    fn ratio_zero_packs_without_salient_containers() {
        let mut rng = Rng::new(74);
        let w = Tensor::randn(&[16, 20], 0.1, &mut rng);
        let p = initial_parts(&w, &vec![false; 20]);
        let packed = PackedLinear::pack(&p);
        assert_eq!(packed.n_salient(), 0);
        let back = packed.unpack();
        assert_eq!(back.w_sal.data, p.w_sal.data);
        assert_eq!(back.sign_ns.data, p.sign_ns.data);
    }

    #[test]
    fn model_accounting_sums_layers() {
        use crate::quant::container::PackedModel;
        let parts = vec![
            vec![demo_parts(12, 16, 75), demo_parts(12, 16, 76)],
            vec![demo_parts(12, 16, 77), demo_parts(12, 16, 78)],
        ];
        let pm = PackedModel::pack(&parts);
        assert_eq!(pm.method(), "ptq161");
        assert_eq!(pm.n_layers(), 2);
        assert_eq!(pm.weights(), 4 * 12 * 16);
        let per: u64 =
            pm.layers.iter().flatten().map(|c| c.storage_bits()).sum();
        assert_eq!(pm.storage_bits(), per);
        assert!(pm.effective_bits() > 1.0);
    }

    #[test]
    fn trait_dequantize_matches_parts_dequantize() {
        use crate::quant::PackedContainer;
        let p = demo_parts(16, 24, 80);
        let packed = PackedLinear::pack(&p);
        let via_trait = PackedContainer::dequantize(&packed);
        assert_eq!(via_trait.data, p.dequantize().data);
        assert_eq!(PackedContainer::method(&packed), "ptq161");
    }
}
