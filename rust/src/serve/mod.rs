//! Serving layer: continuous-batching generation over the eval pipeline.
//!
//! * [`batcher`] — admission queue (FIFO, max-wait cut, deadlines)
//! * [`engine`] — slot-based continuous-batching decode loop (plus the
//!   drain/static baseline it is benchmarked against)
//! * [`metrics`] — per-request latency split, percentiles, lane occupancy,
//!   JSON export into `runs_dir()`
//!
//! At this scale the absolute numbers characterize the native CPU path
//! (the paper's F.3 discussion); the packed memory wins come from
//! packing::memory. The scheduling wins — lane refill beating batch drain
//! on skewed request lengths — are measured by `benches/bench_serve.rs`.

pub mod batcher;
pub mod engine;
pub mod metrics;

use anyhow::Result;

pub use engine::{Engine, EngineCfg};
pub use metrics::{percentile, MetricsRegistry, RequestMetric};

use crate::coordinator::Pipeline;
use crate::eval::ModelEval;

#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Byte-tokenized verbatim; an empty prompt is seeded with a single
    /// space token (the decoder needs at least one context position), so
    /// its response text starts with that space.
    pub prompt: String,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub new_tokens: usize,
    /// submit -> lane admission
    pub queue_ms: f64,
    /// lane admission -> last token
    pub decode_ms: f64,
    /// submit -> last token
    pub latency_ms: f64,
}

/// Greedy-generate for up to b_eval requests at once (legacy one-shot
/// contract, now a thin wrapper over the engine's drain mode). Responses
/// come back in request order.
pub fn generate_batch(
    pipe: &Pipeline,
    model: &ModelEval,
    requests: &[GenRequest],
) -> Result<Vec<GenResponse>> {
    let mut engine = Engine::new(pipe, model);
    let mut metrics = MetricsRegistry::new("generate_batch");
    engine.run_drain_batch(requests, &mut metrics)
}

