//! PTQ1.61 CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain    --model tiny --steps 400
//!   preprocess  --model tiny --steps 120
//!   quantize    --model tiny --method ptq161 [--preprocessed]
//!   eval        --model tiny --method ptq161 [--preprocessed] [--fused]
//!   serve       --model tiny --method ptq161 --requests 16 [--drain]
//!               [--no-kv] [--backend dense|fused|packed]
//!               (quick-scale by default; --full for the full pipeline;
//!               KV-cached incremental decode unless --no-kv; ptq161
//!               defaults to the prepared packed-container backend;
//!               writes runs/serve_metrics.json)
//!   experiment  <t1..t13|f1|f3..f7|appA|all> [--full]
//!   all         run every experiment (EXPERIMENTS.md regeneration)

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::quant::ptq161::PackedModel;
use ptq161::experiments::{self, ExperimentCtx};
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, MetricsRegistry};
use ptq161::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "pretrain" => {
            let mut ctx = ctx_from(&args)?;
            ctx.pretrain_steps = args.usize_opt("steps", ctx.pretrain_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.pretrained(&model)?;
            println!("pretrained {model}: {} params", p.total_params());
        }
        "preprocess" => {
            let mut ctx = ctx_from(&args)?;
            ctx.preprocess_steps = args.usize_opt("steps", ctx.preprocess_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.preprocessed(&model)?;
            println!("preprocessed {model}: {} params", p.total_params());
        }
        "quantize" | "eval" => {
            let mut ctx = ctx_from(&args)?;
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let pre = args.flag("preprocessed") || method == "ptq161";
            let qm = ctx.quantized(&model, &method, pre)?;
            println!(
                "quantized {model} with {} ({}): {:.3} bits/weight at 4096^2",
                qm.method, qm.bits_label, qm.avg_bits
            );
            if sub == "eval" {
                let wiki = ctx.ppl(&model, &qm.params, &ctx.wiki.clone())?;
                let c4 = ctx.ppl(&model, &qm.params, &ctx.c4.clone())?;
                println!("ppl wiki {wiki:.2}  c4 {c4:.2}");
                if args.flag("fused") {
                    let parts = qm.parts.as_ref().expect("fused path needs ptq161");
                    let pipe = Pipeline::new(&ctx.rt, &model)?;
                    let p = ptq161::eval::ppl::perplexity(
                        &pipe,
                        &ModelEval::Fused { params: &qm.params, parts },
                        &ctx.wiki,
                        ctx.ppl_batches,
                    )?;
                    println!("ppl wiki via fused Pallas-kernel path: {p:.2}");
                }
            }
        }
        "serve" => {
            // serving wants a ready model, not a long experiment: default
            // to quick-scale quantization unless --full is passed
            let mut ctx = if args.flag("full") {
                ExperimentCtx::new(true)?
            } else {
                ExperimentCtx::quick()?
            };
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let n = args.usize_opt("requests", 8);
            let qm = ctx.quantized(&model, &method, method == "ptq161")?;
            let pipe = Pipeline::new(&ctx.rt, &model)?;
            // backend choice: ptq161 serves from the prepared packed
            // containers by default (pack once here, decode forever);
            // --backend dense|fused selects the reconstruction baselines
            let backend = args.str_opt(
                "backend",
                if method == "ptq161" { "packed" } else { "dense" },
            );
            let packed = if backend == "packed" {
                let parts = qm.parts.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("--backend packed needs a ptq161 model")
                })?;
                let pm = PackedModel::pack(parts);
                println!(
                    "packed {} layers: {} KiB resident, {:.3} bits/weight",
                    pm.n_layers(),
                    pm.resident_bytes() / 1024,
                    pm.effective_bits()
                );
                Some(pm)
            } else {
                None
            };
            let me = match backend.as_str() {
                "dense" => ModelEval::Dense(&qm.params),
                "fused" => ModelEval::Fused {
                    params: &qm.params,
                    parts: qm.parts.as_ref().ok_or_else(|| {
                        anyhow::anyhow!("--backend fused needs a ptq161 model")
                    })?,
                },
                "packed" => ModelEval::Packed {
                    params: &qm.params,
                    packed: packed.as_ref().unwrap(),
                },
                other => {
                    anyhow::bail!("unknown backend '{other}' (dense|fused|packed)")
                }
            };
            let mut batcher = Batcher::new(pipe.cfg.b_eval);
            // skewed request lengths: the workload continuous batching is
            // built for (one long request no longer stalls three lanes)
            for i in 0..n {
                let max_new = if i % 4 == 3 { 48 } else { 6 };
                batcher.submit(GenRequest {
                    prompt: format!("the quiet river of alda {}", i % 3),
                    max_new_tokens: max_new,
                });
            }
            let label = if args.flag("drain") { "drain" } else { "continuous" };
            let mut metrics = MetricsRegistry::new(label);
            let mut engine = Engine::new(&pipe, &me);
            // KV-cached incremental decode is the default; --no-kv selects
            // the full-window baseline (token-identical, but per-step cost
            // grows with sequence position)
            engine.cfg.use_kv_cache = !args.flag("no-kv");
            let resps = if args.flag("drain") {
                engine.run_drain(&mut batcher, &mut metrics)?
            } else {
                engine.run(&mut batcher, &mut metrics)?
            };
            for r in &resps {
                let preview: String = r.text.chars().take(56).collect();
                println!(
                    "-> [{:>2}] +{:<3} tok  queue {:>5.0} ms  decode {:>6.0} ms  {preview:?}",
                    r.id, r.new_tokens, r.queue_ms, r.decode_ms
                );
            }
            metrics.print_summary();
            let path = ptq161::runs_dir().join("serve_metrics.json");
            metrics.write_json(&path)?;
            println!("metrics written to {}", path.display());
        }
        "experiment" | "all" => {
            let mut ctx = ctx_from(&args)?;
            let ids: Vec<String> = if sub == "all"
                || args.positional.first().map(String::as_str) == Some("all")
            {
                let mut v: Vec<String> = experiments::ALL_IDS
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                v.extend(experiments::EXTRA_IDS.iter().map(|s| s.to_string()));
                v.push("appA".into());
                v
            } else {
                args.positional.clone()
            };
            for id in ids {
                eprintln!("\n##### experiment {id} #####");
                experiments::run(&mut ctx, &id)?;
            }
        }
        _ => {
            println!(
                "usage: ptq161 <pretrain|preprocess|quantize|eval|serve|experiment|all> \
                 [--model tiny|small] [--method NAME] [--quick] [--full] ..."
            );
        }
    }
    Ok(())
}

fn ctx_from(args: &Args) -> Result<ExperimentCtx> {
    if args.flag("quick") {
        ExperimentCtx::quick()
    } else {
        ExperimentCtx::new(args.flag("full"))
    }
}
