//! Per-lane KV cache for incremental decode.
//!
//! The serve engine owns one [`KvCache`] sized to its lane pool: each lane
//! holds one *slot*, and a slot stores the roped attention keys and the
//! values of every layer for the positions that lane has already decoded.
//! A decode step then only runs the model over the *new* token positions —
//! the quadratic re-read of the window is replaced by one cached-K/V
//! attention pass, so per-token cost is flat in sequence position (the
//! deployment efficiency extreme low-bit PTQ exists to buy; see
//! `ARCHITECTURE.md`).
//!
//! Layout: one contiguous `f32` buffer per side (K and V), indexed as
//! `[slot][layer][position][head][head_dim]`. Rows for a new chunk are
//! written by [`KvCache::append`] layer by layer at the slot's current
//! length, and the length is bumped once per chunk by [`KvCache::advance`]
//! after *all* layers have appended (every layer of one forward must see
//! the same past length). [`KvCache::gather`] materializes the compacted
//! per-step batch the native decode kernels consume: K/V tensors covering
//! only the *live prefix* of the window plus the per-lane valid lengths
//! (the kernel never reads rows at or beyond a lane's length, so stale
//! rows need no zeroing and the dead tail is never copied).
//!
//! Slots are recycled through a free list: [`KvCache::alloc`] on lane
//! admission, [`KvCache::free`] when the lane finishes, and
//! [`KvCache::total_allocs`] counts lifetime allocations so tests can
//! assert that a finished lane's slot really is reused by the next
//! request.

use crate::tensor::Tensor;

/// Per-lane, per-layer K/V store for incremental decode (see the module
/// docs for the layout and the append/advance protocol).
#[derive(Debug)]
pub struct KvCache {
    n_layers: usize,
    heads: usize,
    head_dim: usize,
    capacity: usize,
    /// valid positions per slot (shared by all layers of that slot)
    lens: Vec<usize>,
    in_use: Vec<bool>,
    /// free slot ids, popped on alloc, pushed back on free
    free: Vec<usize>,
    allocs: u64,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// A cache with `slots` lanes, each holding `n_layers` layers of up to
    /// `capacity` positions of `heads * head_dim` features.
    pub fn new(
        slots: usize,
        n_layers: usize,
        capacity: usize,
        heads: usize,
        head_dim: usize,
    ) -> KvCache {
        assert!(slots > 0 && n_layers > 0 && capacity > 0);
        let total = slots * n_layers * capacity * heads * head_dim;
        KvCache {
            n_layers,
            heads,
            head_dim,
            capacity,
            lens: vec![0; slots],
            in_use: vec![false; slots],
            free: (0..slots).rev().collect(),
            allocs: 0,
            k: vec![0.0; total],
            v: vec![0.0; total],
        }
    }

    /// Elements of one cached position (heads * head_dim).
    fn row_elems(&self) -> usize {
        self.heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.capacity * self.row_elems()
    }

    fn base(&self, slot: usize, layer: usize) -> usize {
        (slot * self.n_layers + layer) * self.layer_stride()
    }

    /// Number of slots (== the engine's lane capacity).
    pub fn slots(&self) -> usize {
        self.in_use.len()
    }

    /// Maximum cached positions per slot (the model window).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Valid cached positions of `slot`.
    pub fn len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    /// Slots currently allocated to live lanes.
    pub fn in_use_count(&self) -> usize {
        self.in_use.iter().filter(|&&b| b).count()
    }

    /// Lifetime allocation count — strictly greater than [`Self::slots`]
    /// once freed slots have been reused.
    pub fn total_allocs(&self) -> u64 {
        self.allocs
    }

    /// Resident size of the K+V buffers in bytes (capacity, not fill).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    /// Claim a free slot (length reset to 0), or `None` when every slot is
    /// held by a live lane.
    pub fn alloc(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot]);
        self.in_use[slot] = true;
        self.lens[slot] = 0;
        self.allocs += 1;
        Some(slot)
    }

    /// Return `slot` to the free list; its contents become dead rows that
    /// the next owner overwrites from position 0.
    pub fn free(&mut self, slot: usize) {
        assert!(self.in_use[slot], "freeing a slot that is not in use");
        self.in_use[slot] = false;
        self.lens[slot] = 0;
        self.free.push(slot);
    }

    /// Write one layer's K/V rows for a new chunk at the slot's current
    /// length. `k_rows`/`v_rows` are `t_new * heads * head_dim` elements
    /// (one compacted-batch row of the kernel's `k_new`/`v_new` outputs).
    /// The length is *not* bumped — call [`Self::advance`] once after all
    /// layers appended.
    pub fn append(&mut self, slot: usize, layer: usize, k_rows: &[f32], v_rows: &[f32]) {
        assert!(self.in_use[slot], "append to a free slot");
        assert_eq!(k_rows.len(), v_rows.len());
        let re = self.row_elems();
        assert_eq!(k_rows.len() % re, 0, "append: ragged rows");
        let t_new = k_rows.len() / re;
        let len = self.lens[slot];
        assert!(
            len + t_new <= self.capacity,
            "KV slot overflow: {len} + {t_new} > {}",
            self.capacity
        );
        let at = self.base(slot, layer) + len * re;
        self.k[at..at + k_rows.len()].copy_from_slice(k_rows);
        self.v[at..at + v_rows.len()].copy_from_slice(v_rows);
    }

    /// Bump `slot`'s valid length by `t_new` after every layer appended
    /// its rows for the chunk.
    pub fn advance(&mut self, slot: usize, t_new: usize) {
        assert!(self.lens[slot] + t_new <= self.capacity, "advance past capacity");
        self.lens[slot] += t_new;
    }

    /// Materialize one layer's cached K/V for a compacted batch of slots:
    /// `(k, v, lens)` with `lens[i]` the valid positions of `slots[i]`.
    ///
    /// Only the *live prefix* is copied: `k`/`v` come back as
    /// `(slots.len(), upto, heads, head_dim)` where `upto = max(lens) +
    /// headroom`, clamped to the window capacity — a one-token decode step
    /// passes `headroom = 1` and never pays for the dead tail of the
    /// window (the `_decode` bases accept the shrunk time axis). Rows at
    /// or beyond `lens[i]` are dead and must not be read.
    pub fn gather(
        &self,
        layer: usize,
        slots: &[usize],
        headroom: usize,
    ) -> (Tensor, Tensor, Vec<usize>) {
        let b = slots.len();
        let lens: Vec<usize> = slots
            .iter()
            .map(|&slot| {
                assert!(self.in_use[slot], "gather from a free slot");
                self.lens[slot]
            })
            .collect();
        let max_len = lens.iter().max().copied().unwrap_or(0);
        let upto = (max_len + headroom).clamp(1, self.capacity);
        let re = self.row_elems();
        let shape = [b, upto, self.heads, self.head_dim];
        let mut k = Tensor::zeros(&shape);
        let mut v = Tensor::zeros(&shape);
        for (row, &slot) in slots.iter().enumerate() {
            let at = self.base(slot, layer);
            k.data[row * upto * re..(row + 1) * upto * re]
                .copy_from_slice(&self.k[at..at + upto * re]);
            v.data[row * upto * re..(row + 1) * upto * re]
                .copy_from_slice(&self.v[at..at + upto * re]);
        }
        (k, v, lens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuses_slots() {
        let mut c = KvCache::new(2, 1, 4, 1, 2);
        let a = c.alloc().unwrap();
        let b = c.alloc().unwrap();
        assert_ne!(a, b);
        assert!(c.alloc().is_none(), "pool exhausted");
        assert_eq!(c.in_use_count(), 2);
        c.free(a);
        let a2 = c.alloc().unwrap();
        assert_eq!(a2, a, "freed slot is reused");
        assert_eq!(c.total_allocs(), 3);
    }

    #[test]
    fn append_advance_gather_round_trip() {
        // 1 slot, 2 layers, capacity 3, 1 head of dim 2
        let mut c = KvCache::new(1, 2, 3, 1, 2);
        let s = c.alloc().unwrap();
        // chunk of 2 positions: both layers append, then one advance
        c.append(s, 0, &[1.0, 2.0, 3.0, 4.0], &[-1.0, -2.0, -3.0, -4.0]);
        c.append(s, 1, &[5.0, 6.0, 7.0, 8.0], &[-5.0, -6.0, -7.0, -8.0]);
        c.advance(s, 2);
        assert_eq!(c.len(s), 2);
        let (k0, v0, lens) = c.gather(0, &[s], 1);
        // live prefix only: 2 cached + 1 headroom = 3 positions
        assert_eq!(k0.shape, vec![1, 3, 1, 2]);
        assert_eq!(lens, vec![2]);
        assert_eq!(&k0.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&v0.data[..4], &[-1.0, -2.0, -3.0, -4.0]);
        let (k1, _, _) = c.gather(1, &[s], 1);
        assert_eq!(&k1.data[..4], &[5.0, 6.0, 7.0, 8.0]);
        // one more position lands after the first chunk
        c.append(s, 0, &[9.0, 10.0], &[0.0, 0.0]);
        c.append(s, 1, &[11.0, 12.0], &[0.0, 0.0]);
        c.advance(s, 1);
        let (k0, _, lens) = c.gather(0, &[s], 0);
        assert_eq!(lens, vec![3]);
        assert_eq!(&k0.data[4..6], &[9.0, 10.0]);
        // headroom past the window clamps to capacity
        let (k0, _, _) = c.gather(0, &[s], 5);
        assert_eq!(k0.shape, vec![1, 3, 1, 2]);
    }

    #[test]
    fn gather_orders_rows_by_request() {
        let mut c = KvCache::new(3, 1, 2, 1, 1);
        let s0 = c.alloc().unwrap();
        let s1 = c.alloc().unwrap();
        c.append(s0, 0, &[1.0], &[1.0]);
        c.advance(s0, 1);
        c.append(s1, 0, &[2.0], &[2.0]);
        c.advance(s1, 1);
        // batch order is the caller's order, not slot order; rows are
        // (1 cached + 1 headroom) wide
        let (k, _, lens) = c.gather(0, &[s1, s0], 1);
        assert_eq!(k.shape, vec![2, 2, 1, 1]);
        assert_eq!(k.data[0], 2.0);
        assert_eq!(k.data[2], 1.0);
        assert_eq!(lens, vec![1, 1]);
    }

    #[test]
    #[should_panic]
    fn append_past_capacity_panics() {
        let mut c = KvCache::new(1, 1, 2, 1, 1);
        let s = c.alloc().unwrap();
        c.append(s, 0, &[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn freed_slot_restarts_at_zero() {
        let mut c = KvCache::new(1, 1, 4, 1, 1);
        let s = c.alloc().unwrap();
        c.append(s, 0, &[1.0, 2.0], &[1.0, 2.0]);
        c.advance(s, 2);
        c.free(s);
        let s2 = c.alloc().unwrap();
        assert_eq!(c.len(s2), 0, "reused slot starts empty");
    }
}
