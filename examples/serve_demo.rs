//! Serving demo: batched greedy generation from the quantized model with
//! latency/throughput reporting (paper section F) plus the packed-memory
//! comparison of Table 12.
//!
//!   cargo run --release --example serve_demo

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::experiments::ExperimentCtx;
use ptq161::packing::bitwidth::BitScheme;
use ptq161::packing::memory::table12_row;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{generate_batch, GenRequest, ServeStats};

fn main() -> Result<()> {
    let mut ctx = ExperimentCtx::quick()?;
    let qm = ctx.quantized("tiny", "ptq161", true)?;
    let pipe = Pipeline::new(&ctx.rt, "tiny")?;

    let prompts = [
        "the quiet river of alda holds the ",
        "key boris is ",
        "3 plus 4 equals ",
        "the golden tower of celia ",
        "you know darin finds a ",
        "in the end it was the ",
        "the ancient engine of elena ",
        "key mira is ",
    ];
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for p in prompts {
        batcher.submit(GenRequest { prompt: p.into(), max_new_tokens: 12 });
    }
    let mut stats = ServeStats::default();
    let model = ModelEval::Dense(&qm.params);
    while let Some(batch) = batcher.next_batch() {
        let reqs: Vec<GenRequest> =
            batch.iter().map(|(_, r)| r.clone()).collect();
        let t0 = std::time::Instant::now();
        let resps = generate_batch(&pipe, &model, &reqs)?;
        stats.total_ms += t0.elapsed().as_secs_f64() * 1000.0;
        for r in resps {
            println!("-> {}", r.text.replace('\n', " "));
            stats.requests += 1;
            stats.total_new_tokens += r.new_tokens;
            stats.per_request_ms.push(r.latency_ms);
        }
    }
    println!(
        "\nserved {} requests | throughput {:.1} tok/s | p50 {:.0} ms | p95 {:.0} ms",
        stats.requests,
        stats.throughput_tok_s(),
        stats.p50_ms(),
        stats.p95_ms()
    );

    println!("\npacked checkpoint sizes at real LLaMA shapes (Table 12):");
    for (label, scheme) in [
        ("PB-LLM ", BitScheme::PbLlm { salient_ratio: 0.1 }),
        ("BiLLM  ", BitScheme::BiLlm),
        ("PTQ1.61", BitScheme::Ptq161 { salient_ratio: 0.2 }),
    ] {
        let (gb7, gb13) = table12_row(scheme);
        println!("  {label}  7B {gb7:.2} GiB   13B {gb13:.2} GiB");
    }
    Ok(())
}
