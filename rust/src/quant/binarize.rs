//! Binarization primitives (paper Eq. 2): sign() with the analytic
//! XNOR-Net row scaling factor alpha = |w|_1 / n, with or without a
//! salient-column mask. The "no improvements" ablation row of Table 3 is
//! `PlainBinarize`.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::tensor::Tensor;

/// Row-wise analytic binarization restricted to non-salient columns.
/// Returns (sign_ns, alpha) with sign zeroed on salient columns — matches
/// kernels/ref.py binarize_rowwise_ref.
pub fn binarize_rowwise(w: &Tensor, mask: &[bool]) -> (Tensor, Vec<f32>) {
    let (n, m) = (w.rows(), w.cols());
    assert_eq!(m, mask.len());
    let ns_cnt = mask.iter().filter(|&&b| !b).count().max(1) as f32;
    let mut sign = Tensor::zeros(&[n, m]);
    let mut alpha = vec![0.0f32; n];
    for i in 0..n {
        let wrow = w.row(i);
        let srow = sign.row_mut(i);
        let mut asum = 0.0;
        for j in 0..m {
            if !mask[j] {
                srow[j] = if wrow[j] >= 0.0 { 1.0 } else { -1.0 };
                asum += wrow[j].abs();
            }
        }
        alpha[i] = asum / ns_cnt;
    }
    (sign, alpha)
}

/// Dense dequant of a plain row-binarized weight: alpha * sign(w).
pub fn binarize_dense(w: &Tensor) -> Tensor {
    let mask = vec![false; w.cols()];
    let (sign, alpha) = binarize_rowwise(w, &mask);
    let mut out = sign;
    for i in 0..out.rows() {
        let a = alpha[i];
        for x in out.row_mut(i) {
            *x *= a;
        }
    }
    out
}

/// Table 3 row 1: straight binarization, no mask, analytic scalars.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlainBinarize;

impl Quantizer for PlainBinarize {
    fn name(&self) -> &'static str {
        "Binarize"
    }

    fn bits_label(&self) -> String {
        "1".into()
    }

    fn quantize_linear(&self, w: &Tensor, _calib: &LinearCalib) -> QuantizedLinear {
        QuantizedLinear {
            deq: binarize_dense(w),
            scheme: BitScheme::Uniform { bits: 1.0 },
            parts: None,
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn alpha_is_l1_mean() {
        let w = Tensor::from_vec(&[1, 4], vec![1.0, -2.0, 3.0, -4.0]);
        let (sign, alpha) = binarize_rowwise(&w, &[false; 4]);
        assert_eq!(sign.data, vec![1.0, -1.0, 1.0, -1.0]);
        assert!((alpha[0] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn alpha_minimizes_l2_among_scalars() {
        // XNOR-Net: alpha = mean|w| is the L2-optimal scalar for sign(w)
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[1, 64], 1.0, &mut rng);
        let (_, alpha) = binarize_rowwise(&w, &[false; 64]);
        let err = |a: f32| -> f32 {
            w.row(0)
                .iter()
                .map(|&x| {
                    let s = if x >= 0.0 { a } else { -a };
                    (x - s) * (x - s)
                })
                .sum()
        };
        let e_opt = err(alpha[0]);
        for da in [-0.1f32, -0.01, 0.01, 0.1] {
            assert!(err(alpha[0] + da) >= e_opt - 1e-5);
        }
    }

    #[test]
    fn masked_columns_excluded() {
        let w = Tensor::from_vec(&[1, 4], vec![100.0, -2.0, 3.0, -4.0]);
        let mask = vec![true, false, false, false];
        let (sign, alpha) = binarize_rowwise(&w, &mask);
        assert_eq!(sign.at2(0, 0), 0.0);
        assert!((alpha[0] - 3.0).abs() < 1e-6); // mean of |{-2,3,-4}|
    }

    #[test]
    fn dense_dequant_signs() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let d = binarize_dense(&w);
        for i in 0..8 {
            for j in 0..16 {
                assert_eq!(d.at2(i, j) >= 0.0, w.at2(i, j) >= 0.0);
            }
        }
    }
}
