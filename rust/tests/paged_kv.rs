//! Paged KV cache + batched-prefill engine tests (tier-1, no artifacts
//! needed): shared-prefix page adoption must keep every backend
//! token-identical to the non-paged full-window baseline, divergence
//! mid-page must copy-on-write-split instead of corrupting a sibling's
//! prefix, pool exhaustion must backpressure admission (not fail it),
//! and same-length prompts must prefill as one chunked forward.

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::{initial_parts, PackedModel};
use ptq161::quant::Ptq161Parts;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, GenResponse, MetricsRegistry};

/// PTQ1.61 parts for every linear of every layer with a fixed structured
/// mask (every 4th input channel salient).
fn fused_parts(params: &Params, pipe: &Pipeline) -> Vec<Vec<Ptq161Parts>> {
    (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> = (0..w.cols()).map(|j| j % 4 == 0).collect();
                    initial_parts(w, &mask)
                })
                .collect()
        })
        .collect()
}

/// Run one workload through the engine; responses sorted by request id.
fn run_engine(
    pipe: &Pipeline,
    me: &ModelEval,
    reqs: &[GenRequest],
    kv: bool,
    geometry: Option<(usize, Option<usize>)>,
) -> (Vec<GenResponse>, MetricsRegistry, usize) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new("paged_test");
    let mut engine = match geometry {
        Some((ps, pages)) => Engine::with_cache_geometry(pipe, me, ps, pages),
        None => Engine::new(pipe, me),
    };
    engine.cfg.use_kv_cache = kv;
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), reqs.len(), "lost requests");
    let in_use = engine.kv_cache().in_use_count();
    assert_eq!(engine.kv_cache().live_pages(), 0, "leaked pages at drain");
    (resps, metrics, in_use)
}

/// Shared-system-prompt workload: every prompt opens with the same
/// 18-byte head (more than one default 16-position page), so lanes
/// admitted after the first wave adopt the registered prefix page.
fn shared_prefix_requests() -> Vec<GenRequest> {
    let lens = [6usize, 1, 2, 1, 3];
    lens.iter()
        .enumerate()
        .map(|(i, &n)| GenRequest {
            prompt: format!("SYSTEM: be terse. {i}"),
            max_new_tokens: n,
        })
        .collect()
}

#[test]
fn shared_prefix_token_identical_across_backends() {
    // paged decode with prefix adoption must reproduce the non-paged
    // full-window baseline byte-for-byte on every weight representation
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(81);
    let parts = fused_parts(&params, &pipe);
    let packed = PackedModel::pack(&parts);
    let reqs = shared_prefix_requests();
    let backends: Vec<(&str, ModelEval)> = vec![
        ("dense", ModelEval::Dense(&params)),
        ("fused", ModelEval::Fused { params: &params, parts: &parts }),
        ("packed", ModelEval::Packed { params: &params, packed: &packed }),
    ];
    for (name, me) in &backends {
        let (full, _, _) = run_engine(&pipe, me, &reqs, false, None);
        let (paged, metrics, in_use) = run_engine(&pipe, me, &reqs, true, None);
        assert_eq!(in_use, 0, "{name}: leaked cache lanes");
        for (f, p) in full.iter().zip(&paged) {
            assert_eq!(f.id, p.id);
            assert_eq!(
                f.text, p.text,
                "{name}: request {} tokens diverge from full-window",
                f.id
            );
        }
        // later admissions adopted the first wave's registered page
        assert!(
            metrics.prefix_reused_positions > 0,
            "{name}: no shared-prefix adoption happened"
        );
        assert!(metrics.prefix_hit_rate() > 0.0);
    }
}

#[test]
fn shared_prefix_live_bytes_stay_below_full_windows() {
    // the acceptance shape: N requests with a common system prompt keep
    // peak live KV bytes strictly below N x per-lane full-window bytes,
    // with a nonzero prefix hit rate in the exported metrics JSON
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(82);
    let me = ModelEval::Dense(&params);
    let reqs = shared_prefix_requests();
    let (_, metrics, _) = run_engine(&pipe, &me, &reqs, true, None);
    let cfg = &pipe.cfg;
    let window_bytes =
        cfg.n_layers * cfg.seq * cfg.d * 2 * std::mem::size_of::<f32>();
    let live = metrics.kv_live_bytes.unwrap();
    assert!(live > 0);
    assert!(
        live < reqs.len() * window_bytes,
        "live {live} must undershoot {} full windows ({} B)",
        reqs.len(),
        reqs.len() * window_bytes
    );
    assert!(metrics.prefix_hit_rate() > 0.0, "hit rate must be nonzero");
    // the non-vacuous sharing gate: the same workload with the shared
    // head broken (request index FIRST, so no whole-page prefix matches)
    // must physically allocate strictly more pages — adopted pages are
    // referenced, never allocated, and page_allocs is scheduling-
    // independent for a fixed workload, unlike the live-bytes peak
    let unique: Vec<GenRequest> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| GenRequest {
            prompt: format!("{i} SYSTEM: be terse."),
            max_new_tokens: r.max_new_tokens,
        })
        .collect();
    let (_, unshared, _) = run_engine(&pipe, &me, &unique, true, None);
    assert_eq!(unshared.prefix_reused_positions, 0, "control must not share");
    assert!(
        metrics.kv_page_allocs.unwrap() < unshared.kv_page_allocs.unwrap(),
        "sharing must allocate strictly fewer pages: {} vs {}",
        metrics.kv_page_allocs.unwrap(),
        unshared.kv_page_allocs.unwrap()
    );
    // and the snapshot carries the same story
    let json = metrics.snapshot().dump();
    let back = ptq161::util::json::Json::parse(&json).unwrap();
    assert_eq!(
        back.get("kv_live_bytes").and_then(|v| v.as_usize()),
        Some(live)
    );
    assert!(
        back.get("prefix_hit_rate").and_then(|v| v.as_f64()).unwrap() > 0.0
    );
}

#[test]
fn divergence_mid_page_cow_splits_in_engine() {
    // request 0 (long-lived) registers a full 16-token page; request 2's
    // prompt is exactly those 16 tokens, so adoption caps at 15 positions
    // (mid-page) and its first append must CoW-split the shared page —
    // while request 0 keeps decoding from the original
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(83);
    let me = ModelEval::Dense(&params);
    let head = "0123456789abcdef"; // exactly one default page
    let reqs = vec![
        GenRequest { prompt: format!("{head}-tail"), max_new_tokens: 10 },
        GenRequest { prompt: "filler".into(), max_new_tokens: 1 },
        GenRequest { prompt: head.into(), max_new_tokens: 2 },
    ];
    let (full, _, _) = run_engine(&pipe, &me, &reqs, false, None);
    let (paged, metrics, _) = run_engine(&pipe, &me, &reqs, true, None);
    for (f, p) in full.iter().zip(&paged) {
        assert_eq!(f.text, p.text, "request {} diverges under CoW", f.id);
    }
    assert!(
        metrics.prefix_reused_positions >= 15,
        "request 2 must adopt 15 positions, saw {}",
        metrics.prefix_reused_positions
    );
    assert!(
        metrics.kv_cow_splits.unwrap() >= 1,
        "mid-page divergence must copy-on-write split"
    );
}

#[test]
fn pool_exhaustion_backpressures_admission() {
    // a pool of exactly one window serializes admission: every request
    // still completes, and the deferrals are visible in the metrics
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(84);
    let me = ModelEval::Dense(&params);
    let lens = [4usize, 3, 2];
    let reqs: Vec<GenRequest> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| GenRequest {
            // long prompts: each request needs both pages of the pool
            prompt: format!("a twenty char prompt {i}"),
            max_new_tokens: n,
        })
        .collect();
    let (resps, metrics, in_use) =
        run_engine(&pipe, &me, &reqs, true, Some((16, Some(2))));
    assert_eq!(in_use, 0);
    for (r, &want) in resps.iter().zip(&lens) {
        assert_eq!(r.new_tokens, want, "request {} token count", r.id);
    }
    assert!(
        metrics.kv_backpressure_events > 0,
        "an exhausted pool must defer admissions"
    );
    // the paged run is still token-identical to the unconstrained one
    let (free, _, _) = run_engine(&pipe, &me, &reqs, true, None);
    for (a, b) in resps.iter().zip(&free) {
        assert_eq!(a.text, b.text, "backpressure changed request {}", a.id);
    }
}

#[test]
fn batched_prefill_runs_one_forward_per_length_bucket() {
    // two same-length prompts admitted together must prefill as ONE
    // chunked forward: embed_fwd_decode executions equal decode steps,
    // with no extra per-lane prefill call
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(85);
    let me = ModelEval::Dense(&params);
    let count = |name: &str| -> u64 {
        rt.exec_counts.lock().unwrap().get(name).copied().unwrap_or(0)
    };
    let embed = "embed_fwd_decode_micro";
    let reqs: Vec<GenRequest> = (0..2)
        .map(|i| GenRequest { prompt: format!("same len {i}"), max_new_tokens: 3 })
        .collect();
    let e0 = count(embed);
    let (resps, metrics, _) = run_engine(&pipe, &me, &reqs, true, None);
    let embeds = count(embed) - e0;
    assert_eq!(resps.len(), 2);
    // lockstep lanes: 3 steps total (prefill emits token 1), one batched
    // forward each — the per-lane b=1 prefill loop would have taken 4
    assert_eq!(metrics.steps, 3, "steps {}", metrics.steps);
    assert_eq!(
        embeds, 3,
        "same-length prompts must share one prefill forward"
    );
    // different-length prompts split into two buckets on the first step
    let reqs: Vec<GenRequest> = [("short", 3usize), ("a longer prompt", 3)]
        .iter()
        .map(|&(p, n)| GenRequest { prompt: p.into(), max_new_tokens: n })
        .collect();
    let e0 = count(embed);
    let (resps, metrics, _) = run_engine(&pipe, &me, &reqs, true, None);
    let embeds = count(embed) - e0;
    assert_eq!(resps.len(), 2);
    assert_eq!(metrics.steps, 3);
    assert_eq!(embeds, 4, "two length buckets on the prefill step");
}

#[test]
fn undersized_pool_is_floored_at_one_window() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(86);
    let me = ModelEval::Dense(&params);
    // ask for a 1-page pool; the engine must floor it so a maximal
    // request stays admissible (micro window = 2 default pages)
    let engine = Engine::with_cache_geometry(&pipe, &me, 16, Some(1));
    assert_eq!(engine.kv_cache().total_pages(), 2);
    assert_eq!(engine.kv_cache().page_size(), 16);
}
