//! Quickstart: load (or pretrain) a tiny model, quantize it to 1.61-bit
//! with PTQ1.61, and compare perplexity against the FP model — including
//! through the fused Pallas-kernel path that a real deployment would run.
//!
//!   make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ppl::perplexity;
use ptq161::eval::ModelEval;
use ptq161::experiments::ExperimentCtx;

fn main() -> Result<()> {
    let mut ctx = ExperimentCtx::quick()?;
    let model = "tiny";

    // 1. a pretrained starting point (cached under runs/)
    let fp = ctx.pretrained(model)?;
    println!("model '{model}': {} parameters", fp.total_params());

    // 2. PTQ1.61: structured mask + block-wise learned scaling factors on
    //    the preprocessed (restorative-LoRA) checkpoint
    let qm = ctx.quantized(model, "ptq161", true)?;
    println!(
        "quantized with {} -> {:.3} effective bits/weight (4096^2 layer)",
        qm.method, qm.avg_bits
    );

    // 3. evaluate: FP vs fake-quant dense vs the fused kernel path
    let fp_ppl = ctx.ppl(model, &fp, &ctx.wiki.clone())?;
    let q_ppl = ctx.ppl(model, &qm.params, &ctx.wiki.clone())?;
    let pipe = Pipeline::new(&ctx.rt, model)?;
    let fused_ppl = perplexity(
        &pipe,
        &ModelEval::Fused {
            params: &qm.params,
            parts: qm.parts.as_ref().expect("ptq161 carries parts"),
        },
        &ctx.wiki,
        ctx.ppl_batches,
    )?;
    println!("ppl (wiki): FP {fp_ppl:.2} | PTQ1.61 dense {q_ppl:.2} | fused kernel {fused_ppl:.2}");
    assert!((q_ppl - fused_ppl).abs() < 0.05, "kernel path must agree");
    Ok(())
}
