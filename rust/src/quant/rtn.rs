//! Round-to-nearest (RTN) quantization — the primitive every other method
//! builds on: per-row asymmetric b-bit quantize/dequantize with optional
//! clipping, plus per-column 4-bit (the salient-channel format).

use super::{LinearCalib, QuantizedLinear, Quantizer, SalientQuant};
use crate::packing::bitwidth::BitScheme;
use crate::quant::container::IntPacked;
use crate::tensor::Tensor;

/// Quantize one row to `bits` asymmetric with a clip factor on the range;
/// returns the dequantized row in place.
pub fn rtn_row(row: &mut [f32], bits: u32, clip: f32) {
    let mut codes = Vec::new();
    rtn_row_coded(row, bits, clip, &mut codes);
}

/// [`rtn_row`] that also emits the integer codes and the `(scale, min)`
/// affine pair they decode with — the bit-exact source for the packed
/// [`crate::quant::container::IntPacked`] container (the dequantized row
/// is exactly `code * scale + min` elementwise).
pub fn rtn_row_coded(
    row: &mut [f32],
    bits: u32,
    clip: f32,
    codes: &mut Vec<u16>,
) -> (f32, f32) {
    let qmax = ((1u32 << bits) - 1) as f32;
    let mn0 = row.iter().cloned().fold(f32::INFINITY, f32::min);
    let mx0 = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    // LWC-style clipping: scale the range end-points toward zero, which is
    // what tames magnitude outliers (the center of mass of LLM weight rows
    // is ~0, so zero-anchored and center-anchored clipping coincide there).
    let mn = mn0 * clip;
    let mx = mx0 * clip;
    let scale = ((mx - mn) / qmax).max(1e-8);
    for x in row.iter_mut() {
        let q = ((*x - mn) / scale).round().clamp(0.0, qmax);
        codes.push(q as u16);
        *x = q * scale + mn;
    }
    (scale, mn)
}

/// Dense per-row RTN dequantized copy.
pub fn rtn_dense(w: &Tensor, bits: u32, clip: f32) -> Tensor {
    let mut out = w.clone();
    for r in 0..out.rows() {
        rtn_row(out.row_mut(r), bits, clip);
    }
    out
}

/// Per-column (input-channel) 4-bit — matches kernels/ref.py quant4_ref.
pub fn quant4_columns(w: &Tensor, cols: &[bool]) -> Tensor {
    quant4_columns_coded(w, cols).0
}

/// [`quant4_columns`] that also returns the INT4 container (codes +
/// per-column affine params, salient columns in ascending order) the
/// dequantized result was decoded from — the bit-exact source for
/// [`crate::quant::ptq161::packed::PackedLinear`].
pub fn quant4_columns_coded(w: &Tensor, cols: &[bool]) -> (Tensor, SalientQuant) {
    let (n, m) = (w.rows(), w.cols());
    assert_eq!(m, cols.len());
    let mut out = w.clone();
    let mut sq = SalientQuant { codes: Vec::new(), scale: Vec::new(), min: Vec::new() };
    for j in 0..m {
        if !cols[j] {
            continue;
        }
        let mut col: Vec<f32> = (0..n).map(|i| w.at2(i, j)).collect();
        let (codes, scale, mn) = crate::packing::nibble::quantize_column(&col);
        for (i, &c) in codes.iter().enumerate() {
            col[i] = c as f32 * scale + mn;
        }
        for i in 0..n {
            *out.at2_mut(i, j) = col[i];
        }
        sq.codes.extend_from_slice(&codes);
        sq.scale.push(scale);
        sq.min.push(mn);
    }
    (out, sq)
}

/// The RTN baseline method (per-row asymmetric, no calibration use).
#[derive(Debug, Clone, Copy)]
pub struct Rtn {
    pub bits: u32,
}

impl Rtn {
    pub fn new(bits: u32) -> Rtn {
        Rtn { bits }
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> &'static str {
        "RTN"
    }

    fn bits_label(&self) -> String {
        format!("{}", self.bits)
    }

    fn quantize_linear(&self, w: &Tensor, _calib: &LinearCalib) -> QuantizedLinear {
        let mut deq = w.clone();
        let n = deq.rows();
        let mut codes = Vec::with_capacity(n * deq.cols());
        let mut row_scale = Vec::with_capacity(n);
        let mut row_min = Vec::with_capacity(n);
        for r in 0..n {
            let (scale, mn) = rtn_row_coded(deq.row_mut(r), self.bits, 1.0, &mut codes);
            row_scale.push(scale);
            row_min.push(mn);
        }
        let container = IntPacked::new(
            &format!("rtn{}", self.bits),
            self.bits,
            codes,
            row_scale,
            row_min,
            &deq,
        );
        QuantizedLinear {
            deq,
            scheme: BitScheme::Uniform { bits: self.bits as f64 },
            parts: None,
            container: Some(std::sync::Arc::new(container)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::demo;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn rtn_error_bound_property() {
        check(
            "rtn-row-error-le-half-scale",
            50,
            |r: &mut Rng| {
                let n = r.below(100) + 2;
                (0..n).map(|_| r.normal()).collect::<Vec<f32>>()
            },
            |xs| {
                for bits in [2u32, 3, 4, 8] {
                    let mut q = xs.clone();
                    rtn_row(&mut q, bits, 1.0);
                    let mn = xs.iter().cloned().fold(f32::INFINITY, f32::min);
                    let mx =
                        xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let scale = (mx - mn) / ((1u32 << bits) - 1) as f32;
                    for (x, y) in xs.iter().zip(&q) {
                        if (x - y).abs() > scale / 2.0 + 1e-5 {
                            return Err(format!(
                                "bits={bits} err={} scale={scale}",
                                x - y
                            ));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_bits_less_error() {
        let (w, calib) = demo(32, 64, 1);
        let e2 = Rtn::new(2).quantize_linear(&w, &calib).deq.mse(&w);
        let e4 = Rtn::new(4).quantize_linear(&w, &calib).deq.mse(&w);
        let e8 = Rtn::new(8).quantize_linear(&w, &calib).deq.mse(&w);
        assert!(e2 > e4 && e4 > e8, "{e2} {e4} {e8}");
    }

    #[test]
    fn quant4_only_touches_selected_columns() {
        let (w, _) = demo(16, 8, 2);
        let cols = vec![true, false, true, false, false, false, false, false];
        let q = quant4_columns(&w, &cols);
        for i in 0..16 {
            assert_eq!(q.at2(i, 1), w.at2(i, 1));
            assert_eq!(q.at2(i, 4), w.at2(i, 4));
        }
        assert!(q.data != w.data);
    }

    #[test]
    fn clip_tightens_range() {
        let mut a = vec![-10.0, -0.1, 0.0, 0.1, 10.0];
        let mut b = a.clone();
        rtn_row(&mut a, 2, 1.0);
        rtn_row(&mut b, 2, 0.5);
        // with clip the small values are represented better
        assert!((b[1] - (-0.1)).abs() <= (a[1] - (-0.1)).abs() + 1e-6);
    }
}
