//! Serve-engine bench: scheduling (drain vs continuous) and decode-path
//! (full-window vs KV-cached) comparisons, with a correctness gate.
//!
//! Part 1 replays one skewed request-length workload through three
//! configurations — static drain batching, continuous batching over the
//! full-window forward, and continuous batching with the KV cache — and
//! asserts all three produce token-identical responses (greedy decode is
//! per-lane deterministic, so scheduling and caching must not change a
//! single token).
//!
//! Part 2 decodes long sequences and reports per-step wall time early vs
//! late in the sequence: the full-window path grows with position (each
//! step re-runs the whole window), the KV-cached path stays roughly flat
//! (each step runs one token against cached K/V).
//!
//! Part 3 compares the PTQ1.61 decode backends: the fused path (rebuilds
//! the dense Wq' from six part tensors every forward) against the
//! prepared packed path (contracts the 1.61-bit containers directly).
//! Both must emit identical tokens, the packed run must perform zero
//! `qlinear_weight` reconstructions inside the decode loop, and its
//! per-step time is reported against the fused-cached path.
//!
//! Part 4 replays a shared-system-prompt workload through the paged
//! cache: peak live KV bytes must stay strictly below N x per-lane
//! full-window bytes with a nonzero prefix-hit rate, token-identical to
//! the non-paged full-window baseline.
//!
//! Part 5 shards the same packed-backend serve across 1/2/4 OS workers
//! (`run_sharded`): responses must stay byte-identical for every worker
//! count, and on a host with >= 4 cores the 4-worker deployment must
//! clear 1.5x the single-worker throughput.
//!
//! Part 6 exercises the method-agnostic packed abstraction: each
//! quantizer with a `PackedContainer` impl (RTN2, GPTQ2, PB-LLM, BiLLM)
//! quantizes the model on synthetic calibration, and the same workload is
//! served dense vs packed — tokens must be byte-identical per method,
//! with measured bits/weight and the packed/dense step ratio reported
//! under a `cross_method` summary section.
//!
//! Part 7 is the overload section: a mixed long/short workload where
//! every third prompt spans several pages. With the scheduler levers
//! off, each long prompt prefills in one monolithic step and stalls
//! every decoding lane for its full duration; with chunked prefill (and
//! preemption armed) the per-step prefill work is bounded, so p99
//! inter-token latency must improve at byte-identical tokens. A third
//! run repeats the workload against an undersized page pool to prove
//! lane preemption actually fires, restores recompute their positions,
//! and the tokens still match.
//!
//! Part 8 sweeps open-loop arrival rates over the streaming HTTP front
//! door: per rate leg a front door is self-hosted on an ephemeral
//! loopback port and driven with seeded-Poisson arrivals by independent
//! client threads, measuring client-observed wall-clock TTFT tails
//! end-to-end (HTTP parse -> queue -> lane -> SSE write). Every streamed
//! request must reassemble byte-identically from its token-id events
//! (the `open_loop.identity` gate) and every offered request must reach
//! a terminal outcome (`open_loop.completion`); the per-rate
//! `ttft_p99_ms` series and the saturation-knee throughput are exported
//! ungated (machine-speed dependent).
//!
//! The whole run's summary is also written as machine-readable JSON to
//! `runs/BENCH_serve.json` (mean step ms per backend, packed/fused step
//! ratio, KV live/reserved bytes, prefix-hit rate, worker-scaling
//! factors) for CI's bench-regression gate (`python/tools/check_bench.py`
//! against `runs/BENCH_baseline.json`) and tooling. Written as a merge:
//! foreign sections (`bench_packing`) are preserved, and a
//! run-id-suffixed copy keeps every run's artifact from being clobbered.
//!
//! Runs on FP-initialized weights (scheduling/caching cost is independent
//! of training) and needs no artifacts directory.

use std::time::Instant;

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::{initial_parts, PackedModel};
use ptq161::quant::{by_name, LinearCalib, Ptq161Parts};
use ptq161::tensor::Tensor;
use ptq161::util::rng::Rng;
use ptq161::runtime::autodiff::qlinear_weight_reconstructions;
use ptq161::runtime::Runtime;
use ptq161::runtime::kv::PrefixRouter;
use ptq161::serve::batcher::{Batcher, ShardedQueue};
use ptq161::serve::{
    percentile, run_open_loop, run_sharded, schedule, serve_http, Engine,
    EngineCfg, GenRequest, GenResponse, HttpServerCfg, LoadCfg,
    MetricsRegistry, ShardSpec,
};
use ptq161::util::json::{arr, num, obj, s, Json};
use ptq161::util::runid;

fn run_mode(
    pipe: &Pipeline,
    model: &ModelEval,
    reqs: &[GenRequest],
    label: &str,
    drain: bool,
    kv: bool,
) -> (MetricsRegistry, Vec<GenResponse>, f64) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new(label);
    let mut engine = Engine::new(pipe, model);
    engine.cfg.use_kv_cache = kv;
    let t0 = Instant::now();
    let mut resps = if drain {
        engine.run_drain(&mut batcher, &mut metrics).unwrap()
    } else {
        engine.run(&mut batcher, &mut metrics).unwrap()
    };
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(resps.len(), reqs.len(), "{label}: lost requests");
    assert_eq!(engine.kv_cache().in_use_count(), 0, "{label}: leaked slots");
    resps.sort_by_key(|r| r.id);
    (metrics, resps, wall)
}

/// Quantize every block linear with `method` (synthetic calibration) into
/// a dense-baseline params clone plus the prepared container model.
fn quantized_model(
    pipe: &Pipeline,
    params: &Params,
    method: &str,
    seed: u64,
) -> (Params, PackedModel) {
    let mut rng = Rng::new(seed);
    let q = by_name(method).unwrap();
    let mut dense = params.clone();
    let mut layers = Vec::new();
    for l in 0..pipe.cfg.n_layers {
        let mut layer = Vec::new();
        for lin in LINEARS {
            let name = format!("l{l}.{lin}");
            let w = params.get(&name);
            let inn = w.cols();
            let x = Tensor::randn(&[2 * inn, inn], 1.0, &mut rng);
            let mut calib = LinearCalib::empty(inn);
            calib.accumulate(&x, true);
            let ql = q.quantize_linear(w, &calib);
            *dense.get_mut(&name) = ql.deq;
            layer.push(ql.container.unwrap_or_else(|| {
                panic!("{method} must emit a container for {name}")
            }));
        }
        layers.push(layer);
    }
    (dense, PackedModel::from_containers(method, &layers))
}

/// Overload leg: single-loop serve with explicit scheduler levers and an
/// optional explicit page pool. Returns metrics plus texts ordered by id.
fn run_overload(
    pipe: &Pipeline,
    model: &ModelEval,
    reqs: &[GenRequest],
    label: &str,
    kv_pages: Option<usize>,
    chunk: Option<usize>,
    preempt: bool,
) -> (MetricsRegistry, Vec<String>) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new(label);
    let mut engine = Engine::with_cache_geometry(pipe, model, 16, kv_pages);
    engine.cfg.prefill_chunk = chunk;
    engine.cfg.preempt = preempt;
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    assert_eq!(resps.len(), reqs.len(), "{label}: lost requests");
    assert_eq!(engine.kv_cache().in_use_count(), 0, "{label}: leaked slots");
    resps.sort_by_key(|r| r.id);
    (metrics, resps.into_iter().map(|r| r.text).collect())
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn main() {
    let rt = Runtime::open(&ptq161::artifacts_dir()).unwrap();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(7);
    let model = ModelEval::Dense(&params);

    // ---- part 1: scheduling + decode-path throughput --------------------
    // 16 requests, 1-in-4 long: the regime where batch drain stalls lanes
    let reqs: Vec<GenRequest> = (0..16)
        .map(|i| GenRequest {
            prompt: format!("the quiet river of alda {} ", i % 3),
            max_new_tokens: if i % 4 == 0 { 40 } else { 4 },
        })
        .collect();
    let total_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    println!(
        "# bench_serve: {} requests, {} tokens, lane capacity {}",
        reqs.len(),
        total_tokens,
        pipe.cfg.b_eval
    );
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    let mut texts: Vec<Vec<String>> = Vec::new();
    for (label, drain, kv) in [
        ("drain", true, true),
        ("full-window", false, false),
        ("continuous+kv", false, true),
    ] {
        let (metrics, resps, wall) = run_mode(&pipe, &model, &reqs, label, drain, kv);
        println!(
            "{label:<14} {:>3} steps  occupancy {:.2}  {:>7.1} tok/s  \
             wall {:.2}s  p50 {:>6.0} ms  p95 {:>6.0} ms",
            metrics.steps,
            metrics.lane_occupancy(),
            metrics.throughput_tok_s(),
            wall,
            metrics.p50_ms(),
            metrics.p95_ms()
        );
        results.push((label.to_string(), metrics.throughput_tok_s(), wall));
        texts.push(resps.into_iter().map(|r| r.text).collect());
    }
    // correctness gate: every configuration must emit identical tokens
    for (mode, t) in texts.iter().enumerate().skip(1) {
        assert_eq!(
            t, &texts[0],
            "{}: output differs from {}",
            results[mode].0, results[0].0
        );
    }
    println!("token-identical across all modes: ok");
    let sched = results[2].1 / results[0].1.max(1e-9);
    let cache = results[2].1 / results[1].1.max(1e-9);
    println!("continuous+kv / drain throughput:       {sched:.2}x");
    println!("continuous+kv / full-window throughput: {cache:.2}x");

    // ---- part 2: per-step decode time vs sequence position --------------
    // every lane decodes a long sequence; per-step time early vs late in
    // the run shows full-window growing and cached staying flat
    let long = pipe.cfg.seq - 16;
    let long_reqs: Vec<GenRequest> = (0..pipe.cfg.b_eval)
        .map(|i| GenRequest {
            prompt: format!("position scan {i} "),
            max_new_tokens: long,
        })
        .collect();
    println!("\n# per-step decode time over {long} positions");
    let mut step_series: Vec<Vec<f64>> = Vec::new();
    for (label, kv) in [("full-window", false), ("kv-cached", true)] {
        let (metrics, _, _) = run_mode(&pipe, &model, &long_reqs, label, false, kv);
        let steps = &metrics.step_ms;
        let q = (steps.len() / 4).max(1);
        let early = mean(&steps[..q]);
        let late = mean(&steps[steps.len() - q..]);
        println!(
            "{label:<12} first-quartile step {early:>7.2} ms   \
             last-quartile step {late:>7.2} ms   late/early {:.2}x",
            late / early.max(1e-9)
        );
        step_series.push(steps.clone());
    }
    let growth = |s: &[f64]| {
        let q = (s.len() / 4).max(1);
        mean(&s[s.len() - q..]) / mean(&s[..q]).max(1e-9)
    };
    println!(
        "growth in step time, full-window {:.2}x vs kv-cached {:.2}x \
         (cached decode is ~flat in sequence position)",
        growth(&step_series[0]),
        growth(&step_series[1])
    );

    // ---- part 3: PTQ1.61 decode backends — fused rebuild vs packed ------
    // same quantized weights behind both backends; the packed containers
    // are built once here ("pack once, decode forever")
    let parts: Vec<Vec<Ptq161Parts>> = (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> =
                        (0..w.cols()).map(|j| j % 5 == 0).collect();
                    initial_parts(w, &mask)
                })
                .collect()
        })
        .collect();
    let packed = PackedModel::pack(&parts);
    let fused = ModelEval::Fused { params: &params, parts: &parts };
    let packed_me = ModelEval::Packed { params: &params, packed: &packed };
    println!(
        "\n# PTQ1.61 backends: packed model {} KiB resident, \
         {:.3} bits/weight",
        packed.resident_bytes() / 1024,
        packed.effective_bits()
    );
    let mut q_results: Vec<(String, f64, Vec<String>, u64)> = Vec::new();
    for (label, model, kv) in [
        ("fused-full", &fused, false),
        ("fused+kv", &fused, true),
        ("packed+kv", &packed_me, true),
    ] {
        let recon0 = qlinear_weight_reconstructions();
        let (metrics, resps, _) = run_mode(&pipe, model, &reqs, label, false, kv);
        let recon = qlinear_weight_reconstructions() - recon0;
        println!(
            "{label:<12} mean step {:>7.2} ms  {:>7.1} tok/s  \
             Wq' reconstructions {recon}",
            metrics.mean_step_ms(),
            metrics.throughput_tok_s()
        );
        q_results.push((
            label.to_string(),
            metrics.mean_step_ms(),
            resps.into_iter().map(|r| r.text).collect(),
            recon,
        ));
    }
    for (label, _, texts, _) in q_results.iter().skip(1) {
        assert_eq!(
            texts, &q_results[0].2,
            "{label}: tokens differ from {}",
            q_results[0].0
        );
    }
    println!("token-identical across PTQ1.61 backends: ok");
    assert_eq!(
        q_results[2].3, 0,
        "packed decode must not reconstruct dense weights"
    );
    let packed_fused_ratio = q_results[2].1 / q_results[1].1.max(1e-9);
    println!(
        "packed/fused cached mean step: {packed_fused_ratio:.2}x \
         (at or below 1.0 expected)"
    );

    // ---- part 4: paged cache under a shared system prompt ---------------
    // every request opens with the same >1-page head: later admissions
    // adopt the registered prefix pages instead of recomputing them
    let n_shared = 8;
    let shared: Vec<GenRequest> = (0..n_shared)
        .map(|i| GenRequest {
            prompt: format!(
                "SYSTEM: you are a terse assistant for the alda river desk. \
                 user {i}: "
            ),
            max_new_tokens: if i % 3 == 0 { 24 } else { 6 },
        })
        .collect();
    println!("\n# paged cache: {n_shared} requests, one shared system prompt");
    let (base_m, base_resps, _) =
        run_mode(&pipe, &packed_me, &shared, "shared/full-window", false, false);
    let (paged_m, paged_resps, _) =
        run_mode(&pipe, &packed_me, &shared, "shared/paged", false, true);
    let base_texts: Vec<String> =
        base_resps.into_iter().map(|r| r.text).collect();
    let paged_texts: Vec<String> =
        paged_resps.into_iter().map(|r| r.text).collect();
    assert_eq!(
        paged_texts, base_texts,
        "paged shared-prefix decode must be token-identical"
    );
    let kv_reserved = paged_m.kv_reserved_bytes.unwrap_or(0);
    let kv_live = paged_m.kv_live_bytes.unwrap_or(0);
    let hit_rate = paged_m.prefix_hit_rate();
    let window_bytes = pipe.cfg.n_layers
        * pipe.cfg.seq
        * pipe.cfg.d
        * 2
        * std::mem::size_of::<f32>();
    println!(
        "kv reserved {kv_reserved} B | live peak {kv_live} B \
         ({:.1}% of {n_shared} full windows) | prefix hit rate {hit_rate:.2} \
         | CoW splits {}",
        100.0 * kv_live as f64 / (n_shared * window_bytes) as f64,
        paged_m.kv_cow_splits.unwrap_or(0),
    );
    assert!(
        kv_live > 0 && kv_live < n_shared * window_bytes,
        "paged live bytes must undershoot {n_shared} full windows"
    );
    assert!(hit_rate > 0.0, "shared system prompt must hit the prefix index");
    assert!(base_m.prefix_hit_rate() == 0.0, "full-window path caches nothing");
    // non-vacuous sharing gate: break the shared head (request index
    // first) and the same workload must physically allocate strictly
    // more pages — adopted pages are referenced, never allocated
    let unique: Vec<GenRequest> = shared
        .iter()
        .enumerate()
        .map(|(i, r)| GenRequest {
            prompt: format!(
                "user {i}: SYSTEM: you are a terse assistant for the alda \
                 river desk."
            ),
            max_new_tokens: r.max_new_tokens,
        })
        .collect();
    let (unshared_m, _, _) =
        run_mode(&pipe, &packed_me, &unique, "shared/no-prefix", false, true);
    let shared_allocs = paged_m.kv_page_allocs.unwrap_or(0);
    let unique_allocs = unshared_m.kv_page_allocs.unwrap_or(0);
    println!(
        "page allocations: {shared_allocs} shared-prefix vs {unique_allocs} \
         unique prompts"
    );
    assert!(
        shared_allocs < unique_allocs,
        "prefix sharing must allocate strictly fewer pages"
    );

    // ---- part 5: multi-worker sharded scaling ---------------------------
    // the same packed-backend workload across 1/2/4 OS workers: tokens
    // must not move, throughput must (given the cores to move it)
    let n_scale = 32;
    let scale_reqs: Vec<GenRequest> = (0..n_scale)
        .map(|i| GenRequest {
            prompt: format!("SYSTEM: terse alda desk. user {i}: "),
            max_new_tokens: if i % 4 == 0 { 24 } else { 8 },
        })
        .collect();
    let parallelism = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "\n# sharded scaling: {n_scale} requests over 1/2/4 workers \
         ({parallelism} cores available)"
    );
    let worker_counts = [1usize, 2, 4];
    let mut scale_tput: Vec<f64> = Vec::new();
    let mut scale_texts: Vec<Vec<String>> = Vec::new();
    for &w in &worker_counts {
        let queue = ShardedQueue::new(w.min(pipe.cfg.b_eval));
        for r in &scale_reqs {
            queue.submit(r.clone());
        }
        let router = PrefixRouter::new(16);
        let cfg = EngineCfg { workers: w, ..EngineCfg::default() };
        let spec =
            ShardSpec { label: "scale", page_size: 16, kv_pages: None };
        let t0 = Instant::now();
        let run =
            run_sharded(&pipe, &packed_me, &cfg, &queue, &router, &spec).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(run.responses.len(), n_scale, "workers={w}: lost requests");
        assert_eq!(run.worker_panics, 0, "workers={w}: worker panicked");
        let toks: usize = run.responses.iter().map(|r| r.new_tokens).sum();
        let tput = toks as f64 / wall.max(1e-9);
        println!(
            "workers={w} ({} effective)  {:>7.1} tok/s  wall {wall:.2}s  \
             p95 {:>6.0} ms",
            run.metrics.workers.unwrap_or(1),
            tput,
            run.metrics.p95_ms()
        );
        scale_tput.push(tput);
        scale_texts
            .push(run.responses.into_iter().map(|r| r.text).collect());
    }
    for (i, t) in scale_texts.iter().enumerate().skip(1) {
        assert_eq!(
            t, &scale_texts[0],
            "workers={}: tokens differ from workers=1",
            worker_counts[i]
        );
    }
    println!("token-identical across worker counts: ok");
    let scaling_factor = scale_tput[2] / scale_tput[0].max(1e-9);
    println!("4-worker / 1-worker throughput: {scaling_factor:.2}x");
    if parallelism >= 4 {
        assert!(
            scaling_factor >= 1.5,
            "4 workers must clear 1.5x single-worker throughput on a \
             {parallelism}-core host, got {scaling_factor:.2}x"
        );
    } else {
        println!(
            "(scaling assertion skipped: only {parallelism} cores available)"
        );
    }

    // ---- part 6: one container abstraction, every quantizer -------------
    // per method: quantize tiny's linears on synthetic calibration, then
    // serve the same workload dense vs packed — byte-identical tokens
    // (the containers' decode kernels accumulate in the dense kernel's
    // exact order), zero dense-weight reconstructions, with measured
    // bits/weight and the packed/dense step ratio per method
    let xm_reqs: Vec<GenRequest> = (0..8)
        .map(|i| GenRequest {
            prompt: format!("SYSTEM: terse alda desk. user {i}: "),
            max_new_tokens: if i % 3 == 0 { 12 } else { 4 },
        })
        .collect();
    println!("\n# cross-method packed backends (dense-identical serve)");
    let mut xm_fields: Vec<(&str, _)> = Vec::new();
    for method in ["rtn2", "gptq2", "pbllm", "billm"] {
        let (dense_params, xm_packed) =
            quantized_model(&pipe, &params, method, 17);
        let dense_me = ModelEval::Dense(&dense_params);
        let packed_xm =
            ModelEval::Packed { params: &dense_params, packed: &xm_packed };
        let (dm, dresps, _) = run_mode(
            &pipe, &dense_me, &xm_reqs, &format!("{method}/dense"), false, true,
        );
        let r0 = qlinear_weight_reconstructions();
        let (pm, presps, _) = run_mode(
            &pipe, &packed_xm, &xm_reqs, &format!("{method}/packed"), false, true,
        );
        assert_eq!(
            qlinear_weight_reconstructions() - r0,
            0,
            "{method}: packed decode must not reconstruct dense weights"
        );
        let dtexts: Vec<String> = dresps.into_iter().map(|r| r.text).collect();
        let ptexts: Vec<String> = presps.into_iter().map(|r| r.text).collect();
        assert_eq!(ptexts, dtexts, "{method}: packed tokens diverge from dense");
        let xm_ratio = pm.mean_step_ms() / dm.mean_step_ms().max(1e-9);
        println!(
            "{method:<7} {:.4} bits/weight  {:>5} KiB resident  \
             packed/dense mean step {xm_ratio:.2}x  token-identity ok",
            xm_packed.effective_bits(),
            xm_packed.resident_bytes() / 1024,
        );
        xm_fields.push((
            method,
            obj(vec![
                ("bits_per_weight", num(xm_packed.effective_bits())),
                ("packed_dense_step_ratio", num(xm_ratio)),
                ("packed_bytes", num(xm_packed.resident_bytes() as f64)),
                ("mean_step_ms", num(pm.mean_step_ms())),
            ]),
        ));
    }
    // scalar flag for the regression gate: 1.0 = every method above served
    // byte-identical tokens (the asserts abort the bench otherwise)
    xm_fields.push(("identity", num(1.0)));
    println!("token-identical across all packed methods: ok");

    // ---- part 7: overload — preemption + chunked prefill ----------------
    // mixed long/short: every third prompt spans several pages, shorts
    // decode long enough that a monolithic prefill stall lands in their
    // inter-token gaps. Same workload three ways: levers off, levers on
    // (the p99 ITL comparison), and levers on against an undersized
    // 12-page pool where admission is only possible by evicting a lane
    let overload_reqs: Vec<GenRequest> = (0..24)
        .map(|i| {
            if i % 3 == 2 {
                GenRequest {
                    prompt: format!(
                        "SYSTEM: request {i} files the complete valley \
                         ledger, every entry of the season recited in full \
                         order"
                    ),
                    max_new_tokens: 8,
                }
            } else {
                GenRequest { prompt: format!("q{i}"), max_new_tokens: 24 }
            }
        })
        .collect();
    println!(
        "\n# overload: preemption + chunked prefill vs plain scheduling \
         ({} requests)",
        overload_reqs.len()
    );
    let (off_m, off_texts) = run_overload(
        &pipe, &packed_me, &overload_reqs, "overload/off", None, None, false,
    );
    let (on_m, on_texts) = run_overload(
        &pipe, &packed_me, &overload_reqs, "overload/on", None, Some(16), true,
    );
    assert_eq!(on_texts, off_texts, "scheduler levers changed tokens");
    let p99_on = on_m.p99_itl_ms();
    let p99_off = off_m.p99_itl_ms();
    let p99_itl_overload_ratio = p99_on / p99_off.max(1e-9);
    println!(
        "p99 inter-token latency: on {p99_on:.2} ms vs off {p99_off:.2} ms \
         ({p99_itl_overload_ratio:.2}x, below 1.0 = chunking wins)"
    );
    assert!(
        p99_itl_overload_ratio < 1.0,
        "chunked prefill must improve p99 inter-token latency under \
         overload, got {p99_itl_overload_ratio:.2}x"
    );
    // pressure leg: tiny is 8 pages/window, so 12 aggregate pages cannot
    // hold three short lanes plus a long prompt — preemption must fire,
    // restores must recompute, and not one token may move
    let (press_m, press_texts) = run_overload(
        &pipe,
        &packed_me,
        &overload_reqs,
        "overload/pressure",
        Some(12),
        Some(16),
        true,
    );
    assert_eq!(press_texts, off_texts, "preemption changed tokens");
    assert!(press_m.preemptions >= 1, "undersized pool never preempted");
    assert!(press_m.prefill_chunks >= 1, "long prompts were never chunked");
    assert!(
        press_m.restored_positions >= 1,
        "restores must account recomputed positions"
    );
    println!(
        "pressure leg: {} preemptions, {} prefill chunks, {} restored \
         positions, p99 itl {:.2} ms — token-identical: ok",
        press_m.preemptions,
        press_m.prefill_chunks,
        press_m.restored_positions,
        press_m.p99_itl_ms()
    );

    // ---- part 8: open-loop arrival sweep over the HTTP front door -------
    // per rate leg: self-host the streaming front door (ephemeral
    // loopback port, retires after the leg's requests), drive
    // seeded-Poisson arrivals open-loop — offered rate never waits on
    // completions, so rising client-observed TTFT tails expose the
    // saturation knee end-to-end
    let rates = [4.0f64, 16.0, 64.0];
    let leg_requests = 16usize;
    println!(
        "\n# open-loop HTTP sweep: {leg_requests} requests per leg at \
         {rates:?} req/s"
    );
    let mut leg_ttft_p99: Vec<f64> = Vec::new();
    let mut leg_achieved_req_s: Vec<f64> = Vec::new();
    let mut open_identity = 1.0f64;
    let mut open_completion = 1.0f64;
    for (leg, &rate) in rates.iter().enumerate() {
        let lcfg = LoadCfg {
            rate_hz: rate,
            requests: leg_requests,
            seed: 1000 + leg as u64,
            seq: pipe.cfg.seq,
        };
        let arrivals = schedule(&lcfg);
        let ecfg = EngineCfg { workers: 2, ..EngineCfg::default() };
        let spec =
            ShardSpec { label: "open-loop", page_size: 16, kv_pages: None };
        let hcfg = HttpServerCfg {
            max_requests: Some(leg_requests),
            ..HttpServerCfg::default()
        };
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (report, run) = std::thread::scope(|scope| {
            let (p, m, e, sp, h) =
                (&pipe, &packed_me, &ecfg, &spec, &hcfg);
            let server = scope
                .spawn(move || serve_http(p, m, e, sp, h, listener).unwrap());
            let report = run_open_loop(&addr, &arrivals, rate, pipe.cfg.seq);
            (report, server.join().expect("front door panicked"))
        });
        assert_eq!(run.worker_panics, 0, "leg {leg}: worker panicked");
        assert_eq!(
            report.errors, 0,
            "leg {leg}: open-loop client saw errors"
        );
        // the identity gate: every streamed request's token-id events
        // must reassemble byte-identically to its own done text
        assert_eq!(
            report.identity_ok, report.ok,
            "leg {leg}: streamed tokens failed byte-identity"
        );
        open_identity = open_identity.min(report.identity());
        open_completion = open_completion.min(report.completion());
        let ttft_p99 = percentile(&report.ttft_ms, 0.99);
        let achieved = 1000.0 * report.ok as f64 / report.wall_ms.max(1e-6);
        println!(
            "rate {rate:>5.1} req/s  ok {:>2} / 429 {:>2}  \
             ttft p99 {ttft_p99:>7.1} ms  achieved {achieved:>5.1} req/s  \
             {:>6.1} tok/s",
            report.ok,
            report.rejected,
            report.achieved_tok_s()
        );
        leg_ttft_p99.push(ttft_p99);
        leg_achieved_req_s.push(achieved);
    }
    // the observed request-throughput ceiling: past the knee, offering a
    // higher rate stops raising the achieved rate
    let knee_req_s =
        leg_achieved_req_s.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "streamed byte-identity across all legs: ok \
         (saturation knee ~{knee_req_s:.1} req/s)"
    );

    // ---- machine-readable summary ---------------------------------------
    let backends = arr(q_results.iter().map(|(label, step_ms, _, recon)| {
        obj(vec![
            ("name", s(label)),
            ("mean_step_ms", num(*step_ms)),
            ("reconstructions", num(*recon as f64)),
        ])
    }));
    let summary = obj(vec![
        ("bench", s("bench_serve")),
        ("backends", backends),
        ("packed_fused_step_ratio", num(packed_fused_ratio)),
        ("kv_reserved_bytes", num(kv_reserved as f64)),
        ("kv_live_bytes", num(kv_live as f64)),
        ("prefix_hit_rate", num(hit_rate)),
        ("page_allocs_shared", num(shared_allocs as f64)),
        ("page_allocs_unique", num(unique_allocs as f64)),
        (
            "shared_prefix_requests",
            num(n_shared as f64),
        ),
        ("full_window_bytes_per_lane", num(window_bytes as f64)),
        (
            "worker_scaling",
            obj(vec![
                (
                    "workers",
                    arr(worker_counts.iter().map(|&w| num(w as f64))),
                ),
                (
                    "throughput_tok_s",
                    arr(scale_tput.iter().map(|&t| num(t))),
                ),
                ("factor_w4_over_w1", num(scaling_factor)),
                ("parallelism", num(parallelism as f64)),
            ]),
        ),
        ("cross_method", obj(xm_fields)),
        ("p99_itl_overload_ratio", num(p99_itl_overload_ratio)),
        (
            "overload",
            obj(vec![
                ("p99_itl_on_ms", num(p99_on)),
                ("p99_itl_off_ms", num(p99_off)),
                ("preemptions", num(press_m.preemptions as f64)),
                ("prefill_chunks", num(press_m.prefill_chunks as f64)),
                (
                    "restored_positions",
                    num(press_m.restored_positions as f64),
                ),
            ]),
        ),
        (
            "open_loop",
            obj(vec![
                ("identity", num(open_identity)),
                ("completion", num(open_completion)),
                ("rates_req_s", arr(rates.iter().map(|&r| num(r)))),
                (
                    "ttft_p99_ms",
                    arr(leg_ttft_p99.iter().map(|&t| num(t))),
                ),
                (
                    "achieved_req_s",
                    arr(leg_achieved_req_s.iter().map(|&t| num(t))),
                ),
                ("saturation_knee_req_s", num(knee_req_s)),
            ]),
        ),
        ("token_identity", s("ok")),
    ]);
    // merge, don't overwrite: other benches (bench_packing) own their own
    // sections of this file — refresh our keys, preserve foreign ones
    let dir = ptq161::runs_dir();
    let path = dir.join("BENCH_serve.json");
    let Json::Obj(mut fields) = summary else { unreachable!() };
    if let Some(Json::Obj(existing)) = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
    {
        for (k, v) in existing {
            if !fields.iter().any(|(ours, _)| ours == &k) {
                fields.push((k, v));
            }
        }
    }
    let merged = Json::Obj(fields);
    std::fs::write(&path, merged.dump()).unwrap();
    // run-id-suffixed copy: repeated or concurrent bench runs each keep
    // their own artifact while the stable name stays the merged summary
    let unique =
        dir.join(runid::suffixed("BENCH_serve.json", &runid::run_id()));
    std::fs::write(&unique, merged.dump()).unwrap();
    println!(
        "summary written to {} (run copy {})",
        path.display(),
        unique.display()
    );
}
