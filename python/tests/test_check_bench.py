"""Unit tests for the bench-regression gate (python/tools/check_bench.py).

The gate has no third-party deps, so these always run.  The headline
case mirrors the acceptance criterion: a deliberate 2x slowdown of the
packed decode path (doubling packed_fused_step_ratio) must fail the
gate, while runner-bound scaling shortfalls on small hosts must not.
"""

import copy
import importlib.util
import json
import pathlib

_TOOL = pathlib.Path(__file__).resolve().parents[1] / "tools" / "check_bench.py"
_spec = importlib.util.spec_from_file_location("check_bench", _TOOL)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


BASELINE = {
    "bench": "bench_serve",
    "packed_fused_step_ratio": 1.0,
    "prefix_hit_rate": 0.45,
    "worker_scaling": {"factor_w4_over_w1": 1.7, "parallelism": 4},
    "cross_method": {
        "identity": 1.0,
        "rtn2": {"bits_per_weight": 2.2143},
        "gptq2": {"bits_per_weight": 2.2143},
        "pbllm": {"bits_per_weight": 3.0215},
        "billm": {"bits_per_weight": 3.4286},
    },
    "p99_itl_overload_ratio": 0.75,
    "bench_packing": {
        "simd": "avx2",
        "parallelism": 4,
        "simd_speedup": 1.5,
        "intra_parallel_speedup": 1.5,
    },
    "open_loop": {"identity": 1.0, "completion": 1.0},
}


def fresh_like_baseline():
    return copy.deepcopy(BASELINE)


def test_identical_run_passes():
    assert check_bench.run_check(BASELINE, fresh_like_baseline()) == []


def test_small_drift_within_tolerance_passes():
    fresh = fresh_like_baseline()
    fresh["packed_fused_step_ratio"] = 1.1  # +10% < 20% band
    fresh["prefix_hit_rate"] = 0.40
    fresh["worker_scaling"]["factor_w4_over_w1"] = 1.5
    assert check_bench.run_check(BASELINE, fresh) == []


def test_doubled_packed_step_ratio_fails():
    # the acceptance scenario: packed_qlinear_fwd made 2x slower doubles
    # the packed/fused step ratio and must trip the gate
    fresh = fresh_like_baseline()
    fresh["packed_fused_step_ratio"] = 2.0
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "packed_fused_step_ratio" in failures[0]


def test_prefix_hit_rate_collapse_fails():
    fresh = fresh_like_baseline()
    fresh["prefix_hit_rate"] = 0.1
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "prefix_hit_rate" in failures[0]


def test_scaling_regression_fails_on_big_host():
    fresh = fresh_like_baseline()
    fresh["worker_scaling"] = {"factor_w4_over_w1": 1.0, "parallelism": 4}
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "factor_w4_over_w1" in failures[0]


def test_scaling_skipped_below_min_parallelism():
    # a 2-core runner cannot scale 4 workers; that is hardware, not a
    # regression — the scaling check is skipped, others still apply
    fresh = fresh_like_baseline()
    fresh["worker_scaling"] = {"factor_w4_over_w1": 0.9, "parallelism": 2}
    assert check_bench.run_check(BASELINE, fresh) == []
    fresh["packed_fused_step_ratio"] = 3.0
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "packed_fused_step_ratio" in failures[0]


def test_cross_method_bits_inflation_fails():
    # a container growing a plane (or mis-charging its fp16 vectors)
    # inflates the measured bits/weight deterministically — gate it
    fresh = fresh_like_baseline()
    fresh["cross_method"]["billm"]["bits_per_weight"] = 4.5  # +31%
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "cross_method.billm.bits_per_weight" in failures[0]


def test_cross_method_identity_drop_fails():
    # the section vanishing from the summary (or reporting non-identity)
    # must trip the gate, not silently degrade it to a no-op
    fresh = fresh_like_baseline()
    fresh["cross_method"]["identity"] = 0.0
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "cross_method.identity" in failures[0]
    del fresh["cross_method"]
    failures = check_bench.run_check(BASELINE, fresh)
    assert any("missing from fresh" in f for f in failures)


def test_overload_itl_ratio_band():
    # the overload ratio is "lower is better": chunked prefill losing its
    # tail-latency win (ratio drifting toward 1.0) must trip the gate,
    # while jitter inside the 20% band must not
    fresh = fresh_like_baseline()
    fresh["p99_itl_overload_ratio"] = 0.88  # within 0.75 * 1.2
    assert check_bench.run_check(BASELINE, fresh) == []
    fresh["p99_itl_overload_ratio"] = 0.95  # past the band
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "p99_itl_overload_ratio" in failures[0]


def test_simd_slowdown_fails():
    # the acceptance scenario for the kernel-dispatch stack: the SIMD
    # tier losing its win over the blocked kernel (speedup collapsing to
    # ~1.0 against a 1.5 baseline) must trip the gate
    fresh = fresh_like_baseline()
    fresh["bench_packing"]["simd_speedup"] = 1.0
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "bench_packing.simd_speedup" in failures[0]


def test_intra_parallel_slowdown_fails():
    fresh = fresh_like_baseline()
    fresh["bench_packing"]["intra_parallel_speedup"] = 0.9
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "bench_packing.intra_parallel_speedup" in failures[0]


def test_simd_speedup_skipped_without_simd_tier():
    # a runner without AVX2/NEON (or one pinned to scalar/blocked via
    # env) measures no SIMD ratio — hardware, not a regression.  The
    # intra-parallel check still applies on its own core-count guard.
    for tier in ("none", "blocked", "scalar", None):
        fresh = fresh_like_baseline()
        fresh["bench_packing"]["simd_speedup"] = 0.5
        if tier is None:
            del fresh["bench_packing"]["simd"]
        else:
            fresh["bench_packing"]["simd"] = tier
        assert check_bench.run_check(BASELINE, fresh) == []


def test_intra_parallel_skipped_below_min_parallelism():
    # a 2-core runner cannot show a 4-way kernel split win; skip the
    # intra-parallel ratio but keep gating the SIMD one
    fresh = fresh_like_baseline()
    fresh["bench_packing"]["parallelism"] = 2
    fresh["bench_packing"]["intra_parallel_speedup"] = 0.8
    assert check_bench.run_check(BASELINE, fresh) == []
    fresh["bench_packing"]["simd_speedup"] = 1.0
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "bench_packing.simd_speedup" in failures[0]


def test_open_loop_identity_or_completion_drop_fails():
    # a stream reassembling to different bytes than its terminal response,
    # or the front door dropping offered requests on the floor, must trip
    # the gate (both sit at exactly 1.0, so any drop clears the 20% band)
    fresh = fresh_like_baseline()
    fresh["open_loop"]["identity"] = 0.75
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "open_loop.identity" in failures[0]
    fresh = fresh_like_baseline()
    fresh["open_loop"]["completion"] = 0.5
    failures = check_bench.run_check(BASELINE, fresh)
    assert len(failures) == 1
    assert "open_loop.completion" in failures[0]
    del fresh["open_loop"]
    failures = check_bench.run_check(BASELINE, fresh)
    assert any("missing from fresh" in f for f in failures)


def test_resolve_fresh_prefers_newest_run_suffix(tmp_path):
    # run-id-suffixed summaries: a directory (or missing stable file)
    # resolves to the newest BENCH_serve*.json by mtime
    import os

    old = tmp_path / "BENCH_serve_aaa-1.json"
    new = tmp_path / "BENCH_serve_bbb-2.json"
    old.write_text("{}")
    new.write_text("{}")
    past = old.stat().st_mtime - 100
    os.utime(old, (past, past))
    assert check_bench.resolve_fresh(str(tmp_path)) == str(new)
    missing_stable = tmp_path / "BENCH_serve.json"
    assert check_bench.resolve_fresh(str(missing_stable)) == str(new)
    # an existing file is returned untouched
    missing_stable.write_text("{}")
    assert check_bench.resolve_fresh(str(missing_stable)) == str(missing_stable)
    # nothing to resolve -> loud failure, not a silent no-op gate
    import pytest

    with pytest.raises(FileNotFoundError):
        check_bench.resolve_fresh(str(tmp_path / "empty" / "BENCH_serve.json"))


def test_missing_key_fails():
    fresh = fresh_like_baseline()
    del fresh["packed_fused_step_ratio"]
    failures = check_bench.run_check(BASELINE, fresh)
    assert any("missing from fresh" in f for f in failures)


def test_main_exit_codes(tmp_path):
    base_p = tmp_path / "base.json"
    fresh_p = tmp_path / "fresh.json"
    base_p.write_text(json.dumps(BASELINE))
    fresh_p.write_text(json.dumps(fresh_like_baseline()))
    argv = ["--baseline", str(base_p), "--fresh", str(fresh_p)]
    assert check_bench.main(argv) == 0
    bad = fresh_like_baseline()
    bad["packed_fused_step_ratio"] = 2.0
    fresh_p.write_text(json.dumps(bad))
    assert check_bench.main(argv) == 1


def test_committed_baseline_parses_and_covers_all_checks():
    # the baseline the CI lane diffs against must exist and carry every
    # gated key — otherwise the gate silently degrades to a no-op
    runs = pathlib.Path(__file__).resolve().parents[2] / "runs"
    with open(runs / "BENCH_baseline.json") as f:
        baseline = json.load(f)
    for key, _ in check_bench.CHECKS:
        assert check_bench.get_path(baseline, key) is not None, key
