//! Serve-engine bench: scheduling (drain vs continuous) and decode-path
//! (full-window vs KV-cached) comparisons, with a correctness gate.
//!
//! Part 1 replays one skewed request-length workload through three
//! configurations — static drain batching, continuous batching over the
//! full-window forward, and continuous batching with the KV cache — and
//! asserts all three produce token-identical responses (greedy decode is
//! per-lane deterministic, so scheduling and caching must not change a
//! single token).
//!
//! Part 2 decodes long sequences and reports per-step wall time early vs
//! late in the sequence: the full-window path grows with position (each
//! step re-runs the whole window), the KV-cached path stays roughly flat
//! (each step runs one token against cached K/V).
//!
//! Part 3 compares the PTQ1.61 decode backends: the fused path (rebuilds
//! the dense Wq' from six part tensors every forward) against the
//! prepared packed path (contracts the 1.61-bit containers directly).
//! Both must emit identical tokens, the packed run must perform zero
//! `qlinear_weight` reconstructions inside the decode loop, and its
//! per-step time is reported against the fused-cached path.
//!
//! Runs on FP-initialized weights (scheduling/caching cost is independent
//! of training) and needs no artifacts directory.

use std::time::Instant;

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::LINEARS;
use ptq161::quant::ptq161::{initial_parts, PackedModel};
use ptq161::quant::Ptq161Parts;
use ptq161::runtime::autodiff::qlinear_weight_reconstructions;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, GenResponse, MetricsRegistry};

fn run_mode(
    pipe: &Pipeline,
    model: &ModelEval,
    reqs: &[GenRequest],
    label: &str,
    drain: bool,
    kv: bool,
) -> (MetricsRegistry, Vec<GenResponse>, f64) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new(label);
    let mut engine = Engine::new(pipe, model);
    engine.cfg.use_kv_cache = kv;
    let t0 = Instant::now();
    let mut resps = if drain {
        engine.run_drain(&mut batcher, &mut metrics).unwrap()
    } else {
        engine.run(&mut batcher, &mut metrics).unwrap()
    };
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(resps.len(), reqs.len(), "{label}: lost requests");
    assert_eq!(engine.kv_cache().in_use_count(), 0, "{label}: leaked slots");
    resps.sort_by_key(|r| r.id);
    (metrics, resps, wall)
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn main() {
    let rt = Runtime::open(&ptq161::artifacts_dir()).unwrap();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(7);
    let model = ModelEval::Dense(&params);

    // ---- part 1: scheduling + decode-path throughput --------------------
    // 16 requests, 1-in-4 long: the regime where batch drain stalls lanes
    let reqs: Vec<GenRequest> = (0..16)
        .map(|i| GenRequest {
            prompt: format!("the quiet river of alda {} ", i % 3),
            max_new_tokens: if i % 4 == 0 { 40 } else { 4 },
        })
        .collect();
    let total_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    println!(
        "# bench_serve: {} requests, {} tokens, lane capacity {}",
        reqs.len(),
        total_tokens,
        pipe.cfg.b_eval
    );
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    let mut texts: Vec<Vec<String>> = Vec::new();
    for (label, drain, kv) in [
        ("drain", true, true),
        ("full-window", false, false),
        ("continuous+kv", false, true),
    ] {
        let (metrics, resps, wall) = run_mode(&pipe, &model, &reqs, label, drain, kv);
        println!(
            "{label:<14} {:>3} steps  occupancy {:.2}  {:>7.1} tok/s  \
             wall {:.2}s  p50 {:>6.0} ms  p95 {:>6.0} ms",
            metrics.steps,
            metrics.lane_occupancy(),
            metrics.throughput_tok_s(),
            wall,
            metrics.p50_ms(),
            metrics.p95_ms()
        );
        results.push((label.to_string(), metrics.throughput_tok_s(), wall));
        texts.push(resps.into_iter().map(|r| r.text).collect());
    }
    // correctness gate: every configuration must emit identical tokens
    for (mode, t) in texts.iter().enumerate().skip(1) {
        assert_eq!(
            t, &texts[0],
            "{}: output differs from {}",
            results[mode].0, results[0].0
        );
    }
    println!("token-identical across all modes: ok");
    let sched = results[2].1 / results[0].1.max(1e-9);
    let cache = results[2].1 / results[1].1.max(1e-9);
    println!("continuous+kv / drain throughput:       {sched:.2}x");
    println!("continuous+kv / full-window throughput: {cache:.2}x");

    // ---- part 2: per-step decode time vs sequence position --------------
    // every lane decodes a long sequence; per-step time early vs late in
    // the run shows full-window growing and cached staying flat
    let long = pipe.cfg.seq - 16;
    let long_reqs: Vec<GenRequest> = (0..pipe.cfg.b_eval)
        .map(|i| GenRequest {
            prompt: format!("position scan {i} "),
            max_new_tokens: long,
        })
        .collect();
    println!("\n# per-step decode time over {long} positions");
    let mut step_series: Vec<Vec<f64>> = Vec::new();
    for (label, kv) in [("full-window", false), ("kv-cached", true)] {
        let (metrics, _, _) = run_mode(&pipe, &model, &long_reqs, label, false, kv);
        let steps = &metrics.step_ms;
        let q = (steps.len() / 4).max(1);
        let early = mean(&steps[..q]);
        let late = mean(&steps[steps.len() - q..]);
        println!(
            "{label:<12} first-quartile step {early:>7.2} ms   \
             last-quartile step {late:>7.2} ms   late/early {:.2}x",
            late / early.max(1e-9)
        );
        step_series.push(steps.clone());
    }
    let growth = |s: &[f64]| {
        let q = (s.len() / 4).max(1);
        mean(&s[s.len() - q..]) / mean(&s[..q]).max(1e-9)
    };
    println!(
        "growth in step time, full-window {:.2}x vs kv-cached {:.2}x \
         (cached decode is ~flat in sequence position)",
        growth(&step_series[0]),
        growth(&step_series[1])
    );

    // ---- part 3: PTQ1.61 decode backends — fused rebuild vs packed ------
    // same quantized weights behind both backends; the packed containers
    // are built once here ("pack once, decode forever")
    let parts: Vec<Vec<Ptq161Parts>> = (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> =
                        (0..w.cols()).map(|j| j % 5 == 0).collect();
                    initial_parts(w, &mask)
                })
                .collect()
        })
        .collect();
    let packed = PackedModel::pack(&parts);
    let fused = ModelEval::Fused { params: &params, parts: &parts };
    let packed_me = ModelEval::Packed { params: &params, packed: &packed };
    println!(
        "\n# PTQ1.61 backends: packed model {} KiB resident, \
         {:.3} bits/weight",
        packed.resident_bytes() / 1024,
        packed.effective_bits()
    );
    let mut q_results: Vec<(String, f64, Vec<String>, u64)> = Vec::new();
    for (label, model, kv) in [
        ("fused-full", &fused, false),
        ("fused+kv", &fused, true),
        ("packed+kv", &packed_me, true),
    ] {
        let recon0 = qlinear_weight_reconstructions();
        let (metrics, resps, _) = run_mode(&pipe, model, &reqs, label, false, kv);
        let recon = qlinear_weight_reconstructions() - recon0;
        println!(
            "{label:<12} mean step {:>7.2} ms  {:>7.1} tok/s  \
             Wq' reconstructions {recon}",
            metrics.mean_step_ms(),
            metrics.throughput_tok_s()
        );
        q_results.push((
            label.to_string(),
            metrics.mean_step_ms(),
            resps.into_iter().map(|r| r.text).collect(),
            recon,
        ));
    }
    for (label, _, texts, _) in q_results.iter().skip(1) {
        assert_eq!(
            texts, &q_results[0].2,
            "{label}: tokens differ from {}",
            q_results[0].0
        );
    }
    println!("token-identical across PTQ1.61 backends: ok");
    assert_eq!(
        q_results[2].3, 0,
        "packed decode must not reconstruct dense weights"
    );
    println!(
        "packed/fused cached mean step: {:.2}x (at or below 1.0 expected)",
        q_results[2].1 / q_results[1].1.max(1e-9)
    );
}
