//! Model store: parameter container in the canonical manifest order, byte
//! tokenizer, initialization, and an own binary save/load format (no
//! safetensors offline).

pub mod tokenizer;

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::manifest::ModelConfig;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Block-linear names in canonical order (must match python model.LINEARS).
pub const LINEARS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// Full model parameters in canonical (manifest) order with name lookup.
#[derive(Debug, Clone)]
pub struct Params {
    pub spec: Vec<(String, Vec<usize>)>,
    pub tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl Params {
    pub fn new(spec: Vec<(String, Vec<usize>)>, tensors: Vec<Tensor>) -> Params {
        assert_eq!(spec.len(), tensors.len());
        for ((n, s), t) in spec.iter().zip(&tensors) {
            assert_eq!(s, &t.shape, "param {n} shape mismatch");
        }
        let index =
            spec.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        Params { spec, tensors, index }
    }

    /// LLaMA-style init: norms at 1, matrices ~ N(0, 0.4/sqrt(fan_in)).
    pub fn init(spec: &[(String, Vec<usize>)], seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let tensors = spec
            .iter()
            .map(|(_, shape)| {
                if shape.len() == 1 {
                    Tensor::ones(shape)
                } else {
                    let fan_in = *shape.last().unwrap() as f32;
                    Tensor::randn(shape, 0.4 / fan_in.sqrt(), &mut rng)
                }
            })
            .collect();
        Params::new(spec.to_vec(), tensors)
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.index[name]]
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        &mut self.tensors[self.index[name]]
    }

    pub fn try_get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    /// The 9 per-block tensors of layer `l` in block-artifact order
    /// (attn_norm, wq, wk, wv, wo, mlp_norm, w_gate, w_up, w_down).
    pub fn block(&self, l: usize) -> Vec<&Tensor> {
        let names = block_param_names(l);
        names.iter().map(|n| self.get(n)).collect()
    }

    pub fn linear_name(l: usize, lin: &str) -> String {
        format!("l{l}.{lin}")
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(Tensor::numel).sum()
    }

    // ---------------- binary save/load ----------------
    // format: magic "PTQ1" | u32 count | per tensor:
    //   u32 name_len | name | u32 ndim | u64 dims... | f32 data (LE)

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(b"PTQ1")?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for ((name, _), t) in self.spec.iter().zip(&self.tensors) {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    t.data.as_ptr() as *const u8,
                    t.data.len() * 4,
                )
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Params> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"PTQ1" {
            bail!("bad magic in {}", path.display());
        }
        let count = read_u32(&mut f)? as usize;
        let mut spec = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = read_u32(&mut f)? as usize;
            let mut nbuf = vec![0u8; nlen];
            f.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf)
                .map_err(|_| anyhow!("bad name utf8"))?;
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            let bytes: &mut [u8] = unsafe {
                std::slice::from_raw_parts_mut(
                    data.as_mut_ptr() as *mut u8,
                    n * 4,
                )
            };
            f.read_exact(bytes)?;
            spec.push((name, shape.clone()));
            tensors.push(Tensor::from_vec(&shape, data));
        }
        Ok(Params::new(spec, tensors))
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn block_param_names(l: usize) -> Vec<String> {
    let mut v = vec![format!("l{l}.attn_norm")];
    for n in ["wq", "wk", "wv", "wo"] {
        v.push(format!("l{l}.{n}"));
    }
    v.push(format!("l{l}.mlp_norm"));
    for n in ["w_gate", "w_up", "w_down"] {
        v.push(format!("l{l}.{n}"));
    }
    v
}

pub fn linear_shape(cfg: &ModelConfig, lin: &str) -> (usize, usize) {
    match lin {
        "wq" | "wk" | "wv" | "wo" => (cfg.d, cfg.d),
        "w_gate" | "w_up" => (cfg.ffn, cfg.d),
        "w_down" => (cfg.d, cfg.ffn),
        other => panic!("unknown linear {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> Vec<(String, Vec<usize>)> {
        vec![
            ("embed".into(), vec![16, 8]),
            ("l0.attn_norm".into(), vec![8]),
            ("l0.wq".into(), vec![8, 8]),
        ]
    }

    #[test]
    fn init_norms_ones_weights_small() {
        let p = Params::init(&demo_spec(), 1);
        assert!(p.get("l0.attn_norm").data.iter().all(|&x| x == 1.0));
        let w = p.get("l0.wq");
        assert!(w.data.iter().any(|&x| x != 0.0));
        assert!(w.data.iter().all(|&x| x.abs() < 1.0));
    }

    #[test]
    fn save_load_round_trip() {
        let p = Params::init(&demo_spec(), 2);
        let dir = std::env::temp_dir().join("ptq161_test_params.bin");
        p.save(&dir).unwrap();
        let q = Params::load(&dir).unwrap();
        assert_eq!(p.spec, q.spec);
        for (a, b) in p.tensors.iter().zip(&q.tensors) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn block_names_order_matches_python() {
        let names = block_param_names(2);
        assert_eq!(
            names,
            vec![
                "l2.attn_norm", "l2.wq", "l2.wk", "l2.wv", "l2.wo",
                "l2.mlp_norm", "l2.w_gate", "l2.w_up", "l2.w_down"
            ]
        );
    }

    #[test]
    fn total_params_counts() {
        let p = Params::init(&demo_spec(), 3);
        assert_eq!(p.total_params(), 16 * 8 + 8 + 64);
    }
}
