//! Open-loop load harness for the HTTP front door: seeded-Poisson
//! arrivals over a realistic prompt mix, driven by independent client
//! threads so arrival times never depend on completions (the open-loop
//! property — a saturated server keeps receiving load, which is exactly
//! how queueing delay and overload tails become visible). Wall-clock
//! TTFT and inter-token gaps are measured at the *client*, so the
//! exported percentiles include network framing and queueing, not just
//! engine time.
//!
//! The prompt mix mirrors the serving scenarios the scheduler optimizes
//! for: shared-system-prompt chat (exercises the prefix cache and the
//! placement router), long-context summarize (exercises chunked
//! prefill), and short classify (latency-sensitive small requests).
//!
//! Determinism: [`schedule`] is a pure function of its seed — arrival
//! offsets and prompts are identical run to run — while the measured
//! latencies are, of course, wall clock.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

use super::metrics::percentile;
use super::GenRequest;

/// Load-run shape: offered arrival rate, request count, seed, and the
/// model's sequence window (sizes the summarize prompts).
#[derive(Debug, Clone)]
pub struct LoadCfg {
    /// offered arrivals per second (Poisson intensity)
    pub rate_hz: f64,
    /// total requests to offer
    pub requests: usize,
    /// RNG seed — same seed, same schedule
    pub seed: u64,
    /// model sequence window (long-context prompts are sized against it)
    pub seq: usize,
}

/// One scheduled arrival: when (ms from run start), which mix class, and
/// the request itself.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// arrival offset from run start, ms
    pub at_ms: f64,
    /// mix class: "chat" | "summarize" | "classify"
    pub class: &'static str,
    /// the request to submit
    pub req: GenRequest,
}

/// Build the deterministic arrival schedule: exponential inter-arrival
/// gaps at `rate_hz` (cumulative, so the offsets are a Poisson process)
/// and a 50/20/30 chat/summarize/classify mix.
pub fn schedule(cfg: &LoadCfg) -> Vec<Arrival> {
    let mut rng = Rng::new(cfg.seed);
    let mut t_ms = 0.0f64;
    let system = "You are a terse assistant for the PTQ1.61 serving demo. ";
    (0..cfg.requests)
        .map(|i| {
            // inverse-CDF exponential gap; 1-u > 0 so ln is finite
            let gap_s = -(1.0 - rng.f64()).ln() / cfg.rate_hz.max(1e-9);
            t_ms += gap_s * 1000.0;
            let u = rng.f64();
            let (class, prompt, max_new) = if u < 0.5 {
                // shared system prompt: every chat request carries the
                // same prefix, so the prefix cache and placement router
                // are exercised under live arrivals
                (
                    "chat",
                    format!("{system}User {i} asks about topic {}.", rng.below(8)),
                    12,
                )
            } else if u < 0.7 {
                // long-context summarize: prompt sized to most of the
                // window so chunked prefill has something to chunk
                let body = "data ".repeat((cfg.seq * 2 / 3).max(10) / 5 + 1);
                ("summarize", format!("Summarize: {body}"), 8)
            } else {
                ("classify", format!("label {}", rng.below(100)), 2)
            };
            Arrival {
                at_ms: t_ms,
                class,
                req: GenRequest {
                    prompt,
                    max_new_tokens: max_new,
                },
            }
        })
        .collect()
}

/// What one streamed request yielded, measured at the client.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// request id assigned by the server
    pub id: u64,
    /// streamed token ids, in arrival order
    pub tokens: Vec<i32>,
    /// the `done` event's full decoded text
    pub text: String,
    /// request-sent → first token event, wall clock ms
    pub ttft_ms: f64,
    /// client-observed gaps between consecutive token events, ms
    pub itl_ms: Vec<f64>,
    /// request-sent → terminal event, wall clock ms
    pub total_ms: f64,
    /// every token arrived with the expected contiguous `index`
    pub in_order: bool,
}

/// One request's outcome at the HTTP edge.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// streamed to a terminal `done`
    Stream(StreamResult),
    /// shed with `429 Too Many Requests`
    Overloaded {
        /// the server's `Retry-After` hint, seconds
        retry_after_s: f64,
    },
    /// any other failure: non-2xx status, `error` event, or I/O trouble
    Error {
        /// what went wrong
        reason: String,
    },
}

/// Reconstruct the response text a request's streamed token ids imply:
/// the engine's own tokenization rules (window truncation, empty-prompt
/// seeding) applied to the prompt, plus the streamed tokens, byte-
/// decoded. Equal to the `done` event's text iff the stream carried
/// exactly the tokens the engine committed — the identity gate.
pub fn reconstruct_text(prompt: &str, tokens: &[i32], seq_window: usize) -> String {
    let tk = ByteTokenizer;
    let mut seq = tk.encode(prompt);
    seq.truncate(seq_window - 1);
    if seq.is_empty() {
        seq.push(b' ' as i32);
    }
    seq.extend_from_slice(tokens);
    tk.decode(&seq)
}

/// Blocking SSE client: POST one generate request to `addr` and consume
/// the stream, timing TTFT/ITL at the socket.
pub fn http_generate(addr: &str, req: &GenRequest) -> Outcome {
    match try_generate(addr, req) {
        Ok(outcome) => outcome,
        Err(e) => Outcome::Error { reason: format!("io: {e}") },
    }
}

fn try_generate(addr: &str, req: &GenRequest) -> std::io::Result<Outcome> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(60)))?;
    let body = obj(vec![
        ("prompt", s(&req.prompt)),
        ("max_new_tokens", num(req.max_new_tokens as f64)),
    ])
    .dump();
    let sent_at = Instant::now();
    conn.write_all(
        format!(
            "POST /generate HTTP/1.1\r\nHost: {addr}\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    // read the response head
    let mut buf = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let n = conn.read(&mut chunk)?;
        if n == 0 {
            return Ok(Outcome::Error {
                reason: "connection closed before response head".into(),
            });
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split(' ').nth(1))
        .and_then(|code| code.parse().ok())
        .unwrap_or(0);
    if status == 429 {
        let retry = head
            .lines()
            .find_map(|l| {
                l.split_once(':')
                    .filter(|(k, _)| k.trim().eq_ignore_ascii_case("retry-after"))
            })
            .and_then(|(_, v)| v.trim().parse().ok())
            .unwrap_or(0.0);
        return Ok(Outcome::Overloaded { retry_after_s: retry });
    }
    if status != 200 {
        return Ok(Outcome::Error { reason: format!("http status {status}") });
    }
    // SSE body: frames separated by a blank line, each `event:` + `data:`
    let mut body_buf = buf[head_end + 4..].to_vec();
    let mut result = StreamResult {
        id: 0,
        tokens: Vec::new(),
        text: String::new(),
        ttft_ms: 0.0,
        itl_ms: Vec::new(),
        total_ms: 0.0,
        in_order: true,
    };
    let mut last_token_at: Option<Instant> = None;
    loop {
        while let Some(pos) = body_buf.windows(2).position(|w| w == b"\n\n") {
            let frame = String::from_utf8_lossy(&body_buf[..pos]).to_string();
            body_buf.drain(..pos + 2);
            let mut event = "";
            let mut data = String::new();
            for line in frame.lines() {
                if let Some(rest) = line.strip_prefix("event: ") {
                    event = rest;
                } else if let Some(rest) = line.strip_prefix("data: ") {
                    data = rest.to_string();
                }
            }
            let Ok(payload) = Json::parse(&data) else {
                return Ok(Outcome::Error {
                    reason: format!("unparseable SSE data: {data:?}"),
                });
            };
            let now = Instant::now();
            match event {
                "token" => {
                    let index =
                        payload.get("index").and_then(Json::as_usize).unwrap_or(0);
                    let token =
                        payload.get("token").and_then(Json::as_f64).unwrap_or(0.0)
                            as i32;
                    if index != result.tokens.len() {
                        result.in_order = false;
                    }
                    if result.tokens.is_empty() {
                        result.ttft_ms =
                            now.duration_since(sent_at).as_secs_f64() * 1000.0;
                    }
                    if let Some(prev) = last_token_at {
                        result.itl_ms.push(
                            now.duration_since(prev).as_secs_f64() * 1000.0,
                        );
                    }
                    last_token_at = Some(now);
                    result.tokens.push(token);
                    result.id = payload
                        .get("id")
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0) as u64;
                }
                "done" => {
                    result.text = payload
                        .get("text")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string();
                    result.id = payload
                        .get("id")
                        .and_then(Json::as_f64)
                        .unwrap_or(result.id as f64)
                        as u64;
                    result.total_ms =
                        now.duration_since(sent_at).as_secs_f64() * 1000.0;
                    return Ok(Outcome::Stream(result));
                }
                "error" => {
                    let reason = payload
                        .get("reason")
                        .and_then(Json::as_str)
                        .unwrap_or("unknown")
                        .to_string();
                    return Ok(Outcome::Error { reason });
                }
                _ => {}
            }
        }
        let n = conn.read(&mut chunk)?;
        if n == 0 {
            return Ok(Outcome::Error {
                reason: "stream closed before a terminal event".into(),
            });
        }
        body_buf.extend_from_slice(&chunk[..n]);
    }
}

/// What an open-loop run measured, aggregated over its arrivals.
#[derive(Debug)]
pub struct LoadReport {
    /// offered arrival rate (from the schedule)
    pub rate_hz: f64,
    /// requests offered
    pub offered: usize,
    /// requests streamed to `done`
    pub ok: usize,
    /// requests shed with `429`
    pub rejected: usize,
    /// requests that errored (I/O, non-2xx, `error` event)
    pub errors: usize,
    /// streams whose token indices arrived contiguous AND whose
    /// reconstructed text matched the `done` text (the identity gate)
    pub identity_ok: usize,
    /// per-request client-observed TTFT (ok requests only), ms
    pub ttft_ms: Vec<f64>,
    /// client-observed inter-token gaps across ok requests, ms
    pub itl_ms: Vec<f64>,
    /// tokens streamed across ok requests
    pub total_tokens: usize,
    /// first arrival sent → last outcome, wall ms
    pub wall_ms: f64,
    /// per-class offered counts (deterministic order)
    pub class_counts: BTreeMap<&'static str, usize>,
    /// each arrival's outcome, schedule order
    pub outcomes: Vec<Outcome>,
}

impl LoadReport {
    /// Fraction of offered requests that got a terminal answer (stream
    /// or explicit 429) — 1.0 means nothing was dropped on the floor.
    pub fn completion(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        (self.ok + self.rejected) as f64 / self.offered as f64
    }

    /// Fraction of streamed requests that passed the identity gate.
    pub fn identity(&self) -> f64 {
        if self.ok == 0 {
            return 1.0;
        }
        self.identity_ok as f64 / self.ok as f64
    }

    /// Tokens per second over the run's wall clock.
    pub fn achieved_tok_s(&self) -> f64 {
        1000.0 * self.total_tokens as f64 / self.wall_ms.max(1e-6)
    }

    /// The report as a JSON object (what `load` and bench part 8 export).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("rate_hz", num(self.rate_hz)),
            ("offered", num(self.offered as f64)),
            ("ok", num(self.ok as f64)),
            ("rejected_429", num(self.rejected as f64)),
            ("errors", num(self.errors as f64)),
            ("completion", num(self.completion())),
            ("identity", num(self.identity())),
            ("ttft_p50_ms", num(percentile(&self.ttft_ms, 0.50))),
            ("ttft_p95_ms", num(percentile(&self.ttft_ms, 0.95))),
            ("ttft_p99_ms", num(percentile(&self.ttft_ms, 0.99))),
            ("itl_p50_ms", num(percentile(&self.itl_ms, 0.50))),
            ("itl_p99_ms", num(percentile(&self.itl_ms, 0.99))),
            ("total_tokens", num(self.total_tokens as f64)),
            ("wall_ms", num(self.wall_ms)),
            ("achieved_tok_s", num(self.achieved_tok_s())),
            (
                "achieved_req_s",
                num(1000.0 * self.ok as f64 / self.wall_ms.max(1e-6)),
            ),
            (
                "classes",
                obj(self
                    .class_counts
                    .iter()
                    .map(|(k, v)| (*k, num(*v as f64)))
                    .collect()),
            ),
        ])
    }
}

/// Drive `arrivals` against the HTTP edge at `addr`, open-loop: one
/// client thread per arrival, each sleeping until its scheduled offset
/// and then issuing its request regardless of how the others are faring.
/// `seq_window` is the served model's window (for the identity
/// reconstruction).
pub fn run_open_loop(
    addr: &str,
    arrivals: &[Arrival],
    rate_hz: f64,
    seq_window: usize,
) -> LoadReport {
    let t0 = Instant::now();
    let slots: Mutex<Vec<Option<Outcome>>> =
        Mutex::new(vec![None; arrivals.len()]);
    thread::scope(|scope| {
        for (i, a) in arrivals.iter().enumerate() {
            let slots = &slots;
            scope.spawn(move || {
                let target = t0 + Duration::from_secs_f64(a.at_ms / 1000.0);
                let wait = target.saturating_duration_since(Instant::now());
                if !wait.is_zero() {
                    thread::sleep(wait);
                }
                let outcome = http_generate(addr, &a.req);
                slots.lock().unwrap()[i] = Some(outcome);
            });
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1000.0;
    let outcomes: Vec<Outcome> = slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every arrival thread records an outcome"))
        .collect();
    let mut report = LoadReport {
        rate_hz,
        offered: arrivals.len(),
        ok: 0,
        rejected: 0,
        errors: 0,
        identity_ok: 0,
        ttft_ms: Vec::new(),
        itl_ms: Vec::new(),
        total_tokens: 0,
        wall_ms,
        class_counts: BTreeMap::new(),
        outcomes: Vec::new(),
    };
    for (a, outcome) in arrivals.iter().zip(&outcomes) {
        *report.class_counts.entry(a.class).or_insert(0) += 1;
        match outcome {
            Outcome::Stream(sr) => {
                report.ok += 1;
                report.total_tokens += sr.tokens.len();
                report.ttft_ms.push(sr.ttft_ms);
                report.itl_ms.extend(sr.itl_ms.iter().copied());
                let rebuilt =
                    reconstruct_text(&a.req.prompt, &sr.tokens, seq_window);
                if sr.in_order && rebuilt == sr.text {
                    report.identity_ok += 1;
                }
            }
            Outcome::Overloaded { .. } => report.rejected += 1,
            Outcome::Error { .. } => report.errors += 1,
        }
    }
    report.outcomes = outcomes;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(seed: u64) -> LoadCfg {
        LoadCfg { rate_hz: 50.0, requests: 40, seed, seq: 48 }
    }

    #[test]
    fn schedule_is_deterministic_under_a_seed() {
        let a = schedule(&cfg(7));
        let b = schedule(&cfg(7));
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_ms, y.at_ms);
            assert_eq!(x.class, y.class);
            assert_eq!(x.req.prompt, y.req.prompt);
            assert_eq!(x.req.max_new_tokens, y.req.max_new_tokens);
        }
        // a different seed yields a different schedule
        let c = schedule(&cfg(8));
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.at_ms != y.at_ms
                || x.req.prompt != y.req.prompt),
            "seeds must differentiate the schedule"
        );
    }

    #[test]
    fn schedule_arrivals_increase_and_mix_covers_classes() {
        let arrivals = schedule(&cfg(3));
        for w in arrivals.windows(2) {
            assert!(w[1].at_ms > w[0].at_ms, "Poisson offsets are cumulative");
        }
        let classes: std::collections::HashSet<&str> =
            arrivals.iter().map(|a| a.class).collect();
        assert!(classes.contains("chat"));
        assert!(classes.contains("summarize"));
        assert!(classes.contains("classify"));
        // shared-system-prompt chat requests really share a prefix
        let chats: Vec<&Arrival> =
            arrivals.iter().filter(|a| a.class == "chat").collect();
        assert!(chats.len() >= 2);
        let prefix = &chats[0].req.prompt[..40];
        assert!(chats.iter().all(|a| a.req.prompt.starts_with(prefix)));
    }

    #[test]
    fn reconstruct_text_applies_engine_tokenization_rules() {
        // empty prompt seeds a space, long prompts truncate to seq-1 —
        // identical to Engine::make_lane
        let text = reconstruct_text("", &[b'h' as i32, b'i' as i32], 16);
        assert_eq!(text, " hi");
        let long = "x".repeat(100);
        let text = reconstruct_text(&long, &[b'!' as i32], 8);
        assert_eq!(text, format!("{}!", "x".repeat(7)));
    }

    #[test]
    fn empty_report_is_vacuously_complete() {
        let r = LoadReport {
            rate_hz: 1.0,
            offered: 0,
            ok: 0,
            rejected: 0,
            errors: 0,
            identity_ok: 0,
            ttft_ms: vec![],
            itl_ms: vec![],
            total_tokens: 0,
            wall_ms: 1.0,
            class_counts: BTreeMap::new(),
            outcomes: vec![],
        };
        assert_eq!(r.completion(), 1.0);
        assert_eq!(r.identity(), 1.0);
        let j = Json::parse(&r.to_json().dump()).unwrap();
        assert_eq!(j.get("completion").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("ttft_p99_ms").and_then(Json::as_f64), Some(0.0));
    }
}
