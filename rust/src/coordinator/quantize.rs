//! Whole-model quantization driver: applies a layer-wise quantizer to every
//! block linear, producing a dense fake-quantized model (the paper's eval
//! contract) plus storage accounting and, for PTQ1.61, the structured parts
//! for the fused-kernel path and the block-wise optimizer.

use anyhow::Result;

use super::capture::ModelCalib;
use super::Pipeline;
use crate::model::{Params, LINEARS};
use crate::quant::{ArcContainer, Ptq161Parts, Quantizer};

pub struct QuantModel {
    pub method: String,
    pub bits_label: String,
    /// dense fake-quantized model (norms/embeddings/head untouched)
    pub params: Params,
    /// PTQ1.61 structured parts per [layer][linear]
    pub parts: Option<Vec<Vec<Ptq161Parts>>>,
    /// serve-ready packed containers per [layer][linear] (all-or-nothing:
    /// `Some` only when every block linear emitted one at quantization
    /// time — the methods the packed backend can serve directly)
    pub containers: Option<Vec<Vec<ArcContainer>>>,
    /// weight-count-weighted average effective bits over quantized linears
    pub avg_bits: f64,
}

pub fn quantize_model(
    pipe: &Pipeline,
    params: &Params,
    calib: &ModelCalib,
    method: &dyn Quantizer,
) -> Result<QuantModel> {
    let cfg = &pipe.cfg;
    let mut out = params.clone();
    let mut parts_all: Vec<Vec<Ptq161Parts>> = Vec::new();
    let mut containers_all: Vec<Vec<ArcContainer>> = Vec::new();
    let mut bits_acc = 0.0f64;
    let mut weights_acc = 0.0f64;
    let mut have_parts = true;
    let mut have_containers = true;
    for l in 0..cfg.n_layers {
        let mut layer_parts = Vec::new();
        let mut layer_containers = Vec::new();
        for lin in LINEARS {
            let name = format!("l{l}.{lin}");
            let w = params.get(&name);
            let q = method.quantize_linear(w, calib.get(l, lin));
            bits_acc += q.avg_bits() * w.numel() as f64;
            weights_acc += w.numel() as f64;
            if let Some(p) = &q.parts {
                layer_parts.push(p.clone());
            } else {
                have_parts = false;
            }
            if let Some(c) = &q.container {
                layer_containers.push(c.clone());
            } else {
                have_containers = false;
            }
            *out.get_mut(&name) = q.deq;
        }
        parts_all.push(layer_parts);
        containers_all.push(layer_containers);
    }
    Ok(QuantModel {
        method: method.name().to_string(),
        bits_label: method.bits_label(),
        params: out,
        parts: if have_parts { Some(parts_all) } else { None },
        containers: if have_containers { Some(containers_all) } else { None },
        avg_bits: bits_acc / weights_acc,
    })
}

impl QuantModel {
    /// Rebuild the dense params from (possibly optimizer-updated) parts.
    pub fn refresh_dense_from_parts(&mut self) {
        if let Some(parts) = &self.parts {
            for (l, layer) in parts.iter().enumerate() {
                for (i, lin) in LINEARS.iter().enumerate() {
                    let name = format!("l{l}.{lin}");
                    *self.params.get_mut(&name) = layer[i].dequantize();
                }
            }
        }
    }
}
