//! Prepared packed-weight serve-path tests (tier-1, no artifacts needed).
//!
//! Gates the tentpole invariants of the packed backend: every quantized
//! layer packs into its 1.61-bit containers and round-trips bit-exactly,
//! the packed matvec agrees with the fused qlinear to float roundoff, a
//! packed engine run decodes token-identically to the fused path while
//! performing **zero** dense-weight reconstructions, and the serve
//! metrics carry the cache/packed memory accounting.

use std::sync::Mutex;

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::{initial_parts, PackedLinear, PackedModel};
use ptq161::quant::{by_name, LinearCalib, Ptq161Parts};
use ptq161::runtime::autodiff::{
    kernel_tier, packed_decode_fwd, packed_qlinear_fwd,
    packed_qlinear_fwd_scalar, qlinear_fwd, qlinear_weight_reconstructions,
};
use ptq161::runtime::pool;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, GenResponse, MetricsRegistry};
use ptq161::tensor::Tensor;
use ptq161::util::rng::Rng;

/// The reconstruction counter is process-global; tests that read deltas
/// or call qlinear paths serialize on this so parallel test threads can't
/// perturb each other's counts.
static QLINEAR_LOCK: Mutex<()> = Mutex::new(());

/// Tests that mutate process-global dispatch state (the
/// `PTQ161_FORCE_SCALAR` env var, the pool's split threshold or thread
/// budget) serialize here so concurrent tests see a stable tier.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// A [`PackedLinear`] over a seeded random weight with an arbitrary
/// salient-column mask (the shape-edge suites sweep `out % 4`,
/// `inn % 64`, zero-salient and all-salient layouts).
fn random_packed(
    out: usize,
    inn: usize,
    mask: &dyn Fn(usize) -> bool,
    rng: &mut Rng,
) -> PackedLinear {
    let w = Tensor::randn(&[out, inn], 0.2, rng);
    let mask: Vec<bool> = (0..inn).map(mask).collect();
    let mut parts = initial_parts(&w, &mask);
    for v in parts.alpha_r2.iter_mut() {
        *v = 1.0 + 0.1 * rng.normal();
    }
    for v in parts.mu.iter_mut() {
        *v = 0.05 * rng.normal();
    }
    PackedLinear::pack(&parts)
}

/// Epsilon gate for the re-associating tiers: each output is a
/// length-`inn` product chain against bounded container values, so drift
/// between association orders scales with `inn · Σ|x|` ulps.
fn assert_close_to_oracle(got: &Tensor, want: &Tensor, x: &Tensor, tag: &str) {
    assert_eq!(got.shape, want.shape, "{tag} shape");
    let inn = *x.shape.last().unwrap();
    let rows = x.data.len() / inn.max(1);
    let mut tol = 0.0f32;
    for r in 0..rows {
        let sum_abs: f32 =
            x.data[r * inn..(r + 1) * inn].iter().map(|v| v.abs()).sum();
        tol = tol.max(8.0 * f32::EPSILON * inn as f32 * (1.0 + sum_abs));
    }
    for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (a - b).abs() <= tol,
            "{tag}: deployed kernel drifted from the scalar oracle at \
             {i}: {a} vs {b} (tol {tol})"
        );
    }
}

/// PTQ1.61 parts for every linear of every layer, with blockopt-like
/// learned (non-identity) scaling factors so the packed kernel's r2/mu
/// paths are exercised.
fn learned_parts(
    params: &Params,
    pipe: &Pipeline,
    seed: u64,
    with_mu: bool,
) -> Vec<Vec<Ptq161Parts>> {
    let mut rng = Rng::new(seed);
    (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> =
                        (0..w.cols()).map(|j| j % 4 == 0).collect();
                    let mut p = initial_parts(w, &mask);
                    for v in p.alpha_r1.iter_mut() {
                        *v = 1.0 + 0.05 * rng.normal();
                    }
                    for v in p.alpha_r2.iter_mut() {
                        *v = 1.0 + 0.05 * rng.normal();
                    }
                    if with_mu {
                        for v in p.mu.iter_mut() {
                            *v = 0.01 * rng.normal();
                        }
                    }
                    p
                })
                .collect()
        })
        .collect()
}

/// Run the engine over a fixed skewed workload (mid-flight refill on
/// micro's 2 lanes), responses sorted by request id.
fn run_workload(pipe: &Pipeline, me: &ModelEval) -> Vec<GenResponse> {
    let lens = [2usize, 7, 1, 3, 1];
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for (i, &n) in lens.iter().enumerate() {
        batcher.submit(GenRequest { prompt: format!("pq{i}"), max_new_tokens: n });
    }
    let mut metrics = MetricsRegistry::new("packed_test");
    let mut engine = Engine::new(pipe, me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(engine.kv_cache().in_use_count(), 0, "leaked slots");
    resps
}

#[test]
fn every_layer_packs_and_round_trips_bit_exactly() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(61);
    let parts = learned_parts(&params, &pipe, 62, true);
    for (l, layer) in parts.iter().enumerate() {
        for (i, p) in layer.iter().enumerate() {
            let packed = PackedLinear::pack(p);
            let back = packed.unpack();
            let tag = format!("l{l}.{}", LINEARS[i]);
            assert_eq!(back.mask, p.mask, "{tag} mask");
            assert_eq!(back.w_sal.data, p.w_sal.data, "{tag} w_sal");
            assert_eq!(back.sign_ns.data, p.sign_ns.data, "{tag} signs");
            assert_eq!(back.alpha_s, p.alpha_s, "{tag} alpha_s");
            assert_eq!(back.alpha_r1, p.alpha_r1, "{tag} alpha_r1");
            assert_eq!(back.alpha_r2, p.alpha_r2, "{tag} alpha_r2");
            assert_eq!(back.mu, p.mu, "{tag} mu");
            assert_eq!(back.sal_q, p.sal_q, "{tag} sal_q");
        }
    }
}

#[test]
fn packed_matvec_matches_fused_qlinear() {
    let _g = QLINEAR_LOCK.lock().unwrap();
    let (out, inn) = (24, 40);
    let mut rng = Rng::new(63);
    let w = Tensor::randn(&[out, inn], 0.2, &mut rng);
    let mask: Vec<bool> = (0..inn).map(|j| j % 3 == 0).collect();
    let mut parts = initial_parts(&w, &mask);
    for v in parts.alpha_r2.iter_mut() {
        *v = 1.0 + 0.1 * rng.normal();
    }
    for v in parts.mu.iter_mut() {
        *v = 0.05 * rng.normal();
    }
    let pl = PackedLinear::pack(&parts);
    let x = Tensor::randn(&[3, 5, inn], 1.0, &mut rng);
    let a_s = Tensor::from_vec(&[out], parts.alpha_s.clone());
    let r1 = Tensor::from_vec(&[out], parts.alpha_r1.clone());
    let r2 = Tensor::from_vec(&[inn], parts.alpha_r2.clone());
    let mu = Tensor::from_vec(&[out], parts.mu.clone());
    let fused =
        qlinear_fwd(&x, &a_s, &r1, &r2, &mu, &parts.w_sal, &parts.sign_ns);
    let packed = packed_qlinear_fwd(&x, &pl);
    assert_eq!(packed.shape, fused.shape);
    let m = packed.mse(&fused);
    assert!(m < 1e-10, "packed matvec deviates: mse {m}");
}

#[test]
fn blocked_matvec_bit_identical_to_scalar_kernel() {
    // the 4-row-tiled whole-word kernel must reproduce the scalar set-bit
    // walk bit-for-bit — same ascending accumulation order per row, and
    // the masked adds of the tile pass are exact no-ops for unset bits.
    // Odd row counts exercise the scalar remainder tail.
    let mut rng = Rng::new(68);
    for (out, inn) in [(24usize, 40usize), (27, 70), (3, 129), (65, 64)] {
        let w = Tensor::randn(&[out, inn], 0.2, &mut rng);
        let mask: Vec<bool> = (0..inn).map(|j| j % 5 == 0).collect();
        let mut parts = initial_parts(&w, &mask);
        for v in parts.alpha_r2.iter_mut() {
            *v = 1.0 + 0.1 * rng.normal();
        }
        for v in parts.mu.iter_mut() {
            *v = 0.05 * rng.normal();
        }
        let pl = PackedLinear::pack(&parts);
        let x = Tensor::randn(&[2, 3, inn], 1.0, &mut rng);
        let blocked = packed_qlinear_fwd(&x, &pl);
        let scalar = packed_qlinear_fwd_scalar(&x, &pl);
        assert_eq!(blocked.shape, scalar.shape);
        assert_eq!(
            blocked.data, scalar.data,
            "blocked kernel deviates from scalar at ({out},{inn})"
        );
    }
}

#[test]
fn deployed_dispatch_matches_scalar_oracle_on_shape_edges() {
    // the deployed tier (SIMD where the host supports it, blocked
    // otherwise) is epsilon-gated against the scalar oracle across the
    // layouts that exercise every kernel edge: out % 4 tails, inn % 64
    // sign-word tails, a zero-salient row set (empty nibble stream) and
    // an all-salient one (empty sign words)
    let _g = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(90);
    let cases: Vec<(usize, usize, Box<dyn Fn(usize) -> bool>)> = vec![
        (5, 70, Box::new(|j| j % 5 == 0)),
        (8, 64, Box::new(|j| j % 3 == 0)),
        (3, 129, Box::new(|j| j % 7 == 1)),
        (33, 100, Box::new(|j| j % 4 == 2)),
        (9, 80, Box::new(|_| false)), // zero salient: pure sign kernel
        (9, 80, Box::new(|_| true)),  // all salient: empty sign words
    ];
    for (i, (out, inn, mask)) in cases.iter().enumerate() {
        let pl = random_packed(*out, *inn, mask.as_ref(), &mut rng);
        for batch in [1usize, 3] {
            let x = Tensor::randn(&[batch, *inn], 1.0, &mut rng);
            let got = packed_decode_fwd(&x, &pl);
            let want = packed_qlinear_fwd_scalar(&x, &pl);
            assert_close_to_oracle(
                &got,
                &want,
                &x,
                &format!("case {i} ({out}x{inn}) batch {batch}"),
            );
        }
    }
}

#[test]
fn forced_scalar_dispatch_is_bit_identical_to_oracle() {
    let _g = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(91);
    let pl = random_packed(21, 75, &|j| j % 5 == 0, &mut rng);
    let x = Tensor::randn(&[2, 75], 1.0, &mut rng);
    // restore the prior value afterwards: the CI simd-matrix lane runs
    // this whole binary with the variable pinned
    let prev = std::env::var("PTQ161_FORCE_SCALAR").ok();
    std::env::set_var("PTQ161_FORCE_SCALAR", "1");
    assert_eq!(kernel_tier(), "scalar");
    let forced = packed_decode_fwd(&x, &pl);
    match &prev {
        Some(v) => std::env::set_var("PTQ161_FORCE_SCALAR", v),
        None => std::env::remove_var("PTQ161_FORCE_SCALAR"),
    }
    let oracle = packed_qlinear_fwd_scalar(&x, &pl);
    assert_eq!(
        forced.data, oracle.data,
        "PTQ161_FORCE_SCALAR=1 must pin the scalar oracle bit-for-bit"
    );
}

#[test]
fn forced_scalar_engine_run_token_identical() {
    // the whole serve loop under PTQ161_FORCE_SCALAR=1 must decode the
    // same tokens as the deployed dispatch — the CI simd-matrix lane's
    // in-process equivalent
    let _eg = ENV_LOCK.lock().unwrap();
    let _g = QLINEAR_LOCK.lock().unwrap();
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(92);
    let parts = learned_parts(&params, &pipe, 93, false);
    let packed = PackedModel::pack(&parts);
    let me = ModelEval::Packed { params: &params, packed: &packed };
    let deployed = run_workload(&pipe, &me);
    let prev = std::env::var("PTQ161_FORCE_SCALAR").ok();
    std::env::set_var("PTQ161_FORCE_SCALAR", "1");
    let forced = run_workload(&pipe, &me);
    match &prev {
        Some(v) => std::env::set_var("PTQ161_FORCE_SCALAR", v),
        None => std::env::remove_var("PTQ161_FORCE_SCALAR"),
    }
    assert_eq!(deployed.len(), forced.len());
    for (d, f) in deployed.iter().zip(&forced) {
        assert_eq!(d.id, f.id);
        assert_eq!(
            d.text, f.text,
            "request {} tokens diverge between scalar and deployed tiers",
            d.id
        );
    }
}

#[test]
fn parallel_split_bit_identical_to_serial() {
    // force real multi-chunk splits (threshold floored, budget raised
    // past the host's core count) and require bit-identity with the
    // serial walk for both the scalar and blocked kernels, in both split
    // regimes: many batch rows (batch split) and one wide matvec row
    // (output split)
    let _g = ENV_LOCK.lock().unwrap();
    let mut rng = Rng::new(94);
    let pl = random_packed(37, 96, &|j| j % 5 == 0, &mut rng);
    let xs = [
        Tensor::randn(&[6, 96], 1.0, &mut rng),
        Tensor::randn(&[1, 96], 1.0, &mut rng),
    ];
    let b0 = pool::thread_budget();
    for x in &xs {
        pool::set_local_intra(1);
        let serial_scalar = packed_qlinear_fwd_scalar(x, &pl);
        let serial_blocked = packed_qlinear_fwd(x, &pl);
        pool::set_split_threshold_for_tests(1);
        pool::set_thread_budget(4);
        pool::set_local_intra(4);
        let split_scalar = packed_qlinear_fwd_scalar(x, &pl);
        let split_blocked = packed_qlinear_fwd(x, &pl);
        pool::set_split_threshold_for_tests(pool::MIN_SPLIT_BYTES);
        pool::set_thread_budget(b0);
        pool::set_local_intra(1);
        assert_eq!(
            split_scalar.data, serial_scalar.data,
            "scalar kernel must be split-invariant (batch {})",
            x.shape[0]
        );
        assert_eq!(
            split_blocked.data, serial_blocked.data,
            "blocked kernel must be split-invariant (batch {})",
            x.shape[0]
        );
    }
}

#[test]
fn packed_engine_token_identical_with_zero_reconstructions() {
    let _g = QLINEAR_LOCK.lock().unwrap();
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(64);
    // mu off: the standard PTQ1.61 configuration the serve path defaults to
    let parts = learned_parts(&params, &pipe, 65, false);
    let packed = PackedModel::pack(&parts);
    let fused = ModelEval::Fused { params: &params, parts: &parts };
    let pk = ModelEval::Packed { params: &params, packed: &packed };
    let f0 = qlinear_weight_reconstructions();
    let fused_out = run_workload(&pipe, &fused);
    let fused_recons = qlinear_weight_reconstructions() - f0;
    assert!(fused_recons > 0, "fused path must rebuild Wq' per forward");
    let p0 = qlinear_weight_reconstructions();
    let packed_out = run_workload(&pipe, &pk);
    let packed_recons = qlinear_weight_reconstructions() - p0;
    assert_eq!(
        packed_recons, 0,
        "packed decode loop must never reconstruct dense weights"
    );
    for (f, p) in fused_out.iter().zip(&packed_out) {
        assert_eq!(f.id, p.id);
        assert_eq!(f.text, p.text, "request {} tokens diverge", f.id);
    }
}

/// Quantize every block linear with `method` (synthetic calibration),
/// writing each dense dequantized weight back into a params clone (the
/// dense baseline the packed run must match byte-for-byte) and collecting
/// the emitted containers into a prepared [`PackedModel`].
fn quantized_model(
    pipe: &Pipeline,
    params: &Params,
    method: &str,
    seed: u64,
) -> (Params, PackedModel) {
    let mut rng = Rng::new(seed);
    let q = by_name(method).unwrap();
    let mut dense = params.clone();
    let mut layers = Vec::new();
    for l in 0..pipe.cfg.n_layers {
        let mut layer = Vec::new();
        for lin in LINEARS {
            let name = format!("l{l}.{lin}");
            let w = params.get(&name);
            let inn = w.cols();
            let x = Tensor::randn(&[2 * inn, inn], 1.0, &mut rng);
            let mut calib = LinearCalib::empty(inn);
            calib.accumulate(&x, true);
            let ql = q.quantize_linear(w, &calib);
            *dense.get_mut(&name) = ql.deq;
            layer.push(ql.container.unwrap_or_else(|| {
                panic!("{method} must emit a container for {name}")
            }));
        }
        layers.push(layer);
    }
    (dense, PackedModel::from_containers(method, &layers))
}

#[test]
fn cross_method_packed_token_identical_to_dense() {
    // The tentpole invariant, per method: serving from prepared containers
    // must decode byte-identical tokens to the dense dequantized weights,
    // with zero per-step dense-weight reconstructions. Holds by
    // construction because every container's decode kernel accumulates in
    // the dense kernel's exact order (gated per-op by the property suite
    // in tests/packed_containers.rs; this gates the end-to-end engine).
    let _g = QLINEAR_LOCK.lock().unwrap();
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(71);
    for (i, method) in ["rtn2", "gptq2", "pbllm", "billm"].iter().enumerate() {
        let (dense, packed) =
            quantized_model(&pipe, &params, method, 72 + i as u64);
        assert_eq!(packed.method(), *method);
        let bits = packed.effective_bits();
        assert!(
            bits > 1.0 && bits < 16.0,
            "{method}: implausible bits/weight {bits}"
        );
        let de = ModelEval::Dense(&dense);
        let pe = ModelEval::Packed { params: &dense, packed: &packed };
        let dense_out = run_workload(&pipe, &de);
        let p0 = qlinear_weight_reconstructions();
        let packed_out = run_workload(&pipe, &pe);
        assert_eq!(
            qlinear_weight_reconstructions() - p0,
            0,
            "{method}: packed decode must never reconstruct dense weights"
        );
        assert_eq!(dense_out.len(), packed_out.len());
        for (d, p) in dense_out.iter().zip(&packed_out) {
            assert_eq!(d.id, p.id);
            assert_eq!(
                d.text, p.text,
                "{method}: request {} tokens diverge from dense",
                d.id
            );
        }
    }
}

#[test]
fn packed_engine_exports_memory_accounting() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(66);
    let parts = learned_parts(&params, &pipe, 67, false);
    let packed = PackedModel::pack(&parts);
    let me = ModelEval::Packed { params: &params, packed: &packed };
    let lens = [1usize, 4, 2];
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for (i, &n) in lens.iter().enumerate() {
        batcher.submit(GenRequest {
            prompt: format!("mem{i}"),
            max_new_tokens: n,
        });
    }
    let mut metrics = MetricsRegistry::new("packed_mem");
    let mut engine = Engine::new(&pipe, &me);
    assert_eq!(engine.cfg.backend, "packed");
    let resps = engine.run(&mut batcher, &mut metrics).unwrap();
    assert_eq!(resps.len(), lens.len());
    // engine-recorded memory split: KV page pool + packed containers
    assert_eq!(metrics.backend.as_deref(), Some("packed"));
    assert_eq!(metrics.kv_reserved_bytes, Some(engine.kv_cache().bytes()));
    assert_eq!(
        metrics.kv_live_bytes,
        Some(engine.kv_cache().peak_live_bytes())
    );
    let live = metrics.kv_live_bytes.unwrap();
    assert!(
        live > 0 && live < metrics.kv_reserved_bytes.unwrap(),
        "live occupancy {live} must undershoot the reserved pool"
    );
    assert_eq!(metrics.packed_method.as_deref(), Some("ptq161"));
    assert_eq!(
        metrics.packed_model_bytes,
        Some(packed.resident_bytes())
    );
    let bits = metrics.packed_bits_per_weight.unwrap();
    assert!(
        (bits - packed.effective_bits()).abs() < 1e-12 && bits > 1.0,
        "bits {bits}"
    );
    // micro's tiny layers inflate the fp16 vector share well above the
    // paper's 4096^2 figure; the claim here is plumbing, not the 1.61
    assert!(bits < 16.0, "bits {bits}");
    // kernel-dispatch accounting: the run exports its tier, intra-op
    // thread allowance, and a nonzero in-kernel time window
    let tier = metrics.simd.as_deref().unwrap();
    assert!(
        ["scalar", "blocked", "avx2", "neon"].contains(&tier),
        "unknown kernel tier {tier}"
    );
    assert!(metrics.intra_threads.unwrap() >= 1);
    assert!(
        metrics.kernel_ns.unwrap() > 0,
        "decode steps must charge the kernel counter"
    );
    let share = metrics.kernel_step_share();
    assert!((0.0..=1.0).contains(&share), "share {share}");
    // per-request cached-position high-water marks: prefill caches the
    // prompt, then one position per extra decoded token
    for r in &metrics.requests {
        let prompt_len = 4; // "mem{i}" is 4 bytes
        assert_eq!(
            r.cached_positions,
            prompt_len + r.new_tokens - 1,
            "request {} high-water mark",
            r.id
        );
    }
    assert_eq!(metrics.peak_cached_positions(), 4 + 4 - 1);
}
