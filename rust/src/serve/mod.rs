//! Serving layer: continuous-batching generation over the eval pipeline.
//!
//! * [`batcher`] — admission queues: the single-loop [`batcher::Batcher`]
//!   (FIFO, max-wait cut, deadlines) and the multi-worker
//!   [`batcher::ShardedQueue`] (per-worker shards, work stealing,
//!   placement-aware submit)
//! * [`engine`] — slot-based continuous-batching decode loop with paged
//!   KV-cached incremental decode and batched prefill (plus the
//!   full-window and drain/static baselines it is benchmarked against)
//! * [`metrics`] — per-request latency split, percentiles, lane occupancy,
//!   per-step wall times, paged-cache memory/sharing accounting, JSON
//!   export into `runs_dir()`
//!
//! Each lane binds to a lane of the engine's paged
//! [`crate::runtime::kv::KvCache`]: admission reserves the request's
//! worst-case *page* budget (backpressuring on pool exhaustion, not lane
//! count), prompts are prefilled in batched same-length buckets — with
//! positions covered by a shared whole-page prompt prefix adopted from
//! the cache's content-keyed index instead of recomputed — and every
//! subsequent step decodes one new token per lane against cached K/V, so
//! per-token cost is flat in sequence position (see `ARCHITECTURE.md`
//! for the request data flow). For PTQ1.61 the production backend is
//! `ModelEval::Packed`: weights stay resident in the prepared 1.61-bit
//! containers (`crate::quant::ptq161::packed`) and every decode step
//! contracts them directly — no dense-weight reconstruction. At this
//! scale the absolute numbers characterize the native CPU path (the
//! paper's F.3 discussion); the scheduling/caching/backend wins — lane
//! refill beating batch drain, cached decode beating full-window
//! re-reads, packed beating the rebuild-Wq' fused path — are measured by
//! `benches/bench_serve.rs`.
//!
//! **Multi-worker**: [`engine::run_sharded`] fans the lane pool and the
//! page pool across N OS threads pulling from one work-stealing
//! [`batcher::ShardedQueue`], with prefix-cache-aware placement and
//! byte-identical tokens for every worker count (see `ARCHITECTURE.md`).

pub mod batcher;
pub mod engine;
pub mod http;
pub mod load;
pub mod metrics;
pub mod stream;

use anyhow::Result;

pub use batcher::{Batcher, ShardedQueue};
pub use engine::{
    effective_workers, place_request, run_sharded, run_sharded_live, Engine,
    EngineCfg, ShardRun, ShardSpec,
};
pub use http::{serve_http, HttpServerCfg};
pub use load::{run_open_loop, schedule, Arrival, LoadCfg, LoadReport};
pub use metrics::{percentile, MetricsRegistry, RequestMetric, WorkerStat};
pub use stream::{EmitHub, TokenEvent};

use crate::coordinator::Pipeline;
use crate::eval::ModelEval;

/// One generation request as submitted to the batcher.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Byte-tokenized verbatim; an empty prompt is seeded with a single
    /// space token (the decoder needs at least one context position), so
    /// its response text starts with that space.
    pub prompt: String,
    /// Budget of new tokens (clamped so prompt + new fits the window;
    /// zero-token requests complete at admission without a lane).
    pub max_new_tokens: usize,
}

/// One finished request: decoded text plus its latency split.
#[derive(Debug, Clone)]
pub struct GenResponse {
    /// request id assigned at submit
    pub id: u64,
    /// prompt + generated tokens, byte-decoded
    pub text: String,
    /// tokens actually generated
    pub new_tokens: usize,
    /// submit -> lane admission
    pub queue_ms: f64,
    /// lane admission -> last token
    pub decode_ms: f64,
    /// submit -> last token
    pub latency_ms: f64,
}

/// Greedy-generate for up to b_eval requests at once (legacy one-shot
/// contract, now a thin wrapper over the engine's drain mode). Responses
/// come back in request order.
pub fn generate_batch(
    pipe: &Pipeline,
    model: &ModelEval,
    requests: &[GenRequest],
) -> Result<Vec<GenResponse>> {
    let mut engine = Engine::new(pipe, model);
    let mut metrics = MetricsRegistry::new("generate_batch");
    engine.run_drain_batch(requests, &mut metrics)
}

