//! Serving demo: continuous-batching generation from the quantized model
//! with queue/decode latency accounting (paper section F) plus the
//! packed-memory comparison of Table 12.
//!
//!   cargo run --release --example serve_demo

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::experiments::ExperimentCtx;
use ptq161::packing::bitwidth::BitScheme;
use ptq161::packing::memory::table12_row;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::{Engine, GenRequest, MetricsRegistry};

fn main() -> Result<()> {
    let mut ctx = ExperimentCtx::quick()?;
    let qm = ctx.quantized("tiny", "ptq161", true)?;
    let pipe = Pipeline::new(&ctx.rt, "tiny")?;
    let model = ModelEval::Dense(&qm.params);

    // skewed generation lengths: continuous batching refills the short
    // requests' lanes while the long ones keep decoding
    let prompts = [
        ("the quiet river of alda holds the ", 24),
        ("key boris is ", 6),
        ("3 plus 4 equals ", 4),
        ("the golden tower of celia ", 24),
        ("you know darin finds a ", 6),
        ("in the end it was the ", 8),
        ("the ancient engine of elena ", 24),
        ("key mira is ", 6),
    ];
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for (p, n) in prompts {
        batcher.submit(GenRequest { prompt: p.into(), max_new_tokens: n });
    }
    let mut metrics = MetricsRegistry::new("serve_demo");
    let mut engine = Engine::new(&pipe, &model);
    println!(
        "kv cache: {} lanes x {} positions, {} pages of {} ({:.1} KiB pool)",
        engine.kv_cache().slots(),
        engine.kv_cache().capacity(),
        engine.kv_cache().total_pages(),
        engine.kv_cache().page_size(),
        engine.kv_cache().bytes() as f64 / 1024.0
    );
    let resps = engine.run(&mut batcher, &mut metrics)?;
    println!(
        "kv live peak {:.1} KiB of {:.1} KiB pool | prefix hit rate {:.2}",
        engine.kv_cache().peak_live_bytes() as f64 / 1024.0,
        engine.kv_cache().bytes() as f64 / 1024.0,
        metrics.prefix_hit_rate()
    );
    for r in resps {
        let text: String = r.text.replace('\n', " ").chars().take(64).collect();
        println!("-> [{:>2}] +{:<2} tok  {text}", r.id, r.new_tokens);
    }
    println!();
    metrics.print_summary();
    let path = ptq161::runs_dir().join("serve_demo_metrics.json");
    metrics.write_json(&path)?;
    println!("metrics written to {}", path.display());

    println!("\npacked checkpoint sizes at real LLaMA shapes (Table 12):");
    for (label, scheme) in [
        ("PB-LLM ", BitScheme::PbLlm { salient_ratio: 0.1 }),
        ("BiLLM  ", BitScheme::BiLlm),
        ("PTQ1.61", BitScheme::Ptq161 { salient_ratio: 0.2 }),
    ] {
        let (gb7, gb13) = table12_row(scheme);
        println!("  {label}  7B {gb7:.2} GiB   13B {gb13:.2} GiB");
    }
    Ok(())
}
