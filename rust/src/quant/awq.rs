//! AWQ (Lin et al., 2023): activation-aware weight scaling. Per-channel
//! scales s_j = act_mean_j^α migrate quantization difficulty away from
//! channels with large activations; α is grid-searched to minimize the
//! calibration-weighted output error of the RTN-quantized scaled weight.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::quant::rtn::rtn_dense;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct Awq {
    pub bits: u32,
    pub grid: usize,
}

impl Awq {
    pub fn new(bits: u32) -> Awq {
        Awq { bits, grid: 20 }
    }

    fn scaled_error(&self, w: &Tensor, calib: &LinearCalib, alpha: f32) -> (f32, Tensor) {
        let m = w.cols();
        let mean: f32 = calib.act_abs_mean.iter().sum::<f32>() / m as f32;
        let s: Vec<f32> = calib
            .act_abs_mean
            .iter()
            .map(|&a| ((a / mean.max(1e-8)).max(1e-4)).powf(alpha))
            .collect();
        // quantize w * s, then fold s back out
        let mut ws = w.clone();
        for i in 0..ws.rows() {
            for (j, x) in ws.row_mut(i).iter_mut().enumerate() {
                *x *= s[j];
            }
        }
        let mut deq = rtn_dense(&ws, self.bits, 1.0);
        for i in 0..deq.rows() {
            for (j, x) in deq.row_mut(i).iter_mut().enumerate() {
                *x /= s[j];
            }
        }
        // activation-weighted output error proxy:
        // sum_j E[x_j^2] * ||w_j - dq_j||^2
        let mut err = 0.0f32;
        for i in 0..w.rows() {
            for (j, (&a, &b)) in w.row(i).iter().zip(deq.row(i)).enumerate() {
                let d = a - b;
                err += calib.act_sq_mean[j] * d * d;
            }
        }
        (err, deq)
    }
}

impl Quantizer for Awq {
    fn name(&self) -> &'static str {
        "AWQ"
    }

    fn bits_label(&self) -> String {
        format!("{}", self.bits)
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let mut best: Option<(f32, Tensor)> = None;
        for g in 0..=self.grid {
            let alpha = g as f32 / self.grid as f32; // 0.0 ..= 1.0
            let (err, deq) = self.scaled_error(w, calib, alpha);
            if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
                best = Some((err, deq));
            }
        }
        QuantizedLinear {
            deq: best.unwrap().1,
            scheme: BitScheme::Uniform { bits: self.bits as f64 },
            parts: None,
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::{demo, output_mse};
    use crate::quant::rtn::Rtn;
    use crate::quant::Quantizer;

    #[test]
    fn awq_beats_plain_rtn_under_hot_channels() {
        let (w, calib) = demo(48, 64, 7);
        let a = Awq::new(2).quantize_linear(&w, &calib);
        let r = Rtn::new(2).quantize_linear(&w, &calib);
        // compare on the *activation-weighted* metric AWQ optimizes
        let werr = |deq: &crate::tensor::Tensor| -> f32 {
            let mut e = 0.0;
            for i in 0..w.rows() {
                for (j, (&x, &y)) in
                    w.row(i).iter().zip(deq.row(i)).enumerate()
                {
                    let d = x - y;
                    e += calib.act_sq_mean[j] * d * d;
                }
            }
            e
        };
        assert!(werr(&a.deq) < werr(&r.deq));
    }

    #[test]
    fn awq4_much_better_than_awq2() {
        let (w, calib) = demo(32, 48, 8);
        let a4 = Awq::new(4).quantize_linear(&w, &calib);
        let a2 = Awq::new(2).quantize_linear(&w, &calib);
        let e4 = output_mse(&w, &a4.deq, 4);
        let e2 = output_mse(&w, &a2.deq, 4);
        assert!(e4 < e2 / 10.0, "4-bit {e4} vs 2-bit {e2}");
    }

    #[test]
    fn alpha_zero_is_plain_rtn() {
        let (w, calib) = demo(16, 24, 9);
        let awq = Awq::new(2);
        let (_, deq0) = awq.scaled_error(&w, &calib, 0.0);
        let plain = crate::quant::rtn::rtn_dense(&w, 2, 1.0);
        assert!(deq0.mse(&plain) < 1e-10);
    }
}
