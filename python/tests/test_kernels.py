"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and saliency ratios; these tests are the core
correctness signal for the kernel that every quantized forward runs through.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_matmul import binary_matmul, binary_matmul_3d
from compile.kernels.quant4 import quant4

DIMS = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128])


def make_case(seed, t, out, k, ratio):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(t, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(out, k)).astype(np.float32))
    n_sal = int(round(ratio * k))
    mask = np.zeros(k, np.float32)
    mask[rng.choice(k, n_sal, replace=False)] = 1.0
    mask = jnp.asarray(mask)
    sign, alpha = ref.binarize_rowwise_ref(w, mask)
    w_sal = ref.quant4_ref(w, mask) * mask[None, :]
    r1 = jnp.asarray(rng.uniform(0.5, 1.5, out).astype(np.float32))
    r2 = jnp.asarray(rng.uniform(0.5, 1.5, k).astype(np.float32))
    return x, w, mask, w_sal, sign, alpha, r1, r2


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), t=DIMS, out=DIMS, k=DIMS,
       ratio=st.sampled_from([0.0, 0.1, 0.2, 0.3, 0.5]))
def test_binary_matmul_matches_ref(seed, t, out, k, ratio):
    x, _, _, w_sal, sign, alpha, r1, r2 = make_case(seed, t, out, k, ratio)
    got = binary_matmul(x, w_sal, sign, alpha, r1, r2)
    want = ref.binary_matmul_ref(x, w_sal, sign, alpha, r1, r2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), out=DIMS, k=DIMS,
       ratio=st.sampled_from([0.1, 0.2, 0.4]))
def test_quant4_matches_ref(seed, out, k, ratio):
    _, w, mask, *_ = make_case(seed, 8, out, k, ratio)
    np.testing.assert_allclose(
        quant4(w, mask), ref.quant4_ref(w, mask), rtol=1e-5, atol=1e-5
    )


def test_quant4_error_bound():
    """4-bit RTN error on salient columns is bounded by scale/2."""
    _, w, mask, *_ = make_case(7, 8, 64, 64, 0.3)
    dq = np.asarray(ref.quant4_ref(w, mask))
    w = np.asarray(w)
    span = (w.max(0) - w.min(0)) / 15.0
    err = np.abs(dq - w)
    sal = np.asarray(mask) > 0.5
    assert (err[:, sal] <= span[sal] / 2 + 1e-6).all()
    assert (err[:, ~sal] == 0).all()


def test_reconstruct_identity_when_unit_factors():
    """With a_s=|w| row means, r1=r2=1, Eq. 9 equals classic XNOR scaling."""
    _, w, mask, w_sal, sign, alpha, _, _ = make_case(3, 8, 32, 32, 0.2)
    ones_o, ones_k = jnp.ones(32), jnp.ones(32)
    wq = ref.reconstruct_wq(w_sal, sign, alpha, ones_o, ones_k)
    want = w_sal + alpha[:, None] * sign
    np.testing.assert_allclose(wq, want, rtol=1e-6)


def test_binarize_zeroes_salient_columns():
    _, w, mask, *_ = make_case(11, 8, 48, 64, 0.25)
    sign, alpha = ref.binarize_rowwise_ref(w, mask)
    sign = np.asarray(sign)
    sal = np.asarray(mask) > 0.5
    assert (sign[:, sal] == 0).all()
    assert set(np.unique(sign[:, ~sal])) <= {-1.0, 1.0}
    assert (np.asarray(alpha) > 0).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_custom_vjp_matches_ref_grads(seed):
    """The kernel's analytic backward == autodiff through the oracle."""
    x, _, _, w_sal, sign, alpha, r1, r2 = make_case(seed, 16, 24, 32, 0.2)

    def loss_k(a_s, a_r1, a_r2, xx):
        return jnp.sum(binary_matmul(xx, w_sal, sign, a_s, a_r1, a_r2) ** 2)

    def loss_r(a_s, a_r1, a_r2, xx):
        return jnp.sum(
            ref.binary_matmul_ref(xx, w_sal, sign, a_s, a_r1, a_r2) ** 2
        )

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(alpha, r1, r2, x)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(alpha, r1, r2, x)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_binary_matmul_3d_reshape():
    x, _, _, w_sal, sign, alpha, r1, r2 = make_case(5, 32, 24, 32, 0.2)
    x3 = x.reshape(4, 8, 32)
    got = binary_matmul_3d(x3, w_sal, sign, alpha, r1, r2)
    want = ref.binary_matmul_ref(x, w_sal, sign, alpha, r1, r2)
    np.testing.assert_allclose(got.reshape(32, 24), want, rtol=1e-4, atol=1e-4)


def test_fake_quant_ptq161_composition():
    """Salient columns get the 4-bit values, non-salient get alpha*sign."""
    _, w, mask, *_ = make_case(13, 8, 40, 56, 0.25)
    fq = np.asarray(ref.fake_quant_ptq161_ref(w, mask))
    dq4 = np.asarray(ref.quant4_ref(w, mask))
    sign, alpha = ref.binarize_rowwise_ref(w, mask)
    sal = np.asarray(mask) > 0.5
    np.testing.assert_allclose(fq[:, sal], dq4[:, sal], rtol=1e-6)
    want_ns = (np.asarray(alpha)[:, None] * np.asarray(sign))[:, ~sal]
    np.testing.assert_allclose(fq[:, ~sal], want_ns, rtol=1e-6)
