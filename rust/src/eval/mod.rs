//! Evaluation: perplexity (the paper's core metric) and zero-shot task
//! scoring (lm-evaluation-harness-style length-normalized choice scoring).

pub mod ppl;
pub mod zeroshot;

use crate::coordinator::Pipeline;
use crate::model::{Params, LINEARS};
use crate::quant::PackedModel;
use crate::quant::Ptq161Parts;
use crate::runtime::kv::KvCache;
use crate::tensor::Tensor;

use anyhow::Result;

/// One layer's PTQ1.61 parts as the 6-tensor arrays the fused artifacts
/// take, in LINEARS order.
fn fused_layer_inputs(parts: &[Ptq161Parts]) -> Vec<[Tensor; 6]> {
    parts
        .iter()
        .map(|p| {
            let out = p.alpha_s.len();
            let inn = p.alpha_r2.len();
            [
                p.w_sal.clone(),
                p.sign_ns.clone(),
                Tensor::from_vec(&[out], p.alpha_s.clone()),
                Tensor::from_vec(&[out], p.alpha_r1.clone()),
                Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                Tensor::from_vec(&[out], p.mu.clone()),
            ]
        })
        .collect()
}

/// How to run the model forward — dense fake-quant (paper's eval contract),
/// the fused Pallas-kernel path (reconstructs Wq' from the six part
/// tensors each call), the prepared packed-container path (decodes any
/// method's [`crate::quant::PackedContainer`]s directly, zero per-step
/// reconstruction), or the SmoothQuant W4A4 block (Table 13).
pub enum ModelEval<'a> {
    Dense(&'a Params),
    Fused { params: &'a Params, parts: &'a [Vec<Ptq161Parts>] },
    Packed { params: &'a Params, packed: &'a PackedModel },
    W4A4 { params: &'a Params, smooth: &'a [[Tensor; 4]] },
}

impl<'a> ModelEval<'a> {
    pub fn params(&self) -> &Params {
        match self {
            ModelEval::Dense(p) => p,
            ModelEval::Fused { params, .. } => params,
            ModelEval::Packed { params, .. } => params,
            ModelEval::W4A4 { params, .. } => params,
        }
    }

    /// Short name of the weight representation (serve metrics label).
    pub fn label(&self) -> &'static str {
        match self {
            ModelEval::Dense(..) => "dense",
            ModelEval::Fused { .. } => "fused",
            ModelEval::Packed { .. } => "packed",
            ModelEval::W4A4 { .. } => "w4a4",
        }
    }

    /// The prepared packed model, when this eval serves one (memory
    /// accounting in the serve metrics).
    pub fn packed(&self) -> Option<&PackedModel> {
        match self {
            ModelEval::Packed { packed, .. } => Some(*packed),
            _ => None,
        }
    }

    /// Hidden states after all blocks for one (b_eval, t) token batch.
    ///
    /// The packed path runs the full window through the decode kernels
    /// against an empty K/V past (`lens = 0`), which is bit-identical to
    /// a prefill of the same tokens — so the packed full-window and
    /// KV-cached paths decode identical tokens by construction.
    pub fn forward_h(&self, pipe: &Pipeline, tokens: &[i32]) -> Result<Tensor> {
        let params = self.params();
        let mut h = pipe.embed(params, tokens)?;
        // packed path scratch: the empty (lens = 0, so never read) K/V
        // past is layer-invariant — allocate it once, not per layer
        let empty_past = if let ModelEval::Packed { .. } = self {
            let (b, t) = (h.shape[0], h.shape[1]);
            let nh = pipe.cfg.n_heads;
            let hd = pipe.cfg.d / nh;
            Some((
                Tensor::zeros(&[b, t, nh, hd]),
                Tensor::zeros(&[b, t, nh, hd]),
                vec![0usize; b],
            ))
        } else {
            None
        };
        for l in 0..pipe.cfg.n_layers {
            h = match self {
                ModelEval::Dense(p) => pipe.block_fwd(&h, &p.block(l))?,
                ModelEval::Fused { params, parts } => {
                    let qp = fused_layer_inputs(&parts[l]);
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_fwd(&h, attn_norm, mlp_norm, &qp)?
                }
                ModelEval::Packed { params, packed } => {
                    let (kc, vc, lens) = empty_past.as_ref().unwrap();
                    let layer = &packed.layers[l];
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    let (h_out, _, _) = pipe.qblock_packed_decode(
                        &h, kc, vc, lens, attn_norm, mlp_norm, layer,
                    )?;
                    h_out
                }
                ModelEval::W4A4 { params, smooth } => {
                    pipe.qblock_w4a4(&h, &params.block(l), &smooth[l])?
                }
            };
        }
        Ok(h)
    }

    /// Hidden states for *new* token positions only, against per-lane
    /// cached K/V — the incremental counterpart of [`Self::forward_h`].
    ///
    /// `slots` names one paged-cache lane per compacted-batch row and
    /// `tokens` holds `slots.len() * t_new` ids: prefill passes the
    /// positions of the prompt still to compute (`t_new` = prompt length
    /// minus any shared-prefix pages the engine adopted — lanes may enter
    /// one batch with *different* cached lengths, only the new-chunk
    /// width must match), a decode step passes the single newest token
    /// per lane. Each lane's new positions start at its cached length;
    /// the gather walks the lane's page table into the compacted batch
    /// the decode kernels consume, and the new K/V rows are appended
    /// (page allocation and copy-on-write splits happen inside the cache)
    /// and the lengths advanced before returning, so consecutive calls
    /// chain. For the dense and PTQ1.61-fused/packed paths the result is
    /// bit-identical to [`Self::forward_h`] over the same prefix (see
    /// `runtime::native` on the W4A4 exception).
    pub fn forward_h_incremental(
        &self,
        pipe: &Pipeline,
        cache: &mut KvCache,
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let b = slots.len();
        assert!(b > 0 && tokens.len() % b == 0, "ragged incremental batch");
        let t_new = tokens.len() / b;
        let params = self.params();
        let mut h = pipe.embed_decode(params, tokens, b, t_new)?;
        for l in 0..pipe.cfg.n_layers {
            // gather only the live prefix plus room for the new positions
            let (kc, vc, lens) = cache.gather(l, slots, t_new);
            let (h_out, k_new, v_new) = match self {
                ModelEval::Dense(p) => {
                    pipe.block_fwd_decode(&h, &kc, &vc, &lens, &p.block(l))?
                }
                ModelEval::Fused { params, parts } => {
                    let qp = fused_layer_inputs(&parts[l]);
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_fwd_decode(
                        &h, &kc, &vc, &lens, attn_norm, mlp_norm, &qp,
                    )?
                }
                ModelEval::Packed { params, packed } => {
                    let layer = &packed.layers[l];
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_packed_decode(
                        &h, &kc, &vc, &lens, attn_norm, mlp_norm, layer,
                    )?
                }
                ModelEval::W4A4 { params, smooth } => pipe.qblock_w4a4_decode(
                    &h,
                    &kc,
                    &vc,
                    &lens,
                    &params.block(l),
                    &smooth[l],
                )?,
            };
            let row = t_new * k_new.shape[2] * k_new.shape[3];
            for (r, &slot) in slots.iter().enumerate() {
                cache.append(
                    slot,
                    l,
                    &k_new.data[r * row..(r + 1) * row],
                    &v_new.data[r * row..(r + 1) * row],
                );
            }
            h = h_out;
        }
        for &slot in slots {
            cache.advance(slot, t_new);
        }
        Ok(h)
    }
}

/// Helper: PTQ1.61 parts for the fused path in LINEARS order sanity check.
pub fn parts_shape_ok(parts: &[Vec<Ptq161Parts>], n_layers: usize) -> bool {
    parts.len() == n_layers
        && parts.iter().all(|l| l.len() == LINEARS.len())
}
