//! Tiny CLI argument parser substrate (no clap offline).
//!
//! Supports `cmd sub --flag --key value positional` style: the binary pulls
//! a subcommand, then options by name with typed accessors and defaults.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(mut argv: Vec<String>) -> Args {
        if !argv.is_empty() {
            argv.remove(0); // program name
        }
        let subcommand = match argv.first() {
            Some(a) if !a.starts_with('-') => Some(argv.remove(0)),
            _ => None,
        };
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(name.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { subcommand, positional, options, flags }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().collect())
    }

    pub fn str_opt(&self, name: &str, default: &str) -> String {
        self.options.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn usize_opt(&self, name: &str, default: usize) -> usize {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_opt(&self, name: &str, default: f32) -> f32 {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_opt(&self, name: &str, default: u64) -> u64 {
        self.options
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        let mut v = vec!["prog".to_string()];
        v.extend(args.iter().map(|s| s.to_string()));
        Args::parse(v)
    }

    #[test]
    fn subcommand_and_options() {
        let a = mk(&["quantize", "--method", "ptq161", "--ratio", "0.2"]);
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.str_opt("method", "x"), "ptq161");
        assert!((a.f32_opt("ratio", 0.0) - 0.2).abs() < 1e-6);
    }

    #[test]
    fn equals_style_and_flags() {
        // bare `--flag value` is ambiguous without a schema; flags either
        // come last or use `--key=value` form for options
        let a = mk(&["eval", "pos1", "--steps=50", "--verbose"]);
        assert_eq!(a.usize_opt("steps", 0), 50);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = mk(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_opt("missing", 7), 7);
        assert!(!a.flag("nope"));
    }
}
