"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact pure-jnp counterpart here;
pytest (python/tests/test_kernels.py) asserts allclose between the two across
a hypothesis-driven sweep of shapes and saliency ratios.
"""

import jax.numpy as jnp


def reconstruct_wq(w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2):
    """Eq. 9 of the paper: W_q' = (a_r1 a_r2^T) o (a_s * sign(W_ns)) + W_sal.

    w_sal    (out, in)  dequantized 4-bit salient columns, zeros elsewhere
    sign_ns  (out, in)  +-1 on non-salient columns, zeros on salient columns
    alpha_s  (out,)     per-row magnitude scaling factor
    alpha_r1 (out,)     per-row angular correction
    alpha_r2 (in,)      per-column angular correction
    """
    bin_part = (alpha_r1[:, None] * alpha_r2[None, :]) * (
        alpha_s[:, None] * sign_ns
    )
    return w_sal + bin_part


def binary_matmul_ref(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2):
    """x @ reconstruct_wq(...)^T — oracle for the fused Pallas kernel.

    x (t, in) -> (t, out).
    """
    wq = reconstruct_wq(w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2)
    return x @ wq.T


def quant4_ref(w, mask):
    """Per-input-channel (column) asymmetric 4-bit fake quantization applied
    to the salient columns selected by ``mask``; non-salient columns pass
    through untouched.

    w (out, in), mask (in,) in {0.0, 1.0}. Returns fake-quantized w.
    """
    w_min = jnp.min(w, axis=0, keepdims=True)
    w_max = jnp.max(w, axis=0, keepdims=True)
    scale = jnp.maximum((w_max - w_min) / 15.0, 1e-8)
    q = jnp.clip(jnp.round((w - w_min) / scale), 0.0, 15.0)
    dq = q * scale + w_min
    return jnp.where(mask[None, :] > 0.5, dq, w)


def binarize_rowwise_ref(w, mask):
    """Row-wise analytic binarization (XNOR-Net alpha = mean |w|) restricted
    to non-salient columns. Returns (sign_ns, alpha) where sign_ns is zeroed
    on salient columns.

    w (out, in), mask (in,) 1.0 = salient (excluded from binarization).
    """
    ns = 1.0 - mask
    cnt = jnp.maximum(jnp.sum(ns), 1.0)
    alpha = jnp.sum(jnp.abs(w) * ns[None, :], axis=1) / cnt
    sign = jnp.where(w >= 0.0, 1.0, -1.0) * ns[None, :]
    return sign, alpha


def fake_quant_ptq161_ref(w, mask):
    """Full PTQ1.61-style fake quantization with analytic scaling factors:
    salient columns -> 4-bit per-column, non-salient -> row-wise binarized.
    Used by the restorative-LoRA STE path (L2).
    """
    dq4 = quant4_ref(w, mask) * mask[None, :]
    sign, alpha = binarize_rowwise_ref(w, mask)
    return dq4 + alpha[:, None] * sign
