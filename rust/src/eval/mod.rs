//! Evaluation: perplexity (the paper's core metric) and zero-shot task
//! scoring (lm-evaluation-harness-style length-normalized choice scoring).

pub mod ppl;
pub mod zeroshot;

use crate::coordinator::Pipeline;
use crate::model::{Params, LINEARS};
use crate::quant::Ptq161Parts;
use crate::runtime::kv::KvCache;
use crate::tensor::Tensor;

use anyhow::Result;

/// One layer's PTQ1.61 parts as the 6-tensor arrays the fused artifacts
/// take, in LINEARS order.
fn fused_layer_inputs(parts: &[Ptq161Parts]) -> Vec<[Tensor; 6]> {
    parts
        .iter()
        .map(|p| {
            let out = p.alpha_s.len();
            let inn = p.alpha_r2.len();
            [
                p.w_sal.clone(),
                p.sign_ns.clone(),
                Tensor::from_vec(&[out], p.alpha_s.clone()),
                Tensor::from_vec(&[out], p.alpha_r1.clone()),
                Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                Tensor::from_vec(&[out], p.mu.clone()),
            ]
        })
        .collect()
}

/// How to run the model forward — dense fake-quant (paper's eval contract),
/// the fused Pallas-kernel path (proves the packed representation), or the
/// SmoothQuant W4A4 block (Table 13).
pub enum ModelEval<'a> {
    Dense(&'a Params),
    Fused { params: &'a Params, parts: &'a [Vec<Ptq161Parts>] },
    W4A4 { params: &'a Params, smooth: &'a [[Tensor; 4]] },
}

impl<'a> ModelEval<'a> {
    pub fn params(&self) -> &Params {
        match self {
            ModelEval::Dense(p) => p,
            ModelEval::Fused { params, .. } => params,
            ModelEval::W4A4 { params, .. } => params,
        }
    }

    /// Hidden states after all blocks for one (b_eval, t) token batch.
    pub fn forward_h(&self, pipe: &Pipeline, tokens: &[i32]) -> Result<Tensor> {
        let params = self.params();
        let mut h = pipe.embed(params, tokens)?;
        for l in 0..pipe.cfg.n_layers {
            h = match self {
                ModelEval::Dense(p) => pipe.block_fwd(&h, &p.block(l))?,
                ModelEval::Fused { params, parts } => {
                    let qp = fused_layer_inputs(&parts[l]);
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_fwd(&h, attn_norm, mlp_norm, &qp)?
                }
                ModelEval::W4A4 { params, smooth } => {
                    pipe.qblock_w4a4(&h, &params.block(l), &smooth[l])?
                }
            };
        }
        Ok(h)
    }

    /// Hidden states for *new* token positions only, against per-lane
    /// cached K/V — the incremental counterpart of [`Self::forward_h`].
    ///
    /// `slots` names one cache slot per compacted-batch row and `tokens`
    /// holds `slots.len() * t_new` ids: prefill passes the whole prompt
    /// (`t_new` = prompt length, empty cache), a decode step passes the
    /// single newest token per lane. Each lane's new positions start at
    /// its cached length; the new K/V rows are appended to the cache and
    /// the lengths advanced before returning, so consecutive calls chain.
    /// For the dense and PTQ1.61-fused paths the result is bit-identical
    /// to [`Self::forward_h`] over the same prefix (see `runtime::native`
    /// on the W4A4 exception).
    pub fn forward_h_incremental(
        &self,
        pipe: &Pipeline,
        cache: &mut KvCache,
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let b = slots.len();
        assert!(b > 0 && tokens.len() % b == 0, "ragged incremental batch");
        let t_new = tokens.len() / b;
        let params = self.params();
        let mut h = pipe.embed_decode(params, tokens, b, t_new)?;
        for l in 0..pipe.cfg.n_layers {
            // gather only the live prefix plus room for the new positions
            let (kc, vc, lens) = cache.gather(l, slots, t_new);
            let (h_out, k_new, v_new) = match self {
                ModelEval::Dense(p) => {
                    pipe.block_fwd_decode(&h, &kc, &vc, &lens, &p.block(l))?
                }
                ModelEval::Fused { params, parts } => {
                    let qp = fused_layer_inputs(&parts[l]);
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_fwd_decode(
                        &h, &kc, &vc, &lens, attn_norm, mlp_norm, &qp,
                    )?
                }
                ModelEval::W4A4 { params, smooth } => pipe.qblock_w4a4_decode(
                    &h,
                    &kc,
                    &vc,
                    &lens,
                    &params.block(l),
                    &smooth[l],
                )?,
            };
            let row = t_new * k_new.shape[2] * k_new.shape[3];
            for (r, &slot) in slots.iter().enumerate() {
                cache.append(
                    slot,
                    l,
                    &k_new.data[r * row..(r + 1) * row],
                    &v_new.data[r * row..(r + 1) * row],
                );
            }
            h = h_out;
        }
        for &slot in slots {
            cache.advance(slot, t_new);
        }
        Ok(h)
    }
}

/// Helper: PTQ1.61 parts for the fused path in LINEARS order sanity check.
pub fn parts_shape_ok(parts: &[Vec<Ptq161Parts>], n_layers: usize) -> bool {
    parts.len() == n_layers
        && parts.iter().all(|l| l.len() == LINEARS.len())
}
