//! Quantization preprocessing (paper section 3.4): restorative LoRA.
//!
//! A rank-r LoRA delta is trained on the *pretraining* distribution while
//! the effective weights W + BA/r pass through a PTQ1.61-style fake
//! quantization with a straight-through estimator (the `lora_grad` AOT
//! artifact). Merging the deltas concentrates salient weights into the
//! row-wise pattern per-channel PTQ can represent (Fig. 4); the returned
//! model is then quantized by any method.

use anyhow::Result;

use super::capture::ModelCalib;
use super::Pipeline;
use crate::data::Corpus;
use crate::model::{Params, LINEARS};
use crate::opt::AdamW;
use crate::quant::ptq161::{structured_mask, MaskCriterion};
use crate::runtime::Value;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PreprocessCfg {
    pub steps: usize,
    pub lr: f32,
    pub salient_ratio: f64,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for PreprocessCfg {
    fn default() -> Self {
        PreprocessCfg {
            steps: 120,
            lr: 2e-3,
            salient_ratio: 0.2,
            seed: 23,
            verbose: false,
        }
    }
}

pub struct PreprocessResult {
    pub params: Params,
    /// (step, restorative loss) curve
    pub curve: Vec<(usize, f32)>,
}

/// Train the restorative LoRA and merge it into the weights.
pub fn preprocess(
    pipe: &Pipeline,
    params: &Params,
    calib: &ModelCalib,
    corpus: &Corpus,
    cfg: &PreprocessCfg,
) -> Result<PreprocessResult> {
    let mcfg = &pipe.cfg;
    let r = mcfg.lora_rank;
    let mut rng = Rng::new(cfg.seed);
    // masks per (layer, linear) from the FP activation stats — the same
    // criterion the quantizer will use afterwards
    let mut masks: Vec<Tensor> = Vec::new();
    for l in 0..mcfg.n_layers {
        for lin in LINEARS {
            let c = calib.get(l, lin);
            let m = structured_mask(
                &c.act_abs_mean,
                &c.act_sq_mean,
                cfg.salient_ratio,
                MaskCriterion::ActivationMagnitude,
            );
            masks.push(Tensor::from_vec(
                &[m.len()],
                m.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
            ));
        }
    }
    // LoRA state: A ~ N(0, 0.02), B = 0 (standard init; grads flow to B
    // immediately, to A once B is nonzero)
    let mut ab: Vec<Tensor> = Vec::new();
    for l in 0..mcfg.n_layers {
        for lin in LINEARS {
            let (out, inn) = crate::model::linear_shape(mcfg, lin);
            let _ = l;
            ab.push(Tensor::randn(&[r, inn], 0.02, &mut rng));
            ab.push(Tensor::zeros(&[out, r]));
        }
    }
    let mut opt = AdamW::new(cfg.lr, ab.len());
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let batch = corpus.batch(mcfg.b_train, mcfg.seq, &mut rng);
        let mut inputs: Vec<Value> =
            params.tensors.iter().map(Value::from).collect();
        inputs.extend(ab.iter().map(Value::from));
        inputs.extend(masks.iter().map(Value::from));
        inputs.push(Value::tokens(&[mcfg.b_train, mcfg.seq], batch));
        let mut out = pipe.rt.run_cfg("lora_grad", pipe.cname(), &inputs)?;
        let grads = out.split_off(1);
        let loss = out[0].data[0];
        opt.step(&mut ab, &grads);
        if step % 20 == 0 || step + 1 == cfg.steps {
            curve.push((step, loss));
            if cfg.verbose {
                eprintln!("[preprocess] step {step:>4} loss {loss:.4}");
            }
        }
    }
    // merge: W <- W + B A / r
    let mut merged = params.clone();
    let mut i = 0;
    for l in 0..mcfg.n_layers {
        for lin in LINEARS {
            let a = &ab[2 * i];
            let b = &ab[2 * i + 1];
            let delta = b.matmul(a).scale(1.0 / r as f32);
            let name = format!("l{l}.{lin}");
            *merged.get_mut(&name) = merged.get(&name).add(&delta);
            i += 1;
        }
    }
    Ok(PreprocessResult { params: merged, curve })
}

/// Fig. 4 metric: row-concentration of salient weights. For each linear we
/// mark the top-q fraction of |W| entries as salient and measure what
/// fraction falls in the top-`row_frac` rows by salient count — 1.0 means
/// perfectly row-concentrated, ~row_frac means scattered.
pub fn row_concentration(w: &Tensor, q: f64, row_frac: f64) -> f64 {
    let (n, m) = (w.rows(), w.cols());
    let total = n * m;
    let k = ((total as f64) * q).round() as usize;
    let mut idx: Vec<usize> = (0..total).collect();
    idx.sort_by(|&a, &b| {
        w.data[b].abs().partial_cmp(&w.data[a].abs()).unwrap()
    });
    let mut per_row = vec![0usize; n];
    for &i in &idx[..k] {
        per_row[i / m] += 1;
    }
    let mut counts = per_row.clone();
    counts.sort_unstable_by(|a, b| b.cmp(a));
    let top_rows = ((n as f64) * row_frac).round() as usize;
    let in_top: usize = counts[..top_rows.min(n)].iter().sum();
    in_top as f64 / k.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn row_concentration_detects_pattern() {
        let mut rng = Rng::new(1);
        // scattered: iid weights
        let scattered = Tensor::randn(&[32, 64], 1.0, &mut rng);
        // concentrated: a few rows carry all the big weights
        let mut conc = Tensor::randn(&[32, 64], 0.1, &mut rng);
        for r in 0..6 {
            for x in conc.row_mut(r * 5) {
                *x *= 20.0;
            }
        }
        let cs = row_concentration(&scattered, 0.2, 0.2);
        let cc = row_concentration(&conc, 0.2, 0.2);
        assert!(cc > 0.75, "concentrated: {cc}");
        assert!(cs < 0.6, "scattered: {cs}");
        assert!(cc > cs);
    }

    #[test]
    fn row_concentration_bounds() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let c = row_concentration(&w, 0.3, 0.25);
        assert!((0.0..=1.0).contains(&c));
    }
}
