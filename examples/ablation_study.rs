//! Ablation study (paper Table 3 + Table 7): runs the component ablation
//! and the angular-loss ablation at quick scale.
//!
//!   cargo run --release --example ablation_study

use anyhow::Result;
use ptq161::experiments::{self, ExperimentCtx};

fn main() -> Result<()> {
    let mut ctx = ExperimentCtx::quick()?;
    experiments::run(&mut ctx, "t3")?;
    experiments::run(&mut ctx, "t7")?;
    Ok(())
}
