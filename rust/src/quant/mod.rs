//! Quantization methods: the paper's PTQ1.61 plus every baseline in its
//! evaluation (RTN, GPTQ, AWQ, OmniQuant-lite, QuIP-lite, PB-LLM, BiLLM,
//! OWQ, SmoothQuant W4A4), all implemented from scratch on host tensors.
//!
//! Every method is driven through [`Quantizer::quantize_linear`], consuming
//! the linear's FP weight and its calibration statistics, and producing a
//! dense *dequantized* weight (the fake-quant eval contract used by the
//! paper) plus exact storage accounting. PTQ1.61 additionally emits the
//! structured parts (mask / signs / alphas) consumed by the fused Pallas
//! kernel path and by the block-wise optimizer in the coordinator.

pub mod awq;
pub mod billm;
pub mod binarize;
pub mod container;
pub mod gptq;
pub mod omniquant;
pub mod pbllm;
pub mod ptq161;
pub mod quip;
pub mod rtn;
pub mod smoothquant;

pub use container::{ArcContainer, PackedContainer, PackedModel};

use crate::packing::bitwidth::BitScheme;
use crate::tensor::Tensor;

/// Calibration statistics for one linear layer, accumulated by the
/// coordinator's capture pass over the calibration set.
#[derive(Debug, Clone)]
pub struct LinearCalib {
    /// mean |x| per input channel (structured mask, AWQ scaling)
    pub act_abs_mean: Vec<f32>,
    /// mean x^2 per input channel (~ diag of the GPTQ Hessian / n)
    pub act_sq_mean: Vec<f32>,
    /// full Hessian X^T X (in, in) — populated when a method needs it
    pub hessian: Option<Tensor>,
    /// number of activation rows accumulated
    pub n_rows: usize,
}

impl LinearCalib {
    pub fn empty(in_dim: usize) -> LinearCalib {
        LinearCalib {
            act_abs_mean: vec![0.0; in_dim],
            act_sq_mean: vec![0.0; in_dim],
            hessian: None,
            n_rows: 0,
        }
    }

    /// Accumulate a batch of activation rows (rows, in).
    pub fn accumulate(&mut self, x: &Tensor, with_hessian: bool) {
        let (rows, in_dim) = (x.rows(), x.cols());
        assert_eq!(in_dim, self.act_abs_mean.len());
        let prev = self.n_rows as f32;
        let total = prev + rows as f32;
        let abs = x.col_abs_mean();
        let sq = x.col_sq_mean();
        for j in 0..in_dim {
            self.act_abs_mean[j] =
                (self.act_abs_mean[j] * prev + abs[j] * rows as f32) / total;
            self.act_sq_mean[j] =
                (self.act_sq_mean[j] * prev + sq[j] * rows as f32) / total;
        }
        if with_hessian {
            let h = self
                .hessian
                .get_or_insert_with(|| Tensor::zeros(&[in_dim, in_dim]));
            x.xtx_into(h);
        }
        self.n_rows += rows;
    }
}

/// The exact INT4 container behind a `Ptq161Parts::w_sal`: per salient
/// column (in ascending channel order) the `out`-length 4-bit codes plus
/// the `(scale, min)` pair that decodes them. Carrying the codes from
/// quantization time is what lets [`crate::quant::ptq161::packed`] build
/// its bit-exact packed containers without re-deriving the affine
/// parameters from dequantized floats.
#[derive(Debug, Clone, PartialEq)]
pub struct SalientQuant {
    /// codes column-major: `codes[c * out + i]` for salient column `c`,
    /// output row `i`
    pub codes: Vec<u8>,
    /// per-salient-column quantization step
    pub scale: Vec<f32>,
    /// per-salient-column zero offset (the code-0 value)
    pub min: Vec<f32>,
}

/// PTQ1.61 structured representation (Eq. 9 operands, fed to the fused
/// Pallas kernel artifact and the block-wise optimizer).
#[derive(Debug, Clone)]
pub struct Ptq161Parts {
    /// salient input-channel mask (in,)
    pub mask: Vec<bool>,
    /// dequantized 4-bit salient columns, zero elsewhere (out, in)
    pub w_sal: Tensor,
    /// +-1 on non-salient columns, 0 on salient (out, in)
    pub sign_ns: Tensor,
    pub alpha_s: Vec<f32>,
    pub alpha_r1: Vec<f32>,
    pub alpha_r2: Vec<f32>,
    /// learnable row mean (Table 9 ablation; zeros normally)
    pub mu: Vec<f32>,
    /// INT4 codes + affine params behind `w_sal` (populated by the
    /// quantizer; `None` only for hand-assembled parts)
    pub sal_q: Option<SalientQuant>,
}

impl Ptq161Parts {
    /// Dense dequantized weight W_q' (Eq. 9 + mu on binarized columns).
    pub fn dequantize(&self) -> Tensor {
        let (n, m) = (self.sign_ns.rows(), self.sign_ns.cols());
        let mut out = self.w_sal.clone();
        for i in 0..n {
            let c = self.alpha_r1[i] * self.alpha_s[i];
            let mu = self.mu[i];
            let row = out.row_mut(i);
            let sign_row = self.sign_ns.row(i);
            for j in 0..m {
                if !self.mask[j] {
                    row[j] += c * self.alpha_r2[j] * sign_row[j] + mu;
                }
            }
        }
        out
    }

    pub fn n_salient(&self) -> usize {
        self.mask.iter().filter(|&&b| b).count()
    }
}

/// Result of quantizing one linear layer.
#[derive(Debug, Clone)]
pub struct QuantizedLinear {
    /// dense dequantized weight for the fake-quant eval path (out, in)
    pub deq: Tensor,
    /// storage accounting scheme for this method
    pub scheme: BitScheme,
    /// PTQ1.61 structured parts (None for baselines)
    pub parts: Option<Ptq161Parts>,
    /// serve-ready packed container, built at quantization time with the
    /// codes the method already computed (None for methods without a
    /// container impl; PTQ1.61 packs from `parts` *after* block-wise
    /// optimization instead, so it also stays None here)
    pub container: Option<ArcContainer>,
}

impl QuantizedLinear {
    pub fn avg_bits(&self) -> f64 {
        crate::packing::bitwidth::average_bits(
            self.scheme,
            self.deq.rows(),
            self.deq.cols(),
        )
    }
}

/// A weight-quantization method operating layer-by-layer.
pub trait Quantizer {
    fn name(&self) -> &'static str;
    /// the "Bits" column string as the paper prints it
    fn bits_label(&self) -> String;
    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear;
    /// whether this method needs the full Hessian accumulated
    fn needs_hessian(&self) -> bool {
        false
    }
}

/// Method registry for CLI / experiment harness dispatch.
pub fn by_name(name: &str) -> Option<Box<dyn Quantizer>> {
    let q: Box<dyn Quantizer> = match name {
        "rtn2" => Box::new(rtn::Rtn::new(2)),
        "rtn1" => Box::new(binarize::PlainBinarize),
        "gptq2" => Box::new(gptq::Gptq::new(2)),
        "awq2" => Box::new(awq::Awq::new(2)),
        "omniquant2" => Box::new(omniquant::OmniQuantLite::new(2)),
        "quip2" => Box::new(quip::QuipLite::new(2)),
        "owq2" => Box::new(gptq::Owq::new(0.2)),
        "pbllm" => Box::new(pbllm::PbLlm::new(0.1)),
        "billm" => Box::new(billm::BiLlm::default()),
        "ptq161" => Box::new(ptq161::Ptq161::default()),
        _ => return None,
    };
    Some(q)
}

pub const BASELINE_METHODS: [&str; 8] = [
    "awq2", "gptq2", "quip2", "omniquant2", "owq2", "pbllm", "billm",
    "ptq161",
];

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::util::rng::Rng;

    /// A weight matrix + synthetic calibration with a few dominant
    /// activation channels (the regime the paper's Fig. 3a shows).
    pub fn demo(out: usize, inn: usize, seed: u64) -> (Tensor, LinearCalib) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[out, inn], 0.1, &mut rng);
        // enough rows that the Hessian is comfortably full-rank
        let rows = 4 * inn;
        let mut x = Tensor::randn(&[rows, inn], 1.0, &mut rng);
        for r in 0..rows {
            // correlated channels (a shared latent factor), as neighbouring
            // hidden dims in a real transformer are — this is what gives
            // GPTQ's cross-column error compensation something to exploit
            let common = x.at2(r, 0);
            for j in 1..inn {
                *x.at2_mut(r, j) += 0.6 * common;
            }
            for j in 0..inn / 8 {
                *x.at2_mut(r, j * 8) *= 8.0; // hot channels
            }
        }
        let mut calib = LinearCalib::empty(inn);
        calib.accumulate(&x, true);
        (w, calib)
    }

    /// Deterministic input batch drawn like the calibration distribution
    /// (same hot channels as demo()).
    pub fn fresh_inputs(inn: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let mut x = Tensor::randn(&[32, inn], 1.0, &mut rng);
        for r in 0..32 {
            let common = x.at2(r, 0);
            for j in 1..inn {
                *x.at2_mut(r, j) += 0.6 * common;
            }
            for j in 0..inn / 8 {
                *x.at2_mut(r, j * 8) *= 8.0;
            }
        }
        x
    }

    /// Output-MSE of a dequantized weight vs FP on fresh inputs drawn from
    /// the *same* hot-channel distribution demo() calibrates with (methods
    /// that use calibration optimize for that distribution).
    pub fn output_mse(w: &Tensor, deq: &Tensor, seed: u64) -> f32 {
        let x = fresh_inputs(w.cols(), seed);
        x.matmul(&w.t()).mse(&x.matmul(&deq.t()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calib_accumulation_averages() {
        let x1 = Tensor::from_vec(&[2, 2], vec![1.0, -2.0, 3.0, 2.0]);
        let x2 = Tensor::from_vec(&[2, 2], vec![5.0, 0.0, 5.0, 0.0]);
        let mut c = LinearCalib::empty(2);
        c.accumulate(&x1, false);
        assert_eq!(c.act_abs_mean, vec![2.0, 2.0]);
        c.accumulate(&x2, false);
        assert_eq!(c.n_rows, 4);
        assert!((c.act_abs_mean[0] - 3.5).abs() < 1e-6);
        assert!((c.act_abs_mean[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn registry_resolves_all() {
        for m in BASELINE_METHODS {
            assert!(by_name(m).is_some(), "{m}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn parts_dequantize_matches_manual() {
        let parts = Ptq161Parts {
            mask: vec![true, false],
            w_sal: Tensor::from_vec(&[2, 2], vec![0.5, 0.0, -0.5, 0.0]),
            sign_ns: Tensor::from_vec(&[2, 2], vec![0.0, 1.0, 0.0, -1.0]),
            alpha_s: vec![2.0, 3.0],
            alpha_r1: vec![1.0, 0.5],
            alpha_r2: vec![1.0, 2.0],
            mu: vec![0.1, 0.0],
            sal_q: None,
        };
        let d = parts.dequantize();
        // row0: [0.5, 1*2*2*1 + 0.1] ; row1: [-0.5, 0.5*3*2*-1 + 0]
        assert!((d.at2(0, 0) - 0.5).abs() < 1e-6);
        assert!((d.at2(0, 1) - 4.1).abs() < 1e-6);
        assert!((d.at2(1, 1) + 3.0).abs() < 1e-6);
    }
}
