"""L2 correctness: model graphs, losses, and gradient plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = dict(M.CONFIGS["tiny"])
CFG.update(seq=16, b_eval=2, b_train=2)  # small shapes for test speed


def init_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in M.param_spec(cfg):
        if len(shape) == 1:
            out.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.4 / np.sqrt(shape[-1])
            out.append(jnp.asarray(
                rng.normal(0, std, shape).astype(np.float32)))
    return out


def block_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    p = {}
    for name, shape in M.block_param_spec(cfg, 0):
        short = name.split(".", 1)[1]
        if len(shape) == 1:
            p[short] = jnp.ones(shape, jnp.float32)
        else:
            p[short] = jnp.asarray(
                rng.normal(0, 0.05, shape).astype(np.float32))
    return p


def test_param_spec_counts():
    spec = M.param_spec(M.CONFIGS["tiny"])
    # embed + 4 layers x 9 + norm_f + w_out
    assert len(spec) == 1 + 4 * 9 + 2
    names = [n for n, _ in spec]
    assert names[0] == "embed" and names[-1] == "w_out"
    assert "l3.w_down" in names


def test_block_fwd_shapes_and_residual():
    p = block_params(CFG)
    h = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, 16, CFG["d"])).astype(np.float32))
    out = M.block_fwd(h, p, CFG)
    assert out.shape == h.shape
    # residual path: zero weights => identity block
    pz = {k: (v if v.ndim == 1 else jnp.zeros_like(v)) for k, v in p.items()}
    np.testing.assert_allclose(M.block_fwd(h, pz, CFG), h, atol=1e-6)


def test_block_capture_consistent_with_fwd():
    p = block_params(CFG)
    h = jnp.asarray(np.random.default_rng(2).normal(
        size=(2, 16, CFG["d"])).astype(np.float32))
    x_attn, x_o, x_mlp, x_down, h_out = M.block_capture(h, p, CFG)
    np.testing.assert_allclose(h_out, M.block_fwd(h, p, CFG), rtol=1e-6)
    assert x_attn.shape == (2, 16, CFG["d"])
    assert x_down.shape == (2, 16, CFG["ffn"])


def exact_qparts(p, cfg):
    """Quant parts that reconstruct W exactly: sign_ns := W, a=r=1, mu=0.
    (sign_ns is just a matrix input to the kernel — using W validates the
    qblock plumbing against the FP block bit-for-bit.)"""
    qp = {}
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        qp[n] = (jnp.zeros((out, inn)), p[n], jnp.ones(out), jnp.ones(out),
                 jnp.ones(inn), jnp.zeros(out))
    return qp


def test_qblock_equals_block_when_exact():
    p = block_params(CFG)
    h = jnp.asarray(np.random.default_rng(3).normal(
        size=(2, 16, CFG["d"])).astype(np.float32))
    qp = exact_qparts(p, CFG)
    got = M.qblock_fwd(h, (p["attn_norm"], p["mlp_norm"]), qp, CFG)
    want = M.block_fwd(h, p, CFG)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qblock_mu_shifts_output():
    """Non-zero mu must change the output (Table 9 knob is live)."""
    p = block_params(CFG)
    h = jnp.asarray(np.random.default_rng(4).normal(
        size=(2, 16, CFG["d"])).astype(np.float32))
    rng = np.random.default_rng(5)
    qp = {}
    for n in M.LINEARS:
        out, inn = M.linear_shape(CFG, n)
        w = jnp.asarray(rng.normal(0, 0.05, (out, inn)).astype(np.float32))
        mask = jnp.zeros(inn)
        sign, alpha = ref.binarize_rowwise_ref(w, mask)
        qp[n] = (jnp.zeros((out, inn)), sign, alpha, jnp.ones(out),
                 jnp.ones(inn), jnp.zeros(out))
    norms = (p["attn_norm"], p["mlp_norm"])
    y0 = M.qblock_fwd(h, norms, qp, CFG)
    qp2 = {n: v[:5] + (jnp.full(v[5].shape, 0.01),) for n, v in qp.items()}
    y1 = M.qblock_fwd(h, norms, qp2, CFG)
    assert float(jnp.max(jnp.abs(y1 - y0))) > 1e-4


def test_head_fwd_nll_matches_manual():
    rng = np.random.default_rng(6)
    h = jnp.asarray(rng.normal(size=(2, 16, CFG["d"])).astype(np.float32))
    norm_f = jnp.ones(CFG["d"])
    w_out = jnp.asarray(
        rng.normal(0, 0.05, (CFG["vocab"], CFG["d"])).astype(np.float32))
    tokens = jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32)
    nll_sum, logits = M.head_fwd(h, norm_f, w_out, tokens)
    logp = jax.nn.log_softmax(np.asarray(logits)[:, :-1], axis=-1)
    manual = -sum(
        logp[b, t, int(tokens[b, t + 1])]
        for b in range(2) for t in range(15)
    )
    np.testing.assert_allclose(float(nll_sum), manual, rtol=1e-5)
    assert logits.shape == (2, 16, CFG["vocab"])


def test_lm_loss_near_uniform_at_init():
    """Tiny random init => loss ~ log(vocab)."""
    params = init_params(CFG)
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32)
    loss = float(M.lm_loss(params, tokens, CFG))
    assert abs(loss - np.log(256)) < 0.5


def test_lm_grad_fn_descends():
    params = init_params(CFG)
    rng = np.random.default_rng(8)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32)
    fn = M.lm_grad_fn(CFG)
    outs = fn(*params, tokens)
    loss0, grads = float(outs[0]), outs[1:]
    assert len(grads) == len(params)
    stepped = [p - 0.5 * g for p, g in zip(params, grads)]
    loss1 = float(M.lm_loss(stepped, tokens, CFG))
    assert loss1 < loss0


def test_block_opt_grad_finite_difference():
    """Analytic alpha gradients (through the Pallas custom VJP) match FD."""
    cfg = CFG
    p = block_params(cfg, seed=10)
    rng = np.random.default_rng(11)
    h = jnp.asarray(rng.normal(size=(2, 16, cfg["d"])).astype(np.float32))
    learn, consts = [], []
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        w = p[n]
        mask = np.zeros(inn, np.float32)
        mask[rng.choice(inn, inn // 5, replace=False)] = 1.0
        mask = jnp.asarray(mask)
        sign, alpha = ref.binarize_rowwise_ref(w, mask)
        w_sal = ref.quant4_ref(w, mask) * mask[None, :]
        learn += [alpha, jnp.ones(out), jnp.ones(inn), jnp.zeros(out)]
        consts += [w_sal, sign]
    f1 = M.block_fwd(h, p, cfg)
    x_q = h + 0.01
    f3 = M.block_fwd(x_q, p, cfg)
    norms = (p["attn_norm"], p["mlp_norm"])

    def loss(lf):
        return M.block_opt_loss(lf, x_q, f1, f3, norms, consts, 1.0, cfg)

    g = jax.grad(loss)(learn)
    # finite-difference two entries of alpha_s of wq (learn[0])
    eps = 1e-3
    for idx in [0, 3]:
        lp = [x for x in learn]
        lp[0] = learn[0].at[idx].add(eps)
        lm_ = [x for x in learn]
        lm_[0] = learn[0].at[idx].add(-eps)
        fd = (float(loss(lp)) - float(loss(lm_))) / (2 * eps)
        np.testing.assert_allclose(float(g[0][idx]), fd, rtol=0.08, atol=5e-4)


def test_block_opt_nlc_weight_zero_drops_angular_term():
    cfg = CFG
    p = block_params(cfg, seed=12)
    rng = np.random.default_rng(13)
    h = jnp.asarray(rng.normal(size=(2, 16, cfg["d"])).astype(np.float32))
    learn, consts = [], []
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        mask = jnp.zeros(inn)
        sign, alpha = ref.binarize_rowwise_ref(p[n], mask)
        learn += [alpha, jnp.ones(out), jnp.ones(inn), jnp.zeros(out)]
        consts += [jnp.zeros((out, inn)), sign]
    f1 = M.block_fwd(h, p, cfg)
    norms = (p["attn_norm"], p["mlp_norm"])
    l1 = float(M.block_opt_loss(learn, h, f1, f1, norms, consts, 1.0, cfg))
    l0 = float(M.block_opt_loss(learn, h, f1, f1, norms, consts, 0.0, cfg))
    assert l1 > l0  # angular term adds a positive -log(cos) penalty


def test_lora_loss_grad_nonzero_and_descends():
    cfg = CFG
    params = init_params(cfg, seed=14)
    rng = np.random.default_rng(15)
    tokens = jnp.asarray(rng.integers(0, 256, (2, 16)), jnp.int32)
    r = cfg["lora_rank"]
    ab, masks = [], []
    for l in range(cfg["n_layers"]):
        for n in M.LINEARS:
            out, inn = M.linear_shape(cfg, n)
            ab += [jnp.asarray(rng.normal(0, 0.01, (r, inn)), jnp.float32),
                   jnp.zeros((out, r), jnp.float32)]
            m = np.zeros(inn, np.float32)
            m[rng.choice(inn, inn // 5, replace=False)] = 1.0
            masks.append(jnp.asarray(m))

    loss0, grads = jax.value_and_grad(
        lambda abf: M.lora_loss(abf, params, masks, tokens, cfg))(ab)
    gnorm = sum(float(jnp.sum(g * g)) for g in grads)
    assert gnorm > 0.0  # STE lets gradient flow through the fake quant
    stepped = [x - 2.0 * g for x, g in zip(ab, grads)]
    loss1 = float(M.lora_loss(stepped, params, masks, tokens, cfg))
    assert loss1 < float(loss0)
