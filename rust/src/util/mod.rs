//! Substrate utilities built in-repo (the offline environment has no
//! serde/clap/criterion/proptest/rand): JSON, RNG, CLI parsing, a bench
//! harness, and property-based testing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod runid;
