//! Scheduler torture tests (tier-1, no artifacts needed): chunked
//! prefill and lane preemption must never change a single token. The
//! oracle is always the plain single-loop engine with neither feature
//! enabled — greedy decode is per-lane deterministic, so any divergence
//! under chunking, page-pressure eviction, forced preemption ticks,
//! sharding, or their seeded-RNG combinations is a scheduler bug, not
//! model noise. Satellite coverage rides along: deadline expiry must
//! reach parked requests, and restores must account their recomputed
//! positions.

use std::time::Duration;

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::model::{Params, LINEARS};
use ptq161::quant::ptq161::{initial_parts, PackedModel};
use ptq161::quant::Ptq161Parts;
use ptq161::runtime::kv::PrefixRouter;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::{Batcher, ShardedQueue};
use ptq161::serve::{
    run_sharded, Engine, EngineCfg, GenRequest, MetricsRegistry, ShardRun,
    ShardSpec,
};
use ptq161::util::rng::Rng;

/// PTQ1.61 parts for every linear with a fixed structured mask.
fn fused_parts(params: &Params, pipe: &Pipeline) -> Vec<Vec<Ptq161Parts>> {
    (0..pipe.cfg.n_layers)
        .map(|l| {
            LINEARS
                .iter()
                .map(|lin| {
                    let w = params.get(&format!("l{l}.{lin}"));
                    let mask: Vec<bool> =
                        (0..w.cols()).map(|j| j % 4 == 0).collect();
                    initial_parts(w, &mask)
                })
                .collect()
        })
        .collect()
}

/// Mixed long/short workload: every third prompt is long enough (after
/// window truncation) to span several pages and several prefill chunks,
/// and some prompts share a prefix so preemption interacts with the
/// prefix index. Sized for debug-mode CI.
fn overload_requests(n: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            if i % 3 == 2 {
                GenRequest {
                    prompt: format!(
                        "SYSTEM: long context {i} of the valley desk rolls on"
                    ),
                    max_new_tokens: 3,
                }
            } else {
                GenRequest {
                    prompt: format!("q{i}"),
                    max_new_tokens: 6,
                }
            }
        })
        .collect()
}

/// Plain single-loop run — the identity oracle (no chunking, no
/// preemption, fully provisioned pool). Texts indexed by request id.
fn oracle(pipe: &Pipeline, me: &ModelEval, reqs: &[GenRequest]) -> Vec<String> {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new("oracle");
    let mut engine = Engine::new(pipe, me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), reqs.len());
    resps.into_iter().map(|r| r.text).collect()
}

/// Single-loop run under a scheduler configuration: explicit cache
/// geometry plus the chunk/preempt levers. Returns texts by id and the
/// run's metrics.
fn tortured(
    pipe: &Pipeline,
    me: &ModelEval,
    reqs: &[GenRequest],
    kv_pages: Option<usize>,
    cfg: EngineCfg,
) -> (Vec<String>, MetricsRegistry) {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new("torture");
    let mut engine = Engine::with_cache_geometry(pipe, me, 16, kv_pages);
    engine.cfg = EngineCfg { backend: engine.cfg.backend, ..cfg };
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), reqs.len(), "scheduler lost or duplicated requests");
    (resps.into_iter().map(|r| r.text).collect(), metrics)
}

#[test]
fn chunked_prefill_is_token_identical_across_chunk_sizes() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(201);
    let me = ModelEval::Dense(&params);
    let reqs = overload_requests(6);
    let base = oracle(&pipe, &me, &reqs);
    for chunk in [1usize, 2, 3, 5, 64] {
        let cfg = EngineCfg {
            prefill_chunk: Some(chunk),
            ..EngineCfg::default()
        };
        let (texts, m) = tortured(&pipe, &me, &reqs, None, cfg);
        assert_eq!(texts, base, "chunk={chunk}: tokens diverge");
        if chunk <= 5 {
            // long prompts (30+ tokens after truncation) cannot fit one
            // small chunk, so the budget must actually have split them
            assert!(m.prefill_chunks > 0, "chunk={chunk}: nothing was split");
        }
    }
}

#[test]
fn page_pressure_preemption_restores_token_identically() {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(202);
    let me = ModelEval::Dense(&params);
    let reqs = overload_requests(8);
    let base = oracle(&pipe, &me, &reqs);
    // micro: seq 32, page_size 16 → 2 pages per window, 4-page default
    // pool. A 3-page pool admits two short requests (1 page each) and
    // then a long one (2 pages) only by evicting — preemption must fire
    // and every token must still match the oracle.
    let cfg = EngineCfg { preempt: true, ..EngineCfg::default() };
    let (texts, m) = tortured(&pipe, &me, &reqs, Some(3), cfg);
    assert_eq!(texts, base, "preemption changed tokens");
    assert!(m.preemptions >= 1, "undersized pool never preempted");
    assert!(
        m.restored_positions > 0,
        "restores must account their recomputed positions"
    );
    // control: same pool without --preempt only ever backpressures
    let off = EngineCfg::default();
    let (texts_off, m_off) = tortured(&pipe, &me, &reqs, Some(3), off);
    assert_eq!(texts_off, base);
    assert_eq!(m_off.preemptions, 0);
}

#[test]
fn forced_preemption_randomized_schedules_stay_identical() {
    // Seeded-RNG torture: random page budgets, chunk sizes, forced
    // preemption cadences, and submission orders. Every schedule must
    // reproduce the oracle byte-for-byte. Seeds are fixed so a failure
    // is replayable.
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(203);
    let me = ModelEval::Dense(&params);
    let per_window = pipe.cfg.seq.div_ceil(16);
    let full_pool = pipe.cfg.b_eval * per_window;
    let mut rng = Rng::new(0xC0FFEE);
    for trial in 0..6 {
        let mut reqs = overload_requests(7);
        rng.shuffle(&mut reqs);
        let base = oracle(&pipe, &me, &reqs);
        let kv_pages = per_window + rng.below(full_pool - per_window + 1);
        let chunk = 1 + rng.below(8);
        let every = 2 + rng.below(5);
        let cfg = EngineCfg {
            prefill_chunk: Some(chunk),
            preempt: true,
            preempt_every: Some(every),
            ..EngineCfg::default()
        };
        let (texts, m) = tortured(&pipe, &me, &reqs, Some(kv_pages), cfg);
        assert_eq!(
            texts, base,
            "trial {trial}: pages={kv_pages} chunk={chunk} every={every}"
        );
        assert!(
            m.preemptions >= 1,
            "trial {trial}: the forced tick must preempt at least once"
        );
    }
}

/// Sharded torture run over an explicit scheduler config.
fn sharded_tortured(
    pipe: &Pipeline,
    me: &ModelEval,
    reqs: &[GenRequest],
    workers: usize,
    kv_pages: Option<usize>,
    cfg: EngineCfg,
) -> ShardRun {
    let queue = ShardedQueue::new(workers);
    for r in reqs {
        queue.submit(r.clone());
    }
    let router = PrefixRouter::new(16);
    let cfg = EngineCfg { workers, ..cfg };
    let spec = ShardSpec { label: "sharded-torture", page_size: 16, kv_pages };
    run_sharded(pipe, me, &cfg, &queue, &router, &spec).unwrap()
}

#[test]
fn torture_matrix_worker_counts_by_backends() {
    // The headline matrix: 1/2/4 workers × dense/packed under forced
    // preemption, chunked prefill, and an undersized aggregate pool —
    // all byte-identical to the no-preemption single-loop oracle.
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(204);
    let parts = fused_parts(&params, &pipe);
    let packed = PackedModel::pack(&parts);
    // tiny: seq 128, b_eval 4 → 8 pages per window, 32-page full pool.
    // 26 aggregate pages undersizes every multi-lane partition.
    let reqs: Vec<GenRequest> = (0..6)
        .map(|i| {
            if i % 3 == 2 {
                GenRequest {
                    prompt: format!(
                        "SYSTEM: the long valley ledger {i} continues \
                         in exhaustive detail across the whole window"
                    ),
                    max_new_tokens: 3,
                }
            } else {
                GenRequest { prompt: format!("q{i}"), max_new_tokens: 4 }
            }
        })
        .collect();
    let backends: Vec<(&str, ModelEval)> = vec![
        ("dense", ModelEval::Dense(&params)),
        ("packed", ModelEval::Packed { params: &params, packed: &packed }),
    ];
    for (name, me) in &backends {
        let base = oracle(&pipe, me, &reqs);
        for workers in [1usize, 2, 4] {
            let cfg = EngineCfg {
                prefill_chunk: Some(8),
                preempt: true,
                preempt_every: Some(3),
                ..EngineCfg::default()
            };
            let run =
                sharded_tortured(&pipe, me, &reqs, workers, Some(26), cfg);
            assert_eq!(run.worker_panics, 0, "{name}/w{workers}: panicked");
            assert!(run.failed_requests.is_empty());
            assert_eq!(run.responses.len(), reqs.len());
            let texts: Vec<String> =
                run.responses.into_iter().map(|r| r.text).collect();
            assert_eq!(
                texts, base,
                "{name}/w{workers}: preempted shards diverge from oracle"
            );
        }
    }
}

#[test]
fn preempted_request_past_deadline_expires_instead_of_restoring() {
    // Regression for the expire_overdue bugfix: a request preempted past
    // its deadline must be dropped by expiry, not silently restored.
    // Deterministic setup: park an already-overdue victim directly (the
    // exact state a preemption past its deadline leaves behind) next to
    // a live request, and run the engine.
    use std::time::Instant;

    use ptq161::serve::batcher::PreemptedReq;

    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(205);
    let me = ModelEval::Dense(&params);
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    let live = batcher.submit(GenRequest {
        prompt: "healthy request".into(),
        max_new_tokens: 3,
    });
    let now = Instant::now();
    batcher.park(PreemptedReq {
        id: 999,
        req: GenRequest { prompt: "doomed".into(), max_new_tokens: 8 },
        seq: vec![100, 111, 112],
        prompt_len: 2,
        max_new: 8,
        submitted: now,
        admitted: now,
        deadline: Some(Duration::ZERO),
        last_token_at: None,
    });
    let mut metrics = MetricsRegistry::new("deadline");
    let mut engine = Engine::new(&pipe, &me);
    let resps = engine.run(&mut batcher, &mut metrics).unwrap();
    // before the fix, expire_overdue never looked at the parked store:
    // the doomed request restored (and finished) instead of expiring
    assert_eq!(metrics.expired, 1, "the parked overdue request must expire");
    assert_eq!(resps.len(), 1, "only the healthy request completes");
    assert_eq!(resps[0].id, live);
    assert_eq!(batcher.pending(), 0, "nothing may stay parked forever");
}
