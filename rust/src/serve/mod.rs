//! Serving: batched greedy generation over the eval pipeline, with
//! latency/throughput accounting (the paper's F.3 discussion; at this
//! scale the numbers characterize the fake-quant CPU path, and the packed
//! memory wins come from packing::memory).

pub mod batcher;

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Pipeline;
use crate::eval::ModelEval;
use crate::model::tokenizer::ByteTokenizer;

#[derive(Debug, Clone)]
pub struct GenRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
}

#[derive(Debug, Clone)]
pub struct GenResponse {
    pub text: String,
    pub latency_ms: f64,
    pub new_tokens: usize,
}

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub total_new_tokens: usize,
    pub total_ms: f64,
    pub per_request_ms: Vec<f64>,
}

impl ServeStats {
    pub fn throughput_tok_s(&self) -> f64 {
        1000.0 * self.total_new_tokens as f64 / self.total_ms.max(1e-9)
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.per_request_ms, 0.5)
    }

    pub fn p95_ms(&self) -> f64 {
        percentile(&self.per_request_ms, 0.95)
    }
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() - 1) as f64 * p) as usize]
}

/// Greedy-generate for up to b_eval requests at once. Each step runs the
/// full window (no KV cache in the AOT artifact — fixed shapes), so the
/// cost model is steps x full-forward; the batcher amortizes it 4-wide.
pub fn generate_batch(
    pipe: &Pipeline,
    model: &ModelEval,
    requests: &[GenRequest],
) -> Result<Vec<GenResponse>> {
    let tk = ByteTokenizer;
    let (b, t, vocab) = (pipe.cfg.b_eval, pipe.cfg.seq, pipe.cfg.vocab);
    assert!(requests.len() <= b, "batch too wide");
    let mut seqs: Vec<Vec<i32>> =
        requests.iter().map(|r| tk.encode(&r.prompt)).collect();
    for s in seqs.iter_mut() {
        s.truncate(t - 1);
    }
    let lens0: Vec<usize> = seqs.iter().map(Vec::len).collect();
    let max_new = requests
        .iter()
        .map(|r| r.max_new_tokens)
        .max()
        .unwrap_or(0)
        .min(t - seqs.iter().map(Vec::len).max().unwrap_or(0));
    let t0 = Instant::now();
    for _ in 0..max_new {
        let mut tokens = vec![0i32; b * t];
        for (i, s) in seqs.iter().enumerate() {
            tokens[i * t..i * t + s.len()].copy_from_slice(s);
        }
        let h = model.forward_h(pipe, &tokens)?;
        let (_, logits) = pipe.head(model.params(), &h, &tokens)?;
        for (i, s) in seqs.iter_mut().enumerate() {
            if s.len() >= t || s.len() - lens0[i] >= requests[i].max_new_tokens
            {
                continue;
            }
            let pos = s.len() - 1;
            let row = &logits.data[(i * t + pos) * vocab..(i * t + pos + 1) * vocab];
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j as i32)
                .unwrap();
            s.push(next);
        }
    }
    let elapsed = t0.elapsed().as_secs_f64() * 1000.0;
    Ok(seqs
        .into_iter()
        .zip(requests)
        .zip(lens0)
        .map(|((s, _r), l0)| GenResponse {
            text: tk.decode(&s),
            latency_ms: elapsed,
            new_tokens: s.len() - l0.min(s.len()),
        })
        .collect())
}
