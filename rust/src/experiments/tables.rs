//! Table regenerators (paper Tables 1-13). Each prints the paper-shaped
//! rows and saves a CSV under runs/. Absolute values live at reproduction
//! scale; the *shape* (who wins, by what factor) is the claim being
//! reproduced — EXPERIMENTS.md records paper-vs-measured per table.

use anyhow::Result;

use super::ExperimentCtx;
use crate::coordinator::quantize::QuantModel;
use crate::data::tasks::{TaskKind, ALL_KINDS};
use crate::eval::zeroshot::run_suite;
use crate::eval::ModelEval;
use crate::packing::bitwidth::BitScheme;
use crate::packing::memory::table12_row;
use crate::quant::ptq161::parts_storage_bits;
use crate::quant::smoothquant::SmoothQuant;
use crate::report::{fmt_bits, fmt_ppl, Table};
use crate::tensor::Tensor;

pub const T1_METHODS: [&str; 7] =
    ["awq2", "gptq2", "quip2", "omniquant2", "pbllm", "billm", "ptq161"];

/// "Bits" cell for a quantized model, measured rather than hardcoded:
/// methods that emit structured parts (PTQ1.61) are charged what their
/// packed containers store (`parts_storage_bits`, the shape-only form of
/// `PackedLinear::storage_bits` — mask and scaling overheads included);
/// baselines print their Appendix-A closed-form average at the quantized
/// layer shapes.
fn bits_cell(qm: &QuantModel) -> String {
    match &qm.parts {
        Some(parts) => {
            let mut bits = 0u64;
            let mut weights = 0u64;
            for p in parts.iter().flatten() {
                bits += parts_storage_bits(p);
                weights += (p.sign_ns.rows() * p.sign_ns.cols()) as u64;
            }
            fmt_bits(bits as f64 / weights.max(1) as f64)
        }
        None => fmt_bits(qm.avg_bits),
    }
}

/// Table 1: perplexity on wiki + c4 across methods and model sizes.
/// PTQ1.61 runs on the preprocessed model (the paper's full method).
pub fn t1_perplexity(ctx: &mut ExperimentCtx) -> Result<()> {
    for ds in ["wiki", "c4"] {
        let mut tbl = Table::new(
            &format!("Table 1 ({ds}): PPL, lower is better"),
            &{
                let mut h = vec!["Method", "Bits"];
                h.extend(ctx.models.iter().map(|s| s.as_str()));
                h
            },
        );
        let corpus = if ds == "wiki" { ctx.wiki.clone() } else { ctx.c4.clone() };
        // FP row
        let mut row = vec!["FP".to_string(), "32".to_string()];
        for m in ctx.models.clone() {
            let p = ctx.pretrained(&m)?;
            row.push(fmt_ppl(ctx.ppl(&m, &p, &corpus)?));
        }
        tbl.row(row);
        for method in T1_METHODS {
            let mut row = vec![method.to_string(), String::new()];
            for m in ctx.models.clone() {
                let pre = method == "ptq161"; // full method uses preprocessing
                let qm = ctx.quantized(&m, method, pre)?;
                if row[1].is_empty() {
                    row[1] = bits_cell(&qm);
                }
                row.push(fmt_ppl(ctx.ppl(&m, &qm.params, &corpus)?));
            }
            tbl.row(row);
        }
        tbl.print();
        tbl.save_csv(&crate::runs_dir().join(format!("t1_{ds}.csv")))?;
    }
    Ok(())
}

/// Table 2: zero-shot reasoning accuracies.
pub fn t2_reasoning(ctx: &mut ExperimentCtx) -> Result<()> {
    let kinds = [
        TaskKind::Collocation,
        TaskKind::VerbAgreement,
        TaskKind::Cloze,
        TaskKind::Retrieval,
    ];
    for m in ctx.models.clone() {
        let mut header = vec!["Method", "Bits"];
        header.extend(kinds.iter().map(|k| k.label()));
        header.push("Avg");
        let mut tbl =
            Table::new(&format!("Table 2 ({m}): zero-shot accuracy %"), &header);
        // gather all model variants first (mutable ctx ops), then score
        let mut variants: Vec<(String, String, crate::model::Params)> =
            vec![("FP".into(), "32".into(), ctx.pretrained(&m)?)];
        for method in ["gptq2", "omniquant2", "pbllm", "billm", "ptq161"] {
            let qm = ctx.quantized(&m, method, method == "ptq161")?;
            let bits = bits_cell(&qm);
            variants.push((method.to_string(), bits, qm.params));
        }
        let n_tasks = ctx.tasks_per_suite;
        let pipe = ctx.pipeline(&m)?;
        for (name, bits, params) in &variants {
            let rows = run_suite(
                &pipe,
                &ModelEval::Dense(params),
                &kinds,
                n_tasks,
                77,
            )?;
            let avg: f64 =
                rows.iter().map(|(_, a)| *a).sum::<f64>() / rows.len() as f64;
            let mut cells = vec![name.clone(), bits.clone()];
            cells.extend(rows.iter().map(|(_, a)| format!("{a:.1}")));
            cells.push(format!("{avg:.1}"));
            tbl.row(cells);
        }
        tbl.print();
        tbl.save_csv(&crate::runs_dir().join(format!("t2_{m}.csv")))?;
    }
    Ok(())
}

/// Table 3: ablation — mask / learnable scalars / preprocessing.
pub fn t3_ablation(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 3: ablation (PPL)",
        &["StructuredMask", "LearnableScalar", "Preprocess", "wiki", "c4"],
    );
    let rows: Vec<(&str, bool, bool, bool)> = vec![
        ("rtn1", false, false, false),      // plain binarization
        ("ptq161-analytic", true, false, false),
        ("rtn1", false, false, true),       // preprocess only
        ("ptq161", true, true, false),      // mask + learned scalars
        ("ptq161", true, true, true),       // full method
    ];
    for (method, mask, scalar, pre) in rows {
        let (wiki, c4) = if method == "ptq161-analytic" {
            // analytic PTQ1.61 parts without block-wise optimization
            let params = ctx.pretrained(&m)?;
            let mc = ctx.calib(&m, false)?;
            let pipe = ctx.pipeline(&m)?;
            let q = crate::quant::ptq161::Ptq161::default();
            let qm = crate::coordinator::quantize::quantize_model(
                &pipe,
                &params,
                &mc,
                &q,
            )?;
            ctx.cache_calib(&m, false, mc);
            (
                ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?,
                ctx.ppl(&m, &qm.params, &ctx.c4.clone())?,
            )
        } else {
            let qm = ctx.quantized(&m, method, pre)?;
            (
                ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?,
                ctx.ppl(&m, &qm.params, &ctx.c4.clone())?,
            )
        };
        let tick = |b: bool| if b { "yes" } else { "-" }.to_string();
        tbl.row(vec![
            tick(mask),
            tick(scalar),
            tick(pre),
            fmt_ppl(wiki),
            fmt_ppl(c4),
        ]);
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t3.csv"))?;
    Ok(())
}

/// Table 4: OWQ (2-bit, fp16 outlier columns) vs PTQ1.61.
pub fn t4_owq(ctx: &mut ExperimentCtx) -> Result<()> {
    let mut tbl = Table::new(
        "Table 4: OWQ vs PTQ1.61 (PPL)",
        &["Model", "Method", "Bits", "wiki", "c4"],
    );
    for m in ctx.models.clone() {
        for method in ["owq2", "ptq161"] {
            let qm = ctx.quantized(&m, method, method == "ptq161")?;
            tbl.row(vec![
                m.clone(),
                qm.method.clone(),
                bits_cell(&qm),
                fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?),
                fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.c4.clone())?),
            ]);
        }
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t4.csv"))?;
    Ok(())
}

/// Table 5: structured mask criterion — activation (ours) vs Hessian (OWQ).
pub fn t5_mask_criterion(ctx: &mut ExperimentCtx) -> Result<()> {
    use crate::coordinator::blockopt::{ptq161_optimize, BlockOptCfg};
    use crate::quant::ptq161::MaskCriterion;
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 5: mask criterion (PPL)",
        &["Mask", "wiki", "c4"],
    );
    let params = ctx.pretrained(&m)?;
    let mc = ctx.calib(&m, false)?;
    let pipe = ctx.pipeline(&m)?;
    for (label, crit) in [
        ("OWQ (Hessian)", MaskCriterion::HessianDiag),
        ("Ours (activation)", MaskCriterion::ActivationMagnitude),
    ] {
        let (qm, _) = ptq161_optimize(
            &pipe,
            &params,
            &mc,
            &BlockOptCfg {
                epochs: ctx.blockopt_epochs,
                criterion: crit,
                ..Default::default()
            },
        )?;
        tbl.row(vec![
            label.to_string(),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.c4.clone())?),
        ]);
    }
    ctx.cache_calib(&m, false, mc);
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t5.csv"))?;
    Ok(())
}

/// Table 6: PTQ1.61* (no preprocessing) vs PTQ1.61 vs baselines.
pub fn t6_preprocess_gain(ctx: &mut ExperimentCtx) -> Result<()> {
    for ds in ["wiki", "c4"] {
        let corpus = if ds == "wiki" { ctx.wiki.clone() } else { ctx.c4.clone() };
        let mut header = vec!["Method", "Bits"];
        header.extend(ctx.models.iter().map(|s| s.as_str()));
        let mut tbl = Table::new(
            &format!("Table 6 ({ds}): preprocessing gain (PPL)"),
            &header,
        );
        for (label, method, pre) in [
            ("OmniQuant", "omniquant2", false),
            ("PB-LLM", "pbllm", false),
            ("BiLLM", "billm", false),
            ("PTQ1.61*", "ptq161", false),
            ("PTQ1.61", "ptq161", true),
        ] {
            let mut row = vec![label.to_string(), String::new()];
            for m in ctx.models.clone() {
                let qm = ctx.quantized(&m, method, pre)?;
                if row[1].is_empty() {
                    row[1] = bits_cell(&qm);
                }
                row.push(fmt_ppl(ctx.ppl(&m, &qm.params, &corpus)?));
            }
            tbl.row(row);
        }
        tbl.print();
        tbl.save_csv(&crate::runs_dir().join(format!("t6_{ds}.csv")))?;
    }
    Ok(())
}

/// Table 7: angular (-log cos) loss on/off in block-wise optimization.
pub fn t7_angular(ctx: &mut ExperimentCtx) -> Result<()> {
    use crate::coordinator::blockopt::{ptq161_optimize, BlockOptCfg};
    let m = ctx.models[0].clone();
    let mut tbl =
        Table::new("Table 7: angular loss (PPL)", &["Angular", "wiki", "c4"]);
    let params = ctx.pretrained(&m)?;
    let mc = ctx.calib(&m, false)?;
    let pipe = ctx.pipeline(&m)?;
    for (label, w) in [("w/o", 0.0f32), ("w", 1.0f32)] {
        let (qm, _) = ptq161_optimize(
            &pipe,
            &params,
            &mc,
            &BlockOptCfg {
                epochs: ctx.blockopt_epochs,
                nlc_w: w,
                ..Default::default()
            },
        )?;
        tbl.row(vec![
            label.to_string(),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.c4.clone())?),
        ]);
    }
    ctx.cache_calib(&m, false, mc);
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t7.csv"))?;
    Ok(())
}

/// Table 8: resource requirements of the quantization passes.
pub fn t8_resources(ctx: &mut ExperimentCtx) -> Result<()> {
    use std::time::Instant;
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 8: resource requirements",
        &["Stage", "Wall time (s)", "Params touched (MB)"],
    );
    let params = ctx.pretrained(&m)?;
    let mb =
        (params.total_params() * 4) as f64 / (1024.0 * 1024.0);
    let mc = ctx.calib(&m, false)?;
    let pipe = ctx.pipeline(&m)?;
    let t0 = Instant::now();
    let q = crate::quant::by_name("omniquant2").unwrap();
    let _ = crate::coordinator::quantize::quantize_model(
        &pipe, &params, &mc, q.as_ref(),
    )?;
    tbl.row(vec![
        "OmniQuant-lite".into(),
        format!("{:.1}", t0.elapsed().as_secs_f64()),
        format!("{mb:.1}"),
    ]);
    let t0 = Instant::now();
    let _ = crate::coordinator::blockopt::ptq161_optimize(
        &pipe,
        &params,
        &mc,
        &crate::coordinator::blockopt::BlockOptCfg {
            epochs: ctx.blockopt_epochs,
            ..Default::default()
        },
    )?;
    tbl.row(vec![
        "PTQ1.61 (blockwise opt)".into(),
        format!("{:.1}", t0.elapsed().as_secs_f64()),
        format!("{mb:.1}"),
    ]);
    ctx.cache_calib(&m, false, mc);
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t8.csv"))?;
    Ok(())
}

/// Table 9: learnable row-wise mean (QA-LoRA group-size-1 analog).
pub fn t9_learnable_mean(ctx: &mut ExperimentCtx) -> Result<()> {
    use crate::coordinator::blockopt::{ptq161_optimize, BlockOptCfg};
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 9: learnable row-wise mean (PPL)",
        &["Variant", "wiki", "c4"],
    );
    let params = ctx.pretrained(&m)?;
    let mc = ctx.calib(&m, false)?;
    let pipe = ctx.pipeline(&m)?;
    for (label, learn_mu) in [("standard", false), ("learnable mean", true)] {
        let (qm, _) = ptq161_optimize(
            &pipe,
            &params,
            &mc,
            &BlockOptCfg {
                epochs: ctx.blockopt_epochs,
                learn_mu,
                ..Default::default()
            },
        )?;
        tbl.row(vec![
            label.to_string(),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.wiki.clone())?),
            fmt_ppl(ctx.ppl(&m, &qm.params, &ctx.c4.clone())?),
        ]);
    }
    ctx.cache_calib(&m, false, mc);
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t9.csv"))?;
    Ok(())
}

/// Table 10: held-out arithmetic — near-chance for all methods.
pub fn t10_hard_tasks(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 10: hard-task accuracy % (chance = 25)",
        &["Method", "GSM-a (arith)"],
    );
    let mut variants = Vec::new();
    for method in ["pbllm", "billm", "ptq161"] {
        let qm = ctx.quantized(&m, method, method == "ptq161")?;
        variants.push((method.to_string(), qm.params));
    }
    let n_tasks = ctx.tasks_per_suite;
    let pipe = ctx.pipeline(&m)?;
    for (method, params) in &variants {
        let rows = run_suite(
            &pipe,
            &ModelEval::Dense(params),
            &[TaskKind::Arithmetic],
            n_tasks,
            78,
        )?;
        tbl.row(vec![method.clone(), format!("{:.1}", rows[0].1)]);
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t10.csv"))?;
    Ok(())
}

/// Table 11: long-context retrieval (LongBench analog).
pub fn t11_long_context(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let mut tbl = Table::new(
        "Table 11: kv-retrieval accuracy % (chance = 25)",
        &["Method", "Long-a (kv)"],
    );
    let mut variants = Vec::new();
    for method in ["pbllm", "billm", "ptq161"] {
        let qm = ctx.quantized(&m, method, method == "ptq161")?;
        variants.push((method.to_string(), qm.params));
    }
    let n_tasks = ctx.tasks_per_suite;
    let pipe = ctx.pipeline(&m)?;
    for (method, params) in &variants {
        let rows = run_suite(
            &pipe,
            &ModelEval::Dense(params),
            &[TaskKind::Retrieval],
            n_tasks,
            79,
        )?;
        tbl.row(vec![method.clone(), format!("{:.1}", rows[0].1)]);
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t11.csv"))?;
    Ok(())
}

/// Table 12: inference memory (analytic over real LLaMA shapes — exact).
pub fn t12_memory(_ctx: &mut ExperimentCtx) -> Result<()> {
    let mut tbl = Table::new(
        "Table 12: inference memory (GiB, real LLaMA shapes)",
        &["Method", "LLaMA-7B", "LLaMA-13B"],
    );
    for (label, scheme) in [
        ("PB-LLM", BitScheme::PbLlm { salient_ratio: 0.1 }),
        ("BiLLM", BitScheme::BiLlm),
        ("PTQ1.61", BitScheme::Ptq161 { salient_ratio: 0.2 }),
    ] {
        let (a, b) = table12_row(scheme);
        tbl.row(vec![
            label.to_string(),
            format!("{a:.2}"),
            format!("{b:.2}"),
        ]);
    }
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t12.csv"))?;
    Ok(())
}

/// Table 13: FP vs PB-LLM vs SmoothQuant W4A4 vs PTQ1.61 (zero-shot).
pub fn t13_w4a4(ctx: &mut ExperimentCtx) -> Result<()> {
    let m = ctx.models[0].clone();
    let kinds = [
        TaskKind::Collocation,
        TaskKind::VerbAgreement,
        TaskKind::Cloze,
        TaskKind::Retrieval,
    ];
    let mut header = vec!["Method"];
    header.extend(kinds.iter().map(|k| k.label()));
    header.push("Avg");
    let mut tbl =
        Table::new(&format!("Table 13 ({m}): W4A4 comparison %"), &header);
    let push = |tbl: &mut Table,
                name: &str,
                rows: &[(TaskKind, f64)]| {
        let avg: f64 =
            rows.iter().map(|(_, a)| *a).sum::<f64>() / rows.len() as f64;
        let mut cells = vec![name.to_string()];
        cells.extend(rows.iter().map(|(_, a)| format!("{a:.1}")));
        cells.push(format!("{avg:.1}"));
        tbl.row(cells);
    };
    // all mutable-ctx products first
    let fp = ctx.pretrained(&m)?;
    let qm_pb = ctx.quantized(&m, "pbllm", false)?;
    let qm_ptq = ctx.quantized(&m, "ptq161", true)?;
    let mc = ctx.calib(&m, false)?;
    let n_tasks = ctx.tasks_per_suite;
    let n_layers = ctx.pipeline(&m)?.cfg.n_layers;
    let sq = SmoothQuant::default();
    let mut smooth: Vec<[Tensor; 4]> = Vec::new();
    for l in 0..n_layers {
        let s_attn = sq.shared_vector(
            &[
                fp.get(&format!("l{l}.wq")),
                fp.get(&format!("l{l}.wk")),
                fp.get(&format!("l{l}.wv")),
            ],
            mc.get(l, "wq"),
        );
        let s_o = sq.smooth_vector(fp.get(&format!("l{l}.wo")), mc.get(l, "wo"));
        let s_mlp = sq.shared_vector(
            &[fp.get(&format!("l{l}.w_gate")), fp.get(&format!("l{l}.w_up"))],
            mc.get(l, "w_gate"),
        );
        let s_down =
            sq.smooth_vector(fp.get(&format!("l{l}.w_down")), mc.get(l, "w_down"));
        smooth.push([
            Tensor::from_vec(&[s_attn.len()], s_attn),
            Tensor::from_vec(&[s_o.len()], s_o),
            Tensor::from_vec(&[s_mlp.len()], s_mlp),
            Tensor::from_vec(&[s_down.len()], s_down),
        ]);
    }
    ctx.cache_calib(&m, false, mc);
    let pipe = ctx.pipeline(&m)?;
    let rows = run_suite(&pipe, &ModelEval::Dense(&fp), &kinds, n_tasks, 80)?;
    push(&mut tbl, "FP", &rows);
    let rows =
        run_suite(&pipe, &ModelEval::Dense(&qm_pb.params), &kinds, n_tasks, 80)?;
    push(&mut tbl, "PB-LLM", &rows);
    let rows = run_suite(
        &pipe,
        &ModelEval::W4A4 { params: &fp, smooth: &smooth },
        &kinds,
        n_tasks,
        80,
    )?;
    push(&mut tbl, "SQ(W4A4)", &rows);
    let rows =
        run_suite(&pipe, &ModelEval::Dense(&qm_ptq.params), &kinds, n_tasks, 80)?;
    push(&mut tbl, "PTQ1.61", &rows);
    tbl.print();
    tbl.save_csv(&crate::runs_dir().join("t13.csv"))?;
    let _ = ALL_KINDS; // referenced by docs
    Ok(())
}
