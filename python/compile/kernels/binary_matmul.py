"""L1 Pallas kernel: fused mixed binar/4-bit matmul (the paper's hot spot).

The quantized linear layer computes y = x @ W_q'^T where W_q' is never
materialized in HBM: each (t_blk, out_blk) tile reconstructs its slice of
W_q' = W_sal + (a_r1 a_r2^T) o (a_s * sign_ns)     (paper Eq. 9)
in VMEM right before the MXU matmul, the TPU analog of the fused
dequant-GEMM a real sub-2-bit deployment would need (DESIGN.md
#hardware-adaptation).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the kernel's tiling structure is still exercised.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, pref: int = 128) -> int:
    """Largest divisor of n that is <= pref (kernel tiles must divide n)."""
    b = min(n, pref)
    while n % b != 0:
        b -= 1
    return b


def _kernel(x_ref, w_sal_ref, sign_ref, a_s_ref, a_r1_ref, a_r2_ref, o_ref):
    # Reconstruct this tile of W_q' in VMEM (Eq. 9), then one MXU matmul.
    scale = (a_r1_ref[...] * a_s_ref[...])[:, None] * a_r2_ref[...][None, :]
    w = w_sal_ref[...] + scale * sign_ref[...]
    o_ref[...] = jnp.dot(
        x_ref[...], w.T, preferred_element_type=jnp.float32
    )


@jax.custom_vjp
def binary_matmul(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2):
    """Fused quantized matmul: (t, in) x (out, in) -> (t, out).

    Tiling: grid over (t / t_blk, out / out_blk); the contraction (in) axis
    stays whole per tile — at reproduction sizes (in <= 512) a full-K tile of
    x and W easily fits VMEM; see EXPERIMENTS.md #perf for the footprint
    table.

    Reverse-mode AD cannot trace through ``pallas_call``; the block-wise
    scaling-factor optimization (Eq. 7) differentiates wrt the alphas, so the
    kernel carries an analytic custom VJP (below) — the backward pass is what
    a hand-written kernel gradient would compute.
    """
    t, k = x.shape
    out, k2 = w_sal.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    tb = _pick_block(t)
    ob = _pick_block(out)
    grid = (t // tb, out // ob)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((ob, k), lambda i, j: (j, 0)),
            pl.BlockSpec((ob, k), lambda i, j: (j, 0)),
            pl.BlockSpec((ob,), lambda i, j: (j,)),
            pl.BlockSpec((ob,), lambda i, j: (j,)),
            pl.BlockSpec((k,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tb, ob), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, out), jnp.float32),
        interpret=True,
    )(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2)


def _bm_fwd(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2):
    y = binary_matmul(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2)
    return y, (x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2)


def _bm_bwd(res, dy):
    """Analytic gradients of y = x @ (w_sal + (r1 r2^T) o (a_s sign))^T."""
    x, w_sal, sign, a_s, r1, r2 = res
    scale = (r1 * a_s)[:, None] * r2[None, :]
    wq = w_sal + scale * sign
    dx = dy @ wq
    dwq = dy.T @ x                       # (out, in)
    g = dwq * sign                       # shared factor for alpha grads
    gr2 = g * r2[None, :]
    da_s = jnp.sum(gr2, axis=1) * r1
    dr1 = jnp.sum(gr2, axis=1) * a_s
    dr2 = jnp.sum(g * (r1 * a_s)[:, None], axis=0)
    dw_sal = dwq                          # constant in practice; exact anyway
    dsign = dwq * scale
    return dx, dw_sal, dsign, da_s, dr1, dr2


binary_matmul.defvjp(_bm_fwd, _bm_bwd)


def binary_matmul_3d(x, w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2):
    """(b, t, in) convenience wrapper: flattens tokens, calls the kernel."""
    b, t, k = x.shape
    y = binary_matmul(
        x.reshape(b * t, k), w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2
    )
    return y.reshape(b, t, -1)
