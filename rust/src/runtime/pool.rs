//! Persistent intra-op worker pool for the decode kernels.
//!
//! `par_rows`' original scoped-thread split paid a thread-spawn per layer
//! call and hard-coded its heuristics (a `min(8)` thread cap and a
//! `rows / 128` threshold), which left a wide single-row decode matvec
//! serial on any host. This module replaces that with:
//!
//! - a process-wide pool of detached worker threads, spawned once and
//!   reused by every chunked kernel call ([`run_chunked`]);
//! - one bytes-of-work split policy ([`plan_chunks`]): split only when the
//!   total work clears [`MIN_SPLIT_BYTES`], and size chunks so each claims
//!   at least [`MIN_CHUNK_BYTES`] of it;
//! - a global thread budget that composes with `--workers N` sharding: the
//!   budget defaults to `available_parallelism` (overridable via
//!   `serve --intra-threads` / `PTQ161_INTRA_THREADS`), and each engine
//!   worker thread pins its own per-thread share with [`set_local_intra`]
//!   so N shards × intra-op chunks never oversubscribe the machine.
//!
//! Scheduling protocol: a caller publishes a [`Job`] (a chunk counter plus
//! a `Fn(usize)` task), then claims and runs chunks itself alongside the
//! pool workers and blocks until every claimed chunk has *finished*. The
//! caller always participating means a 1-thread budget degrades to a plain
//! serial loop and the pool can never deadlock waiting for a free worker.
//! Worker panics are caught and re-raised on the submitting caller.
//!
//! Chunk assignment is dynamic (an atomic claim counter), but kernels
//! built on this stay bit-identical to their serial form because every
//! output element is computed *whole* inside exactly one chunk — the split
//! changes which thread runs an output row, never the accumulation order
//! within it.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Work below this many bytes runs serially: it would not amortize the
/// pool's wake-up/notify cost. (Matches the old `par_rows` threshold of
/// 2^16 f32 elements.)
pub const MIN_SPLIT_BYTES: usize = 1 << 18;
/// Each chunk must claim at least this much work, so tiny tails never
/// outnumber the useful chunks.
pub const MIN_CHUNK_BYTES: usize = 1 << 16;

/// Resolved global thread budget; 0 = not yet resolved.
static BUDGET: AtomicUsize = AtomicUsize::new(0);
/// Split threshold, lowered by tests to force chunking on tiny shapes.
static SPLIT_BYTES: AtomicUsize = AtomicUsize::new(MIN_SPLIT_BYTES);

thread_local! {
    /// Per-thread intra-op thread allowance; 0 = unset (use the budget).
    /// Sharded engine workers set this to `budget / workers`.
    static LOCAL_INTRA: Cell<usize> = const { Cell::new(0) };
}

/// The process-wide intra-op thread budget: an explicit
/// [`set_thread_budget`] wins, then `PTQ161_INTRA_THREADS`, then
/// `available_parallelism`. Resolved once and cached.
pub fn thread_budget() -> usize {
    let b = BUDGET.load(Ordering::Relaxed);
    if b != 0 {
        return b;
    }
    let n = std::env::var("PTQ161_INTRA_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    BUDGET.store(n, Ordering::Relaxed);
    n
}

/// Override the global budget (the `serve --intra-threads` knob). Takes
/// effect for every subsequent split decision; already-idle pool workers
/// beyond a shrunk budget simply stay idle.
pub fn set_thread_budget(n: usize) {
    BUDGET.store(n.max(1), Ordering::Relaxed);
}

/// Pin this thread's intra-op allowance (sharded engine workers use
/// `budget / workers` so the shards compose instead of oversubscribing).
pub fn set_local_intra(n: usize) {
    LOCAL_INTRA.with(|c| c.set(n.max(1)));
}

/// The split width the current thread may use: its pinned allowance if
/// set (clamped to the budget), else the whole budget.
pub fn local_intra() -> usize {
    let b = thread_budget();
    let l = LOCAL_INTRA.with(|c| c.get());
    if l == 0 {
        b
    } else {
        l.min(b)
    }
}

/// Lower the serial/parallel threshold so tests can force splits on
/// shapes far below the production cutoff.
#[doc(hidden)]
pub fn set_split_threshold_for_tests(bytes: usize) {
    SPLIT_BYTES.store(bytes.max(1), Ordering::Relaxed);
}

/// How many chunks to split `units` work items of `bytes_per_unit` across
/// `threads`: 1 (serial) unless the total clears the split threshold,
/// then enough chunks that each claims [`MIN_CHUNK_BYTES`], capped by the
/// thread count and the unit count.
pub fn plan_chunks(units: usize, bytes_per_unit: usize, threads: usize) -> usize {
    plan_chunks_with(units, bytes_per_unit, threads, SPLIT_BYTES.load(Ordering::Relaxed))
}

fn plan_chunks_with(
    units: usize,
    bytes_per_unit: usize,
    threads: usize,
    min_split: usize,
) -> usize {
    if threads <= 1 || units <= 1 {
        return 1;
    }
    let total = units.saturating_mul(bytes_per_unit);
    if total < min_split {
        return 1;
    }
    (total / MIN_CHUNK_BYTES).max(1).min(threads).min(units)
}

type Task = dyn Fn(usize) + Sync;

struct JobState {
    done: usize,
    panicked: bool,
}

/// One chunked call in flight: workers and the submitting caller claim
/// chunk indices from `next` and report completion through `state`.
///
/// `task` is a raw (lifetime-erased) view of the caller's closure. It is
/// only ever dereferenced for a chunk index claimed while `next` was
/// below `chunks`, and the caller blocks in [`run_chunked`] until
/// `done == chunks` — i.e. until every such dereference has finished —
/// so the pointee outlives every use. The `state` mutex hand-off also
/// gives the caller a happens-before edge over the chunks' writes.
struct Job {
    task: *const Task,
    chunks: usize,
    next: AtomicUsize,
    state: Mutex<JobState>,
    finished: Condvar,
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run chunks until the claim counter is exhausted. Panics
    /// are caught and recorded; the first payload is returned so the
    /// submitting caller can re-raise its own.
    fn claim_and_run(&self) -> Option<Box<dyn std::any::Any + Send + 'static>> {
        let mut first = None;
        loop {
            let idx = self.next.fetch_add(1, Ordering::Relaxed);
            if idx >= self.chunks {
                return first;
            }
            let res =
                catch_unwind(AssertUnwindSafe(|| unsafe { (*self.task)(idx) }));
            let mut st = self.state.lock().unwrap();
            st.done += 1;
            if res.is_err() {
                st.panicked = true;
            }
            if st.done == self.chunks {
                self.finished.notify_all();
            }
            drop(st);
            if let Err(e) = res {
                if first.is_none() {
                    first = Some(e);
                }
            }
        }
    }

    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.chunks
    }
}

struct Pool {
    queue: Mutex<VecDeque<Arc<Job>>>,
    available: Condvar,
    spawned: Mutex<usize>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        queue: Mutex::new(VecDeque::new()),
        available: Condvar::new(),
        spawned: Mutex::new(0),
    })
}

/// Lazily top the pool up to `budget - 1` detached workers (the caller
/// of every job is the remaining thread of the budget).
fn ensure_workers() {
    let want = thread_budget().saturating_sub(1);
    let p = pool();
    let mut n = p.spawned.lock().unwrap();
    while *n < want {
        *n += 1;
        std::thread::Builder::new()
            .name(format!("ptq161-intra-{n}"))
            .spawn(worker_loop)
            .expect("spawn intra-op pool worker");
    }
}

fn worker_loop() {
    let p = pool();
    loop {
        let job = {
            let mut q = p.queue.lock().unwrap();
            loop {
                // drop fully-claimed jobs so their closures can retire
                while q.front().is_some_and(|j| j.exhausted()) {
                    q.pop_front();
                }
                if let Some(front) = q.front() {
                    break Arc::clone(front);
                }
                q = p.available.wait(q).unwrap();
            }
        };
        // worker panics are swallowed here; the submitting caller sees
        // `state.panicked` and re-raises
        let _ = job.claim_and_run();
    }
}

/// Run `f(0), f(1), …, f(chunks - 1)` across the pool plus the calling
/// thread, returning when **all** chunks have finished. `chunks <= 1`
/// runs inline. If any chunk panics, the panic is re-raised here (the
/// caller's own payload when it was the caller's chunk).
pub fn run_chunked(chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if chunks <= 1 {
        if chunks == 1 {
            f(0);
        }
        return;
    }
    ensure_workers();
    let job = Arc::new(Job {
        // lifetime-erasing cast (`dyn + '_` -> `dyn + 'static` behind a
        // raw pointer); see the Job safety comment for why this is sound
        task: unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const Task>(f)
        },
        chunks,
        next: AtomicUsize::new(0),
        state: Mutex::new(JobState { done: 0, panicked: false }),
        finished: Condvar::new(),
    });
    {
        let p = pool();
        let mut q = p.queue.lock().unwrap();
        q.push_back(Arc::clone(&job));
        // wake enough workers for the chunks beyond the caller's own
        p.available.notify_all();
    }
    // the caller works too — even on a panic it keeps claiming, so every
    // chunk is guaranteed an executor whether or not workers are free
    let caller_panic = job.claim_and_run();
    let mut st = job.state.lock().unwrap();
    while st.done < job.chunks {
        st = job.finished.wait(st).unwrap();
    }
    let worker_panicked = st.panicked;
    drop(st);
    if let Some(e) = caller_panic {
        resume_unwind(e);
    }
    if worker_panicked {
        panic!("intra-op pool worker panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    const MB: usize = 1 << 20;

    #[test]
    fn plan_chunks_decision_table() {
        let plan = |u, b, t| plan_chunks_with(u, b, t, MIN_SPLIT_BYTES);
        // a single unit or a single thread can never split
        assert_eq!(plan(1, MB, 8), 1);
        assert_eq!(plan(4096, 4096, 1), 1);
        // below the bytes-of-work threshold: serial, no matter the host
        assert_eq!(plan(4096, 4, 8), 1); // 16 KiB total
        assert_eq!(plan(2048, 64, 16), 1); // 128 KiB total
        // past the threshold: one chunk per MIN_CHUNK_BYTES, thread-capped
        assert_eq!(plan(4096, 256, 8), 8); // 1 MiB -> 16, capped at 8
        assert_eq!(plan(1 << 20, 4, 2), 2);
        // the old par_rows blind spots: a wide single matvec now splits
        // across all threads (old: rows/128 + min(8) forced 1), and an
        // 8-unit giant is capped by units, not the old 8-thread ceiling
        assert_eq!(plan(4096, 4096, 16), 16);
        assert_eq!(plan(8, MB, 16), 8);
        // threshold boundary is inclusive
        assert_eq!(plan(2, MIN_SPLIT_BYTES / 2, 4), 2);
        assert_eq!(plan(2, MIN_SPLIT_BYTES / 2 - 1, 4), 1);
    }

    #[test]
    fn run_chunked_covers_every_chunk_exactly_once() {
        for chunks in [0usize, 1, 2, 7, 33] {
            let hits: Vec<AtomicUsize> =
                (0..chunks.max(1)).map(|_| AtomicUsize::new(0)).collect();
            run_chunked(chunks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate().take(chunks) {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i}");
            }
        }
    }

    #[test]
    fn run_chunked_sums_match_serial() {
        let acc = AtomicU64::new(0);
        run_chunked(16, &|i| {
            acc.fetch_add((i as u64 + 1) * (i as u64 + 1), Ordering::Relaxed);
        });
        let want: u64 = (1..=16u64).map(|v| v * v).sum();
        assert_eq!(acc.load(Ordering::Relaxed), want);
    }

    #[test]
    fn run_chunked_propagates_panics() {
        let done = AtomicUsize::new(0);
        let res = catch_unwind(AssertUnwindSafe(|| {
            run_chunked(8, &|i| {
                if i == 3 {
                    panic!("chunk 3 exploded");
                }
                done.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(res.is_err(), "panic must cross run_chunked");
        // every non-panicking chunk still ran before the re-raise
        assert_eq!(done.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn local_intra_clamps_to_budget() {
        let b = thread_budget();
        assert!(b >= 1);
        set_local_intra(1);
        assert_eq!(local_intra(), 1);
        set_local_intra(usize::MAX);
        assert_eq!(local_intra(), b);
        // restore "unset" semantics for other tests on this thread
        LOCAL_INTRA.with(|c| c.set(0));
        assert_eq!(local_intra(), b);
    }
}
