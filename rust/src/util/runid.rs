//! Run identifiers for metrics files. Concurrent or repeated runs used
//! to clobber each other's `runs/*.json`; suffixing each export with a
//! run id keeps every run's artifact while a stable-named copy stays in
//! place for tooling that hardcodes the path.

use std::process;
use std::time::{SystemTime, UNIX_EPOCH};

/// A short, practically-unique id for this run: unix seconds + pid, both
/// hex. Two runs collide only if the same pid is reused within a second.
pub fn run_id() -> String {
    let secs = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    format!("{secs:x}-{:x}", process::id())
}

/// `name.json` -> `name_<rid>.json` (appends when there is no extension).
pub fn suffixed(file_name: &str, rid: &str) -> String {
    match file_name.rsplit_once('.') {
        Some((stem, ext)) => format!("{stem}_{rid}.{ext}"),
        None => format!("{file_name}_{rid}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_id_is_hex_pair() {
        let rid = run_id();
        let (a, b) = rid.split_once('-').expect("secs-pid shape");
        assert!(u64::from_str_radix(a, 16).is_ok());
        assert!(u64::from_str_radix(b, 16).is_ok());
    }

    #[test]
    fn suffix_goes_before_the_extension() {
        assert_eq!(suffixed("serve_metrics.json", "ab-1"),
                   "serve_metrics_ab-1.json");
        assert_eq!(suffixed("noext", "ab-1"), "noext_ab-1");
    }
}
