//! Packing benches: the bit-exact containers on a real LLaMA layer slice —
//! pack/unpack throughput bounds the (de)serialization cost of a deployed
//! 1.61-bit checkpoint, and the prepared-container matvec is the packed
//! serve path's per-token inner loop (vs the fused path's rebuild-Wq'
//! matmul).
//!
//! The wide-matvec section measures the kernel-dispatch stack on decode's
//! actual shape (one batch row against a ≥2048-row layer): the blocked
//! single-thread tier vs the deployed tier (SIMD when detected) at one
//! intra-op thread and at the full pool budget. The three speedup ratios
//! (`simd_speedup`, `intra_parallel_speedup`, `combined_speedup`) are
//! merged into `runs/BENCH_serve.json` under `bench_packing` for CI's
//! bench-regression gate — merged, not overwritten: `bench_serve` owns
//! the rest of that file and runs first.
//!
//! Correctness gates here mirror the dispatch contracts: the blocked tier
//! must stay *bit-identical* to the scalar oracle, while the deployed
//! tier (possibly SIMD, re-associated adds) gets a magnitude-scaled
//! epsilon gate against the same oracle.

use ptq161::packing::bitpack::BitVec;
use ptq161::packing::nibble::{quantize_column, NibbleVec};
use ptq161::quant::ptq161::{initial_parts, PackedLinear};
use ptq161::runtime::autodiff::{
    kernel_tier, packed_decode_fwd, packed_qlinear_fwd,
    packed_qlinear_fwd_scalar, qlinear_fwd,
};
use ptq161::runtime::pool;
use ptq161::tensor::Tensor;
use ptq161::util::bench::Bencher;
use ptq161::util::json::{num, obj, s, Json};
use ptq161::util::rng::Rng;

/// Assert `got` matches the scalar oracle within the re-association
/// bound: each output is a length-`inn` chain of products of `x` against
/// bounded container values, so the worst-case tier-to-tier drift scales
/// with `inn · Σ|x|` ulps.
fn assert_close_to_oracle(got: &Tensor, want: &Tensor, x: &Tensor, inn: usize) {
    let sum_abs: f32 = x.data.iter().map(|v| v.abs()).sum();
    let tol = 8.0 * f32::EPSILON * inn as f32 * (1.0 + sum_abs);
    for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
        assert!(
            (a - b).abs() <= tol,
            "deployed kernel drifted from the scalar oracle at {i}: \
             {a} vs {b} (tol {tol})"
        );
    }
}

fn main() {
    let mut rng = Rng::new(3);
    let n = 4096 * 64; // 64 rows of a 4096-wide layer
    let weights: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
    let b = Bencher::quick();
    b.run("packing/bitpack_signs_256k", || BitVec::from_signs(&weights));
    let bv = BitVec::from_signs(&weights);
    b.run("packing/unpack_signs_256k", || bv.to_signs());
    let col: Vec<f32> = weights[..4096].to_vec();
    b.run("packing/quant4_column_4096", || quantize_column(&col));
    let (codes, _, _) = quantize_column(&col);
    b.run("packing/nibble_pack_4096", || NibbleVec::from_codes(&codes));

    // prepared packed-weight containers: pack once, then the serve-path
    // matvec against a reconstruction-free 1.61-bit layer
    let (out, inn) = (512, 512);
    let w = Tensor::randn(&[out, inn], 0.1, &mut rng);
    let mask: Vec<bool> = (0..inn).map(|j| j % 5 == 0).collect();
    let parts = initial_parts(&w, &mask);
    b.run("packing/packed_linear_pack_512x512", || {
        PackedLinear::pack(&parts)
    });
    let pl = PackedLinear::pack(&parts);
    let x = Tensor::randn(&[1, inn], 1.0, &mut rng);
    let a_s = Tensor::from_vec(&[out], parts.alpha_s.clone());
    let r1 = Tensor::from_vec(&[out], parts.alpha_r1.clone());
    let r2 = Tensor::from_vec(&[inn], parts.alpha_r2.clone());
    let mu = Tensor::from_vec(&[out], parts.mu.clone());
    b.run("packing/fused_matvec_rebuild_512", || {
        qlinear_fwd(&x, &a_s, &r1, &r2, &mu, &parts.w_sal, &parts.sign_ns)
    });
    // scalar set-bit walk vs the 4-row-tiled whole-word kernel the serve
    // path runs: same containers, bit-identical outputs, the delta is the
    // blocked accumulation's win
    let scalar = b.run("packing/packed_matvec_512_scalar", || {
        packed_qlinear_fwd_scalar(&x, &pl)
    });
    let blocked =
        b.run("packing/packed_matvec_512_blocked", || packed_qlinear_fwd(&x, &pl));
    assert_eq!(
        packed_qlinear_fwd(&x, &pl).data,
        packed_qlinear_fwd_scalar(&x, &pl).data,
        "blocked kernel must stay bit-identical to the scalar walk"
    );
    // the deployed dispatch (SIMD where detected) re-associates the adds:
    // epsilon gate, never bit-compared
    assert_close_to_oracle(
        &packed_decode_fwd(&x, &pl),
        &packed_qlinear_fwd_scalar(&x, &pl),
        &x,
        inn,
    );
    println!(
        "blocked/scalar packed matvec mean: {:.2}x (below 1.0 = blocked wins)",
        blocked.mean_ns / scalar.mean_ns.max(1e-9)
    );
    println!(
        "packed 512x512: {} bytes resident, {:.3} bits/weight",
        pl.resident_bytes(),
        pl.effective_bits()
    );

    // ---- kernel-dispatch stack on decode's shape ------------------------
    // one batch row against a wide layer: the case the output-row split
    // and the SIMD tiers exist for
    let (wout, winn) = (2048, 1024);
    let ww = Tensor::randn(&[wout, winn], 0.1, &mut rng);
    let wmask: Vec<bool> = (0..winn).map(|j| j % 5 == 0).collect();
    let wparts = initial_parts(&ww, &wmask);
    let wpl = PackedLinear::pack(&wparts);
    let wx = Tensor::randn(&[1, winn], 1.0, &mut rng);
    assert_close_to_oracle(
        &packed_decode_fwd(&wx, &wpl),
        &packed_qlinear_fwd_scalar(&wx, &wpl),
        &wx,
        winn,
    );
    let budget = pool::thread_budget();
    let tier = kernel_tier();
    pool::set_local_intra(1);
    let blocked_1t = b.run("packing/packed_matvec_2048_blocked_1t", || {
        packed_qlinear_fwd(&wx, &wpl)
    });
    let deployed_1t = b.run("packing/packed_matvec_2048_deployed_1t", || {
        packed_decode_fwd(&wx, &wpl)
    });
    pool::set_local_intra(budget);
    let deployed_nt = b.run("packing/packed_matvec_2048_deployed_nt", || {
        packed_decode_fwd(&wx, &wpl)
    });
    let simd_speedup = blocked_1t.mean_ns / deployed_1t.mean_ns.max(1e-9);
    let intra_speedup = deployed_1t.mean_ns / deployed_nt.mean_ns.max(1e-9);
    let combined = blocked_1t.mean_ns / deployed_nt.mean_ns.max(1e-9);
    println!(
        "kernel dispatch 2048x1024 (tier {tier}, {budget} intra threads): \
         simd {simd_speedup:.2}x, intra-parallel {intra_speedup:.2}x, \
         combined {combined:.2}x over blocked single-thread"
    );
    let simd_available = tier == "avx2" || tier == "neon";
    if budget >= 4 && simd_available {
        assert!(
            combined >= 2.0,
            "SIMD + intra-parallel must be >= 2x over the blocked \
             single-thread tier on a >= 4-core host, got {combined:.2}x"
        );
    }

    // merge (not overwrite) into the serve-bench summary: bench_serve
    // writes the rest of this file and runs first in CI
    let path = ptq161::runs_dir().join("BENCH_serve.json");
    let mut fields: Vec<(String, Json)> = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| match j {
            Json::Obj(kv) => Some(kv),
            _ => None,
        })
        .unwrap_or_default();
    fields.retain(|(k, _)| k != "bench_packing");
    fields.push((
        "bench_packing".to_string(),
        obj(vec![
            ("simd", s(tier)),
            ("parallelism", num(budget as f64)),
            ("simd_speedup", num(simd_speedup)),
            ("intra_parallel_speedup", num(intra_speedup)),
            ("combined_speedup", num(combined)),
            ("blocked_1t_mean_ns", num(blocked_1t.mean_ns)),
            ("deployed_1t_mean_ns", num(deployed_1t.mean_ns)),
            ("deployed_nt_mean_ns", num(deployed_nt.mean_ns)),
        ]),
    ));
    std::fs::write(&path, Json::Obj(fields).dump()).unwrap();
    println!("kernel-dispatch summary merged into {}", path.display());
}
