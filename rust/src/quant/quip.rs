//! QuIP-lite (Chee et al., 2024): incoherence processing. The weight is
//! rotated by a seeded random orthogonal matrix on the input side
//! (W' = W Q), quantized at b bits, and rotated back (dequant = W'' Q^T).
//! Rotation spreads outliers across coordinates, the core of QuIP's
//! guarantee; the LDLQ rounding is approximated by GPTQ-style per-row RTN
//! on the rotated weight at this scale.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::quant::rtn::rtn_dense;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Random orthogonal (m, m) via a product of Householder reflections.
pub fn random_orthogonal(m: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut q = Tensor::zeros(&[m, m]);
    for i in 0..m {
        *q.at2_mut(i, i) = 1.0;
    }
    // enough dense reflections to spread any single-coordinate outlier
    let reflections = 32.min(m);
    for _ in 0..reflections {
        let mut v: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-8);
        for x in v.iter_mut() {
            *x /= norm;
        }
        // Q <- Q (I - 2 v v^T)
        for r in 0..m {
            let row = q.row(r);
            let dot: f32 = row.iter().zip(&v).map(|(&a, &b)| a * b).sum();
            let row = q.row_mut(r);
            for (j, x) in row.iter_mut().enumerate() {
                *x -= 2.0 * dot * v[j];
            }
        }
    }
    q
}

#[derive(Debug, Clone, Copy)]
pub struct QuipLite {
    pub bits: u32,
    pub seed: u64,
}

impl QuipLite {
    pub fn new(bits: u32) -> QuipLite {
        QuipLite { bits, seed: 0x9u64 }
    }
}

impl Quantizer for QuipLite {
    fn name(&self) -> &'static str {
        "QuIP"
    }

    fn bits_label(&self) -> String {
        format!("{}", self.bits)
    }

    fn quantize_linear(&self, w: &Tensor, _calib: &LinearCalib) -> QuantizedLinear {
        let m = w.cols();
        let q = random_orthogonal(m, self.seed ^ m as u64);
        let rotated = w.matmul(&q);
        let deq_rot = rtn_dense(&rotated, self.bits, 1.0);
        let deq = deq_rot.matmul(&q.t());
        QuantizedLinear {
            deq,
            scheme: BitScheme::Uniform { bits: self.bits as f64 },
            parts: None,
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::quant::testutil::demo;
    use crate::quant::Quantizer;

    #[test]
    fn rotation_is_orthogonal() {
        let q = random_orthogonal(24, 5);
        let id = q.matmul(&q.t());
        for i in 0..24 {
            for j in 0..24 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at2(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn helps_on_outlier_weights() {
        // several outliers of different magnitudes per row: the asymmetric
        // RTN grid can anchor at most its two end-points on them, while the
        // rotation spreads all of them into a near-gaussian row
        let (mut w, calib) = demo(24, 32, 15);
        for i in 0..24 {
            *w.at2_mut(i, 0) = 3.0;
            *w.at2_mut(i, 11) = -2.5;
            *w.at2_mut(i, 23) = 1.8;
        }
        let qp = QuipLite::new(2).quantize_linear(&w, &calib);
        let r = Rtn::new(2).quantize_linear(&w, &calib);
        assert!(
            qp.deq.mse(&w) < r.deq.mse(&w),
            "quip {} vs rtn {}",
            qp.deq.mse(&w),
            r.deq.mse(&w)
        );
    }

    #[test]
    fn deterministic() {
        let (w, calib) = demo(8, 16, 16);
        let a = QuipLite::new(2).quantize_linear(&w, &calib);
        let b = QuipLite::new(2).quantize_linear(&w, &calib);
        assert_eq!(a.deq.data, b.deq.data);
    }
}
