//! Manifest: the typed view of artifacts/manifest.json (the Python↔Rust
//! contract). Parsed with the in-repo JSON substrate.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub base: String,
    pub config: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Index of an input by name (call sites assemble positionally but
    /// assert names when the ordering is subtle).
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|io| io.name == name)
    }
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub ffn: usize,
    pub seq: usize,
    pub b_train: usize,
    pub b_eval: usize,
    pub lora_rank: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub configs: HashMap<String, ModelConfig>,
    /// canonical parameter order per config: (name, shape)
    pub param_spec: HashMap<String, Vec<(String, Vec<usize>)>>,
    /// block linear names in canonical order (wq..w_down)
    pub linears: Vec<String>,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

fn io_from_json(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("io missing name"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("io missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?,
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("f32")
            .to_string(),
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut configs = HashMap::new();
        for (cname, cj) in root
            .get("configs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing configs"))?
        {
            let u = |k: &str| -> Result<usize> {
                cj.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("config {cname} missing {k}"))
            };
            configs.insert(
                cname.clone(),
                ModelConfig {
                    name: cname.clone(),
                    vocab: u("vocab")?,
                    d: u("d")?,
                    n_heads: u("n_heads")?,
                    n_layers: u("n_layers")?,
                    ffn: u("ffn")?,
                    seq: u("seq")?,
                    b_train: u("b_train")?,
                    b_eval: u("b_eval")?,
                    lora_rank: u("lora_rank")?,
                },
            );
        }
        let mut param_spec = HashMap::new();
        for (cname, sj) in root
            .get("param_spec")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing param_spec"))?
        {
            let mut spec = Vec::new();
            for entry in sj.as_arr().ok_or_else(|| anyhow!("bad spec"))? {
                let name = entry
                    .idx(0)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("bad spec name"))?
                    .to_string();
                let shape: Vec<usize> = entry
                    .idx(1)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("bad spec shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                spec.push((name, shape));
            }
            param_spec.insert(cname.clone(), spec);
        }
        let linears = root
            .get("linears")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing linears"))?
            .iter()
            .filter_map(|j| j.as_str().map(str::to_string))
            .collect();
        let mut artifacts = HashMap::new();
        for aj in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let gets = |k: &str| -> Result<String> {
                aj.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            let spec = ArtifactSpec {
                name: gets("name")?,
                base: gets("base")?,
                config: gets("config")?,
                file: gets("file")?,
                inputs: aj
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing inputs"))?
                    .iter()
                    .map(io_from_json)
                    .collect::<Result<_>>()?,
                outputs: aj
                    .get("outputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing outputs"))?
                    .iter()
                    .map(io_from_json)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { configs, param_spec, linears, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "configs": {"tiny": {"vocab":256,"d":128,"n_heads":4,"n_layers":4,
        "ffn":352,"seq":128,"b_train":8,"b_eval":4,"rope_theta":10000.0,
        "lora_rank":8,"name":"tiny"}},
      "param_spec": {"tiny": [["embed",[256,128]],["norm_f",[128]]]},
      "linears": ["wq","wk","wv","wo","w_gate","w_up","w_down"],
      "artifacts": [{"name":"head_fwd_tiny","base":"head_fwd",
        "config":"tiny","file":"head_fwd_tiny.hlo.txt",
        "inputs":[{"name":"h","shape":[4,128,128],"dtype":"f32"},
                  {"name":"tokens","shape":[4,128],"dtype":"i32"}],
        "outputs":[{"name":"nll_sum","shape":[],"dtype":"f32"}]}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.configs["tiny"].d, 128);
        assert_eq!(m.param_spec["tiny"][0].0, "embed");
        assert_eq!(m.linears.len(), 7);
        let art = &m.artifacts["head_fwd_tiny"];
        assert_eq!(art.inputs[1].dtype, "i32");
        assert_eq!(art.input_index("tokens"), Some(1));
        assert_eq!(art.outputs[0].shape, Vec::<usize>::new());
    }

    #[test]
    fn rejects_missing_sections() {
        assert!(Manifest::parse("{}").is_err());
    }
}
