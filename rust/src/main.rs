//! PTQ1.61 CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   pretrain    --model tiny --steps 400
//!   preprocess  --model tiny --steps 120
//!   quantize    --model tiny --method ptq161 [--preprocessed]
//!   eval        --model tiny --method ptq161 [--preprocessed] [--fused]
//!   serve       --model tiny --method ptq161 --requests 8
//!   experiment  <t1..t13|f1|f3..f7|appA|all> [--full]
//!   all         run every experiment (EXPERIMENTS.md regeneration)

use anyhow::Result;
use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::experiments::{self, ExperimentCtx};
use ptq161::serve::{generate_batch, GenRequest};
use ptq161::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "pretrain" => {
            let mut ctx = ctx_from(&args)?;
            ctx.pretrain_steps = args.usize_opt("steps", ctx.pretrain_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.pretrained(&model)?;
            println!("pretrained {model}: {} params", p.total_params());
        }
        "preprocess" => {
            let mut ctx = ctx_from(&args)?;
            ctx.preprocess_steps = args.usize_opt("steps", ctx.preprocess_steps);
            let model = args.str_opt("model", "tiny");
            let p = ctx.preprocessed(&model)?;
            println!("preprocessed {model}: {} params", p.total_params());
        }
        "quantize" | "eval" => {
            let mut ctx = ctx_from(&args)?;
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let pre = args.flag("preprocessed") || method == "ptq161";
            let qm = ctx.quantized(&model, &method, pre)?;
            println!(
                "quantized {model} with {} ({}): {:.3} bits/weight at 4096^2",
                qm.method, qm.bits_label, qm.avg_bits
            );
            if sub == "eval" {
                let wiki = ctx.ppl(&model, &qm.params, &ctx.wiki.clone())?;
                let c4 = ctx.ppl(&model, &qm.params, &ctx.c4.clone())?;
                println!("ppl wiki {wiki:.2}  c4 {c4:.2}");
                if args.flag("fused") {
                    let parts = qm.parts.as_ref().expect("fused path needs ptq161");
                    let pipe = Pipeline::new(&ctx.rt, &model)?;
                    let p = ptq161::eval::ppl::perplexity(
                        &pipe,
                        &ModelEval::Fused { params: &qm.params, parts },
                        &ctx.wiki,
                        ctx.ppl_batches,
                    )?;
                    println!("ppl wiki via fused Pallas-kernel path: {p:.2}");
                }
            }
        }
        "serve" => {
            let mut ctx = ctx_from(&args)?;
            let model = args.str_opt("model", "tiny");
            let method = args.str_opt("method", "ptq161");
            let n = args.usize_opt("requests", 8);
            let qm = ctx.quantized(&model, &method, method == "ptq161")?;
            let pipe = Pipeline::new(&ctx.rt, &model)?;
            let mut batcher = ptq161::serve::batcher::Batcher::new(pipe.cfg.b_eval);
            for i in 0..n {
                batcher.submit(GenRequest {
                    prompt: format!("the quiet river of alda {}", i % 3),
                    max_new_tokens: 16,
                });
            }
            let mut stats = ptq161::serve::ServeStats::default();
            while let Some(batch) = batcher.next_batch() {
                let reqs: Vec<GenRequest> =
                    batch.iter().map(|(_, r)| r.clone()).collect();
                let t0 = std::time::Instant::now();
                let resps =
                    generate_batch(&pipe, &ModelEval::Dense(&qm.params), &reqs)?;
                let ms = t0.elapsed().as_secs_f64() * 1000.0;
                for r in &resps {
                    stats.requests += 1;
                    stats.total_new_tokens += r.new_tokens;
                    stats.per_request_ms.push(r.latency_ms);
                    println!("-> {:?}", &r.text[..r.text.len().min(72)]);
                }
                stats.total_ms += ms;
            }
            println!(
                "served {} reqs: {:.1} tok/s, p50 {:.0} ms, p95 {:.0} ms",
                stats.requests,
                stats.throughput_tok_s(),
                stats.p50_ms(),
                stats.p95_ms()
            );
        }
        "experiment" | "all" => {
            let mut ctx = ctx_from(&args)?;
            let ids: Vec<String> = if sub == "all"
                || args.positional.first().map(String::as_str) == Some("all")
            {
                let mut v: Vec<String> = experiments::ALL_IDS
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
                v.extend(experiments::EXTRA_IDS.iter().map(|s| s.to_string()));
                v.push("appA".into());
                v
            } else {
                args.positional.clone()
            };
            for id in ids {
                eprintln!("\n##### experiment {id} #####");
                experiments::run(&mut ctx, &id)?;
            }
        }
        _ => {
            println!(
                "usage: ptq161 <pretrain|preprocess|quantize|eval|serve|experiment|all> \
                 [--model tiny|small] [--method NAME] [--quick] [--full] ..."
            );
        }
    }
    Ok(())
}

fn ctx_from(args: &Args) -> Result<ExperimentCtx> {
    if args.flag("quick") {
        ExperimentCtx::quick()
    } else {
        ExperimentCtx::new(args.flag("full"))
    }
}
