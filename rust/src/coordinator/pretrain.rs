//! Pretraining loop: the Rust coordinator drives the AOT `lm_grad`
//! executable (whole-model fwd+bwd in one XLA module) and applies AdamW on
//! the host. Produces the "pretrained model" every PTQ experiment starts
//! from and logs the loss curve (the e2e example records it in
//! EXPERIMENTS.md).

use anyhow::Result;

use super::Pipeline;
use crate::data::Corpus;
use crate::model::Params;
use crate::opt::AdamW;
use crate::runtime::Value;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { steps: 400, lr: 3e-3, seed: 7, log_every: 25 }
    }
}

pub struct PretrainResult {
    pub params: Params,
    /// (step, loss) curve
    pub curve: Vec<(usize, f32)>,
}

/// One lm_grad execution: returns (loss, grads in param order).
pub fn lm_grad(
    pipe: &Pipeline,
    params: &Params,
    tokens: &[i32],
) -> Result<(f32, Vec<crate::tensor::Tensor>)> {
    let (b, t) = (pipe.cfg.b_train, pipe.cfg.seq);
    let mut inputs: Vec<Value> =
        params.tensors.iter().map(Value::from).collect();
    inputs.push(Value::tokens(&[b, t], tokens.to_vec()));
    let mut out = pipe.rt.run_cfg("lm_grad", pipe.cname(), &inputs)?;
    let grads = out.split_off(1);
    Ok((out[0].data[0], grads))
}

pub fn pretrain(
    pipe: &Pipeline,
    corpus: &Corpus,
    cfg: &PretrainConfig,
) -> Result<PretrainResult> {
    let mut params = pipe.init_params(cfg.seed);
    let mut opt = AdamW::new(cfg.lr, params.tensors.len());
    let mut rng = Rng::new(cfg.seed ^ 0xBEEF);
    let mut curve = Vec::new();
    for step in 0..cfg.steps {
        let batch = corpus.batch(pipe.cfg.b_train, pipe.cfg.seq, &mut rng);
        let (loss, grads) = lm_grad(pipe, &params, &batch)?;
        opt.step(&mut params.tensors, &grads);
        if step % cfg.log_every == 0 || step + 1 == cfg.steps {
            curve.push((step, loss));
            eprintln!("[pretrain {}] step {step:>4} loss {loss:.4}", pipe.cname());
        }
    }
    Ok(PretrainResult { params, curve })
}

/// Load a cached pretrained checkpoint or train + save one.
pub fn pretrain_cached(
    pipe: &Pipeline,
    corpus: &Corpus,
    cfg: &PretrainConfig,
) -> Result<PretrainResult> {
    let path = crate::runs_dir()
        .join(format!("pretrained_{}_{}steps.bin", pipe.cname(), cfg.steps));
    if path.exists() {
        eprintln!("[pretrain] loading cached {}", path.display());
        return Ok(PretrainResult {
            params: Params::load(&path)?,
            curve: Vec::new(),
        });
    }
    let res = pretrain(pipe, corpus, cfg)?;
    res.params.save(&path)?;
    Ok(res)
}
