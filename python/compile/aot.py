"""AOT compiler: lower every L2 graph to HLO *text* + write the manifest.

HLO text (never ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 rust crate links) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot [--out-dir ../artifacts] [--configs tiny,small]

The manifest (artifacts/manifest.json) is the binary contract with the Rust
runtime: canonical parameter order, every artifact's input/output names,
shapes and dtypes, and the model configs themselves.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def block_param_ios(cfg, prefix=""):
    return [_io(prefix + n.split(".", 1)[1], s)
            for n, s in M.block_param_spec(cfg, 0)]


def qparts_ios(cfg):
    ios = []
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        ios += [
            _io(f"{n}.w_sal", (out, inn)),
            _io(f"{n}.sign_ns", (out, inn)),
            _io(f"{n}.alpha_s", (out,)),
            _io(f"{n}.alpha_r1", (out,)),
            _io(f"{n}.alpha_r2", (inn,)),
            _io(f"{n}.mu", (out,)),
        ]
    return ios


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, input ios, output ios)
# ---------------------------------------------------------------------------

def build_artifacts(cfg):
    d, ffn, vocab = cfg["d"], cfg["ffn"], cfg["vocab"]
    t, be, bt = cfg["seq"], cfg["b_eval"], cfg["b_train"]
    nl = len(M.LINEARS)
    nlin = cfg["n_layers"] * nl
    arts = {}

    # --- embed_fwd ---
    def embed_fn(tokens, embed):
        return (M.embed_fwd(tokens, embed),)
    arts["embed_fwd"] = (
        embed_fn,
        [_io("tokens", (be, t), "i32"), _io("embed", (vocab, d))],
        [_io("h", (be, t, d))],
    )

    # --- block_fwd / block_capture ---
    bp_names = [n for n, _ in M.block_param_spec(cfg, 0)]

    def block_fn(h, *ps):
        p = {n.split(".", 1)[1]: x for n, x in zip(bp_names, ps)}
        return (M.block_fwd(h, p, cfg),)

    def capture_fn(h, *ps):
        p = {n.split(".", 1)[1]: x for n, x in zip(bp_names, ps)}
        return M.block_capture(h, p, cfg)

    bp_ios = block_param_ios(cfg)
    arts["block_fwd"] = (
        block_fn, [_io("h", (be, t, d))] + bp_ios, [_io("h_out", (be, t, d))]
    )
    arts["block_capture"] = (
        capture_fn,
        [_io("h", (be, t, d))] + bp_ios,
        [
            _io("x_attn", (be, t, d)), _io("x_o", (be, t, d)),
            _io("x_mlp", (be, t, d)), _io("x_down", (be, t, ffn)),
            _io("h_out", (be, t, d)),
        ],
    )

    # --- qblock_fwd (fused Pallas kernel inside) ---
    def qblock_fn(h, attn_norm, mlp_norm, *parts):
        qp = {}
        for i, n in enumerate(M.LINEARS):
            qp[n] = tuple(parts[6 * i:6 * i + 6])
        return (M.qblock_fwd(h, (attn_norm, mlp_norm), qp, cfg),)

    arts["qblock_fwd"] = (
        qblock_fn,
        [_io("h", (be, t, d)), _io("attn_norm", (d,)), _io("mlp_norm", (d,))]
        + qparts_ios(cfg),
        [_io("h_out", (be, t, d))],
    )

    # --- qblock_w4a4_fwd (SmoothQuant, Table 13) ---
    def w4a4_fn(h, *args):
        p = {n.split(".", 1)[1]: x for n, x in zip(bp_names, args[:len(bp_names)])}
        s_attn, s_o, s_mlp, s_down = args[len(bp_names):]
        smooth = {"wq": s_attn, "wk": s_attn, "wv": s_attn, "wo": s_o,
                  "w_gate": s_mlp, "w_up": s_mlp, "w_down": s_down}
        return (M.qblock_w4a4_fwd(h, p, smooth, cfg),)

    arts["qblock_w4a4_fwd"] = (
        w4a4_fn,
        [_io("h", (be, t, d))] + bp_ios
        + [_io("s_attn", (d,)), _io("s_o", (d,)), _io("s_mlp", (d,)),
           _io("s_down", (ffn,))],
        [_io("h_out", (be, t, d))],
    )

    # --- head_fwd ---
    def head_fn(h, norm_f, w_out, tokens):
        return M.head_fwd(h, norm_f, w_out, tokens)

    arts["head_fwd"] = (
        head_fn,
        [_io("h", (be, t, d)), _io("norm_f", (d,)),
         _io("w_out", (vocab, d)), _io("tokens", (be, t), "i32")],
        [_io("nll_sum", ()), _io("logits", (be, t, vocab))],
    )

    # --- lm_grad (pretraining) ---
    spec = M.param_spec(cfg)
    param_ios = [_io(n, s) for n, s in spec]
    arts["lm_grad"] = (
        M.lm_grad_fn(cfg),
        param_ios + [_io("tokens", (bt, t), "i32")],
        [_io("loss", ())] + [_io("g." + n, s) for n, s in spec],
    )

    # --- lora_grad (preprocessing) ---
    ab_ios, ab_outs, mask_ios = [], [], []
    r = cfg["lora_rank"]
    for l in range(cfg["n_layers"]):
        for n in M.LINEARS:
            out, inn = M.linear_shape(cfg, n)
            ab_ios += [_io(f"l{l}.{n}.A", (r, inn)),
                       _io(f"l{l}.{n}.B", (out, r))]
            ab_outs += [_io(f"g.l{l}.{n}.A", (r, inn)),
                        _io(f"g.l{l}.{n}.B", (out, r))]
    for l in range(cfg["n_layers"]):
        for n in M.LINEARS:
            _, inn = M.linear_shape(cfg, n)
            mask_ios.append(_io(f"l{l}.{n}.mask", (inn,)))
    arts["lora_grad"] = (
        M.lora_grad_fn(cfg),
        param_ios + ab_ios + mask_ios + [_io("tokens", (bt, t), "i32")],
        [_io("loss", ())] + ab_outs,
    )

    # --- block_opt_grad (Eq. 5-7) ---
    learn_ios, learn_outs, const_ios = [], [], []
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        learn_ios += [_io(f"{n}.alpha_s", (out,)), _io(f"{n}.alpha_r1", (out,)),
                      _io(f"{n}.alpha_r2", (inn,)), _io(f"{n}.mu", (out,))]
        learn_outs += [_io(f"g.{n}.alpha_s", (out,)),
                       _io(f"g.{n}.alpha_r1", (out,)),
                       _io(f"g.{n}.alpha_r2", (inn,)),
                       _io(f"g.{n}.mu", (out,))]
    for n in M.LINEARS:
        out, inn = M.linear_shape(cfg, n)
        const_ios += [_io(f"{n}.w_sal", (out, inn)),
                      _io(f"{n}.sign_ns", (out, inn))]
    arts["block_opt_grad"] = (
        M.block_opt_grad_fn(cfg),
        learn_ios
        + [_io("x_q", (be, t, d)), _io("f1", (be, t, d)),
           _io("f3", (be, t, d)), _io("attn_norm", (d,)),
           _io("mlp_norm", (d,))]
        + const_ios + [_io("nlc_w", ())],
        [_io("loss", ())] + learn_outs,
    )

    return arts


def lower_artifact(fn, in_ios):
    specs = []
    for io in in_ios:
        mk = i32 if io["dtype"] == "i32" else f32
        specs.append(mk(io["shape"]))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--only", default=None,
                    help="comma list of artifact base names to (re)build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"configs": {}, "param_spec": {}, "linears": M.LINEARS,
                "artifacts": []}
    only = set(args.only.split(",")) if args.only else None
    for cname in args.configs.split(","):
        cfg = M.CONFIGS[cname]
        manifest["configs"][cname] = cfg
        manifest["param_spec"][cname] = [
            [n, list(s)] for n, s in M.param_spec(cfg)
        ]
        for base, (fn, in_ios, out_ios) in build_artifacts(cfg).items():
            if only and base not in only:
                continue
            name = f"{base}_{cname}"
            path = os.path.join(args.out_dir, name + ".hlo.txt")
            text = lower_artifact(fn, in_ios)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append({
                "name": name, "base": base, "config": cname,
                "file": name + ".hlo.txt",
                "inputs": in_ios, "outputs": out_ios,
            })
            print(f"  lowered {name}: {len(in_ios)} in / {len(out_ios)} out "
                  f"({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to "
          f"{args.out_dir}")


if __name__ == "__main__":
    main()
