//! HTTP front-door integration tests (tier-1, std `TcpStream` clients,
//! no artifacts): the SSE stream must carry exactly the token ids the
//! engine commits — reassembling byte-identical to the in-process
//! single-loop engine at 1 and 2 workers; a malformed request must get a
//! `400` without wedging a lane; a client that disconnects mid-stream
//! must have its lane and KV pages freed (observed via the `/stats`
//! gauges returning to zero); and a full queue must shed load with `429`
//! + `Retry-After` instead of queueing unboundedly.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

use ptq161::coordinator::Pipeline;
use ptq161::eval::ModelEval;
use ptq161::runtime::Runtime;
use ptq161::serve::batcher::Batcher;
use ptq161::serve::load::{http_generate, reconstruct_text, Outcome};
use ptq161::serve::{
    serve_http, Engine, EngineCfg, GenRequest, HttpServerCfg,
    MetricsRegistry, ShardRun, ShardSpec,
};
use ptq161::util::json::Json;

/// Single-loop in-process engine run — the identity baseline. Texts in
/// submit order (ids are assigned in submit order on both paths).
fn baseline(pipe: &Pipeline, me: &ModelEval, reqs: &[GenRequest]) -> Vec<String> {
    let mut batcher = Batcher::new(pipe.cfg.b_eval);
    for r in reqs {
        batcher.submit(r.clone());
    }
    let mut metrics = MetricsRegistry::new("baseline");
    let mut engine = Engine::new(pipe, me);
    let mut resps = engine.run(&mut batcher, &mut metrics).unwrap();
    resps.sort_by_key(|r| r.id);
    resps.into_iter().map(|r| r.text).collect()
}

/// Send raw bytes, read the full response (the server closes after each
/// response, so read-to-end terminates).
fn raw(addr: &str, request: &[u8]) -> String {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    conn.write_all(request).unwrap();
    let mut out = Vec::new();
    conn.read_to_end(&mut out).unwrap();
    String::from_utf8_lossy(&out).into_owned()
}

/// The `/stats` gauges as parsed JSON.
fn stats(addr: &str) -> Json {
    let resp = raw(addr, b"GET /stats HTTP/1.1\r\n\r\n");
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("{}");
    Json::parse(body).unwrap()
}

fn gauge(j: &Json, key: &str) -> usize {
    j.get(key).and_then(Json::as_usize).unwrap_or(usize::MAX)
}

/// Spawn a bounded front door, run `client` against it, return what the
/// server's engine deployment produced.
fn with_server<T>(
    workers: usize,
    hcfg: &HttpServerCfg,
    seed: u64,
    client: impl FnOnce(&str, &Pipeline) -> T,
) -> (ShardRun, T) {
    let rt = Runtime::native();
    let pipe = Pipeline::new(&rt, "micro").unwrap();
    let params = pipe.init_params(seed);
    let me = ModelEval::Dense(&params);
    let ecfg = EngineCfg { workers, ..EngineCfg::default() };
    let spec = ShardSpec { label: "http-test", page_size: 16, kv_pages: None };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    thread::scope(|scope| {
        let (p, m, e, sp, h) = (&pipe, &me, &ecfg, &spec, hcfg);
        let server =
            scope.spawn(move || serve_http(p, m, e, sp, h, listener).unwrap());
        let out = client(&addr, &pipe);
        let run = server.join().expect("server thread panicked");
        assert_eq!(run.worker_panics, 0, "a worker panicked under HTTP load");
        (run, out)
    })
}

#[test]
fn sse_stream_is_byte_identical_to_in_process_engine() {
    // micro has b_eval = 2, so 2 is the max effective worker count
    for workers in [1usize, 2] {
        let reqs: Vec<GenRequest> = (0..4)
            .map(|i| GenRequest {
                prompt: format!("SYSTEM: be terse. req {i}"),
                max_new_tokens: [3, 1, 4, 2][i % 4],
            })
            .collect();
        let hcfg = HttpServerCfg {
            max_requests: Some(reqs.len()),
            ..HttpServerCfg::default()
        };
        let (run, streamed) = with_server(workers, &hcfg, 91, |addr, pipe| {
            let mut streamed = Vec::new();
            for r in &reqs {
                match http_generate(addr, r) {
                    Outcome::Stream(sr) => {
                        assert!(sr.in_order, "token indices out of order");
                        // streamed token ids must reassemble to the done
                        // text byte-for-byte
                        assert_eq!(
                            reconstruct_text(&r.prompt, &sr.tokens, pipe.cfg.seq),
                            sr.text,
                            "stream does not reassemble its own response"
                        );
                        assert_eq!(sr.tokens.len(), r.max_new_tokens);
                        streamed.push(sr.text);
                    }
                    other => panic!("expected a stream, got {other:?}"),
                }
            }
            let base = baseline(pipe, &ModelEval::Dense(&pipe.init_params(91)), &reqs);
            assert_eq!(
                streamed, base,
                "w{workers}: streamed tokens diverge from in-process engine"
            );
            streamed
        });
        assert_eq!(run.responses.len(), streamed.len());
        // engine-side TTFT must be recorded for every emitting request
        let snap = Json::parse(&run.metrics.snapshot().dump()).unwrap();
        assert!(
            snap.get("ttft_p99_ms").and_then(Json::as_f64).unwrap() > 0.0,
            "w{workers}: ttft percentiles missing from metrics"
        );
    }
}

#[test]
fn malformed_request_gets_400_without_wedging_a_lane() {
    let hcfg = HttpServerCfg { max_requests: Some(1), ..HttpServerCfg::default() };
    let (_run, ()) = with_server(1, &hcfg, 92, |addr, pipe| {
        // not JSON at all
        let body = "this is not json";
        let resp = raw(
            addr,
            format!(
                "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // JSON but missing the prompt field
        let body = r#"{"max_new_tokens": 4}"#;
        let resp = raw(
            addr,
            format!(
                "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // a broken request line
        let resp = raw(addr, b"NONSENSE\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "got: {resp}");
        // unknown route
        let resp = raw(addr, b"GET /nope HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"), "got: {resp}");
        // after all of that, a valid request must still stream fine
        let req = GenRequest { prompt: "still alive".into(), max_new_tokens: 2 };
        match http_generate(addr, &req) {
            Outcome::Stream(sr) => {
                assert_eq!(
                    reconstruct_text(&req.prompt, &sr.tokens, pipe.cfg.seq),
                    sr.text
                );
            }
            other => panic!("expected a stream, got {other:?}"),
        }
    });
}

#[test]
fn client_disconnect_mid_stream_frees_lane_and_kv_pages() {
    // one cancel + one final request retire the server
    let hcfg = HttpServerCfg { max_requests: Some(2), ..HttpServerCfg::default() };
    let (run, ()) = with_server(1, &hcfg, 93, |addr, _pipe| {
        // start a long stream, read until the first token event, vanish
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let body = r#"{"prompt": "disconnect me", "max_new_tokens": 40}"#;
        conn.write_all(
            format!(
                "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut seen = Vec::new();
        let mut chunk = [0u8; 1024];
        while !String::from_utf8_lossy(&seen).contains("event: token") {
            let n = conn.read(&mut chunk).unwrap();
            assert!(n > 0, "stream ended before the first token");
            seen.extend_from_slice(&chunk[..n]);
        }
        drop(conn);
        // the owning worker must sweep the cancel: lane freed, KV pages
        // freed, the cancellation counted — all observable via /stats
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let j = stats(addr);
            if gauge(&j, "active") == 0
                && gauge(&j, "kv_live_bytes") == 0
                && gauge(&j, "cancelled") == 1
            {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "lane/pages never freed after disconnect: {}",
                j.dump()
            );
            thread::sleep(Duration::from_millis(25));
        }
        // the freed capacity must be reusable
        let req = GenRequest { prompt: "after the storm".into(), max_new_tokens: 2 };
        match http_generate(addr, &req) {
            Outcome::Stream(sr) => assert_eq!(sr.tokens.len(), 2),
            other => panic!("expected a stream, got {other:?}"),
        }
    });
    assert_eq!(run.metrics.cancelled, 1, "cancel missing from merged metrics");
    // only the surviving request has a response
    assert_eq!(run.responses.len(), 1);
}

#[test]
fn full_queue_sheds_load_with_429_and_retry_after() {
    // queue_cap 0: every generate is shed — deterministic backpressure
    let hcfg = HttpServerCfg {
        queue_cap: 0,
        retry_after_s: 3,
        max_requests: Some(2),
    };
    let (run, ()) = with_server(1, &hcfg, 94, |addr, _pipe| {
        for _ in 0..2 {
            let req = GenRequest { prompt: "shed me".into(), max_new_tokens: 2 };
            match http_generate(addr, &req) {
                Outcome::Overloaded { retry_after_s } => {
                    assert_eq!(retry_after_s, 3.0, "Retry-After hint wrong");
                }
                other => panic!("expected 429, got {other:?}"),
            }
        }
    });
    assert_eq!(run.responses.len(), 0);
}

#[test]
fn healthz_and_stats_respond() {
    let hcfg = HttpServerCfg { max_requests: Some(1), ..HttpServerCfg::default() };
    let (_run, ()) = with_server(1, &hcfg, 95, |addr, pipe| {
        let resp = raw(addr, b"GET /healthz HTTP/1.1\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200"), "got: {resp}");
        assert!(resp.contains("\"ok\":true"), "got: {resp}");
        let j = stats(addr);
        for key in
            ["active", "kv_live_bytes", "pending", "done", "cancelled", "rejected"]
        {
            assert!(j.get(key).is_some(), "missing /stats key {key}");
        }
        // retire the server
        let req = GenRequest { prompt: "bye".into(), max_new_tokens: 1 };
        match http_generate(addr, &req) {
            Outcome::Stream(sr) => {
                assert_eq!(
                    reconstruct_text(&req.prompt, &sr.tokens, pipe.cfg.seq),
                    sr.text
                );
            }
            other => panic!("expected a stream, got {other:?}"),
        }
    });
}
