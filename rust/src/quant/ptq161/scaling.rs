//! Initial PTQ1.61 decomposition: given a weight and its structured mask,
//! build the Eq. 9 operands with *analytic* starting values — salient
//! columns 4-bit-quantized per channel, non-salient binarized with
//! alpha_s = |w|_1 / n_w (Eq. 2), and the angular factors alpha_r1/alpha_r2
//! at 1 (identity). The block-wise optimizer then learns all three.

use super::super::Ptq161Parts;
use crate::quant::binarize::binarize_rowwise;
use crate::quant::rtn::quant4_columns_coded;
use crate::tensor::Tensor;

pub fn initial_parts(w: &Tensor, mask: &[bool]) -> Ptq161Parts {
    let (n, m) = (w.rows(), w.cols());
    assert_eq!(m, mask.len());
    // salient columns: per-column 4-bit, zeros elsewhere; the codes +
    // affine params ride along so the packed container is bit-exact
    let (dq4, sal_q) = quant4_columns_coded(w, mask);
    let mut w_sal = Tensor::zeros(&[n, m]);
    for i in 0..n {
        for j in 0..m {
            if mask[j] {
                *w_sal.at2_mut(i, j) = dq4.at2(i, j);
            }
        }
    }
    let (sign_ns, alpha_s) = binarize_rowwise(w, mask);
    Ptq161Parts {
        mask: mask.to_vec(),
        w_sal,
        sign_ns,
        alpha_s,
        alpha_r1: vec![1.0; n],
        alpha_r2: vec![1.0; m],
        mu: vec![0.0; n],
        sal_q: Some(sal_q),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::testutil::demo;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn dequant_reconstruction_error_drops_with_mask() {
        let (w, _) = demo(32, 48, 21);
        let no_mask = initial_parts(&w, &vec![false; 48]);
        let mut mask = vec![false; 48];
        for j in 0..10 {
            mask[j] = true;
        }
        let with_mask = initial_parts(&w, &mask);
        assert!(
            with_mask.dequantize().mse(&w) < no_mask.dequantize().mse(&w)
        );
    }

    #[test]
    fn composition_invariant_property() {
        // salient columns hold 4-bit values (error <= scale/2), non-salient
        // hold exactly +-alpha_s, and the two partitions never overlap.
        check(
            "ptq161-parts-composition",
            30,
            |r: &mut Rng| {
                let n = r.below(24) + 4;
                let m = r.below(32) + 8;
                let data: Vec<f32> =
                    (0..n * m).map(|_| r.normal() * 0.1).collect();
                (vec![n, m], data)
            },
            |(shape, data)| {
                let (n, m) = (shape[0], shape[1]);
                let w = Tensor::from_vec(&[n, m], data.clone());
                let mut mask = vec![false; m];
                for j in 0..m / 5 {
                    mask[j * 5] = true;
                }
                let parts = initial_parts(&w, &mask);
                let deq = parts.dequantize();
                for i in 0..n {
                    for j in 0..m {
                        let v = deq.at2(i, j);
                        if mask[j] {
                            if parts.sign_ns.at2(i, j) != 0.0 {
                                return Err("sign on salient col".into());
                            }
                        } else {
                            let want = parts.alpha_s[i]
                                * if w.at2(i, j) >= 0.0 { 1.0 } else { -1.0 };
                            if (v - want).abs() > 1e-5 {
                                return Err(format!(
                                    "ns ({i},{j}): {v} != {want}"
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn identity_angular_factors_at_init() {
        let (w, _) = demo(8, 16, 22);
        let p = initial_parts(&w, &vec![false; 16]);
        assert!(p.alpha_r1.iter().all(|&x| x == 1.0));
        assert!(p.alpha_r2.iter().all(|&x| x == 1.0));
        assert!(p.mu.iter().all(|&x| x == 0.0));
    }
}
