//! Quantizer micro-benchmarks (Table-8 resource shape at layer scale):
//! per-linear cost of every method on a realistic (ffn x d) weight.

use ptq161::quant::{by_name, LinearCalib, BASELINE_METHODS};
use ptq161::tensor::Tensor;
use ptq161::util::bench::Bencher;
use ptq161::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let (out, inn) = (352, 128); // tiny's w_gate shape
    let w = Tensor::randn(&[out, inn], 0.1, &mut rng);
    let x = Tensor::randn(&[512, inn], 1.0, &mut rng);
    let mut calib = LinearCalib::empty(inn);
    calib.accumulate(&x, true);
    let b = Bencher::quick();
    println!("# quantize one {out}x{inn} linear");
    for m in BASELINE_METHODS {
        let q = by_name(m).unwrap();
        b.run(&format!("quantize/{m}"), || {
            q.quantize_linear(&w, &calib)
        });
    }
}
