//! Evaluation: perplexity (the paper's core metric) and zero-shot task
//! scoring (lm-evaluation-harness-style length-normalized choice scoring).

pub mod ppl;
pub mod zeroshot;

use crate::coordinator::Pipeline;
use crate::model::{Params, LINEARS};
use crate::quant::Ptq161Parts;
use crate::tensor::Tensor;

use anyhow::Result;

/// How to run the model forward — dense fake-quant (paper's eval contract),
/// the fused Pallas-kernel path (proves the packed representation), or the
/// SmoothQuant W4A4 block (Table 13).
pub enum ModelEval<'a> {
    Dense(&'a Params),
    Fused { params: &'a Params, parts: &'a [Vec<Ptq161Parts>] },
    W4A4 { params: &'a Params, smooth: &'a [[Tensor; 4]] },
}

impl<'a> ModelEval<'a> {
    pub fn params(&self) -> &Params {
        match self {
            ModelEval::Dense(p) => p,
            ModelEval::Fused { params, .. } => params,
            ModelEval::W4A4 { params, .. } => params,
        }
    }

    /// Hidden states after all blocks for one (b_eval, t) token batch.
    pub fn forward_h(&self, pipe: &Pipeline, tokens: &[i32]) -> Result<Tensor> {
        let params = self.params();
        let mut h = pipe.embed(params, tokens)?;
        for l in 0..pipe.cfg.n_layers {
            h = match self {
                ModelEval::Dense(p) => pipe.block_fwd(&h, &p.block(l))?,
                ModelEval::Fused { params, parts } => {
                    let qp: Vec<[Tensor; 6]> = parts[l]
                        .iter()
                        .map(|p| {
                            let out = p.alpha_s.len();
                            let inn = p.alpha_r2.len();
                            [
                                p.w_sal.clone(),
                                p.sign_ns.clone(),
                                Tensor::from_vec(&[out], p.alpha_s.clone()),
                                Tensor::from_vec(&[out], p.alpha_r1.clone()),
                                Tensor::from_vec(&[inn], p.alpha_r2.clone()),
                                Tensor::from_vec(&[out], p.mu.clone()),
                            ]
                        })
                        .collect();
                    let attn_norm = params.get(&format!("l{l}.attn_norm"));
                    let mlp_norm = params.get(&format!("l{l}.mlp_norm"));
                    pipe.qblock_fwd(&h, attn_norm, mlp_norm, &qp)?
                }
                ModelEval::W4A4 { params, smooth } => {
                    pipe.qblock_w4a4(&h, &params.block(l), &smooth[l])?
                }
            };
        }
        Ok(h)
    }
}

/// Helper: PTQ1.61 parts for the fused path in LINEARS order sanity check.
pub fn parts_shape_ok(parts: &[Vec<Ptq161Parts>], n_layers: usize) -> bool {
    parts.len() == n_layers
        && parts.iter().all(|l| l.len() == LINEARS.len())
}
