//! Zero-shot task generators — synthetic analogs of the paper's reasoning
//! benchmarks, each keyed to one regularity the corpus actually teaches
//! (see data/mod.rs). Scoring (length-normalized choice log-prob, as in
//! lm-evaluation-harness) lives in eval/zeroshot.rs.

use super::{collocated_adj, preferred_verb, ADJS, NAMES, NOUNS, VALUES, VERBS};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// 4-choice: which verb follows a name (ARC-analog, regularity 1)
    VerbAgreement,
    /// 2-choice: correct collocated adjective vs wrong one (PIQA-analog)
    Collocation,
    /// cloze: paragraph-final topic noun (LAMBADA-analog, regularity 3)
    Cloze,
    /// key-value retrieval with distractor facts (LongBench-analog)
    Retrieval,
    /// held-out digit arithmetic (GSM8K-analog; expected near chance)
    Arithmetic,
}

impl TaskKind {
    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::VerbAgreement => "ARC-a (verb)",
            TaskKind::Collocation => "PIQA-a (adj)",
            TaskKind::Cloze => "LAMB-a (cloze)",
            TaskKind::Retrieval => "Long-a (kv)",
            TaskKind::Arithmetic => "GSM-a (arith)",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Task {
    pub prompt: Vec<i32>,
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
}

impl Task {
    pub fn n_choices(&self) -> usize {
        self.choices.len()
    }
}

/// Generate `n` task instances, deterministic in `seed`.
pub fn generate(kind: TaskKind, n: usize, seed: u64) -> Vec<Task> {
    let tk = ByteTokenizer;
    let mut rng = Rng::new(seed ^ 0x7A5C);
    (0..n).map(|_| one(kind, &mut rng, &tk)).collect()
}

fn pick_distinct(rng: &mut Rng, n: usize, k: usize, correct: usize) -> Vec<usize> {
    // k distractors != correct
    let mut out = Vec::new();
    while out.len() < k {
        let c = rng.below(n);
        if c != correct && !out.contains(&c) {
            out.push(c);
        }
    }
    out
}

fn shuffled_choices(
    rng: &mut Rng,
    correct: String,
    distractors: Vec<String>,
) -> (Vec<String>, usize) {
    let mut all = vec![correct];
    all.extend(distractors);
    let mut order: Vec<usize> = (0..all.len()).collect();
    rng.shuffle(&mut order);
    let answer = order.iter().position(|&i| i == 0).unwrap();
    let choices = order.into_iter().map(|i| all[i].clone()).collect();
    (choices, answer)
}

fn one(kind: TaskKind, rng: &mut Rng, tk: &ByteTokenizer) -> Task {
    match kind {
        TaskKind::VerbAgreement => {
            let name_i = rng.below(NAMES.len());
            let noun_i = rng.below(NOUNS.len());
            let prompt = format!(
                "the {} {} of {} ",
                collocated_adj(noun_i), NOUNS[noun_i], NAMES[name_i]
            );
            let correct = preferred_verb(name_i).to_string();
            let dis = pick_distinct(rng, VERBS.len(), 3, name_i % VERBS.len())
                .into_iter()
                .map(|v| VERBS[v].to_string())
                .collect();
            let (choices, answer) = shuffled_choices(rng, correct, dis);
            Task {
                prompt: tk.encode(&prompt),
                choices: choices.iter().map(|c| tk.encode(c)).collect(),
                answer,
            }
        }
        TaskKind::Collocation => {
            let noun_i = rng.below(NOUNS.len());
            let prompt = "the ".to_string();
            let correct = format!("{} {}", collocated_adj(noun_i), NOUNS[noun_i]);
            let wrong_adj = pick_distinct(rng, ADJS.len(), 1, noun_i % ADJS.len());
            let wrong = format!("{} {}", ADJS[wrong_adj[0]], NOUNS[noun_i]);
            let (choices, answer) = shuffled_choices(rng, correct, vec![wrong]);
            Task {
                prompt: tk.encode(&prompt),
                choices: choices.iter().map(|c| tk.encode(c)).collect(),
                answer,
            }
        }
        TaskKind::Cloze => {
            let topic = rng.below(NOUNS.len());
            let name_i = rng.below(NAMES.len());
            let mut p = format!(
                "the {} {} of {} {} the {} {} . ",
                collocated_adj(topic), NOUNS[topic], NAMES[name_i],
                preferred_verb(name_i), collocated_adj(topic), NOUNS[topic],
            );
            p.push_str("in the end it was the ");
            let correct = NOUNS[topic].to_string();
            let dis = pick_distinct(rng, NOUNS.len(), 3, topic)
                .into_iter()
                .map(|i| NOUNS[i].to_string())
                .collect();
            let (choices, answer) = shuffled_choices(rng, correct, dis);
            Task {
                prompt: tk.encode(&p),
                choices: choices.iter().map(|c| tk.encode(c)).collect(),
                answer,
            }
        }
        TaskKind::Retrieval => {
            let key_i = rng.below(NAMES.len());
            let val_i = rng.below(VALUES.len());
            let mut p = format!("key {} is {} . ", NAMES[key_i], VALUES[val_i]);
            // distractor facts about *other* keys
            for _ in 0..3 {
                let k = pick_distinct(rng, NAMES.len(), 1, key_i)[0];
                let v = rng.below(VALUES.len());
                p.push_str(&format!("key {} is {} . ", NAMES[k], VALUES[v]));
            }
            p.push_str(&format!("key {} is ", NAMES[key_i]));
            let correct = VALUES[val_i].to_string();
            let dis = pick_distinct(rng, VALUES.len(), 3, val_i)
                .into_iter()
                .map(|i| VALUES[i].to_string())
                .collect();
            let (choices, answer) = shuffled_choices(rng, correct, dis);
            Task {
                prompt: tk.encode(&p),
                choices: choices.iter().map(|c| tk.encode(c)).collect(),
                answer,
            }
        }
        TaskKind::Arithmetic => {
            let a = rng.below(9) + 1;
            let b = rng.below(9) + 1;
            let p = format!("{} plus {} equals ", a, b);
            let correct = format!("{}", a + b);
            let mut dis = Vec::new();
            while dis.len() < 3 {
                let w = rng.below(17) + 2;
                if w != a + b && !dis.contains(&format!("{w}")) {
                    dis.push(format!("{w}"));
                }
            }
            let (choices, answer) = shuffled_choices(rng, correct, dis);
            Task {
                prompt: tk.encode(&p),
                choices: choices.iter().map(|c| tk.encode(c)).collect(),
                answer,
            }
        }
    }
}

pub const ALL_KINDS: [TaskKind; 5] = [
    TaskKind::Collocation,
    TaskKind::VerbAgreement,
    TaskKind::Cloze,
    TaskKind::Retrieval,
    TaskKind::Arithmetic,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_well_formed() {
        for kind in ALL_KINDS {
            let a = generate(kind, 20, 3);
            let b = generate(kind, 20, 3);
            assert_eq!(a.len(), 20);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.answer, y.answer);
                assert!(x.answer < x.choices.len());
                assert!(!x.prompt.is_empty());
                assert!(x.choices.iter().all(|c| !c.is_empty()));
            }
        }
    }

    #[test]
    fn choice_counts() {
        assert_eq!(generate(TaskKind::Collocation, 5, 1)[0].n_choices(), 2);
        assert_eq!(generate(TaskKind::VerbAgreement, 5, 1)[0].n_choices(), 4);
        assert_eq!(generate(TaskKind::Cloze, 5, 1)[0].n_choices(), 4);
    }

    #[test]
    fn answers_not_always_first() {
        let tasks = generate(TaskKind::Cloze, 50, 5);
        assert!(tasks.iter().any(|t| t.answer != 0));
    }

    #[test]
    fn retrieval_prompt_contains_distractors() {
        let t = &generate(TaskKind::Retrieval, 1, 2)[0];
        let text = ByteTokenizer.decode(&t.prompt);
        assert!(text.matches("key ").count() >= 4);
    }
}
