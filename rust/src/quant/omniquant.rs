//! OmniQuant-lite: learnable weight clipping (LWC) distilled to its
//! substance at this scale — per-row clip factors chosen by search to
//! minimize the activation-weighted output error of b-bit RTN. This is the
//! strongest "classical 2-bit" baseline family in the paper's tables.

use super::{LinearCalib, QuantizedLinear, Quantizer};
use crate::packing::bitwidth::BitScheme;
use crate::quant::rtn::rtn_row;
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct OmniQuantLite {
    pub bits: u32,
    pub grid: usize,
}

impl OmniQuantLite {
    pub fn new(bits: u32) -> OmniQuantLite {
        OmniQuantLite { bits, grid: 12 }
    }
}

impl Quantizer for OmniQuantLite {
    fn name(&self) -> &'static str {
        "OmniQuant"
    }

    fn bits_label(&self) -> String {
        format!("{}", self.bits)
    }

    fn quantize_linear(&self, w: &Tensor, calib: &LinearCalib) -> QuantizedLinear {
        let (n, m) = (w.rows(), w.cols());
        let mut deq = Tensor::zeros(&[n, m]);
        // per-row learnable clip: search gamma in (0.4 ..= 1.0]
        for r in 0..n {
            let row = w.row(r);
            let mut best_err = f32::INFINITY;
            let mut best: Vec<f32> = row.to_vec();
            for g in 0..=self.grid {
                let gamma = 1.0 - 0.6 * (g as f32 / self.grid as f32);
                let mut cand = row.to_vec();
                rtn_row(&mut cand, self.bits, gamma);
                let err: f32 = cand
                    .iter()
                    .zip(row)
                    .enumerate()
                    .map(|(j, (&q, &x))| {
                        let d = q - x;
                        calib.act_sq_mean[j] * d * d
                    })
                    .sum();
                if err < best_err {
                    best_err = err;
                    best = cand;
                }
            }
            deq.row_mut(r).copy_from_slice(&best);
        }
        QuantizedLinear {
            deq,
            scheme: BitScheme::Uniform { bits: self.bits as f64 },
            parts: None,
            container: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::quant::testutil::demo;
    use crate::quant::Quantizer;

    fn weighted_err(w: &Tensor, deq: &Tensor, sq: &[f32]) -> f32 {
        let mut e = 0.0;
        for i in 0..w.rows() {
            for (j, (&x, &y)) in w.row(i).iter().zip(deq.row(i)).enumerate() {
                e += sq[j] * (x - y) * (x - y);
            }
        }
        e
    }

    #[test]
    fn clipping_never_worse_than_rtn() {
        let (w, calib) = demo(32, 48, 14);
        let o = OmniQuantLite::new(2).quantize_linear(&w, &calib);
        let r = Rtn::new(2).quantize_linear(&w, &calib);
        let eo = weighted_err(&w, &o.deq, &calib.act_sq_mean);
        let er = weighted_err(&w, &r.deq, &calib.act_sq_mean);
        assert!(eo <= er + 1e-6, "omni {eo} vs rtn {er}");
    }

    #[test]
    fn helps_on_outlier_heavy_rows() {
        // one huge negative outlier whose input channel is nearly dead:
        // the activation-weighted objective wants the outlier clipped away
        // so the live small-weight channels quantize finely
        let mut w = Tensor::full(&[1, 16], 0.1);
        w.data[0] = -10.0;
        for j in 1..16 {
            w.data[j] = if j % 2 == 0 { 0.1 } else { -0.1 };
        }
        let mut sq = vec![10.0; 16];
        sq[0] = 0.001; // outlier channel barely fires
        let calib = super::super::LinearCalib {
            act_abs_mean: sq.iter().map(|x: &f32| x.sqrt()).collect(),
            act_sq_mean: sq.clone(),
            hessian: None,
            n_rows: 1,
        };
        let o = OmniQuantLite::new(2).quantize_linear(&w, &calib);
        let r = Rtn::new(2).quantize_linear(&w, &calib);
        let eo = weighted_err(&w, &o.deq, &sq);
        let er = weighted_err(&w, &r.deq, &sq);
        assert!(eo < er, "omni weighted err {eo} vs rtn {er}");
    }
}
