//! L3 coordinator: owns the full lifecycle — pretraining, calibration
//! capture, quantization dispatch, block-wise scaling-factor optimization,
//! and restorative-LoRA preprocessing — by sequencing AOT executables
//! through the PJRT runtime. Python never runs here.

pub mod blockopt;
pub mod capture;
pub mod preprocess;
pub mod pretrain;
pub mod quantize;

use anyhow::{anyhow, Result};

use crate::model::{Params, LINEARS};
use crate::quant::ArcContainer;
use crate::runtime::manifest::ModelConfig;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;

/// A runtime bound to one model config: the layer-pipeline primitive every
/// higher stage (eval, capture, blockopt, serve) is built from.
pub struct Pipeline<'a> {
    pub rt: &'a Runtime,
    pub cfg: ModelConfig,
}

impl<'a> Pipeline<'a> {
    pub fn new(rt: &'a Runtime, cname: &str) -> Result<Pipeline<'a>> {
        let cfg = rt
            .manifest
            .configs
            .get(cname)
            .ok_or_else(|| anyhow!("unknown config {cname}"))?
            .clone();
        Ok(Pipeline { rt, cfg })
    }

    pub fn cname(&self) -> &str {
        &self.cfg.name
    }

    pub fn param_spec(&self) -> &[(String, Vec<usize>)] {
        &self.rt.manifest.param_spec[&self.cfg.name]
    }

    pub fn init_params(&self, seed: u64) -> Params {
        Params::init(self.param_spec(), seed)
    }

    /// tokens (b, t) -> hidden states. The batch dimension is derived from
    /// the token count: the serve engine runs compacted batches of active
    /// lanes (b <= b_eval), the eval pipeline always passes b_eval rows.
    pub fn embed(&self, params: &Params, tokens: &[i32]) -> Result<Tensor> {
        let t = self.cfg.seq;
        assert!(
            !tokens.is_empty() && tokens.len() % t == 0,
            "tokens must be a whole number of {t}-wide rows"
        );
        let b = tokens.len() / t;
        let out = self.rt.run_cfg(
            "embed_fwd",
            &self.cfg.name,
            &[
                Value::tokens(&[b, t], tokens.to_vec()),
                params.get("embed").into(),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// One FP (or dense-dequantized) block forward.
    pub fn block_fwd(&self, h: &Tensor, block: &[&Tensor]) -> Result<Tensor> {
        let mut inputs: Vec<Value> = vec![h.into()];
        inputs.extend(block.iter().map(|&t| Value::from(t)));
        let out = self.rt.run_cfg("block_fwd", &self.cfg.name, &inputs)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Block forward that also returns the four linear-input captures:
    /// (x_attn, x_o, x_mlp, x_down, h_out).
    pub fn block_capture(
        &self,
        h: &Tensor,
        block: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let mut inputs: Vec<Value> = vec![h.into()];
        inputs.extend(block.iter().map(|&t| Value::from(t)));
        self.rt.run_cfg("block_capture", &self.cfg.name, &inputs)
    }

    /// Quantized block via the fused Pallas kernel artifact. `qparts` is
    /// ordered per LINEARS: (w_sal, sign_ns, alpha_s, alpha_r1, alpha_r2, mu).
    pub fn qblock_fwd(
        &self,
        h: &Tensor,
        attn_norm: &Tensor,
        mlp_norm: &Tensor,
        qparts: &[[Tensor; 6]],
    ) -> Result<Tensor> {
        assert_eq!(qparts.len(), LINEARS.len());
        let mut inputs: Vec<Value> =
            vec![h.into(), attn_norm.into(), mlp_norm.into()];
        for parts in qparts {
            for p in parts {
                inputs.push(p.into());
            }
        }
        let out = self.rt.run_cfg("qblock_fwd", &self.cfg.name, &inputs)?;
        Ok(out.into_iter().next().unwrap())
    }

    /// SmoothQuant W4A4 block (Table 13). smooth = (s_attn, s_o, s_mlp,
    /// s_down).
    pub fn qblock_w4a4(
        &self,
        h: &Tensor,
        block: &[&Tensor],
        smooth: &[Tensor; 4],
    ) -> Result<Tensor> {
        let mut inputs: Vec<Value> = vec![h.into()];
        inputs.extend(block.iter().map(|&t| Value::from(t)));
        inputs.extend(smooth.iter().map(Value::from));
        let out = self.rt.run_cfg("qblock_w4a4_fwd", &self.cfg.name, &inputs)?;
        Ok(out.into_iter().next().unwrap())
    }

    // ---- KV-cached incremental decode ------------------------------
    // The `*_decode` bases run the model over *new* token positions only,
    // against per-lane cached K/V (runtime::kv::KvCache). `lens[i]` is
    // lane i's valid cached length == the absolute position of its first
    // new token. See ARCHITECTURE.md for the full contract.

    /// Embed a compacted chunk of new positions: `tokens` is `b * t_new`
    /// ids with `t_new <= seq` (prefill passes the prompt, a decode step
    /// passes one token per lane).
    pub fn embed_decode(
        &self,
        params: &Params,
        tokens: &[i32],
        b: usize,
        t_new: usize,
    ) -> Result<Tensor> {
        assert_eq!(tokens.len(), b * t_new, "embed_decode token count");
        let out = self.rt.run_cfg(
            "embed_fwd_decode",
            &self.cfg.name,
            &[
                Value::tokens(&[b, t_new], tokens.to_vec()),
                params.get("embed").into(),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// The `h_new + caches + lens` input prefix every block decode shares.
    fn decode_prefix(
        h_new: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        lens: &[usize],
    ) -> Vec<Value> {
        vec![
            h_new.into(),
            k_cache.into(),
            v_cache.into(),
            Value::tokens(&[lens.len()], lens.iter().map(|&l| l as i32).collect()),
        ]
    }

    fn unpack_decode(out: Vec<Tensor>) -> (Tensor, Tensor, Tensor) {
        let mut it = out.into_iter();
        let h = it.next().unwrap();
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        (h, k, v)
    }

    /// One FP (or dense-dequantized) block over new positions against
    /// cached K/V: returns `(h_out, k_new, v_new)` — the new K rows come
    /// back roped, ready to append to the cache.
    pub fn block_fwd_decode(
        &self,
        h_new: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        lens: &[usize],
        block: &[&Tensor],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let mut inputs = Self::decode_prefix(h_new, k_cache, v_cache, lens);
        inputs.extend(block.iter().map(|&t| Value::from(t)));
        let out = self.rt.run_cfg("block_fwd_decode", &self.cfg.name, &inputs)?;
        Ok(Self::unpack_decode(out))
    }

    /// PTQ1.61 fused quantized block over new positions (decode variant
    /// of [`Self::qblock_fwd`]): `qparts` per LINEARS as (w_sal, sign_ns,
    /// alpha_s, alpha_r1, alpha_r2, mu).
    pub fn qblock_fwd_decode(
        &self,
        h_new: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        lens: &[usize],
        attn_norm: &Tensor,
        mlp_norm: &Tensor,
        qparts: &[[Tensor; 6]],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        assert_eq!(qparts.len(), LINEARS.len());
        let mut inputs = Self::decode_prefix(h_new, k_cache, v_cache, lens);
        inputs.push(attn_norm.into());
        inputs.push(mlp_norm.into());
        for parts in qparts {
            for p in parts {
                inputs.push(p.into());
            }
        }
        let out = self.rt.run_cfg("qblock_fwd_decode", &self.cfg.name, &inputs)?;
        Ok(Self::unpack_decode(out))
    }

    /// Quantized block over new positions served straight from the
    /// prepared packed containers (decode variant of the packed backend,
    /// any method with a [`crate::quant::PackedContainer`] impl): `layer`
    /// holds one container per block linear in LINEARS order.
    ///
    /// Packed containers are host structures, not artifact `Value`s, so
    /// this calls the native backend directly instead of going through
    /// `Runtime::run` — the execution is still counted in the runtime's
    /// per-artifact tally under `qblock_packed_decode_{config}`.
    pub fn qblock_packed_decode(
        &self,
        h_new: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        lens: &[usize],
        attn_norm: &Tensor,
        mlp_norm: &Tensor,
        layer: &[ArcContainer],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        assert_eq!(layer.len(), LINEARS.len());
        *self
            .rt
            .exec_counts
            .lock()
            .unwrap()
            .entry(format!("qblock_packed_decode_{}", self.cfg.name))
            .or_insert(0) += 1;
        let out = crate::runtime::native::packed_block_decode(
            &self.cfg,
            h_new,
            k_cache,
            v_cache,
            lens,
            attn_norm,
            mlp_norm,
            layer,
        )?;
        Ok(Self::unpack_decode(out))
    }

    /// SmoothQuant W4A4 block over new positions (decode variant of
    /// [`Self::qblock_w4a4`]). Note: its activation scale is computed over
    /// the current chunk, so it is numerically close but not bit-equal to
    /// the full-window fake-quant.
    pub fn qblock_w4a4_decode(
        &self,
        h_new: &Tensor,
        k_cache: &Tensor,
        v_cache: &Tensor,
        lens: &[usize],
        block: &[&Tensor],
        smooth: &[Tensor; 4],
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let mut inputs = Self::decode_prefix(h_new, k_cache, v_cache, lens);
        inputs.extend(block.iter().map(|&t| Value::from(t)));
        inputs.extend(smooth.iter().map(Value::from));
        let out =
            self.rt.run_cfg("qblock_w4a4_fwd_decode", &self.cfg.name, &inputs)?;
        Ok(Self::unpack_decode(out))
    }

    /// Final norm + output projection for new positions only: logits
    /// `(b, t_new, vocab)`, no NLL (decode never needs the loss).
    pub fn head_decode(&self, params: &Params, h_new: &Tensor) -> Result<Tensor> {
        let out = self.rt.run_cfg(
            "head_fwd_decode",
            &self.cfg.name,
            &[
                h_new.into(),
                params.get("norm_f").into(),
                params.get("w_out").into(),
            ],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Final norm + head: returns (nll_sum, logits). Batch dimension is
    /// derived from the token count, matching `embed`.
    pub fn head(
        &self,
        params: &Params,
        h: &Tensor,
        tokens: &[i32],
    ) -> Result<(f32, Tensor)> {
        let t = self.cfg.seq;
        assert!(
            !tokens.is_empty() && tokens.len() % t == 0,
            "tokens must be a whole number of {t}-wide rows"
        );
        let b = tokens.len() / t;
        let out = self.rt.run_cfg(
            "head_fwd",
            &self.cfg.name,
            &[
                h.into(),
                params.get("norm_f").into(),
                params.get("w_out").into(),
                Value::tokens(&[b, t], tokens.to_vec()),
            ],
        )?;
        let mut it = out.into_iter();
        let nll = it.next().unwrap().data[0];
        let logits = it.next().unwrap();
        Ok((nll, logits))
    }

    /// Full forward over dense params (FP or fake-quantized): sum NLL.
    pub fn nll_sum(&self, params: &Params, tokens: &[i32]) -> Result<f32> {
        let mut h = self.embed(params, tokens)?;
        for l in 0..self.cfg.n_layers {
            h = self.block_fwd(&h, &params.block(l))?;
        }
        Ok(self.head(params, &h, tokens)?.0)
    }

    /// Tokens predicted per eval batch (for PPL normalization).
    pub fn tokens_per_batch(&self) -> usize {
        self.cfg.b_eval * (self.cfg.seq - 1)
    }
}

#[cfg(test)]
mod tests {
    // Pipeline methods are integration-tested in rust/tests/ (they need
    // built artifacts); here we only check pure helper wiring.
    use crate::model::LINEARS;

    #[test]
    fn linears_order_is_the_manifest_order() {
        assert_eq!(
            LINEARS,
            ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"]
        );
    }
}
