//! Runtime benches: PJRT executable dispatch for each pipeline stage —
//! the numbers behind the end-to-end latency model (EXPERIMENTS.md §Perf).
//! Skips cleanly when artifacts are not built.

use ptq161::coordinator::Pipeline;
use ptq161::runtime::Runtime;
use ptq161::util::bench::Bencher;
use ptq161::util::rng::Rng;

fn main() {
    let dir = ptq161::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: artifacts not built, skipping");
        return;
    }
    let rt = Runtime::open(&dir).unwrap();
    let pipe = Pipeline::new(&rt, "tiny").unwrap();
    let params = pipe.init_params(1);
    let mut rng = Rng::new(2);
    let tokens: Vec<i32> = (0..pipe.cfg.b_eval * pipe.cfg.seq)
        .map(|_| rng.below(256) as i32)
        .collect();
    let b = Bencher::quick();
    let h = pipe.embed(&params, &tokens).unwrap();
    b.run("runtime/embed_fwd", || pipe.embed(&params, &tokens).unwrap());
    b.run("runtime/block_fwd", || {
        pipe.block_fwd(&h, &params.block(0)).unwrap()
    });
    b.run("runtime/block_capture", || {
        pipe.block_capture(&h, &params.block(0)).unwrap()
    });
    b.run("runtime/head_fwd", || {
        pipe.head(&params, &h, &tokens).unwrap()
    });
    b.run("runtime/full_eval_fwd", || {
        pipe.nll_sum(&params, &tokens).unwrap()
    });
    let train_tokens: Vec<i32> = (0..pipe.cfg.b_train * pipe.cfg.seq)
        .map(|_| rng.below(256) as i32)
        .collect();
    b.run("runtime/lm_grad_step", || {
        ptq161::coordinator::pretrain::lm_grad(&pipe, &params, &train_tokens)
            .unwrap()
    });
}
